"""End-to-end driver: train a ~160M-parameter LM with FlashCP packing.

The model (12L, d=768, 12H, vocab 50304 — GPT-2-small scale; 162M params
with untied head)
trains on packed multi-document sequences with document-masked attention,
through the identical framework path used by the production configs
(planner -> plan encoding -> CP-capable attention -> AdamW -> checkpoints).

A few hundred steps on CPU:

    PYTHONPATH=src python examples/train_lm.py --steps 300

(~2-4 s/step at seq 256 x batch 1 on one CPU core; checkpoints land in
/tmp/repro_lm100m.  Use --steps 20 for a quick look.)
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import types

import repro.configs as configs
from repro.configs.base import ModelConfig

LM_100M = ModelConfig(
    name="lm_124m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=50304,
    head_dim=64,
    mlp="gelu",
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--dataset", default="pile")
    args = ap.parse_args()

    # register the example config and drive the standard trainer
    configs.ARCHS[LM_100M.name] = LM_100M
    print(f"training {LM_100M.name}: "
          f"{LM_100M.param_count()/1e6:.0f}M params, "
          f"seq {args.seq_len}, batch {args.batch}, {args.steps} steps")

    from repro.launch.train import train
    out = train(types.SimpleNamespace(
        arch=LM_100M.name, smoke=False, mesh="1x1", strategy="flashcp",
        attention_impl="xla", dataset=args.dataset, seq_len=args.seq_len,
        batch=args.batch, steps=args.steps, lr=args.lr, q_chunk=128,
        grad_compression="none", checkpoint_dir="/tmp/repro_lm100m",
        ckpt_every=100, log_every=10, resume=True, prefetch=True,
        no_remat=False, fail_at=-1))
    print(f"done: loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
