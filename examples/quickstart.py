"""Quickstart: FlashCP in five minutes, on CPU.

1. Pack documents into a context window and run Algorithm 1 — inspect the
   sharding plan against the baselines (balance + communication).
2. Train a tiny decoder for a few steps through the full framework path
   (planner -> plan encoding -> doc-masked attention -> AdamW).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.planner.baselines import BASELINE_PLANNERS
from repro.planner.heuristic import flashcp_plan
from repro.core.workload import comm_saving
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence


def show_plans():
    print("=" * 70)
    print("FlashCP sharding plans: 128K context, 8 CP workers, WLB-LLM mix")
    print("=" * 70)
    rng = make_rng(0)
    lens = pack_sequence("wlb_llm", 131072, rng)
    print(f"packed {len(lens)} documents "
          f"(min {lens.min()}, median {int(np.median(lens))}, "
          f"max {lens.max()} tokens)\n")

    plan, stats = flashcp_plan(lens, 8)
    print("FlashCP (Algorithm 1):")
    print(plan.describe())
    print(f"  comm saving     : {comm_saving(plan):.1%} of the full "
          f"exchange (Eq.4 -> Eq.5)")
    print(f"  whole docs      : {stats.whole_docs}/{len(lens)} "
          f"(zero communication for these)\n")

    for name in ("llama3", "per_doc"):
        p = BASELINE_PLANNERS[name](lens, 8)
        print(f"{name} baseline: imbalance {p.imbalance_ratio():.3f}, "
              f"{len(p.shards)} shards, comm {p.comm_tokens()} tokens/rank")
    print()


def tiny_training():
    print("=" * 70)
    print("Tiny end-to-end training (reduced starcoder2_3b, CPU)")
    print("=" * 70)
    import types
    from repro.launch.train import train

    out = train(types.SimpleNamespace(
        arch="starcoder2_3b", smoke=True, mesh="1x1", strategy="flashcp",
        attention_impl="xla", dataset="wlb_llm", seq_len=256, batch=2,
        steps=10, lr=1e-3, q_chunk=128, grad_compression="none",
        checkpoint_dir="/tmp/repro_quickstart_ckpt", ckpt_every=0,
        log_every=2, resume=False, prefetch=False, no_remat=False,
        fail_at=-1))
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"over {len(out['losses'])} steps")


if __name__ == "__main__":
    show_plans()
    tiny_training()
