"""Plan explorer: visualize how each CP strategy shards a packed sequence.

ASCII rendering of worker assignments plus the balance/communication
numbers the paper's figures are built from.

    PYTHONPATH=src python examples/plan_explorer.py --dataset pile --cp 8
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.baselines import BASELINE_PLANNERS
from repro.core.workload import comm_saving, comm_tokens_static
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence

GLYPHS = "0123456789abcdef"


def render(plan, width=100):
    """One row per packed position range; glyph = worker id."""
    C = plan.context_len
    doc_starts = np.concatenate([[0], np.cumsum(plan.doc_lens)])[:-1]
    owner = np.zeros(C, np.int32)
    for s in plan.shards:
        g = doc_starts[s.doc_id] + s.start
        owner[g:g + s.length] = s.worker
    cells = np.array_split(owner, width)
    line = "".join(GLYPHS[int(np.bincount(c).argmax())] for c in cells)
    # document boundary markers
    marks = [" "] * width
    for d in doc_starts[1:]:
        marks[min(int(d * width / C), width - 1)] = "|"
    return "".join(marks) + "\n" + line


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="pile",
                    choices=["wlb_llm", "pile", "redpajama"])
    ap.add_argument("--context", type=int, default=32768)
    ap.add_argument("--cp", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = make_rng(args.seed)
    lens = pack_sequence(args.dataset, args.context, rng)
    print(f"{args.dataset}: {len(lens)} documents in {args.context} tokens "
          f"(| marks document boundaries; digits are CP worker ids)\n")

    for name in ("llama3", "per_doc", "flashcp"):
        plan = BASELINE_PLANNERS[name](lens, args.cp)
        print(f"--- {name}")
        print(render(plan))
        static = comm_tokens_static(args.context, args.cp)
        print(f"    imbalance {plan.imbalance_ratio():.3f} | "
              f"shards {len(plan.shards)} | "
              f"comm {plan.comm_tokens()}/{static} tokens/rank "
              f"({comm_saving(plan):.0%} saved)\n")


if __name__ == "__main__":
    main()
