"""Plan explorer: visualize how each CP strategy shards a packed sequence.

ASCII rendering of worker assignments plus the balance/communication
numbers the paper's figures are built from.  Strategies resolve through
the :mod:`repro.planner` registry — pass ``--strategy all`` to sweep every
registered planner, or a comma-separated subset.

    PYTHONPATH=src python examples/plan_explorer.py --dataset pile --cp 8
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.planner import available_planners, get_planner
from repro.core.workload import comm_saving, comm_tokens_static
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence

GLYPHS = "0123456789abcdef"


def render(plan, width=100):
    """One row per packed position range; glyph = worker id."""
    C = plan.context_len
    doc_starts = np.concatenate([[0], np.cumsum(plan.doc_lens)])[:-1]
    a = plan.arrays
    owner = np.zeros(C, np.int32)
    g = doc_starts[a.doc_id] + a.start
    for lo, ln, w in zip(g, a.length, a.worker):
        owner[lo:lo + ln] = w
    cells = np.array_split(owner, width)
    line = "".join(GLYPHS[int(np.bincount(c).argmax())] for c in cells)
    # document boundary markers
    marks = [" "] * width
    for d in doc_starts[1:]:
        marks[min(int(d * width / C), width - 1)] = "|"
    return "".join(marks) + "\n" + line


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="pile",
                    choices=["wlb_llm", "pile", "redpajama"])
    ap.add_argument("--context", type=int, default=32768)
    ap.add_argument("--cp", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strategy", default="llama3,per_doc,flashcp",
                    help="comma-separated planner names, or 'all'")
    args = ap.parse_args()

    rng = make_rng(args.seed)
    lens = pack_sequence(args.dataset, args.context, rng)
    print(f"{args.dataset}: {len(lens)} documents in {args.context} tokens "
          f"(| marks document boundaries; digits are CP worker ids)\n")

    if args.strategy == "all":
        # skip the exponential reference solver on realistic mixes
        names = [n for n in available_planners()
                 if get_planner(n).info.cost_hint != "exponential"]
    else:
        names = args.strategy.split(",")

    for name in names:
        planner = get_planner(name)
        info = planner.info
        plan = planner(lens, args.cp)
        print(f"--- {name}  [comm={info.comm_style}, exec={info.exec_style}"
              f"{', order-preserving' if info.preserves_token_order else ''}]")
        print(render(plan))
        static = comm_tokens_static(args.context, args.cp)
        print(f"    imbalance {plan.imbalance_ratio():.3f} | "
              f"shards {len(plan.arrays)} | "
              f"comm {plan.comm_tokens()}/{static} tokens/rank "
              f"({comm_saving(plan):.0%} saved)\n")


if __name__ == "__main__":
    main()
