"""Serving example: batched prefill + token-by-token decode with KV cache.

    PYTHONPATH=src python examples/serve_decode.py --requests 4 --gen 16
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import types

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve(types.SimpleNamespace(
        arch=args.arch, smoke=True, mesh="1x1", requests=args.requests,
        prompt_len=args.prompt_len, gen=args.gen))
    print("generated token matrix shape:", out["tokens"].shape)


if __name__ == "__main__":
    main()
