"""Serving example: continuous-batching prefill + flash-decode engine.

    PYTHONPATH=src python examples/serve_decode.py --requests 4 --gen 16
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import types

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--decode-impl", default="flash",
                    choices=("flash", "dense"))
    args = ap.parse_args()
    out = serve(types.SimpleNamespace(
        arch=args.arch, smoke=True, requests=args.requests,
        prompt_len=args.prompt_len, gen=args.gen,
        decode_impl=args.decode_impl))
    done = sorted(out["results"])
    print(f"completed requests: {done}; "
          f"tokens per request: {[len(out['tokens'][r]) for r in done]}")


if __name__ == "__main__":
    main()
