"""Planner tests: Algorithm 1 invariants, baselines, Eq. 4/5 accounting.

Property-based (hypothesis) over random document mixes: every plan tiles
the documents exactly, satisfies the equal-token constraint, and FlashCP's
communication never exceeds the static full exchange.
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from repro.planner.baselines import (contiguous_plan, llama3_plan, per_doc_plan,
                                  ring_zigzag_plan)
from repro.planner.heuristic import flashcp_plan, zigzag_doc_shards
from repro.planner.ilp import bnb_plan
from repro.planner.plan import ShardingPlan, validate_plan
from repro.core.workload import (comm_saving, comm_tokens_static,
                                 plan_comm_bytes, shard_workload)
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence


def _doc_mix(rng, context, n_docs):
    cuts = np.sort(rng.choice(np.arange(1, context), n_docs - 1,
                              replace=False))
    lens = np.diff(np.concatenate([[0], cuts, [context]]))
    return lens[lens > 0]


# --------------------------------------------------------------------- #
# hypothesis properties
# --------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n_docs=st.integers(1, 40),
       cp=st.sampled_from([2, 4, 8, 16]))
def test_flashcp_plan_invariants(seed, n_docs, cp):
    rng = np.random.default_rng(seed)
    context = 16 * cp * rng.integers(2, 16)
    lens = _doc_mix(rng, context, min(n_docs, context // 2))
    plan, stats = flashcp_plan(lens, cp)
    # tiles docs exactly; tokens equal within the zigzag-remainder slack
    validate_plan(plan, token_tolerance=cp)
    t = plan.tokens_per_worker()
    assert t.max() - t.min() <= cp
    assert plan.imbalance_ratio() >= 1.0
    # Eq.5 never exceeds Eq.4's static exchange
    assert plan.comm_tokens() <= comm_tokens_static(context, cp)
    assert stats.comm_tokens == plan.comm_tokens()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), cp=st.sampled_from([2, 4, 8]))
def test_baseline_plan_invariants(seed, cp):
    rng = np.random.default_rng(seed)
    context = 16 * cp * int(rng.integers(2, 12))
    lens = _doc_mix(rng, context, int(rng.integers(1, 20)))
    l3 = llama3_plan(lens, cp)
    validate_plan(l3)
    ct = contiguous_plan(lens, cp)
    validate_plan(ct)
    pd = per_doc_plan(lens, cp)
    validate_plan(pd, require_equal_tokens=False)
    # per-doc zigzag balances tokens within +-1 per document
    t = pd.tokens_per_worker()
    assert t.max() - t.min() <= len(lens)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_zigzag_balances_single_doc(seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(64, 4096))
    N = 4
    shards = zigzag_doc_shards(0, d, N)
    plan = ShardingPlan(doc_lens=np.asarray([d]), shards=shards,
                        num_workers=N)
    w = plan.workload_per_worker()
    # zigzag pairing: near-perfect attention balance for one document
    assert w.max() / max(w.mean(), 1) < 1.35
    t = plan.tokens_per_worker()
    assert t.max() - t.min() <= 2


# --------------------------------------------------------------------- #
# behaviour on realistic mixes (paper's qualitative claims)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dataset", ["wlb_llm", "pile", "redpajama"])
def test_flashcp_beats_llama3_balance_and_static_comm(dataset):
    # the paper's setting: 128K context windows, 8 CP workers
    rng = make_rng(1)
    ratios, savings, l3_ratios = [], [], []
    for _ in range(3):
        lens = pack_sequence(dataset, 131072, rng)
        plan, _ = flashcp_plan(lens, 8)
        l3 = llama3_plan(lens, 8)
        ratios.append(plan.imbalance_ratio())
        l3_ratios.append(l3.imbalance_ratio())
        savings.append(comm_saving(plan))
    assert np.mean(ratios) < 1.10                 # balanced (paper: ~1.04)
    assert np.mean(ratios) < np.mean(l3_ratios)   # better than Llama3 CP
    assert np.mean(savings) > 0.10                # real comm savings
    # (paper: 28% heuristic comm saving on Pile, 23.6%/34.5% measured
    # comm-latency reduction on WLB-LLM/Pile)


def test_comm_bytes_formula():
    # one doc split across 2 workers: head (s=100) is the only non-last
    # shard -> Eq.5 term = 100 tokens
    from repro.planner.plan import Shard
    plan = ShardingPlan(
        doc_lens=np.asarray([400]),
        shards=[Shard(0, 0, 100, 1), Shard(0, 100, 300, 0)],
        num_workers=2)
    assert plan.comm_tokens() == 100
    bytes_ = plan_comm_bytes(plan, kv_heads=8, head_dim=128, dtype_bytes=2)
    assert bytes_ == 4 * 100 * 8 * 128 * 1 * 2


def test_workload_formula():
    assert shard_workload(0, 4) == (4 + 1) * 4 / 2
    assert shard_workload(10, 4) == (2 * 10 + 4 + 1) * 4 / 2


def test_ring_plan_is_per_doc_with_ring_comm():
    lens = [512, 256, 256]
    r = ring_zigzag_plan(lens, 4)
    p = per_doc_plan(lens, 4)
    assert r.comm_style == "ring" and p.comm_style == "allgather"
    assert len(r.shards) == len(p.shards)
    # ring uses the static critical path (full KV travels the ring)
    assert r.comm_tokens() == comm_tokens_static(1024, 4)


# --------------------------------------------------------------------- #
# exact reference (B&B "ILP") vs heuristic — Table 2 analogue
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bnb_at_least_as_good_as_heuristic(seed):
    rng = np.random.default_rng(seed)
    lens = _doc_mix(rng, 2048, 7)
    res = bnb_plan(lens, 4, lambda_comm=0.5, max_nodes=200_000)
    validate_plan(res.plan)
    plan, _ = flashcp_plan(lens, 4)
    heur_obj = plan.imbalance_ratio() + 0.5 * plan.comm_tokens() / 512
    assert res.objective <= heur_obj + 1e-9
