"""Flattened work-queue kernel scheduling (grid="flat").

Properties of :func:`build_work_queue` — the queue is an exact
permutation of the rectangular visit set with contiguous LPT-ordered
rows and correct FIRST/LAST/VALID boundary flags — plus interpret-mode
fwd+grad parity of the flat vs rect kernel schedules across GQA group
sizes and across the CP table emission (per-rank concat layouts and
chunked hop tables for CP in {2, 4}).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # parity tests below run regardless
    HAVE_HYPOTHESIS = False

from repro.kernels.doc_attention import (FLAG_FIRST, FLAG_LAST, FLAG_VALID,
                                         build_block_tables,
                                         build_work_queue)
from repro.kernels.ops import doc_flash_attention
from repro.kernels.ref import mha_reference
from repro.planner import emit_visit_tables, visit_table_shapes

RNG = np.random.default_rng(0)


def _layout(B, Tq, Tk, n_docs, *, q_pad=0, kv_pad=0, seed=0):
    rng = np.random.default_rng(seed)
    kv_doc = np.sort(rng.integers(0, n_docs, (B, Tk)).astype(np.int32), 1)
    kv_pos = np.zeros_like(kv_doc)
    for b in range(B):
        for d in np.unique(kv_doc[b]):
            m = kv_doc[b] == d
            kv_pos[b, m] = np.arange(m.sum())
    idx = np.sort(rng.choice(Tk, Tq, replace=False))
    q_doc, q_pos = kv_doc[:, idx].copy(), kv_pos[:, idx].copy()
    if q_pad:
        q_doc[:, -q_pad:] = -1
    if kv_pad:
        kv_doc[:, -kv_pad:] = -1
    return q_doc, q_pos, kv_doc, kv_pos


def _check_queue_properties(idx, nvis, row, col, flags):
    """One queue direction against its rectangular source tables."""
    B, R, V = idx.shape
    for b in range(B):
        steps = [(int(r), int(c), int(f))
                 for r, c, f in zip(row[b], col[b], flags[b])]
        # 1. valid steps are an exact permutation of the rect visit set
        rect = sorted((r, int(idx[b, r, vi]))
                      for r in range(R) for vi in range(int(nvis[b, r])))
        flat = sorted((r, c) for r, c, f in steps if f & FLAG_VALID)
        assert flat == rect, f"sample {b}: queue is not a permutation"
        # 2. per row: contiguous steps, exactly one FIRST (at the start)
        #    and one LAST (at the end); every row appears (sentinels
        #    cover empty rows)
        seen = []
        for r, c, f in steps:
            if f & (FLAG_FIRST | FLAG_LAST | FLAG_VALID):
                if not seen or seen[-1] != r:
                    seen.append(r)
        assert sorted(seen) == list(range(R)), f"sample {b}: rows missing"
        assert len(set(seen)) == len(seen), f"sample {b}: row split"
        per_row = {}
        for r, c, f in steps:
            if f & (FLAG_FIRST | FLAG_LAST | FLAG_VALID):
                per_row.setdefault(r, []).append(f)
        for r, fl in per_row.items():
            assert fl[0] & FLAG_FIRST and sum(bool(f & FLAG_FIRST)
                                              for f in fl) == 1
            assert fl[-1] & FLAG_LAST and sum(bool(f & FLAG_LAST)
                                              for f in fl) == 1
            want = int(nvis[b, r])
            assert sum(bool(f & FLAG_VALID) for f in fl) == want
            assert len(fl) == max(want, 1)   # empty rows: one sentinel
        # 3. LPT: rows appear in non-increasing visit-count order
        counts = [int(nvis[b, r]) for r in seen]
        assert counts == sorted(counts, reverse=True), \
            f"sample {b}: not LPT-ordered"
        # 4. pad tail never re-triggers init/finalize/compute
        tail = steps[sum(max(int(nvis[b, r]), 1) for r in range(R)):]
        assert all(f == 0 for _, _, f in tail)


def _queue_permutation_case(seed, docs, q_pad):
    B, Tq, Tk, bq, bk = 2, 64, 64, 8, 16
    qd, qp, kd, kp = _layout(B, Tq, Tk, docs, seed=seed, q_pad=q_pad)
    t = build_block_tables(qd, qp, kd, kp, block_q=bq, block_k=bk)
    _check_queue_properties(t.kv_idx, t.kv_nvis, t.fq_row, t.fq_col,
                            t.fq_flags)
    _check_queue_properties(t.q_idx, t.q_nvis, t.rq_row, t.rq_col,
                            t.rq_flags)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), docs=st.integers(1, 6),
           q_pad=st.integers(0, 12))
    def test_work_queue_is_exact_row_permutation(seed, docs, q_pad):
        _queue_permutation_case(seed, docs, q_pad)
else:
    @pytest.mark.parametrize("seed,docs,q_pad",
                             [(0, 1, 0), (1, 3, 5), (2, 6, 12),
                              (3, 4, 0), (4, 2, 7)])
    def test_work_queue_is_exact_row_permutation(seed, docs, q_pad):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _queue_permutation_case(seed, docs, q_pad)


def test_work_queue_all_empty_rows():
    """Fully-padded metadata: every row is a sentinel, nothing valid."""
    qd = np.full((1, 32), -1, np.int32)
    qp = np.zeros((1, 32), np.int32)
    t = build_block_tables(qd, qp, qd, qp, block_q=8, block_k=8)
    assert not np.any(t.fq_flags & FLAG_VALID)
    assert np.count_nonzero(t.fq_flags & FLAG_FIRST) == 4   # one per row
    _check_queue_properties(t.kv_idx, t.kv_nvis, t.fq_row, t.fq_col,
                            t.fq_flags)


def test_work_queue_pad_to_steps():
    qd, qp, kd, kp = _layout(1, 64, 64, 3, seed=7)
    t = build_block_tables(qd, qp, kd, kp, block_q=8, block_k=8)
    S = t.fq_row.shape[-1]
    row, col, flags = build_work_queue(t.kv_idx, t.kv_nvis,
                                       pad_to_steps=S + 13)
    assert row.shape == (1, S + 13)
    np.testing.assert_array_equal(row[:, :S], t.fq_row)
    assert not np.any(flags[:, S:])
    _check_queue_properties(t.kv_idx, t.kv_nvis, row, col, flags)


# --------------------------------------------------------------------- #
# interpret-mode parity: flat vs rect schedules
# --------------------------------------------------------------------- #
def _tensors(B, Hq, Hkv, Tq, Tk, D, seed=1):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, Hq, Tq, D)).astype(np.float32)
    k = rng.standard_normal((B, Hkv, Tk, D)).astype(np.float32)
    v = rng.standard_normal((B, Hkv, Tk, D)).astype(np.float32)
    return map(jnp.asarray, (q, k, v))


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (4, 1)])
def test_flat_matches_rect_fwd_and_grad_gqa(Hq, Hkv):
    """Flat and rect schedules agree (and match the oracle) for MHA,
    GQA and MQA group sizes, values and gradients."""
    B, Tq, Tk, D, bq, bk = 2, 64, 128, 16, 16, 16
    qd, qp, kd, kp = _layout(B, Tq, Tk, 4, q_pad=3, kv_pad=5)
    q, k, v = _tensors(B, Hq, Hkv, Tq, Tk, D)
    tabs = build_block_tables(qd, qp, kd, kp, block_q=bq, block_k=bk)
    jqd, jqp, jkd, jkp = map(jnp.asarray, (qd, qp, kd, kp))
    ref = mha_reference(q, k, v, jqd, jqp, jkd, jkp)

    outs, grads = {}, {}
    for grid in ("rect", "flat"):
        outs[grid] = doc_flash_attention(q, k, v, jqd, jqp, jkd, jkp,
                                         tabs, grid=grid, interpret=True)
        np.testing.assert_allclose(np.asarray(outs[grid]), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5, err_msg=grid)
        grads[grid] = jax.grad(
            lambda *a, g=grid: jnp.sum(doc_flash_attention(
                *a, jqd, jqp, jkd, jkp, tabs, grid=g,
                interpret=True) ** 2), (0, 1, 2))(q, k, v)
    # flat vs rect: the same visit set in a different order — bitwise-
    # level agreement is not guaranteed (fp reassociation), tight
    # tolerance is
    np.testing.assert_allclose(np.asarray(outs["flat"]),
                               np.asarray(outs["rect"]),
                               atol=1e-5, rtol=1e-5)
    for a, b, nm in zip(grads["flat"], grads["rect"], "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4,
                                   err_msg=f"d{nm}")


def test_flat_partial_mode_matches_rect():
    """The (o, lse) partial form — the CP merge substrate input — agrees
    across schedules, including the dlse backward fold."""
    B, Hq, Hkv, T, D = 1, 4, 2, 64, 16
    qd, qp, kd, kp = _layout(B, T, T, 3, q_pad=4)
    q, k, v = _tensors(B, Hq, Hkv, T, T, D)
    tabs = build_block_tables(qd, qp, kd, kp, block_q=16, block_k=16)
    jqd, jqp, jkd, jkp = map(jnp.asarray, (qd, qp, kd, kp))

    def run(grid):
        def f(q, k, v):
            o, lse = doc_flash_attention(q, k, v, jqd, jqp, jkd, jkp,
                                         tabs, grid=grid, interpret=True,
                                         partial=True)
            lse = jnp.where(jnp.isfinite(lse), lse, 0.0)
            return jnp.sum(o.astype(jnp.float32) ** 2) + jnp.sum(lse)
        return jax.value_and_grad(f, (0, 1, 2))(q, k, v)

    lr, gr = run("rect")
    lf, gf = run("flat")
    np.testing.assert_allclose(float(lf), float(lr), rtol=1e-6)
    for a, b, nm in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4, err_msg=f"d{nm}")


# --------------------------------------------------------------------- #
# CP table emission: per-rank flat tables across CP sizes
# --------------------------------------------------------------------- #
def _enc(cp, lens=(70, 23, 100, 40, 23), B=2):
    from repro.planner.baselines import BASELINE_PLANNERS
    from repro.planner import encode_plan_batch
    plans = [BASELINE_PLANNERS["flashcp"](np.asarray(lens, np.int64), cp)
             for _ in range(B)]
    return encode_plan_batch(plans, align=16)


@pytest.mark.parametrize("cp", [2, 4])
def test_emitted_flat_tables_match_rect_per_rank(cp):
    """Monolithic concat layout, per (sample, rank): the emitted flat
    queue drives the kernel to the same outputs and gradients as the
    emitted rect tables (the CP{2,4} island-level parity of the grid
    switch, without needing simulated devices)."""
    stack, encs = _enc(cp)
    tabs = emit_visit_tables(stack["doc"], stack["pos"],
                             stack["gath_doc"], stack["gath_pos"],
                             num_workers=cp, strategy="flashcp",
                             overlap="none", grid="both",
                             block_q=16, block_k=16)
    t_loc, buf = encs[0].t_loc, encs[0].buf_len
    Hq, Hkv, D = 4, 2, 8
    rng = np.random.default_rng(3)
    for b in (0, 1):
        for r in range(cp):
            qd = stack["doc"][b, r * t_loc:(r + 1) * t_loc][None]
            qp = stack["pos"][b, r * t_loc:(r + 1) * t_loc][None]
            gd = stack["gath_doc"][b].copy()
            gd[r * buf:(r + 1) * buf] = -2          # self-masked segment
            kd = np.concatenate([qd[0], gd])[None]
            kp = np.concatenate([qp[0], stack["gath_pos"][b]])[None]
            Tq, Tk = qd.shape[1], kd.shape[1]
            q, k, v = _tensors(1, Hq, Hkv, Tq, Tk, D,
                               seed=int(rng.integers(1 << 30)))
            jqd, jqp, jkd, jkp = map(jnp.asarray, (qd, qp, kd, kp))

            rect = tuple(jnp.asarray(tabs[f"tab_{n}"][b, r][None])
                         for n in ("kv_idx", "kv_nvis", "q_idx", "q_nvis"))
            flat = tuple(jnp.asarray(tabs[f"tab_{n}"][b, r][None])
                         for n in ("fq_row", "fq_col", "fq_flags",
                                   "rq_row", "rq_col", "rq_flags"))

            def loss(grid, tt):
                def f(q, k, v):
                    return jnp.sum(doc_flash_attention(
                        q, k, v, jqd, jqp, jkd, jkp, tt, grid=grid,
                        block_q=16, block_k=16, interpret=True) ** 2)
                return jax.value_and_grad(f, (0, 1, 2))(q, k, v)

            lr, gr = loss("rect", rect)
            lf, gf = loss("flat", flat)
            np.testing.assert_allclose(float(lf), float(lr), rtol=1e-5,
                                       err_msg=f"b{b} rank{r}")
            for a, bb, nm in zip(gf, gr, "qkv"):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(bb), atol=5e-4, rtol=5e-4,
                    err_msg=f"b{b} rank{r} d{nm}")


@pytest.mark.parametrize("cp", [2, 4])
def test_emitted_flat_hop_tables_match_direct_build(cp):
    """Chunked emission: hop h of rank r must equal the directly-built
    queue of (q_r, payload of rank (r-1-h) mod N) — the same rotation
    contract the rect emitter test pins, for the flat layout."""
    stack, encs = _enc(cp)
    tabs = emit_visit_tables(stack["doc"], stack["pos"],
                             stack["gath_doc"], stack["gath_pos"],
                             num_workers=cp, strategy="flashcp",
                             overlap="chunked", grid="flat",
                             pad_to="exact", block_q=16, block_k=16)
    t_loc, buf = encs[0].t_loc, encs[0].buf_len
    b = 0
    for r in range(cp):
        qd = stack["doc"][b, r * t_loc:(r + 1) * t_loc][None]
        qp = stack["pos"][b, r * t_loc:(r + 1) * t_loc][None]
        for h in range(cp - 1):
            src = (r - 1 - h) % cp
            kd = stack["gath_doc"][b, src * buf:(src + 1) * buf][None]
            kp = stack["gath_pos"][b, src * buf:(src + 1) * buf][None]
            ref = build_block_tables(qd, qp, kd, kp, block_q=16,
                                     block_k=16)
            got = tabs["tab_hop_fq_row"][b, r, h]
            S = ref.fq_row.shape[-1]
            np.testing.assert_array_equal(got[:S], ref.fq_row[0])
            np.testing.assert_array_equal(
                tabs["tab_hop_fq_flags"][b, r, h][:S], ref.fq_flags[0])
            assert not np.any(tabs["tab_hop_fq_flags"][b, r, h][S:])


def test_emitter_full_pad_matches_flat_spec_shapes():
    cp = 4
    stack, encs = _enc(cp)
    B = stack["doc"].shape[0]
    for overlap in ("none", "chunked"):
        tabs = emit_visit_tables(stack["doc"], stack["pos"],
                                 stack["gath_doc"], stack["gath_pos"],
                                 num_workers=cp, strategy="flashcp",
                                 overlap=overlap, grid="both",
                                 block_q=16, block_k=16, pad_to="full")
        shapes = visit_table_shapes(B, cp, encs[0].t_loc, encs[0].buf_len,
                                    strategy="flashcp", overlap=overlap,
                                    block_q=16, block_k=16, grid="both")
        assert set(tabs) == set(shapes)
        for key, shape in shapes.items():
            assert tabs[key].shape == shape, (key, tabs[key].shape, shape)
