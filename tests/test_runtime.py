"""Runtime tests: fault tolerance supervision, elastic mesh shrink,
straggler monitor, sharding rules."""

import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_abstract_mesh
from repro.runtime import (FailureAction, FailurePolicy, HeartbeatMonitor,
                           StragglerMonitor, TrainingFailure,
                           run_with_recovery, shrink_mesh_shape)
from repro.runtime.sharding import batch_specs, param_shardings


def test_heartbeat_monitor():
    mon = HeartbeatMonitor(num_hosts=4, timeout_s=10.0)
    for h in range(4):
        mon.beat(h, t=100.0)
    assert mon.failed_hosts(now=105.0) == []
    mon.beat(2, t=120.0)
    assert mon.failed_hosts(now=119.0) == [0, 1, 3]
    assert not mon.healthy(now=119.0)


def test_failure_policy():
    pol = FailurePolicy(min_hosts=2, max_restarts=2)
    assert pol.decide(4, []) == FailureAction.RESTART
    # an ABORT verdict (too few survivors) never burns a restart slot
    assert pol.decide(1, [0, 2]) == FailureAction.ABORT
    assert pol.restarts == 1
    assert pol.decide(3, [1]) == FailureAction.ELASTIC_SHRINK
    assert pol.decide(4, []) == FailureAction.ABORT  # restart budget spent
    assert pol.restarts == 2


def test_run_with_recovery_restarts_and_finishes():
    steps_run = []
    fail_once = {"done": False}

    def step(s):
        if s == 3 and not fail_once["done"]:
            fail_once["done"] = True
            raise TrainingFailure("boom")
        steps_run.append(s)

    restores = []

    def on_restore(action, failed):
        restores.append(action)
        return 2  # checkpoint at step 2

    final = run_with_recovery(step, start_step=0, total_steps=6,
                              policy=FailurePolicy(min_hosts=1),
                              on_restore=on_restore,
                              logger=lambda *_: None)
    assert final == 6
    assert restores == [FailureAction.RESTART]
    assert steps_run == [0, 1, 2, 2, 3, 4, 5]   # replay from checkpoint


def test_run_with_recovery_threads_real_alive_count():
    """Satellite fix: the loop tracks cumulative dead hosts, so the
    policy's min_hosts check sees the real survivor count instead of a
    constant (which used to grant every shrink forever)."""
    losses = iter([[0], [1]])

    def step(s):
        if s == 2:
            hosts = next(losses, None)
            if hosts is not None:
                raise TrainingFailure("host down", failed_hosts=hosts)

    actions = []

    def on_restore(action, failed):
        actions.append(action)
        return 1

    # 4 hosts, min 3: losing host 0 leaves 3 (shrink OK); losing host 1
    # as well leaves 2 < 3 -> abort re-raises the failure
    with pytest.raises(TrainingFailure, match="host down"):
        run_with_recovery(step, start_step=0, total_steps=8,
                          policy=FailurePolicy(min_hosts=3),
                          on_restore=on_restore, num_hosts=4,
                          logger=lambda *_: None)
    assert actions == [FailureAction.ELASTIC_SHRINK]


def test_run_with_recovery_num_hosts_from_monitor():
    mon = HeartbeatMonitor(num_hosts=6, timeout_s=1e6)
    for h in range(6):
        mon.beat(h)                               # all healthy at start

    fail_once = {"done": False}

    def step(s):
        if s == 1 and not fail_once["done"]:
            fail_once["done"] = True
            raise TrainingFailure("x", failed_hosts=[5])

    final = run_with_recovery(step, start_step=0, total_steps=3,
                              policy=FailurePolicy(min_hosts=5),
                              on_restore=lambda a, f: 0, monitor=mon,
                              logger=lambda *_: None)
    assert final == 3                             # 5 survivors >= min 5


def test_elastic_shrink():
    plan = shrink_mesh_shape(192, model_axis=16, old_data_axis=16)
    assert plan.mesh_shape == (8, 16)
    assert plan.accum_factor == 2               # preserves global batch
    with pytest.raises(ValueError):
        shrink_mesh_shape(8, model_axis=16)


def test_straggler_monitor_tightens_target():
    mon = StragglerMonitor(window=20, jitter_threshold=1.15)
    for _ in range(15):
        mon.record_step(1.0)
    for _ in range(5):
        mon.record_step(2.0)                    # jittery tail
    assert mon.jitter > 1.15
    before = mon.target_imbalance
    after = mon.adjusted_target()
    assert after < before


# --------------------------------------------------------------------- #
def test_param_sharding_rules():
    # AbstractMesh: sharding rules are pure metadata (no devices needed)
    mesh = make_abstract_mesh((2, 2), ("data", "model"))
    params = {
        "embed": {"e": jnp.zeros((100, 64))},
        "layers": {"sub_0": {
            "mlp": {"wi": jnp.zeros((4, 64, 256))},
            "moe": {"wi": jnp.zeros((4, 8, 64, 256))},
        }},
        "scalar": jnp.zeros(()),
    }
    sh = param_shardings(mesh, params)
    assert sh["scalar"].spec == P()
    # stacked-scan leaves never shard dim 0
    assert sh["layers"]["sub_0"]["mlp"]["wi"].spec[0] is None
    # expert leaves put E on the model axis (EP layout)
    moe_spec = sh["layers"]["sub_0"]["moe"]["wi"].spec
    assert moe_spec[1] == "model"
    # something actually got sharded for big leaves
    assert any(s is not None for s in sh["embed"]["e"].spec)


def test_batch_specs_fallback_replicates_indivisible_batch():
    mesh = make_abstract_mesh((2, 2), ("data", "model"))
    specs = batch_specs(mesh, {"tokens": (1, 512), "labels": (4, 512)})
    assert specs["tokens"][0] is None           # batch=1 can't split 2 ways
    assert specs["labels"][0] == "data"
    assert specs["labels"][1] == "model"
