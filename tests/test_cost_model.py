"""Analytic cost-model backfill (DESIGN.md §Autotune).

Unit coverage for the roofline model the benchmarks and the autotuner
share (``repro.autotune.cost_model``, re-exported by
``benchmarks.cost_model``): kernel-efficiency curve shape, attention
block-work accounting against the planner's own exact Eq. W_i workload
counters (the same quantities plan_check's PLAN004 verifies), the
four-term step breakdown's internal consistency, and the rank-level
regression tying ``schedule_model`` to the committed BENCH_overlap.json
measurement.
"""

import json
import sys
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))           # for `benchmarks.*`

from repro.autotune.cost_model import (BLOCK, L_HALF, ModelDims,
                                       _attention_block_work, _kernel_eff,
                                       step_breakdown, visited_tile_counts)
from repro.core.workload import plan_comm_bytes
from repro.planner import get_planner

DIMS = ModelDims(num_heads=8, kv_heads=4, head_dim=64)


def _plan(strategy="flashcp", seed=0, n=12, N=4, quantum=None):
    rng = np.random.default_rng(seed)
    lens = rng.integers(32, 700, n).astype(np.int64)
    q = quantum or (2 * N)
    lens[-1] += (-lens.sum()) % q           # context divisible for any style
    return get_planner(strategy)(lens, N, validate=False)


# --------------------------------------------------------------------- #
# _kernel_eff
# --------------------------------------------------------------------- #
def test_kernel_eff_shape():
    exts = [1, 64, 512, 2048, 16384, 1 << 20]
    effs = [_kernel_eff(e) for e in exts]
    assert all(0.0 < e < 1.0 for e in effs)
    assert effs == sorted(effs)             # monotone in extent
    assert _kernel_eff(int(L_HALF)) == pytest.approx(0.5)
    assert _kernel_eff(16384) == pytest.approx(16384 / (16384 + L_HALF))


# --------------------------------------------------------------------- #
# _attention_block_work / visited_tile_counts vs exact Eq. W_i counters
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["flashcp", "llama3", "per_doc",
                                      "contiguous", "ring_zigzag"])
def test_block_work_bounds_exact_workload(strategy):
    plan = _plan(strategy)
    w = plan.workload_per_worker()          # exact token pairs (Eq. W_i)
    t = visited_tile_counts(plan)

    # tile covering dominates the exact pair count on every worker ...
    assert np.all(t["visited"] * BLOCK * BLOCK >= w - 1e-6)
    # ... but by no more than the per-shard tile-boundary slack
    a = plan.arrays
    q_tiles = -(-a.length // BLOCK)
    kv_tiles = -(-(a.start + a.length) // BLOCK)
    slack = np.bincount(
        a.worker, weights=(q_tiles + kv_tiles + 1) * BLOCK * BLOCK,
        minlength=plan.num_workers)
    assert np.all(t["visited"] * BLOCK * BLOCK <= w + slack)

    # the busiest-worker pairs returned for the roofline agree with the
    # per-worker maximum, scaled by the (<=1) kernel efficiency
    pairs, n_shards = _attention_block_work(plan)
    per_worker_tiles = t["visited"] * BLOCK * BLOCK
    assert pairs >= per_worker_tiles.max() - 1e-6   # eff divisor inflates
    assert n_shards == int(np.bincount(
        a.worker, minlength=plan.num_workers).max())


def test_ring_extent_collapses_to_shard_length():
    plan = _plan("ring_zigzag")
    collective, _ = _attention_block_work(plan, ring=False)
    ring, _ = _attention_block_work(plan, ring=True)
    # same visited tiles, worse efficiency (shorter kernel extents)
    assert ring >= collective - 1e-6


# --------------------------------------------------------------------- #
# step_breakdown consistency
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["flashcp", "llama3", "ring_zigzag"])
def test_step_breakdown_totals_and_comm_accounting(strategy):
    plan = _plan(strategy)
    bd = step_breakdown(plan, DIMS, train=True)
    assert bd["total_s"] == pytest.approx(
        bd["attn_s"] + bd["comm_s"] + bd["other_s"] + bd["linear_s"])
    assert all(bd[k] >= 0.0 for k in
               ("attn_s", "comm_s", "other_s", "linear_s"))
    # comm bytes are exactly the Eq.4/5 accounting of core.workload
    assert bd["comm_bytes"] == plan_comm_bytes(
        plan, DIMS.kv_heads, DIMS.head_dim, dtype_bytes=2,
        fwd_and_bwd=True)
    assert bd["shards"] == len(plan.arrays)
    assert bd["imbalance"] == pytest.approx(plan.imbalance_ratio())


def test_step_breakdown_train_vs_infer():
    plan = _plan("flashcp")
    train = step_breakdown(plan, DIMS, train=True)
    infer = step_breakdown(plan, DIMS, train=False)
    # fwd+bwd trains 3x the GEMM flops and 2x the wire of inference
    assert train["linear_s"] == pytest.approx(3.0 * infer["linear_s"])
    assert train["comm_bytes"] == 2 * infer["comm_bytes"]
    assert train["total_s"] > infer["total_s"]


def test_step_breakdown_dtype_bytes_scales_wire():
    plan = _plan("flashcp")
    bf16 = step_breakdown(plan, DIMS, dtype_bytes=2)
    int8 = step_breakdown(plan, DIMS, dtype_bytes=1)
    assert int8["comm_bytes"] * 2 == bf16["comm_bytes"]
    assert int8["comm_s"] <= bf16["comm_s"]


def test_sharding_aware_comm_beats_static_allgather():
    # the paper's core claim, reflected by the model: FlashCP's Eq.5
    # buffer moves fewer bytes than the full-KV all-gather on a mixed pool
    flash = step_breakdown(_plan("flashcp", seed=3), DIMS)
    llama = step_breakdown(_plan("llama3", seed=3), DIMS)
    assert flash["comm_bytes"] <= llama["comm_bytes"]


# --------------------------------------------------------------------- #
# benchmarks.cost_model shim
# --------------------------------------------------------------------- #
def test_benchmarks_shim_reexports_identical_objects():
    from benchmarks import cost_model as shim
    from repro.autotune import cost_model as real

    for name in ("BLOCK", "HW", "L_HALF", "ModelDims", "_kernel_eff",
                 "_attention_block_work", "step_breakdown",
                 "visited_tile_counts"):
        assert getattr(shim, name) is getattr(real, name)


# --------------------------------------------------------------------- #
# schedule_model vs the committed BENCH_overlap.json measurement
# --------------------------------------------------------------------- #
def test_schedule_model_ranks_agree_with_measured_overlap():
    """Rank-level regression: the HLO schedule model and the measured
    wallclock must order blocking vs chunked CP execution the same way
    (absolute magnitudes differ — CPU emulation vs modeled v5e)."""
    path = ROOT / "BENCH_overlap.json"
    if not path.exists():
        pytest.skip("BENCH_overlap.json not committed")
    execu = json.loads(path.read_text())["execution"]
    none, chunked = execu["none"], execu["chunked"]

    # measured: chunked overlap beats blocking
    assert chunked["wallclock_us"] < none["wallclock_us"]
    # modeled: same order, and the win comes from hidden comm
    assert chunked["modeled_makespan_us"] < none["modeled_makespan_us"]
    assert chunked["exposed_comm_us"] < none["exposed_comm_us"]
    # chunking splits the collective into per-hop pieces
    assert chunked["collective_count"] > none["collective_count"]
    assert execu["exposed_comm_reduction_x"] == pytest.approx(
        none["exposed_comm_us"] / chunked["exposed_comm_us"])
