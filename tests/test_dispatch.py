"""Adaptive DP×CP dispatcher invariants (DESIGN.md §Dispatch).

Properties (hypothesis where available, fixed-seed fallback otherwise):
every pool document assigned exactly once; per-group token counts within
the LPT tolerance; CP-degree choices respect mesh/batch divisibility;
the legacy per-rank pipeline is bit-identical with dispatch off; ragged
dispatch batches are token-weighted in the loss (the global masked mean
equals the manual token-weighted combination of per-row losses); and the
same pool dispatched at different degrees carries the same data.
"""

import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence
from repro.data.pipeline import (PipelineConfig, make_batch,
                                 make_dispatch_batch)
from repro.dispatch import (DispatchConfig, cp_degree_options,
                            dispatch_step, imbalance, lpt_assign,
                            pack_pool, sequence_workload)

C = 2048


def _pool(seed, n_docs, max_len=C):
    rng = np.random.default_rng(seed)
    return rng.integers(16, max_len + 1, n_docs).astype(np.int64)


# --------------------------------------------------------------------- #
# pack_pool: every document assigned exactly once
# --------------------------------------------------------------------- #
def _pack_case(seed, n_docs, n_bins, quantum):
    pool = _pool(seed, n_docs)
    packed = pack_pool(pool, n_bins, C, quantum=quantum)

    placed = np.concatenate([d for d in packed.bin_docs if len(d)]) \
        if any(len(d) for d in packed.bin_docs) else np.zeros(0, np.int64)
    everywhere = np.concatenate([placed, packed.dropped_docs])
    # exactly once: placed ∪ dropped is a permutation of the pool indices
    assert sorted(everywhere.tolist()) == list(range(len(pool)))

    # lengths never grow; token conservation incl. truncation
    total = 0
    for lens, docs in zip(packed.bins, packed.bin_docs):
        assert np.all(lens >= 1)
        assert np.all(lens <= pool[docs])
        total += int(lens.sum())
    assert total + packed.truncated_tokens == int(pool.sum())

    # capacity + quantum divisibility
    fills = packed.bin_tokens
    assert np.all(fills <= C)
    assert np.all(fills % quantum == 0)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000), n_docs=st.integers(1, 60),
           n_bins=st.integers(1, 6),
           quantum=st.sampled_from([1, 4, 16]))
    def test_pack_pool_assigns_each_doc_once(seed, n_docs, n_bins, quantum):
        _pack_case(seed, n_docs, n_bins, quantum)
else:
    @pytest.mark.parametrize("seed,n_docs,n_bins,quantum",
                             [(0, 1, 1, 1), (1, 40, 4, 16), (2, 60, 6, 4),
                              (3, 7, 3, 1), (4, 25, 2, 16), (5, 13, 5, 4)])
    def test_pack_pool_assigns_each_doc_once(seed, n_docs, n_bins, quantum):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _pack_case(seed, n_docs, n_bins, quantum)


# --------------------------------------------------------------------- #
# lpt_assign: cardinality + the LPT load bound
# --------------------------------------------------------------------- #
def _lpt_case(seed, n_groups, per_group):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.0, 100.0, n_groups * per_group)
    assign = lpt_assign(w, n_groups, per_group=per_group)
    counts = np.bincount(assign, minlength=n_groups)
    assert np.all(counts == per_group)
    loads = np.bincount(assign, weights=w, minlength=n_groups)
    assert loads.max() <= loads.mean() + w.max() + 1e-9


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000), n_groups=st.integers(1, 8),
           per_group=st.integers(1, 8))
    def test_lpt_cardinality_and_bound(seed, n_groups, per_group):
        _lpt_case(seed, n_groups, per_group)
else:
    @pytest.mark.parametrize("seed,n_groups,per_group",
                             [(0, 1, 1), (1, 4, 2), (2, 8, 8), (3, 3, 5),
                              (4, 6, 1), (5, 2, 7)])
    def test_lpt_cardinality_and_bound(seed, n_groups, per_group):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _lpt_case(seed, n_groups, per_group)


# --------------------------------------------------------------------- #
# dispatch_step: divisibility + group-token tolerance
# --------------------------------------------------------------------- #
def _dispatch_case(seed, data, model, seqs_per_group_hint):
    seqs = seqs_per_group_hint * (data * model)   # divisible for any g
    pool = _pool(seed, 8 * seqs, max_len=C // 2)
    cfg = DispatchConfig(data=data, model=model, seqs=seqs,
                         target_imbalance=1.1, quantum=16)
    plan = dispatch_step(pool, cfg, C)

    g = plan.cp_degree
    assert model % g == 0                       # subgroup splits the CP axis
    assert (data * model) % g == 0
    assert plan.n_groups == data * model // g
    assert seqs % plan.n_groups == 0            # batch shards the group axis
    assert plan.seqs_per_group * plan.n_groups == seqs
    assert C % (16 * g) == 0 or C % 16 == 0     # quantum admissibility

    # rows are group-major and bin totals meet the Eq.2 quantum
    assert plan.group_of_row.tolist() == sorted(plan.group_of_row.tolist())
    for lens in plan.rows:
        assert int(lens.sum()) % g == 0
        assert int(lens.sum()) <= C

    # group token counts: max/mean within the LPT tolerance of one bin
    tok = plan.group_tokens
    assert tok.sum() + plan.truncated_tokens == pool.sum()
    assert tok.max() <= tok.mean() + C + 1e-9
    assert plan.token_imbalance == pytest.approx(imbalance(tok))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000),
           data=st.sampled_from([1, 2]), model=st.sampled_from([1, 2, 4]),
           seqs_per_group_hint=st.integers(1, 3))
    def test_dispatch_divisibility_and_tolerance(seed, data, model,
                                                 seqs_per_group_hint):
        _dispatch_case(seed, data, model, seqs_per_group_hint)
else:
    @pytest.mark.parametrize("seed,data,model,hint",
                             [(0, 1, 1, 1), (1, 1, 4, 1), (2, 2, 4, 2),
                              (3, 2, 2, 1), (4, 2, 1, 3), (5, 1, 2, 2)])
    def test_dispatch_divisibility_and_tolerance(seed, data, model, hint):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _dispatch_case(seed, data, model, hint)


def test_degree_options_and_fixed_cp():
    cfg = DispatchConfig(data=2, model=4, seqs=8)
    assert cp_degree_options(cfg, C) == [1, 2, 4]
    # seqs=2 cannot spread over 8 groups (g=1) but can over 2 (g=4)
    cfg2 = DispatchConfig(data=2, model=4, seqs=2)
    assert cp_degree_options(cfg2, C) == [4]
    with pytest.raises(ValueError):
        cp_degree_options(DispatchConfig(data=2, model=4, seqs=2,
                                         fixed_cp=2), C)


def test_degree_adapts_to_profile():
    """Short-doc pools stay at CP 1; a heavy tail escalates to the full
    axis (the only tiling whose groups can absorb the monster doc)."""
    cfg = DispatchConfig(data=1, model=4, seqs=4, target_imbalance=1.1)
    short = _pool(0, 200, max_len=256)
    assert dispatch_step(short, cfg, C).cp_degree == 1
    heavy = np.concatenate([[int(C * 0.9)],
                            _pool(1, 40, max_len=256)]).astype(np.int64)
    assert dispatch_step(heavy, cfg, C).cp_degree == 4


def test_sequence_workload_matches_closed_form():
    lens = np.asarray([5, 1, 10])
    assert sequence_workload(lens) == 15.0 + 1.0 + 55.0


# --------------------------------------------------------------------- #
# legacy per-rank path: bit-identical with dispatch off
# --------------------------------------------------------------------- #
def _legacy_reference(cfg, step, dp_rank=0):
    """Frozen copy of the pre-dispatch make_batch synthesis (PR 4 state):
    shared rng, rows drawn sequentially in row order."""
    from repro.data.pipeline import _plan
    from repro.planner import encode_plan_batch, plan_many

    rng = make_rng(hash((cfg.seed, dp_rank, step)) % (2 ** 63))
    doc_lens_list = [pack_sequence(cfg.dataset, cfg.context_len, rng)
                     for _ in range(cfg.batch_per_host)]
    plans = plan_many(lambda lens: _plan(cfg, lens), doc_lens_list,
                      workers=cfg.planner_workers)
    stack, _ = encode_plan_batch(plans, buf_len=cfg.buf_len,
                                 align=cfg.align)
    B, C_pad = stack["perm"].shape
    tokens = np.full((B, C_pad), -1, np.int32)
    labels = np.full((B, C_pad), -1, np.int32)
    for b, lens in enumerate(doc_lens_list):
        n_tok = int(lens.sum())
        packed = ((rng.zipf(1.3, n_tok) - 1) % cfg.vocab_size
                  ).astype(np.int32)
        rep = rng.random(n_tok) < 0.25
        rep[0] = False
        idx = np.arange(n_tok)
        prev = np.maximum(idx - 1, 0)
        packed = np.where(rep, packed[prev], packed)
        perm = stack["perm"][b]
        valid = perm >= 0
        tokens[b, valid] = packed[perm[valid]]
        nxt = perm + 1
        is_final = np.zeros_like(valid)
        ends = np.cumsum(lens) - 1
        is_final[valid] = np.isin(perm[valid], ends)
        lab_ok = valid & ~is_final
        labels[b, lab_ok] = packed[np.minimum(nxt[lab_ok],
                                              len(packed) - 1)]
    return {**stack, "tokens": tokens, "labels": labels}


def test_legacy_path_bit_identical():
    cfg = PipelineConfig(dataset="pile", context_len=C, batch_per_host=3,
                         cp_size=4, strategy="flashcp", vocab_size=997,
                         seed=13, align=16)
    got = make_batch(cfg, step=5)
    want = _legacy_reference(cfg, step=5)
    for key in want:
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)
    assert "seq_tokens" not in got and "group_id" not in got


# --------------------------------------------------------------------- #
# dispatch batches: shape/metadata invariants + degree-invariant data
# --------------------------------------------------------------------- #
def _dispatch_pipe(**kw):
    base = dict(dataset="pile", context_len=C, batch_per_host=4,
                cp_size=4, strategy="flashcp", vocab_size=1000, seed=7,
                align=16)
    base.update(kw)
    return PipelineConfig(**base)


def test_dispatch_batch_invariants():
    cfg = _dispatch_pipe()
    dcfg = DispatchConfig(data=2, model=4, seqs=8, quantum=16)
    b = make_dispatch_batch(cfg, dcfg, step=3)
    ds = b["stats"]["dispatch"]
    g = ds["cp_degree"]
    B, C_pad = b["tokens"].shape
    assert B == 8 and C_pad == C          # t_loc pinned to C / cp
    assert b["send_idx"].shape[:2] == (8, g)
    # seq_tokens == valid plan slots == unmasked tokens per row
    np.testing.assert_array_equal(b["seq_tokens"],
                                  (b["perm"] >= 0).sum(1))
    np.testing.assert_array_equal(b["seq_tokens"],
                                  (b["tokens"] >= 0).sum(1))
    assert np.all(b["labels"][b["perm"] < 0] == -1)
    # group-major rows matching the dispatch stats
    assert b["group_id"].tolist() == sorted(b["group_id"].tolist())
    np.testing.assert_array_equal(
        np.bincount(b["group_id"], weights=b["seq_tokens"]),
        ds["group_tokens"])
    # deterministic
    b2 = make_dispatch_batch(cfg, dcfg, step=3)
    for k in ("tokens", "labels", "doc", "pos", "send_idx",
              "seq_tokens", "group_id"):
        np.testing.assert_array_equal(b[k], b2[k], err_msg=k)


def test_dispatch_data_invariant_across_degrees():
    """The same pool dispatched at different CP degrees carries the same
    documents and the same synthesized tokens (content-keyed streams)."""
    cfg = _dispatch_pipe()
    batches = {
        g: make_dispatch_batch(
            cfg, DispatchConfig(data=2, model=4, seqs=8, fixed_cp=g,
                                bin_quantum=4), step=2)
        for g in (2, 4)}
    tok = {g: np.sort(b["tokens"][b["tokens"] >= 0])
           for g, b in batches.items()}
    np.testing.assert_array_equal(tok[2], tok[4])
    lab = {g: np.sort(b["labels"][b["labels"] >= 0])
           for g, b in batches.items()}
    np.testing.assert_array_equal(lab[2], lab[4])
    assert batches[2]["seq_tokens"].sum() == batches[4]["seq_tokens"].sum()


# --------------------------------------------------------------------- #
# ragged batches are token-weighted in the loss
# --------------------------------------------------------------------- #
def test_ragged_loss_is_token_weighted():
    """Global masked-mean CE == Σ_r ce_r·m_r / Σ_r m_r over ragged rows —
    groups of unequal token counts contribute by token count."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_for_smoke
    from repro.models import loss_fn, make_local_context

    mcfg = dataclasses.replace(reduce_for_smoke(get_config("starcoder2_3b")),
                               dtype="float32")
    cfg = _dispatch_pipe(context_len=512, vocab_size=mcfg.vocab_size)
    dcfg = DispatchConfig(data=1, model=2, seqs=4, fixed_cp=2, quantum=16)
    b = make_dispatch_batch(cfg, dcfg, step=1)
    assert len(set(b["seq_tokens"].tolist())) > 1, "mix not ragged"

    params_rng = jax.random.PRNGKey(0)
    from repro.models import init_params
    params = init_params(params_rng, mcfg)

    def row_loss(r):
        sl = slice(r, r + 1)
        ctx = make_local_context(jnp.asarray(b["doc"][sl]),
                                 jnp.asarray(b["pos"][sl]), q_chunk=64)
        batch = {"tokens": jnp.asarray(b["tokens"][sl]),
                 "labels": jnp.asarray(b["labels"][sl])}
        loss, _ = loss_fn(params, mcfg, ctx, batch, remat=False)
        return float(loss)

    ctx = make_local_context(jnp.asarray(b["doc"]), jnp.asarray(b["pos"]),
                             q_chunk=64)
    whole, _ = loss_fn(params, mcfg, ctx,
                       {"tokens": jnp.asarray(b["tokens"]),
                        "labels": jnp.asarray(b["labels"])}, remat=False)

    m = (b["labels"] >= 0).sum(1).astype(np.float64)
    weighted = sum(row_loss(r) * m[r] for r in range(4)) / m.sum()
    assert float(whole) == pytest.approx(weighted, rel=1e-5)
    # and NOT the unweighted per-row mean (the raggedness is real)
    unweighted = np.mean([row_loss(r) for r in range(4)])
    assert abs(unweighted - weighted) > 0 or np.allclose(m, m[0])


# --------------------------------------------------------------------- #
# multi-device subprocess check (CP{2,4} × DP2 vs single-group baseline)
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_dispatch_mesh_parity():
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(here, "multidevice",
                                      "dispatch_check.py")],
        capture_output=True, text=True, timeout=1200, env=env)
    assert proc.returncode == 0, \
        f"dispatch_check.py failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n" \
        f"STDERR:\n{proc.stderr[-4000:]}"
    assert "DISPATCH_CHECK_PASS" in proc.stdout
