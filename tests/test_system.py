"""End-to-end system tests: the real training driver (loss goes down,
checkpoint/restart is bit-deterministic, failure injection recovers), the
serving driver, and the roofline/HLO analyzer on a known graph."""

import dataclasses
import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest


def _args(**kw):
    base = dict(arch="starcoder2_3b", smoke=True, mesh="1x1",
                strategy="flashcp", attention_impl="xla", dataset="wlb_llm",
                seq_len=256, batch=2, steps=8, lr=1e-3, q_chunk=128,
                grad_compression="none", checkpoint_dir="", ckpt_every=0,
                log_every=100, resume=False, prefetch=False, no_remat=False,
                fail_at=-1)
    base.update(kw)
    return types.SimpleNamespace(**base)


def test_training_loss_decreases(tmp_path):
    from repro.launch.train import train
    out = train(_args(checkpoint_dir=str(tmp_path), steps=12))
    losses = out["losses"]
    assert out["final_step"] == 12
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.05
    assert all(np.isfinite(losses))


def test_training_failure_recovery_is_deterministic(tmp_path):
    """Inject a failure; the recovered run must replay the identical loss
    trajectory (deterministic pipeline + checkpoint restore)."""
    from repro.launch.train import train
    ref = train(_args(checkpoint_dir=str(tmp_path / "a"), steps=6))
    out = train(_args(checkpoint_dir=str(tmp_path / "b"), steps=6,
                      ckpt_every=2, resume=True, fail_at=4))
    # the recovered run covers all 6 steps; the post-restore replay of the
    # final steps must reproduce the uninterrupted run exactly
    assert out["final_step"] == 6
    np.testing.assert_allclose(out["losses"][-3:], ref["losses"][-3:],
                               rtol=1e-6)


def test_training_with_compression(tmp_path):
    from repro.launch.train import train
    out = train(_args(checkpoint_dir=str(tmp_path), steps=8,
                      grad_compression="int8"))
    assert np.isfinite(out["losses"]).all()
    assert np.mean(out["losses"][-2:]) < np.mean(out["losses"][:2]) + 0.1


def test_serve_driver():
    from repro.launch.serve import serve
    out = serve(types.SimpleNamespace(arch="starcoder2_3b", smoke=True,
                                      requests=2, prompt_len=32, gen=4))
    assert sorted(out["tokens"]) == [0, 1]
    for toks in out["tokens"].values():
        assert toks.shape == (4,) and (toks >= 0).all()
    # no prompt replay: prefill is chunk steps only, and the decode
    # window excludes the prefill-produced first token (3 per request).
    # Under the unified scheduler slots enter decode as soon as their own
    # prefill completes, so the decode window can span up to 2*3 steps
    # depending on prompt-length skew — but never stalls.
    assert out["stats"]["prefill_decode_steps"] == 0
    assert out["stats"]["prefill_steps"] > 0
    assert out["stats"]["decode_tokens"] == 2 * 3
    assert 3 <= out["stats"]["decode_steps"] <= 6
    assert out["stats"]["stalled_decode_steps"] == 0


# --------------------------------------------------------------------- #
def test_hlo_analyzer_counts_trip_counts():
    """Known graph: scan of k matmuls must report k x the flops."""
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jnp.zeros((64, 64))
    compiled = jax.jit(f).lower(x, x).compile()
    cost = analyze_hlo(compiled.as_text())
    expect = 7 * 2 * 64 ** 3
    assert expect * 0.99 <= cost.flops <= expect * 1.2


def test_hlo_analyzer_collectives():
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.roofline import roofline_terms

    terms = roofline_terms(197e12, 819e9, 50e9)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(1.0)
    assert terms["collective_s"] == pytest.approx(1.0)

    # known single-collective graph
    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    # trivial: no collectives on a 1x1 mesh
    compiled = jax.jit(lambda x: x + 1).lower(jnp.zeros((8, 8))).compile()
    cost = analyze_hlo(compiled.as_text())
    assert cost.collective_wire_bytes == 0


def test_dryrun_cell_records_schema():
    """The dry-run record for one tiny local cell has the full schema the
    EXPERIMENTS.md tables read (run on the saved full-matrix results if
    present, else skip)."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("dry-run matrix not yet generated")
    recs = json.load(open(path))
    ok = [r for r in recs if r["status"] == "ok"]
    assert ok, "no successful dry-run records"
    for r in ok:
        assert {"arch", "shape", "mesh", "memory", "cost", "collectives",
                "roofline"} <= set(r)
        assert r["roofline"]["dominant"] in ("compute", "memory",
                                             "collective")


# --------------------------------------------------------------------- #
def test_decode_matches_forward_logits():
    """Serving-path consistency: token-by-token decode with the KV/SSM
    caches must reproduce the teacher-forced forward logits at every
    position (binds attn_apply/attn_decode, rope positions, cache updates
    and — for hybrid archs — the mamba train/decode paths together).

    Root cause of the former xfail (seed "jamba numeric drift"): it was
    never kernel numerics — the attention decode path matches at ~2e-6
    (starcoder below; flash_decode's f32 accumulation and softmax scale
    are validated against the forward oracle in test_kernels.py) and so
    does the mamba recurrence in isolation.  Jamba is *MoE*: the
    teacher-forced forward routes all B*T tokens through
    capacity-clipped dispatch jointly (capacity_factor=1.25 -> experts
    overflow and *late tokens get dropped*), while one-token decode
    steps route B tokens at a time and essentially never drop.  The
    divergence is a documented semantic property of capacity-based
    routing, not numeric drift — so the hybrid arch is checked with
    capacity lifted to the drop-free regime, where decode must (and
    does) match tightly; the divergence under the training capacity
    factor is asserted too, pinning the root cause.
    """
    import dataclasses
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, reduce_for_smoke
    from repro.models import (decode_step, forward, init_cache, init_params,
                              make_local_context)

    B, T = 2, 24

    def worst_decode_diff(cfg):
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T))
                             .astype(np.int32))
        doc = jnp.zeros((B, T), jnp.int32)
        pos = jnp.asarray(np.tile(np.arange(T, dtype=np.int32), (B, 1)))
        ctx = make_local_context(doc, pos, q_chunk=8)
        params = init_params(jax.random.PRNGKey(0), cfg)
        ref_logits, _ = forward(params, cfg, ctx, {"tokens": tokens},
                                remat=False)
        cache = init_cache(cfg, B, T)
        # jitted like the serving engine: one compile per cfg (eager
        # flash-decode interpret re-traces the kernel every step)
        dec = jax.jit(lambda p, c, b, t: decode_step(p, cfg, c, b, t))
        worst = 0.0
        for t in range(T):
            lg, cache = dec(params, cache,
                            {"tokens": tokens[:, t]},
                            jnp.full((B,), t, jnp.int32))
            worst = max(worst, float(np.max(np.abs(
                np.asarray(lg) - np.asarray(ref_logits[:, t])))))
        return worst

    # attention-only arch: strict parity, no routing in the way
    assert worst_decode_diff(reduce_for_smoke(ARCHS["starcoder2_3b"])) \
        < 1e-4

    # hybrid MoE arch: strict parity once capacity clipping can't drop
    # tokens (cap >= all routed tokens)
    jamba = reduce_for_smoke(ARCHS["jamba_v0_1_52b"])
    dropfree = dataclasses.replace(jamba, capacity_factor=float(B * T))
    assert worst_decode_diff(dropfree) < 1e-4

    # ... and the divergence under the training capacity factor is real
    # and capacity-induced (if this starts passing, routing went
    # drop-free and the drop-free branch above is redundant)
    assert worst_decode_diff(jamba) > 1e-2
