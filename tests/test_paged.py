"""Paged-KV serving tests: block-pool alloc/release/refcount lifecycle,
prefix-trie sharing, copy-on-write on shared-block append, pool
exhaustion admission backoff, paged-vs-dense greedy parity on a ragged
mix, unified token-budget scheduling, and per-request sampling
determinism (properties via hypothesis where available, fixed-seed
fallback otherwise)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs import get_config, reduce_for_smoke
from repro.serve import (BlockPool, PrefixCache, Request, Scheduler,
                         ServeEngine)

RNG = np.random.default_rng(0)


def _smoke(arch="starcoder2_3b"):
    return reduce_for_smoke(get_config(arch))


# ===================================================================== #
# block pool
# ===================================================================== #
def test_block_pool_lifecycle():
    pool = BlockPool(4, 16)
    a = pool.alloc(2)
    assert a is not None and len(a) == 2
    assert pool.allocated_count == 2 and pool.free_count == 2
    # all-or-nothing: asking for more than free allocates none
    assert pool.alloc(3) is None and pool.allocated_count == 2
    # refcount: shared block survives one release
    pool.retain([a[0]])
    assert pool.is_shared(a[0]) and pool.refcount(a[0]) == 2
    freed = pool.release(a)
    assert freed == [a[1]] and pool.refcount(a[0]) == 1
    freed = pool.release([a[0]])
    assert freed == [a[0]] and pool.free_count == 4
    assert pool.peak_allocated == 2
    # double-free / retain-of-free raise
    with pytest.raises(ValueError):
        pool.release([a[0]])
    with pytest.raises(ValueError):
        pool.retain([a[0]])


def _pool_invariant_case(seed, n_ops):
    """Random alloc/retain/release sequences keep the pool and a mirror
    refcount map in lockstep; free + allocated always covers the pool."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(8, 4)
    mirror = {b: 0 for b in range(8)}
    for _ in range(n_ops):
        live = [b for b, r in mirror.items() if r > 0]
        op = rng.integers(0, 3)
        if op == 0:
            n = int(rng.integers(1, 5))
            got = pool.alloc(n)
            n_free = sum(1 for r in mirror.values() if r == 0)
            if n > n_free:
                assert got is None
            else:
                assert got is not None and len(got) == n
                for b in got:
                    assert mirror[b] == 0
                    mirror[b] = 1
        elif op == 1 and live:
            b = live[rng.integers(len(live))]
            pool.retain([b])
            mirror[b] += 1
        elif op == 2 and live:
            b = live[rng.integers(len(live))]
            freed = pool.release([b])
            mirror[b] -= 1
            assert freed == ([b] if mirror[b] == 0 else [])
        assert all(pool.refcount(b) == r for b, r in mirror.items())
        assert pool.free_count + pool.allocated_count == 8
        assert pool.allocated_count == sum(r > 0 for r in mirror.values())


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_ops=st.integers(1, 60))
    def test_pool_invariants(seed, n_ops):
        _pool_invariant_case(seed, n_ops)
else:
    @pytest.mark.parametrize("seed,n_ops",
                             [(0, 10), (1, 60), (2, 33), (3, 47), (4, 5),
                              (5, 58)])
    def test_pool_invariants(seed, n_ops):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _pool_invariant_case(seed, n_ops)


# ===================================================================== #
# prefix trie
# ===================================================================== #
def test_prefix_trie_match_insert_evict():
    bs = 4
    pool = BlockPool(16, bs)
    pc = PrefixCache(bs)
    toks = np.arange(10, dtype=np.int32)          # 2 full blocks + tail
    table = pool.alloc(3)
    assert pc.match(toks) == []                   # cold: full miss
    added = pc.insert(toks, table, pool)
    assert added == 2 and len(pc) == 2            # tail block never cached
    assert pool.refcount(table[0]) == 2           # owner + cache
    # a second reader adopts the chain
    m = pc.match(toks)
    assert m == table[:2]
    # diverging block 2 matches only block 1's chain
    other = np.concatenate([toks[:4], np.asarray([99, 98, 97, 96, 5, 6],
                                                 np.int32)])
    assert pc.match(other) == table[:1]
    # same tokens under a different parent are a different node
    shifted = np.concatenate([np.asarray([7] * bs, np.int32), toks[:bs]])
    assert pc.match(shifted) == []
    # eviction: parent (block 0) is not a leaf, so only block 1 can go,
    # and only once the owner's reference is dropped
    assert pc.evict(2, pool) == 0                 # owner still holds refs
    pool.release(table)
    assert pc.evict(1, pool) == 1 and len(pc) == 1
    assert pool.refcount(table[1]) == 0
    assert pc.evict(5, pool) == 1 and len(pc) == 0
    assert pool.free_count == 16


def test_prefix_sharing_skips_prefill_compute():
    """Two requests with the same prompt: the second adopts the first's
    blocks (hit rate > 0), recomputes only the final token, and decodes
    to the same greedy continuation."""
    cfg = _smoke()
    Tp = 32                                       # 2 full 16-token blocks
    prompt = RNG.integers(0, cfg.vocab_size, Tp).astype(np.int32)
    # one block of headroom over the dense-equivalent default: the
    # fully-cached repeat holds its 2 matched blocks AND needs a fresh
    # tail block plus the COW spare
    eng = ServeEngine(cfg, num_slots=1, max_len=48, prefill_chunk=8,
                      num_blocks=4, seed=0)
    assert eng.layout == "paged"
    r0 = eng.submit(prompt, max_new=4)
    eng.run()
    assert eng.stats["prefill_chunk_tokens"] == Tp
    r1 = eng.submit(prompt.copy(), max_new=4)
    out = eng.run()
    # fully-cached prompt: only the last token is recomputed (its logits
    # seed sampling), landing in a shared block -> one COW
    assert eng.stats["prefill_chunk_tokens"] == Tp + 1
    assert eng.stats["prefill_cached_tokens"] == Tp
    assert eng.stats["cow_copies"] == 1
    assert eng.prefix.hit_rate() > 0
    assert np.array_equal(out[r0]["tokens"], out[r1]["tokens"])
    # the canonical cached chain survived the COW: a third reader still
    # matches and agrees
    r2 = eng.submit(prompt.copy(), max_new=4)
    out = eng.run()
    assert np.array_equal(out[r0]["tokens"], out[r2]["tokens"])
    assert eng.stats["cow_copies"] == 2


def test_cow_preserves_concurrent_reader():
    """A COW append while another live request still reads the shared
    block must not corrupt that reader: both requests decode as if they
    owned private caches (checked against a fresh engine)."""
    cfg = _smoke()
    Tp = 16                                       # exactly 1 full block
    prompt = RNG.integers(0, cfg.vocab_size, Tp).astype(np.int32)

    eng = ServeEngine(cfg, num_slots=2, max_len=32, prefill_chunk=8,
                      seed=0)
    ra = eng.submit(prompt, max_new=8)
    eng.run()
    # rb matches ra's cached block while ra's blocks are still cached;
    # its first write COWs the shared block
    rb = eng.submit(prompt.copy(), max_new=8)
    out = eng.run()
    assert eng.stats["cow_copies"] >= 1
    solo = ServeEngine(cfg, num_slots=1, max_len=32, prefill_chunk=8,
                       seed=0)
    rs = solo.submit(prompt, max_new=8)
    ref = solo.run()
    assert np.array_equal(out[ra]["tokens"], ref[rs]["tokens"])
    assert np.array_equal(out[rb]["tokens"], ref[rs]["tokens"])


# ===================================================================== #
# pool exhaustion -> admission backoff
# ===================================================================== #
def test_pool_exhaustion_backs_off_admission():
    """A pool too small for two concurrent requests serializes them via
    admission backoff (FIFO preserved, nothing rejected, greedy results
    identical to an unconstrained dense engine)."""
    cfg = _smoke()
    prompts = [RNG.integers(0, cfg.vocab_size, 20).astype(np.int32)
               for _ in range(3)]

    def drive(**kw):
        eng = ServeEngine(cfg, num_slots=2, max_len=48, prefill_chunk=8,
                          seed=0, **kw)
        for p in prompts:
            eng.submit(p, max_new=4)
        return eng, eng.run()

    # each request needs ceil((20+4-1)/16) = 2 blocks; 3 blocks cannot
    # hold two requests at once
    eng, out = drive(num_blocks=3, prefix_cache=False)
    ref_eng, ref = drive(kv_layout="dense")
    assert eng.stats["admission_backoffs"] > 0
    assert eng.pool.peak_allocated <= 3
    assert all(out[r]["status"] == "ok" for r in out)
    for r in out:
        assert np.array_equal(out[r]["tokens"], ref[r]["tokens"])


def test_undersized_pool_rejects_unplaceable_request():
    """A request whose block working set can never fit the pool is
    rejected (status="rejected", reason naming the pool) instead of
    killing the loop; requests queued behind it still complete."""
    cfg = _smoke()
    eng = ServeEngine(cfg, num_slots=2, max_len=64, prefill_chunk=8,
                      num_blocks=2, prefix_cache=False, seed=0)
    big = eng.submit(RNG.integers(0, cfg.vocab_size, 40).astype(np.int32),
                     max_new=8)                       # 3 blocks > 2-pool
    ok = eng.submit(RNG.integers(0, cfg.vocab_size, 10).astype(np.int32),
                    max_new=4)                        # 1 block
    out = eng.run()
    assert out[big]["status"] == "rejected"
    assert "pool" in out[big]["reason"]
    assert out[ok]["status"] == "ok" and len(out[ok]["tokens"]) == 4


def test_eviction_protects_matched_prefix_blocks():
    """Admission under pool pressure must not let the LRU sweep free
    blocks the incoming request still lists as matched (they are
    retained before eviction, and the match shrinks before any of its
    blocks may be evicted): the request backs off cleanly instead of
    aliasing its matched prefix with freshly-allocated copies of the
    same physical blocks — the old path died with a mid-run COW
    RuntimeError here — and completes correctly once the live request
    pinning the pool retires."""
    cfg = _smoke()
    P = RNG.integers(0, cfg.vocab_size, 32).astype(np.int32)
    Q = RNG.integers(0, cfg.vocab_size, 16).astype(np.int32)
    long_p = np.concatenate(
        [P, RNG.integers(0, cfg.vocab_size, 16).astype(np.int32)])
    eng = ServeEngine(cfg, num_slots=2, max_len=64, prefill_chunk=16,
                      num_blocks=6, seed=0)
    eng.submit(P, max_new=4)
    eng.run()                        # P's 2 full blocks stay prefix-cached
    # Q pins 3 pool blocks while it decodes; long_p then matches P's
    # chain but needs 2 fresh blocks with only 1 free — its eviction
    # sweep finds nothing unprotected and backs off
    rc = eng.submit(Q, max_new=20)
    rb = eng.submit(long_p, max_new=4)
    out = eng.run()
    assert eng.stats["admission_backoffs"] > 0
    assert out[rc]["status"] == "ok" and out[rb]["status"] == "ok"
    solo = ServeEngine(cfg, num_slots=1, max_len=64, prefill_chunk=16,
                       seed=0)
    rs = solo.submit(long_p.copy(), max_new=4)
    ref = solo.run()
    assert np.array_equal(out[rb]["tokens"], ref[rs]["tokens"])


# ===================================================================== #
# paged vs dense greedy parity on a ragged mix
# ===================================================================== #
def _parity_case(seed, lens):
    cfg = _smoke()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, l).astype(np.int32)
               for l in lens]

    def drive(**kw):
        eng = ServeEngine(cfg, num_slots=2, max_len=64, prefill_chunk=8,
                          seed=0, **kw)
        for p in prompts:
            eng.submit(p, max_new=5)
        return eng.run()

    paged = drive()
    dense = drive(kv_layout="dense")
    assert set(paged) == set(dense) == set(range(len(lens)))
    for r in paged:
        assert np.array_equal(paged[r]["tokens"], dense[r]["tokens"])


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           lens=st.lists(st.integers(3, 40), min_size=1, max_size=4))
    def test_paged_vs_dense_greedy_parity(seed, lens):
        _parity_case(seed, lens)
else:
    @pytest.mark.parametrize("seed,lens",
                             [(0, [12]), (1, [16, 32, 7]),
                              (2, [40, 3, 17, 24])])
    def test_paged_vs_dense_greedy_parity(seed, lens):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _parity_case(seed, lens)


# ===================================================================== #
# unified token-budget scheduling
# ===================================================================== #
def _ready_slot(sc, slot, rid, Tp):
    sc.submit(Request(rid=rid, tokens=np.arange(Tp, dtype=np.int32),
                      max_new=4))
    placed = sc.admit()
    sc.start(placed[-1][0], first_token=1)
    return placed[-1][0]


def test_token_budget_splits_prefill_and_decode():
    sc = Scheduler(3, 128, prefill_chunk=16, token_budget=10)
    _ready_slot(sc, 0, rid=0, Tp=4)               # decoding
    sc.submit(Request(rid=1, tokens=np.arange(60, dtype=np.int32),
                      max_new=4))
    sc.admit()
    # 10-token budget: 1 decode token first, 9 left for the prefill
    prefill, decode = sc.plan_step()
    assert decode == [0] and prefill == [(1, 0, 9)]
    sc.note_prefill(1, 9)
    prefill, decode = sc.plan_step()
    assert prefill == [(1, 9, 9)]
    sc.note_prefill(1, 9)
    # a second decoder shrinks the prefill share
    sc.record(np.asarray([5, 0, 0]), [0])
    _ready_slot(sc, 2, rid=2, Tp=4)
    prefill, decode = sc.plan_step()
    assert sorted(decode) == [0, 2] and prefill == [(1, 18, 8)]


def test_serial_mode_stalls_decodes_unified_does_not():
    """A long prompt admitted next to an in-flight decode: serial
    scheduling produces decode-stall steps, the unified budget none —
    and both yield identical greedy tokens."""
    cfg = _smoke()
    short = RNG.integers(0, cfg.vocab_size, 6).astype(np.int32)
    long_p = RNG.integers(0, cfg.vocab_size, 48).astype(np.int32)

    def drive(**kw):
        eng = ServeEngine(cfg, num_slots=2, max_len=64, prefill_chunk=8,
                          seed=0, **kw)
        eng.submit(short, max_new=12)
        eng.submit(long_p, max_new=4)
        return eng, eng.run()

    eu, ou = drive()
    es, os_ = drive(unified=False)
    assert eu.stats["stalled_decode_steps"] == 0
    assert es.stats["stalled_decode_steps"] > 0
    for r in ou:
        assert np.array_equal(ou[r]["tokens"], os_[r]["tokens"])


# ===================================================================== #
# per-request sampling determinism
# ===================================================================== #
def test_sampling_deterministic_per_rid():
    """Temperature>0 requests own independent key streams keyed by
    (engine seed, rid, n_generated): identical concurrent prompts must
    NOT share a stream, and any request must reproduce bit-for-bit
    across runs and batch compositions (same engine seed)."""
    cfg = _smoke()
    prompt = RNG.integers(0, cfg.vocab_size, 12).astype(np.int32)
    kw = dict(max_new=10, temperature=1.0)

    def drive(n_copies, seed=0):
        eng = ServeEngine(cfg, num_slots=2, max_len=32, prefill_chunk=8,
                          seed=seed)
        rids = [eng.submit(prompt.copy(), **kw) for _ in range(n_copies)]
        out = eng.run()
        return [out[r]["tokens"] for r in rids]

    a0, a1 = drive(2)
    # identical concurrent requests sample independently
    assert not np.array_equal(a0, a1)
    # same engine seed reproduces bit-for-bit
    b0, b1 = drive(2)
    assert np.array_equal(a0, b0) and np.array_equal(a1, b1)
    # rid 0 is invariant to what else shares the batch
    (c0,) = drive(1)
    assert np.array_equal(a0, c0)
    # a different engine seed moves the streams
    d0, _ = drive(2, seed=7)
    assert not np.array_equal(a0, d0)
