"""Optimizer substrate tests: AdamW reference parity, clipping, schedules,
gradient compression with error feedback (convergence property)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_tree, constant, ef_init, global_norm,
                         warmup_cosine, wire_bytes)


def _naive_adamw(p, g, m, v, t, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    return p - lr * (mh / (np.sqrt(vh) + eps) + wd * p), m, v


def test_adamw_matches_reference():
    rng = np.random.default_rng(0)
    p = {"a": jnp.asarray(rng.standard_normal((4, 3)).astype(np.float32)),
         "b": {"c": jnp.asarray(rng.standard_normal(7).astype(np.float32))}}
    state = adamw_init(p)
    ref = jax.tree.map(lambda x: np.asarray(x, np.float64), p)
    m = jax.tree.map(lambda x: np.zeros_like(np.asarray(x)), p)
    v = jax.tree.map(lambda x: np.zeros_like(np.asarray(x)), p)

    for t in range(1, 4):
        g = jax.tree.map(
            lambda x: jnp.asarray(
                rng.standard_normal(x.shape).astype(np.float32)), p)
        p, state = adamw_update(p, g, state, lr=1e-2, b1=0.9, b2=0.95,
                                eps=1e-8, weight_decay=0.1)
        flat_ref, td = jax.tree.flatten(ref)
        flat_g = td.flatten_up_to(g)
        flat_m = td.flatten_up_to(m)
        flat_v = td.flatten_up_to(v)
        out = [_naive_adamw(r, np.asarray(gg), mm, vv, t, 1e-2, 0.9, 0.95,
                            1e-8, 0.1)
               for r, gg, mm, vv in zip(flat_ref, flat_g, flat_m, flat_v)]
        ref = td.unflatten([o[0] for o in out])
        m = td.unflatten([o[1] for o in out])
        v = td.unflatten([o[2] for o in out])
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), b, atol=1e-5)


def test_clipping():
    g = {"w": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 3.0 * np.sqrt(10), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the limit: untouched
    clipped2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["w"]),
                               np.asarray(g["w"]), rtol=1e-6)


def test_schedules():
    lr = warmup_cosine(jnp.asarray(0), base_lr=1.0, warmup_steps=10,
                       total_steps=100)
    assert float(lr) == 0.0
    lr = warmup_cosine(jnp.asarray(10), base_lr=1.0, warmup_steps=10,
                       total_steps=100)
    np.testing.assert_allclose(float(lr), 1.0, rtol=1e-6)
    lr_end = warmup_cosine(jnp.asarray(100), base_lr=1.0, warmup_steps=10,
                           total_steps=100, min_ratio=0.1)
    np.testing.assert_allclose(float(lr_end), 0.1, rtol=1e-5)
    assert float(constant(5, base_lr=0.3)) == pytest.approx(0.3)


@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compression_with_error_feedback_converges(scheme):
    """EF-compressed gradient descent still reaches the optimum of a
    quadratic — the error-feedback accumulator bounds the bias."""
    A = jnp.asarray(np.diag(np.linspace(1.0, 3.0, 16)).astype(np.float32))
    target = jnp.asarray(np.linspace(-1, 1, 16).astype(np.float32))

    x = {"w": jnp.zeros(16)}
    ef = ef_init(x)
    for _ in range(300):
        g = {"w": A @ (x["w"] - target)}
        comp, ef = compress_tree(g, ef, scheme, topk_frac=0.25)
        x = {"w": x["w"] - 0.05 * comp["w"]}
    np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(target),
                               atol=0.05)


def test_compression_wire_bytes():
    p = {"w": jnp.zeros((1000,))}
    assert wire_bytes(p, "none") == 4000
    assert wire_bytes(p, "int8") == 1004
    assert wire_bytes(p, "topk", topk_frac=0.05) == 50 * 8


def test_no_compression_identity():
    g = {"w": jnp.arange(8.0)}
    ef = ef_init(g)
    out, ef2 = compress_tree(g, ef, "none")
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))
