"""Serving-engine tests: flash-decode partial/sharded parity, cache-writing
chunked prefill (zero decode steps, replay parity), continuous-batching
admit/retire, flash-vs-dense greedy parity, audio-frame prefill, sampling."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core.cp_attention import finalize_partial, merge_partials
from repro.kernels.flash_decode import (decode_reference, flash_decode,
                                        flash_decode_sharded)
from repro.models import (decode_step, forward, init_cache, init_params,
                          make_local_context, prefill_forward,
                          supports_cached_prefill)
from repro.serve import Request, Scheduler, ServeEngine
from repro.serve.sampling import apply_top_k, sample_tokens

RNG = np.random.default_rng(0)


def _qkv(B, Hq, Hkv, S, D, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, Hq, D)).astype(dtype))
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(dtype))
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(dtype))
    return q, k, v


# ===================================================================== #
# flash-decode partial mode + LSE merge
# ===================================================================== #
@pytest.mark.parametrize("B,Hq,Hkv,S,D,bk", [
    (2, 4, 2, 128, 16, 32),
    (1, 8, 1, 256, 32, 64),     # MQA (G = 8)
    (3, 4, 4, 64, 64, 16),      # MHA (G = 1)
])
def test_partial_mode_finalizes_to_reference(B, Hq, Hkv, S, D, bk):
    q, k, v = _qkv(B, Hq, Hkv, S, D)
    lengths = jnp.asarray(
        RNG.integers(0, S - 1, (B,)).astype(np.int32)).at[0].set(S - 1)
    part = flash_decode(q, k, v, lengths, block_k=bk, interpret=True,
                        partial=True)
    o, m, l = part
    assert o.shape == (B, Hq, D) and m.shape == l.shape == (B, Hq)
    out = finalize_partial(part, q.dtype)
    ref = decode_reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("lengths", [
    [127, 63],          # length == S-1 clamp boundary + mid
    [7, 0],             # length < block_k, and a single-token request
    [31, 32],           # exactly at a shard boundary
])
def test_sharded_merge_matches_reference(shards, lengths):
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 16
    q, k, v = _qkv(B, Hq, Hkv, S, D)
    ln = jnp.asarray(lengths, jnp.int32)
    out = flash_decode_sharded(q, k, v, ln, shards=shards, block_k=32,
                               interpret=True)
    ref = decode_reference(q, k, v, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_empty_shard_partial_is_merge_identity():
    """A shard with no visible KV (negative local length) must contribute
    nothing: merging it in cannot change the result."""
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 16
    q, k, v = _qkv(B, Hq, Hkv, S, D)
    ln = jnp.asarray([10, 40], jnp.int32)
    real = flash_decode(q, k, v, ln, block_k=16, interpret=True,
                        partial=True)
    empty = flash_decode(q, k, v, jnp.asarray([-1, -1], jnp.int32),
                         block_k=16, interpret=True, partial=True)
    o, m, l = empty
    assert np.all(np.asarray(o) == 0) and np.all(np.asarray(l) == 0)
    merged = finalize_partial(merge_partials([real, empty]), q.dtype)
    alone = finalize_partial(real, q.dtype)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(alone),
                               atol=1e-6, rtol=1e-6)


# ===================================================================== #
# cache-writing chunked prefill
# ===================================================================== #
def _smoke(arch):
    return reduce_for_smoke(get_config(arch))


def test_prefill_cache_matches_replay():
    """Chunked prefill must write the same KV cache as replaying the
    prompt through decode_step, and its last logits must match the
    teacher-forced forward."""
    cfg = _smoke("starcoder2_3b")
    B, Tp, S, C = 2, 12, 24, 4
    lens = [Tp, 9]
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, Tp))
                         .astype(np.int32))
    params = init_params(jax.random.PRNGKey(0), cfg)

    cache_r = init_cache(cfg, B, S)
    for t in range(Tp):
        _, cache_r = decode_step(params, cfg, cache_r,
                                 {"tokens": tokens[:, t]},
                                 jnp.full((B,), t, jnp.int32),
                                 attn_impl="dense")

    cache_p = init_cache(cfg, B, S)
    logits = None
    for c0 in range(0, Tp, C):
        pos = jnp.asarray(np.tile(np.arange(c0, c0 + C, dtype=np.int32),
                                  (B, 1)))
        active = jnp.asarray(np.stack(
            [np.arange(c0, c0 + C) < l for l in lens]))
        logits, cache_p = prefill_forward(
            params, cfg, cache_p, {"tokens": tokens[:, c0:c0 + C]}, pos,
            active)

    kr = np.asarray(jax.tree.leaves(cache_r)[0])
    kp = np.asarray(jax.tree.leaves(cache_p)[0])
    for b, l in enumerate(lens):
        np.testing.assert_allclose(kp[:, b, :, :l], kr[:, b, :, :l],
                                   atol=1e-5, rtol=1e-5)

    doc = jnp.zeros((B, Tp), jnp.int32)
    posf = jnp.asarray(np.tile(np.arange(Tp, dtype=np.int32), (B, 1)))
    ctx = make_local_context(doc, posf, q_chunk=8)
    ref_logits, _ = forward(params, cfg, ctx, {"tokens": tokens},
                            remat=False)
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(ref_logits[0, -1]),
                               atol=1e-4, rtol=1e-4)


def test_prefill_does_zero_decode_steps():
    """Regression for the seed prompt-replay bug: prefill cost must be
    chunk steps, never per-token decode steps, and the chunk-step count
    must be ceil(Tp / C) — independent of Tp in decode steps."""
    cfg = _smoke("starcoder2_3b")
    assert supports_cached_prefill(cfg)
    C = 8
    for Tp in (5, 16, 19):
        eng = ServeEngine(cfg, num_slots=1, max_len=Tp + 4,
                          prefill_chunk=C, seed=0)
        eng.submit(RNG.integers(0, cfg.vocab_size, Tp).astype(np.int32),
                   max_new=2)
        eng.run()
        assert eng.stats["prefill_decode_steps"] == 0
        assert eng.stats["prefill_steps"] == -(-Tp // C)


def test_moe_prefill_routes_drop_free():
    """Regression: chunked prefill must not capacity-clip MoE routing —
    the decode path routes one token per step and never drops, so a
    clipped prefill would write KV inconsistent with the decode-built
    cache.  Prefill (drop-free routing) must match replay exactly."""
    cfg = _smoke("olmoe_1b_7b")
    assert cfg.num_experts > 0 and supports_cached_prefill(cfg)
    B, Tp, S, C = 2, 12, 16, 4
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, Tp))
                         .astype(np.int32))
    params = init_params(jax.random.PRNGKey(0), cfg)

    cache_r = init_cache(cfg, B, S)
    for t in range(Tp):
        _, cache_r = decode_step(params, cfg, cache_r,
                                 {"tokens": tokens[:, t]},
                                 jnp.full((B,), t, jnp.int32),
                                 attn_impl="dense")

    cache_p = init_cache(cfg, B, S)
    for c0 in range(0, Tp, C):
        pos = jnp.asarray(np.tile(np.arange(c0, c0 + C, dtype=np.int32),
                                  (B, 1)))
        active = jnp.ones((B, C), bool)
        _, cache_p = prefill_forward(
            params, cfg, cache_p, {"tokens": tokens[:, c0:c0 + C]}, pos,
            active)

    kr = np.asarray(jax.tree.leaves(cache_r)[0])
    kp = np.asarray(jax.tree.leaves(cache_p)[0])
    np.testing.assert_allclose(kp[:, :, :, :Tp], kr[:, :, :, :Tp],
                               atol=1e-4, rtol=1e-4)


def test_recurrent_arch_falls_back_to_replay():
    cfg = _smoke("jamba_v0_1_52b")
    assert not supports_cached_prefill(cfg)
    eng = ServeEngine(cfg, num_slots=1, max_len=16, seed=0)
    eng.submit(RNG.integers(0, cfg.vocab_size, 6).astype(np.int32),
               max_new=2)
    out = eng.run()
    assert len(out) == 1 and len(out[0]["tokens"]) == 2
    assert eng.stats["prefill_decode_steps"] == 6
    assert eng.stats["prefill_steps"] == 0


# ===================================================================== #
# continuous batching end-to-end
# ===================================================================== #
def _run_engine(cfg, prompts, impl, *, slots=3, max_new=6, shards=1,
                **submit_kw):
    eng = ServeEngine(cfg, num_slots=slots, max_len=64, prefill_chunk=8,
                      decode_impl=impl, attn_shards=shards, seed=0)
    for p in prompts:
        eng.submit(p, max_new=max_new, **submit_kw)
    return eng, eng.run()


def test_serving_smoke_admit_retire_and_flash_dense_parity():
    """More requests than slots: slots retire mid-flight and re-admit;
    greedy outputs must be identical under flash and dense decode (and
    under a 2-way LSE-sharded cache)."""
    cfg = _smoke("starcoder2_3b")
    prompts = [RNG.integers(0, cfg.vocab_size, l).astype(np.int32)
               for l in (12, 7, 19, 5, 15)]
    ef, of = _run_engine(cfg, prompts, "flash")
    ed, od = _run_engine(cfg, prompts, "dense")
    es, osh = _run_engine(cfg, prompts, "flash", shards=2)
    assert set(of) == set(od) == set(osh) == set(range(5))
    for r in of:
        assert np.array_equal(of[r]["tokens"], od[r]["tokens"])
        assert np.array_equal(of[r]["tokens"], osh[r]["tokens"])
    # all slots were reused: 5 requests through 3 slots
    assert ef.stats["admitted"] == ef.stats["retired"] == 5


def test_engine_greedy_matches_full_recompute():
    """The cache path (prefill + incremental decode) reproduces naive
    greedy generation that re-runs the full forward every token."""
    cfg = _smoke("starcoder2_3b")
    prompt = RNG.integers(0, cfg.vocab_size, 10).astype(np.int32)
    n_new = 5
    eng, out = _run_engine(cfg, [prompt], "flash", slots=1, max_new=n_new)
    params = eng.params

    toks = list(prompt)
    ref = []
    for _ in range(n_new):
        T = len(toks)
        doc = jnp.zeros((1, T), jnp.int32)
        pos = jnp.asarray(np.arange(T, dtype=np.int32)[None])
        ctx = make_local_context(doc, pos, q_chunk=8)
        lg, _ = forward(params, cfg, ctx,
                        {"tokens": jnp.asarray(
                            np.asarray(toks, np.int32)[None])},
                        remat=False)
        t = int(np.argmax(np.asarray(lg[0, -1])))
        ref.append(t)
        toks.append(t)
    assert np.array_equal(out[0]["tokens"], np.asarray(ref, np.int32))


def test_eos_retires_early():
    cfg = _smoke("starcoder2_3b")
    prompt = RNG.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng, base = _run_engine(cfg, [prompt], "flash", slots=1, max_new=8)
    gen = base[0]["tokens"]
    eos = int(gen[2])
    eng2, out = _run_engine(cfg, [prompt], "flash", slots=1, max_new=8,
                            eos_id=eos)
    assert out[0]["tokens"][-1] == eos
    assert len(out[0]["tokens"]) <= 3


def test_audio_prompt_frames_reach_the_cache():
    """Regression for the seed zero-frames replay bug: prefilling with
    the request's real frames must change the generation vs zero frames
    — i.e. the frames actually land in the KV cache."""
    cfg = _smoke("musicgen_medium")
    assert cfg.frontend == "audio_frames" and supports_cached_prefill(cfg)
    Tp = 10
    tokens = RNG.integers(0, cfg.vocab_size, Tp).astype(np.int32)
    frames = RNG.standard_normal((Tp, cfg.d_model)).astype(np.float32) * 3

    def gen(fr):
        eng = ServeEngine(cfg, num_slots=1, max_len=24, prefill_chunk=4,
                          seed=0)
        eng.submit(tokens, max_new=4, frames=fr)
        out = eng.run()
        return out[0]["tokens"], eng

    real, eng_r = gen(frames)
    zero, _ = gen(np.zeros_like(frames))
    assert eng_r.stats["prefill_decode_steps"] == 0
    assert not np.array_equal(real, zero), \
        "real prompt frames did not influence the cache"


def test_throughput_accounting_separates_prefill_and_decode():
    """The prefill-produced first token counts as prefill output; decode
    counters cover decode steps only."""
    cfg = _smoke("starcoder2_3b")
    prompt = RNG.integers(0, cfg.vocab_size, 9).astype(np.int32)
    eng, out = _run_engine(cfg, [prompt], "flash", slots=1, max_new=4)
    s = eng.stats
    assert s["prefill_tokens"] == 9
    # 4 generated tokens: 1 from prefill logits + 3 decode steps
    assert len(out[0]["tokens"]) == 4
    assert s["decode_steps"] == 3 and s["decode_tokens"] == 3
    assert s["prefill_s"] > 0 and s["decode_s"] > 0


# ===================================================================== #
# scheduler + sampling units
# ===================================================================== #
def test_scheduler_slot_lifecycle():
    sc = Scheduler(2, 32)
    for rid in range(3):
        sc.submit(Request(rid=rid, tokens=np.arange(4, dtype=np.int32),
                          max_new=2))
    placed = sc.admit()
    assert [s for s, _ in placed] == [0, 1] and len(sc.queue) == 1
    for s, _ in placed:
        sc.start(s, first_token=7)
    assert sc.lengths().tolist() == [4, 4]
    retired = sc.record(np.asarray([5, 6]))   # 2nd token -> both done
    assert retired == [0, 1] and sc.slots == [None, None]
    # the third request takes a freed slot
    placed2 = sc.admit()
    assert [s for s, _ in placed2] == [0] and placed2[0][1].rid == 2
    assert sc.admit() == []
    assert sc.finished[0]["tokens"].tolist() == [7, 5]


def test_scheduler_rejects_oversized_request():
    """Oversized requests surface as status="rejected" entries in the
    results dict instead of raising — one bad request must not kill the
    engine loop or the batch it arrived with."""
    sc = Scheduler(1, 8)
    ok = sc.submit(Request(rid=0, tokens=np.zeros(6, np.int32), max_new=4))
    assert ok is False and not sc.queue
    rej = sc.finished[0]
    assert rej["status"] == "rejected" and len(rej["tokens"]) == 0
    assert "max_len" in rej["reason"]
    # each validation failure names its own cause
    assert sc.submit(Request(rid=1, tokens=np.zeros(0, np.int32),
                             max_new=4)) is False
    assert sc.finished[1]["reason"] == "empty prompt"
    assert sc.submit(Request(rid=2, tokens=np.zeros(3, np.int32),
                             max_new=0)) is False
    assert "max_new" in sc.finished[2]["reason"]
    assert "max_len" not in sc.finished[2]["reason"]
    # end-to-end: the rejected request rides the results dict alongside
    # the completed one
    cfg = _smoke("starcoder2_3b")
    eng = ServeEngine(cfg, num_slots=1, max_len=16, prefill_chunk=8,
                      seed=0)
    good = eng.submit(RNG.integers(0, cfg.vocab_size, 6).astype(np.int32),
                      max_new=2)
    bad = eng.submit(RNG.integers(0, cfg.vocab_size, 30).astype(np.int32),
                     max_new=4)
    out = eng.run()
    assert out[bad]["status"] == "rejected"
    assert out[good]["status"] == "ok" and len(out[good]["tokens"]) == 2


def test_sampling_greedy_and_top_k():
    rng = jax.random.PRNGKey(0)
    logits = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((4, 32)).astype(np.float32))
    # temperature 0 rows are bitwise argmax
    t0 = sample_tokens(rng, logits, jnp.zeros((4,)), jnp.zeros((4,),
                                                               jnp.int32))
    assert np.array_equal(np.asarray(t0), np.asarray(logits.argmax(-1)))
    # top-k masks everything outside each row's k best
    masked = apply_top_k(logits, jnp.asarray([3, 1, 0, 32], jnp.int32))
    a = np.asarray(masked)
    assert (np.isfinite(a[0]).sum() == 3 and np.isfinite(a[1]).sum() == 1
            and np.isfinite(a[2]).sum() == 32
            and np.isfinite(a[3]).sum() == 32)
    # k=1 sampling at any temperature is argmax
    t1 = sample_tokens(rng, logits, jnp.full((4,), 2.0),
                       jnp.ones((4,), jnp.int32))
    assert np.array_equal(np.asarray(t1), np.asarray(logits.argmax(-1)))
    # sampled tokens stay inside the top-k support
    tk = sample_tokens(rng, logits, jnp.full((4,), 1.0),
                       jnp.full((4,), 5, jnp.int32))
    for b in range(4):
        top5 = set(np.asarray(jnp.argsort(logits[b])[-5:]).tolist())
        assert int(tk[b]) in top5
