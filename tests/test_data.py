"""Data pipeline: distribution shapes, packing exactness, determinism,
label masking, prefetcher."""

import numpy as np
import pytest

from repro.data.distributions import DATASETS, make_rng, sample_doc_length
from repro.data.packing import doc_ids_and_positions, pack_sequence
from repro.data.pipeline import PipelineConfig, Prefetcher, make_batch


@pytest.mark.parametrize("dataset", sorted(DATASETS))
def test_pack_exact(dataset):
    rng = make_rng(0)
    for _ in range(5):
        lens = pack_sequence(dataset, 32768, rng)
        assert lens.sum() == 32768
        assert (lens > 0).all()


def test_wlb_is_more_skewed_than_pile():
    rng = make_rng(1)
    w = [sample_doc_length("wlb_llm", rng) for _ in range(3000)]
    p = [sample_doc_length("pile", rng) for _ in range(3000)]
    assert np.percentile(w, 99) > 3 * np.percentile(p, 99)


def test_doc_ids_and_positions():
    doc, pos = doc_ids_and_positions(np.asarray([3, 2]))
    assert doc.tolist() == [0, 0, 0, 1, 1]
    assert pos.tolist() == [0, 1, 2, 0, 1]


def _cfg(**kw):
    base = dict(dataset="pile", context_len=2048, batch_per_host=2,
                cp_size=4, strategy="flashcp", vocab_size=1000, seed=7,
                align=16)
    base.update(kw)
    return PipelineConfig(**base)


def test_batch_determinism():
    b1 = make_batch(_cfg(), step=3)
    b2 = make_batch(_cfg(), step=3)
    for k in ("tokens", "labels", "doc", "pos", "send_idx"):
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = make_batch(_cfg(), step=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # different dp ranks get different data
    b4 = make_batch(_cfg(), step=3, dp_rank=1)
    assert not np.array_equal(b1["tokens"], b4["tokens"])


def test_labels_are_next_tokens_with_doc_final_masked():
    batch = make_batch(_cfg(), step=0)
    tokens, labels = batch["tokens"], batch["labels"]
    doc, pos, perm = batch["doc"], batch["pos"], batch["perm"]
    for b in range(tokens.shape[0]):
        valid = perm[b] >= 0
        # rebuild packed order
        order = np.argsort(perm[b][valid])
        tp = tokens[b][valid][order]
        lp = labels[b][valid][order]
        dp = doc[b][valid][order]
        for t in range(len(tp) - 1):
            if dp[t] == dp[t + 1]:
                assert lp[t] == tp[t + 1]
            else:
                assert lp[t] == -1
        assert lp[-1] == -1


def test_strategies_produce_batches():
    for strategy in ("flashcp", "llama3", "per_doc", "contiguous"):
        b = make_batch(_cfg(strategy=strategy), step=0)
        assert b["tokens"].shape == b["labels"].shape
        assert b["stats"]["imbalance"] >= 1.0


def test_prefetcher():
    pf = Prefetcher(_cfg(), start_step=0, prefetch=2)
    b0 = next(pf)
    b1 = next(pf)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    ref = make_batch(_cfg(), step=0)
    np.testing.assert_array_equal(b0["tokens"], ref["tokens"])
    pf.close()
