"""Elastic degree-replanning recovery (DESIGN.md §Recovery): fail-spec
parsing, surviving-topology replanning, speed-weighted balancing, the
straggler monitor's host EMAs, gradient-accumulation parity, and the
supervisor's shrink flow."""


import numpy as np
import pytest

from repro.dispatch import (DispatchConfig, dispatch_step,
                            effective_imbalance, imbalance, lpt_assign,
                            pack_pool)
from repro.runtime import (ElasticSupervisor, FailureAction, FailureInjector,
                           FailurePolicy, HostTopology, StragglerMonitor,
                           TrainingFailure, parse_fail_spec,
                           parse_straggle_specs, replan_after_failure)


# --------------------------------------------------------------------- #
# injection-spec parsing
# --------------------------------------------------------------------- #
def test_parse_fail_spec():
    assert parse_fail_spec(None) == (-1, [])
    assert parse_fail_spec("") == (-1, [])
    assert parse_fail_spec(-1) == (-1, [])
    assert parse_fail_spec(7) == (7, [])            # legacy int callers
    assert parse_fail_spec("12") == (12, [])
    assert parse_fail_spec("12:3") == (12, [3])
    assert parse_fail_spec("12:1,3") == (12, [1, 3])
    with pytest.raises(ValueError):
        parse_fail_spec("twelve")


def test_parse_straggle_specs():
    assert parse_straggle_specs(None) == {}
    assert parse_straggle_specs(["2:2.0", "0:1.5"]) == {2: 2.0, 0: 1.5}
    with pytest.raises(ValueError):
        parse_straggle_specs(["3"])                 # missing factor
    with pytest.raises(ValueError):
        parse_straggle_specs(["3:0.5"])             # speedups not allowed


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_step=4, fail_hosts=[1])
    inj.maybe_fail(3)
    with pytest.raises(TrainingFailure) as ei:
        inj.maybe_fail(4)
    assert ei.value.failed_hosts == [1]
    inj.maybe_fail(4)                               # replay passes


# --------------------------------------------------------------------- #
# surviving topology
# --------------------------------------------------------------------- #
def test_host_topology():
    topo = HostTopology(num_hosts=4, devices_per_host=2)
    assert topo.num_devices == 8
    assert topo.host_of_device(5) == 2
    assert topo.surviving_hosts({1, 3}) == [0, 2]
    assert topo.surviving_devices({1, 3}) == [0, 1, 4, 5]


def test_replan_after_failure_shrinks_data_axis():
    topo = HostTopology(num_hosts=4, devices_per_host=2)
    plan = replan_after_failure(topo, {3}, data=2, model=4)
    # 6 survivors, model axis kept at 4 -> data shrinks to 1, and the
    # global batch is preserved via 2x gradient accumulation
    assert (plan.data_axis, plan.model_axis) == (1, 4)
    assert plan.devices == [0, 1, 2, 3]             # contiguous prefix
    assert plan.surviving_hosts == [0, 1, 2]
    assert plan.accum_factor == 2
    assert plan.n_devices == 4


def test_replan_infeasible_raises():
    topo = HostTopology(num_hosts=4, devices_per_host=2)
    with pytest.raises(ValueError):
        replan_after_failure(topo, {1, 2, 3}, data=2, model=4)


# --------------------------------------------------------------------- #
# speed-weighted balancing primitives
# --------------------------------------------------------------------- #
def test_lpt_speeds_none_matches_uniform_speeds():
    rng = np.random.default_rng(0)
    for _ in range(10):
        w = rng.integers(1, 1000, size=24).astype(float)
        classic = lpt_assign(w, 4)
        uniform = lpt_assign(w, 4, speeds=np.ones(4))
        np.testing.assert_array_equal(classic, uniform)


def test_weighted_lpt_beats_unweighted_under_slow_group():
    """Capacity-proportional LPT: with one group at half speed, the
    effective (completion-time) imbalance must beat plain LPT's — and
    meet the paper-grade <=1.1 bound on a fine-grained pool."""
    rng = np.random.default_rng(1)
    speeds = np.asarray([1.0, 1.0, 1.0, 0.5])
    for trial in range(5):
        w = np.clip(rng.lognormal(8.0, 1.0, size=96), 64, 1e5)
        plain = np.bincount(lpt_assign(w, 4), weights=w, minlength=4)
        wtd = np.bincount(lpt_assign(w, 4, speeds=speeds), weights=w,
                          minlength=4)
        eff_plain = effective_imbalance(plain, speeds)
        eff_wtd = effective_imbalance(wtd, speeds)
        assert eff_wtd < eff_plain
        assert eff_plain >= 1.4                     # slow group binds
        assert eff_wtd <= 1.1                       # ...until weighted
        # the slow group really holds ~half a fast group's load
        assert wtd[3] < 0.7 * wtd[:3].mean()


def test_lpt_per_group_cardinality_with_speeds():
    w = np.arange(1, 13).astype(float)
    assign = lpt_assign(w, 4, per_group=3,
                        speeds=np.asarray([1.0, 1.0, 0.5, 1.0]))
    assert np.bincount(assign, minlength=4).tolist() == [3, 3, 3, 3]


def test_pack_pool_targets_default_is_legacy():
    rng = np.random.default_rng(2)
    lens = rng.integers(32, 2048, size=40)
    a = pack_pool(lens, 8, 2048, quantum=16)
    b = pack_pool(lens, 8, 2048, quantum=16,
                  targets=np.full(8, 2048, np.int64))
    for ba, bb in zip(a.bins, b.bins):
        np.testing.assert_array_equal(ba, bb)
    assert a.truncated_tokens == b.truncated_tokens


def test_pack_pool_targets_shape_bins():
    """Halved-target bins end up ~half as full; fills never exceed the
    target (clipped to capacity)."""
    rng = np.random.default_rng(3)
    lens = rng.integers(16, 256, size=64)
    targets = np.asarray([1024, 1024, 512, 512], np.int64)
    packed = pack_pool(lens, 4, 1024, quantum=16, targets=targets)
    fills = packed.bin_tokens
    assert (fills <= targets).all()
    assert fills[2:].mean() < 0.75 * fills[:2].mean()
    # conservation: placed + truncated == pool total
    assert int(fills.sum()) + packed.truncated_tokens == int(lens.sum())


def test_effective_imbalance():
    loads = np.asarray([100.0, 100.0])
    assert effective_imbalance(loads) == imbalance(loads) == 1.0
    # equal loads, one group at half speed -> its completion is 2x the
    # fast one's, max/mean = 2/1.5
    assert effective_imbalance(loads, np.asarray([1.0, 0.5])) == \
        pytest.approx(2.0 / 1.5)
    with pytest.raises(AssertionError):
        effective_imbalance(loads, np.asarray([1.0, 0.0]))


# --------------------------------------------------------------------- #
# straggler monitor: host EMAs -> speeds -> dispatcher
# --------------------------------------------------------------------- #
def test_monitor_host_speeds():
    mon = StragglerMonitor()
    for _ in range(12):
        for h in range(4):
            mon.record_host_step(h, 2.0 if h == 3 else 1.0)
    speeds = mon.host_speeds(range(4))
    np.testing.assert_allclose(speeds[:3], 1.0)
    assert speeds[3] == pytest.approx(0.5, abs=0.02)
    # unobserved hosts are assumed healthy
    assert mon.host_speeds([0, 7])[1] == 1.0


def test_monitor_slow_hosts_need_patience():
    mon = StragglerMonitor(slow_speed=0.6, slow_patience=3)
    for i in range(6):
        mon.record_host_step(0, 1.0)
        mon.record_host_step(1, 4.0)
        if i < 2:
            assert mon.slow_hosts() == []
    assert mon.slow_hosts() == [1]
    assert mon.slow_hosts([0]) == []


def test_dispatch_step_uses_device_speeds():
    from repro.data.distributions import make_rng
    from repro.data.packing import sample_doc_pool

    D, M, seqs, C = 4, 2, 16, 2048
    pool = sample_doc_pool("wlb_llm", seqs * C, make_rng(7),
                           max_doc_len=C, min_docs=seqs)
    dcfg = DispatchConfig(data=D, model=M, seqs=seqs, quantum=16)
    dev_speeds = np.repeat([1.0, 1.0, 1.0, 0.5], 2)

    plain = dispatch_step(pool, dcfg, C)
    wtd = dispatch_step(pool, dcfg, C, device_speeds=dev_speeds)
    assert plain.group_speeds is None
    assert wtd.group_speeds is not None

    # judge both placements under the true speeds: the weighted plan's
    # completion-time imbalance must improve on the blind one
    def eff(plan):
        gs = dev_speeds[:plan.n_groups * plan.cp_degree].reshape(
            plan.n_groups, plan.cp_degree).min(axis=1)
        return effective_imbalance(plan.group_workload, gs / gs.max())

    assert eff(wtd) < eff(plain)
    st = wtd.stats()
    assert "work_imbalance_raw" in st and "group_speeds" in st


def test_dispatch_batch_replay_is_deterministic():
    """The dispatch stream is a pure function of (seed, step): replaying
    a step after recovery yields bit-identical tokens/labels/plans, and
    speed weighting never changes token *content* (only placement)."""
    from repro.data.pipeline import PipelineConfig, make_dispatch_batch

    pipe = PipelineConfig(dataset="wlb_llm", context_len=512,
                          batch_per_host=8, cp_size=4, strategy="flashcp",
                          seed=3, align=16)
    dcfg = DispatchConfig(data=2, model=4, seqs=8, quantum=16)
    a = make_dispatch_batch(pipe, dcfg, step=5)
    b = make_dispatch_batch(pipe, dcfg, step=5)
    for k in ("tokens", "labels", "seq_tokens", "group_id", "doc", "pos"):
        np.testing.assert_array_equal(a[k], b[k])

    # content invariance under speeds: same multiset of (row tokens)
    c = make_dispatch_batch(pipe, dcfg, step=5,
                            device_speeds=np.repeat([1.0, 0.5], 4))
    assert sorted(int(t) for t in a["seq_tokens"]) != [] and \
        int(a["tokens"].clip(min=0).sum()) > 0
    assert a["tokens"].shape == c["tokens"].shape


# --------------------------------------------------------------------- #
# gradient accumulation parity
# --------------------------------------------------------------------- #
def test_accum_step_matches_fused():
    """accum=2 token-weighted accumulation equals the fused step (same
    batch, same params) — the property that makes the post-shrink
    trajectory land on the oracle's."""
    import jax

    from repro.compat import set_mesh
    from repro.configs import RunConfig, get_config, reduce_for_smoke
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import PipelineConfig, make_batch
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step
    from repro.launch.train import device_put_batch
    from repro.models import init_params
    from repro.optim import adamw_init

    cfg = reduce_for_smoke(get_config("starcoder2_3b"))
    run = RunConfig(arch="starcoder2_3b", cp_strategy="flashcp",
                    total_steps=4, warmup_steps=1, remat=False)
    shape = ShapeConfig("t", 128, 2, "train")
    mesh = make_local_mesh(1, 1)
    pipe = PipelineConfig(dataset="wlb_llm", context_len=128,
                          batch_per_host=2, cp_size=1, strategy="flashcp",
                          vocab_size=cfg.vocab_size, seed=0, align=1)
    batch = make_batch(pipe, 0)

    outs = {}
    with set_mesh(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        for accum in (1, 2):
            bundle = build_train_step(cfg, mesh, run, shape, q_chunk=64,
                                      accum=accum)
            db = device_put_batch(batch, bundle.in_shardings[2])
            db = {k: v for k, v in db.items()
                  if k in bundle.abstract_inputs[2]}
            fn = jax.jit(bundle.fn)
            p, _, metrics = fn(params, opt, db,
                               jax.numpy.asarray(0, jax.numpy.int32))
            outs[accum] = (p, metrics)

    (p1, m1), (p2, m2) = outs[1], outs[2]
    assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=1e-5)
    assert int(m1["tokens"]) == int(m2["tokens"])
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# --------------------------------------------------------------------- #
# supervisor flow
# --------------------------------------------------------------------- #
def _supervised_run(fail_step, fail_hosts, *, num_hosts=4, dph=2,
                    data=2, model=4, min_hosts=2, ckpt_step=3, steps=8):
    topo = HostTopology(num_hosts=num_hosts, devices_per_host=dph)
    sup = ElasticSupervisor(topo, FailurePolicy(min_hosts=min_hosts),
                            data=data, model=model, logger=lambda *_: None)
    inj = FailureInjector(fail_step, fail_hosts)
    ran, restores = [], []

    def step(s):
        inj.maybe_fail(s)
        ran.append(s)

    def on_restore(action, plan):
        restores.append((action, plan))
        return ckpt_step

    final = sup.run(step, start_step=0, total_steps=steps,
                    on_restore=on_restore)
    return sup, final, ran, restores


def test_supervisor_restart_flow():
    sup, final, ran, restores = _supervised_run(5, [])
    assert final == 8
    assert ran == [0, 1, 2, 3, 4, 3, 4, 5, 6, 7]    # replay from ckpt
    (action, plan), = restores
    assert action == FailureAction.RESTART and plan is None
    assert sup.plan is None and sup.current_axes() == (2, 4)


def test_supervisor_shrink_flow():
    sup, final, ran, restores = _supervised_run(5, [3])
    assert final == 8
    (action, plan), = restores
    assert action == FailureAction.ELASTIC_SHRINK
    assert (plan.data_axis, plan.model_axis) == (1, 4)
    assert plan.devices == [0, 1, 2, 3]
    assert sup.dead == {3}
    assert sup.alive_hosts == 3
    assert sup.current_axes() == (1, 4)


def test_supervisor_aborts_below_min_hosts():
    with pytest.raises(TrainingFailure):
        _supervised_run(5, [1, 2, 3], min_hosts=2)


def test_supervisor_infeasible_shrink_reraises():
    # survivors (1 host x 2 devices) cannot hold the model axis of 4
    with pytest.raises(TrainingFailure):
        _supervised_run(5, [1, 2, 3], min_hosts=1)


def test_supervisor_device_speeds_follow_survivors():
    topo = HostTopology(num_hosts=4, devices_per_host=2)
    mon = StragglerMonitor()
    sup = ElasticSupervisor(topo, FailurePolicy(min_hosts=1),
                            data=2, model=4, monitor=mon,
                            logger=lambda *_: None)
    for _ in range(8):
        for h in range(4):
            mon.record_host_step(h, 2.0 if h == 2 else 1.0)
    speeds = sup.device_speeds()
    assert speeds.shape == (8,)
    assert speeds[4] == pytest.approx(speeds[5])
    assert speeds[4] < 0.6                          # host 2's devices

    # after losing host 2 the renumbered grid is all-fast
    inj = FailureInjector(1, [2])

    def step(s):
        inj.maybe_fail(s)

    sup.run(step, start_step=0, total_steps=2,
            on_restore=lambda a, p: 1)
    speeds = sup.device_speeds()
    assert speeds.shape == (4,)                     # 1x4 shrunk grid
    np.testing.assert_allclose(speeds, 1.0)


def test_run_with_recovery_tracks_cumulative_dead():
    """Satellite fix: run_with_recovery judges the policy against the
    real survivor count, accumulated across failures."""
    from repro.runtime import run_with_recovery

    calls = {"n": 0}

    def step(s):
        if s == 2 and calls["n"] == 0:
            calls["n"] += 1
            raise TrainingFailure("lost 0", failed_hosts=[0])
        if s == 4 and calls["n"] == 1:
            calls["n"] += 1
            raise TrainingFailure("lost 1", failed_hosts=[1])

    # 4 hosts, min 3: first loss leaves 3 (shrink), second leaves 2
    # (abort) — under the old constant-alive bug the second loss would
    # also have been granted
    with pytest.raises(TrainingFailure, match="lost 1"):
        run_with_recovery(step, start_step=0, total_steps=8,
                          policy=FailurePolicy(min_hosts=3),
                          on_restore=lambda a, f: 2, num_hosts=4,
                          logger=lambda *_: None)
