"""Docs integrity: DESIGN.md section references in src/ must resolve.

Runs the same check as ``scripts/check_design_refs.py`` (CI tier-1), so
a dangling ``DESIGN.md §<id>`` citation fails the repo's own gate too.
"""

import importlib.util
import os

HERE = os.path.dirname(os.path.abspath(__file__))
SCRIPT = os.path.join(HERE, "..", "scripts", "check_design_refs.py")


def _load():
    spec = importlib.util.spec_from_file_location("check_design_refs",
                                                  SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_design_refs_resolve():
    mod = _load()
    dangling, anchors, refs = mod.check()
    assert not dangling, (
        f"dangling DESIGN.md references: {dangling}; "
        f"available headings: {sorted(anchors)}")
    # the contract is meaningful only if both sides are non-empty
    assert anchors, "DESIGN.md has no §-headings"
    assert refs, "src/ cites no DESIGN.md sections"
    # the historically-cited sections stay present
    for sec in ("4", "5", "8", "Arch-applicability", "Dispatch"):
        assert sec in anchors, f"DESIGN.md lost §{sec}"
