"""Pallas kernel validation: interpret-mode allclose vs the jnp oracle
across shape/dtype sweeps (fwd, dq, dkv), plus block-table soundness
properties (hypothesis)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis", reason="property tests need the hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from repro.kernels.doc_attention import build_block_tables
from repro.kernels.ops import doc_attention_xla, doc_flash_attention
from repro.kernels.ref import doc_mask, mha_reference

RNG = np.random.default_rng(0)


def _layout(B, Tq, Tk, n_docs, *, q_pad=0, kv_pad=0, seed=0):
    rng = np.random.default_rng(seed)
    kv_doc = np.sort(rng.integers(0, n_docs, (B, Tk)).astype(np.int32), 1)
    kv_pos = np.zeros_like(kv_doc)
    for b in range(B):
        for d in np.unique(kv_doc[b]):
            m = kv_doc[b] == d
            kv_pos[b, m] = np.arange(m.sum())
    idx = np.sort(rng.choice(Tk, Tq, replace=False))
    q_doc, q_pos = kv_doc[:, idx].copy(), kv_pos[:, idx].copy()
    if q_pad:
        q_doc[:, -q_pad:] = -1
    if kv_pad:
        kv_doc[:, -kv_pad:] = -1
    return q_doc, q_pos, kv_doc, kv_pos


def _tensors(B, Hq, Hkv, Tq, Tk, D, dtype, seed=1):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, Hq, Tq, D)).astype(dtype)
    k = rng.standard_normal((B, Hkv, Tk, D)).astype(dtype)
    v = rng.standard_normal((B, Hkv, Tk, D)).astype(dtype)
    return map(jnp.asarray, (q, k, v))


CASES = [
    # B, Hq, Hkv, Tq, Tk, D, bq, bk, docs, dtype, tol
    (2, 4, 2, 64, 128, 16, 16, 16, 4, np.float32, 2e-5),
    (1, 6, 1, 96, 96, 32, 16, 32, 3, np.float32, 2e-5),   # MQA, rect blocks
    (2, 2, 2, 64, 64, 8, 32, 16, 5, np.float32, 2e-5),
    (1, 4, 4, 64, 128, 64, 64, 64, 2, np.float32, 2e-5),
    (2, 4, 2, 64, 128, 16, 16, 16, 4, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,D,bq,bk,docs,dtype,tol", CASES)
def test_fwd_matches_oracle(B, Hq, Hkv, Tq, Tk, D, bq, bk, docs, dtype, tol):
    qd, qp, kd, kp = _layout(B, Tq, Tk, docs, q_pad=3, kv_pad=5)
    q, k, v = _tensors(B, Hq, Hkv, Tq, Tk, D, dtype)
    tabs = build_block_tables(qd, qp, kd, kp, block_q=bq, block_k=bk)
    ref = mha_reference(q, k, v, *map(jnp.asarray, (qd, qp, kd, kp)))
    out = doc_flash_attention(q, k, v, *map(jnp.asarray, (qd, qp, kd, kp)),
                              tabs, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,D,bq,bk,docs,dtype,tol",
                         CASES[:3])
def test_bwd_matches_oracle(B, Hq, Hkv, Tq, Tk, D, bq, bk, docs, dtype, tol):
    qd, qp, kd, kp = _layout(B, Tq, Tk, docs, q_pad=2)
    q, k, v = _tensors(B, Hq, Hkv, Tq, Tk, D, dtype)
    tabs = build_block_tables(qd, qp, kd, kp, block_q=bq, block_k=bk)
    jqd, jqp, jkd, jkp = map(jnp.asarray, (qd, qp, kd, kp))

    g_pl = jax.grad(lambda *a: jnp.sum(doc_flash_attention(
        *a, jqd, jqp, jkd, jkp, tabs, interpret=True) ** 2), (0, 1, 2))(
            q, k, v)
    g_rf = jax.grad(lambda *a: jnp.sum(mha_reference(
        *a, jqd, jqp, jkd, jkp) ** 2), (0, 1, 2))(q, k, v)
    for a, b, nm in zip(g_pl, g_rf, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=25 * tol, rtol=25 * tol,
                                   err_msg=f"d{nm}")


def test_xla_path_matches_oracle():
    qd, qp, kd, kp = _layout(2, 64, 128, 4, kv_pad=7)
    q, k, v = _tensors(2, 4, 2, 64, 128, 16, np.float32)
    ref = mha_reference(q, k, v, *map(jnp.asarray, (qd, qp, kd, kp)))
    for chunk in (16, 64, 999):
        out = doc_attention_xla(q, k, v, *map(jnp.asarray,
                                              (qd, qp, kd, kp)),
                                q_chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_empty_rows_produce_zeros():
    """Fully-padded queries must output exactly zero (not NaN)."""
    qd, qp, kd, kp = _layout(1, 32, 32, 2)
    qd[:, :] = -1
    q, k, v = _tensors(1, 2, 2, 32, 32, 8, np.float32)
    tabs = build_block_tables(qd, qp, kd, kp, block_q=8, block_k=8)
    out = doc_flash_attention(q, k, v, *map(jnp.asarray, (qd, qp, kd, kp)),
                              tabs, interpret=True)
    assert np.all(np.asarray(out) == 0)


# --------------------------------------------------------------------- #
# block-table soundness: skip only provably-invisible, full only
# provably-all-visible
# --------------------------------------------------------------------- #
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), docs=st.integers(1, 6))
def test_block_tables_sound(seed, docs):
    B, Tq, Tk, bq, bk = 1, 64, 64, 8, 16
    qd, qp, kd, kp = _layout(B, Tq, Tk, docs, seed=seed)
    tabs = build_block_tables(qd, qp, kd, kp, block_q=bq, block_k=bk)
    mask = np.asarray(doc_mask(*map(jnp.asarray, (qd, qp, kd, kp))))[0]
    visited = np.zeros((Tq // bq, Tk // bk), bool)
    for qi in range(Tq // bq):
        for vi in range(int(tabs.kv_nvis[0, qi])):
            visited[qi, tabs.kv_idx[0, qi, vi]] = True
    for qi in range(Tq // bq):
        for ki in range(Tk // bk):
            blk = mask[qi * bq:(qi + 1) * bq, ki * bk:(ki + 1) * bk]
            if blk.any():
                assert visited[qi, ki], f"visible block ({qi},{ki}) skipped"
    # reverse tables agree with forward tables
    fwd = {(qi, tabs.kv_idx[0, qi, vi]) for qi in range(Tq // bq)
           for vi in range(int(tabs.kv_nvis[0, qi]))}
    bwd = {(tabs.q_idx[0, ki, vi], ki) for ki in range(Tk // bk)
           for vi in range(int(tabs.q_nvis[0, ki]))}
    assert fwd == bwd


def test_whole_doc_layout_has_higher_block_occupancy():
    """The paper's kernel-efficiency claim, kernel-side: contiguous whole
    docs produce denser visit tables than fine-grained interleavings."""
    B, T = 1, 256
    # whole-doc: one 256-token doc
    d1 = np.zeros((B, T), np.int32)
    p1 = np.arange(T, dtype=np.int32)[None]
    t1 = build_block_tables(d1, p1, d1, p1, block_q=32, block_k=32)
    # fine-grained: 16 docs of 16 tokens
    d2 = np.repeat(np.arange(16, dtype=np.int32), 16)[None]
    p2 = np.tile(np.arange(16, dtype=np.int32), 16)[None]
    t2 = build_block_tables(d2, p2, d2, p2, block_q=32, block_k=32)
    assert t1.full_frac > t2.full_frac
    assert t2.visited_frac < t1.visited_frac  # short docs: sparser visits


# --------------------------------------------------------------------- #
# flash-decode kernel (inference hot spot)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("B,Hq,Hkv,S,D,bk,dtype,tol", [
    (2, 4, 2, 128, 16, 32, np.float32, 2e-5),
    (1, 8, 1, 256, 32, 64, np.float32, 2e-5),    # MQA
    (3, 4, 4, 64, 64, 16, np.float32, 2e-5),
    (2, 4, 2, 128, 16, 32, jnp.bfloat16, 3e-2),
])
def test_flash_decode_matches_reference(B, Hq, Hkv, S, D, bk, dtype, tol):
    from repro.kernels.flash_decode import decode_reference, flash_decode
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, Hq, D))).astype(dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D))).astype(dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D))).astype(dtype)
    # ragged per-request lengths, incl. one empty-ish and one full
    lengths = jnp.asarray(
        rng.integers(0, S - 1, (B,)).astype(np.int32)).at[0].set(S - 1)
    ref = decode_reference(q, k, v, lengths)
    out = flash_decode(q, k, v, lengths, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("length", [0, 7, 31, 32, 127])  # < block_k, ==S-1
def test_flash_decode_clamp_boundaries(length):
    """Index-map clamp correctness at the block edges: lengths below one
    block, at a block boundary, and at the cache end S-1."""
    from repro.kernels.flash_decode import decode_reference, flash_decode
    B, Hq, Hkv, S, D, bk = 2, 4, 2, 128, 16, 32
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((B, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))
    lengths = jnp.asarray([length, S - 1], jnp.int32)
    ref = decode_reference(q, k, v, lengths)
    out = flash_decode(q, k, v, lengths, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_matches_model_decode_attention():
    """The kernel agrees with the model's decode-attention math."""
    from repro.kernels.flash_decode import decode_reference
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))
    t = jnp.asarray([10, 63], jnp.int32)
    # model path (attention.py): explicit mask + softmax
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D) * D ** -0.5
    s = jnp.einsum("bhgd,bhsd->bhgs", qf, k)
    mask = (jnp.arange(S)[None, :] <= t[:, None])[:, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bhgs,bhsd->bhgd", p, v).reshape(B, Hq, D)
    out = decode_reference(q, k, v, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
