"""CP-sharded flash-decode parity (simulated CPU devices).

The serving cache's sequence axis is sharded over the ``model`` mesh
axis: each rank runs ``flash_decode(partial=True)`` on its cache shard
against the *local* length (global length minus the shard offset,
clamped; negative = nothing visible on this rank), and ranks fold their
(o, m, l) partials with :func:`merge_partials_axis` — pmax of the row
max, rescale, psum — before ``finalize_partial``.  The result must match
the single-device dense oracle over the full cache for ragged length
mixes, including requests that live entirely on one shard.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core.cp_attention import finalize_partial, merge_partials_axis
from repro.kernels.flash_decode import decode_reference, flash_decode


def cp_decode(q, k, v, lengths, mesh, *, block_k=32):
    """Decode attention with the cache S axis sharded over ``model``."""
    S = k.shape[2]
    N = mesh.shape["model"]
    Sl = S // N

    def island(q, ks, vs, ln):
        r = jax.lax.axis_index("model")
        local_len = jnp.clip(ln - r * Sl, -1, Sl - 1)
        part = flash_decode(q, ks, vs, local_len, block_k=block_k,
                            interpret=True, partial=True)
        return finalize_partial(merge_partials_axis(part, "model"),
                                q.dtype)

    f = shard_map(
        island, mesh=mesh,
        in_specs=(P(None, None, None), P(None, None, "model", None),
                  P(None, None, "model", None), P(None)),
        out_specs=P(None, None, None), check_vma=False)
    return f(q, k, v, lengths)


def main():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, D = 4, 4, 2, 128, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))

    for N in (2, 4):
        mesh = make_mesh((1, N), ("data", "model"))
        for name, lens in (
                ("ragged", [S - 1, 17, 63, 0]),      # incl. shard-local reqs
                ("boundary", [S // N - 1, S // N, 2 * (S // N) - 1, 5]),
                ("uniform", [S - 1] * B)):
            ln = jnp.asarray(lens, jnp.int32)
            ref = decode_reference(q, k, v, ln)
            out = jax.jit(functools.partial(cp_decode, mesh=mesh))(
                q, k, v, ln)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=2e-5, rtol=2e-5,
                                       err_msg=f"CP{N} {name}")
            print(f"CP{N} {name}: sharded flash-decode merge == oracle")
    print("decode_cp_check OK")


if __name__ == "__main__":
    main()
