"""Static HLO audit of the lowered CP programs (DESIGN.md
§Static-analysis, Layer 2) — nothing executes; the lowered modules are
compiled AOT and their text diffed against the analytic comm budget.

Two phases (both by default; ``attn`` / ``train`` as argv[1] selects
one — ``scripts/flashcheck.py --hlo-*`` runs them this way):

* ``attn``  — the flashcp attention island on a simulated 4-way CP
  mesh, both overlap modes.  Acceptance: the audited per-collective
  wire bytes agree with :func:`repro.analysis.hlo_audit.
  kv_exchange_budget` (i.e. ``repro.core.workload.comm_bytes`` on the
  Eq.5 bucket) within 1%.
* ``train`` — the full smoke train step on a simulated 2x4 mesh: the
  KV exchange budget scales per attention layer, embedding/logits
  all-gathers and gradient all-reduces are admitted explicitly, and
  no f64 / host transfer / lost donation may appear.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np

from repro.analysis import format_findings
from repro.analysis.hlo_audit import (audit_program, collective_totals,
                                      kv_exchange_budget)
from repro.compat import make_mesh, set_mesh
from repro.core.cp_attention import make_cp_context

CP = 4
DOC_LENS = np.asarray([2500, 900, 1800, 1400, 700, 892], np.int64)


def check_attn() -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.planner import encode_plan_batch, get_planner

    mesh = make_mesh((1, CP), ("data", "model"))
    plan = get_planner("flashcp")(DOC_LENS, CP)
    stack, encs = encode_plan_batch([plan], align=128)
    enc = encs[0]
    arrays = {k: jnp.asarray(v) for k, v in stack.items()}
    C_pad = stack["doc"].shape[1]

    B, HQ, HKV, D = 1, 4, 2, 64
    sh = NamedSharding(mesh, P(None, None, "model", None))
    rng = np.random.default_rng(0)
    q, k, v = (jax.device_put(
        jnp.asarray(rng.standard_normal((B, h, C_pad, D)).astype(np.float32)),
        sh) for h in (HQ, HKV, HKV))

    for overlap in ("chunked", "none"):
        with set_mesh(mesh):
            ctx = make_cp_context(mesh, arrays, strategy="flashcp",
                                  impl="xla", batch_axes=(None,),
                                  head_dim=D, q_chunk=512, overlap=overlap)
            text = jax.jit(ctx.attn).lower(q, k, v).compile().as_text()
        budget = kv_exchange_budget(enc.buf_len, CP, HKV, D, dtype_bytes=4,
                                    fwd_and_bwd=False, overlap=overlap,
                                    batch=B)
        findings = audit_program(text, budget, donate_min_bytes=1 << 30,
                                 context=f"attn/{overlap}")
        assert not findings, format_findings(findings)

        # acceptance: audited bytes == analytic comm model within 1%
        kind = "collective-permute" if overlap == "chunked" else "all-gather"
        total = collective_totals(text)[kind]
        cap = budget.allowed[kind]
        err = abs(total - cap) / cap
        print(f"OK attn overlap={overlap}: {kind} {total:.0f} wire bytes "
              f"vs analytic {cap:.0f} (err {err:.2%})")
        assert err < 0.01, (overlap, total, cap)


def check_train() -> None:
    from repro.configs import get_config
    from repro.configs.base import RunConfig, ShapeConfig, reduce_for_smoke
    from repro.launch.steps import build_train_step, default_buf_len

    shape = ShapeConfig("smoke", seq_len=1024, global_batch=2, kind="train")
    cfg = reduce_for_smoke(get_config("starcoder2_3b"))
    data, cp = 2, 4
    mesh = make_mesh((data, cp), ("data", "model"))
    dtype_bytes = np.dtype(cfg.dtype).itemsize
    budget = kv_exchange_budget(
        default_buf_len(shape.seq_len, cp), cp,
        cfg.num_kv_heads, cfg.head_dim, dtype_bytes=dtype_bytes,
        fwd_and_bwd=True, overlap="chunked",
        batch=shape.global_batch // data, layers=cfg.num_layers,
        # embedding/logits gathers and the gradient/loss all-reduce are
        # model-parallel traffic outside the CP exchange; admit the
        # kinds but still forbid full-context KV re-gathers.
        extra={"all-gather": float("inf"), "all-reduce": float("inf")})
    # full-KV re-gather tripwire: well above the legitimate embedding
    # and logits gathers (<= one KV row) but below any full-context
    # multi-layer re-materialization
    import dataclasses
    kv_row_bytes = (shape.seq_len * cfg.num_kv_heads * cfg.head_dim *
                    dtype_bytes)
    budget = dataclasses.replace(budget,
                                 full_gather_bytes=float(4 * kv_row_bytes))

    # audit both the plain step and the adaptive-dispatch step (ragged
    # rows mask compute, not communication — the exchange is static)
    for dispatch in ("off", "adaptive"):
        run = RunConfig(arch=cfg.name, shape="smoke",
                        cp_strategy="flashcp", attention_impl="xla",
                        cp_overlap="chunked", remat=False,
                        dispatch=dispatch)
        with set_mesh(mesh):
            bundle = build_train_step(cfg, mesh, run, shape)
            text = bundle.lower().compile().as_text()

        findings = audit_program(text, budget, donate_min_bytes=1 << 16,
                                 context=f"train/dispatch={dispatch}")
        errors = [f for f in findings if f.severity == "error"]
        assert not errors, format_findings(errors)
        for f in findings:
            print("  note:", f.render().splitlines()[0])

        totals = collective_totals(text)
        cap = budget.allowed["collective-permute"]
        err = abs(totals["collective-permute"] - cap) / cap
        print(f"OK train step dispatch={dispatch}: collective-permute "
              f"{totals['collective-permute']:.0f} wire bytes vs analytic "
              f"{cap:.0f} (err {err:.2%}); kinds={sorted(totals)}")
        assert err < 0.01, (totals, cap)


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("attn", "both"):
        check_attn()
    if which in ("train", "both"):
        check_train()
    print("HLO_AUDIT_CHECK_PASS")


if __name__ == "__main__":
    main()
