"""Full-model CP train-step parity vs single device (8 simulated devices).

The same logical batch (one packed sequence of documents), the same
parameters: the CP execution (FlashCP plan, permuted layout, sharding-aware
comm islands, EP MoE, SSM islands) must produce the same loss and the same
gradient norm as the plain single-device run.  Covers a dense+MoE config
and the hybrid (mamba) config.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.planner.heuristic import flashcp_plan
from repro.planner.baselines import contiguous_plan
from repro.planner.encode import encode_plan_batch
from repro.compat import make_mesh, set_mesh
from repro.core.cp_attention import make_cp_context
from repro.data.packing import doc_ids_and_positions
from repro.models import init_params, loss_fn, make_local_context
from repro.optim import global_norm

B, C, N_CP, DATA = 2, 512, 4, 2
DOC_LENS = np.array([100, 37, 200, 80, 95], dtype=np.int64)


def run_case(arch: str):
    full = get_config(arch)
    # ample MoE capacity: local vs per-rank dispatch drop different tokens
    # at tight capacity (expected EP semantics); parity needs drop-free.
    cfg = dataclasses.replace(reduce_for_smoke(full), dtype="float32",
                              capacity_factor=8.0)
    rng = np.random.default_rng(0)

    tokens_packed = rng.integers(0, cfg.vocab_size, (B, C)).astype(np.int32)
    gdoc, gpos = doc_ids_and_positions(DOC_LENS)
    ends = np.cumsum(DOC_LENS) - 1
    labels_packed = np.roll(tokens_packed, -1, axis=1)
    labels_packed[:, ends] = -1

    extra = {}
    if cfg.frontend == "audio_frames":
        extra["frame_embeds"] = rng.standard_normal(
            (B, C, cfg.d_model)).astype(np.float32)
    if cfg.frontend == "vit_patches":
        extra["patch_embeds"] = rng.standard_normal(
            (B, C, cfg.d_model)).astype(np.float32)
        pm = np.zeros((B, C), bool)
        pm[:, :cfg.num_patch_tokens] = True
        extra["patch_mask"] = pm

    params = init_params(jax.random.PRNGKey(0), cfg)

    # ---- single device reference -------------------------------------- #
    doc1 = jnp.asarray(np.tile(gdoc, (B, 1)).astype(np.int32))
    pos1 = jnp.asarray(np.tile(gpos, (B, 1)).astype(np.int32))
    ctx1 = make_local_context(doc1, pos1, q_chunk=128)
    batch1 = {"tokens": jnp.asarray(tokens_packed),
              "labels": jnp.asarray(labels_packed),
              **{k: jnp.asarray(v) for k, v in extra.items()}}
    (loss1, _), grads1 = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, ctx1, batch1, remat=False),
        has_aux=True)(params)
    gn1 = float(global_norm(grads1))

    # ---- CP execution --------------------------------------------------- #
    planner = contiguous_plan if cfg.family in ("hybrid", "ssm") \
        else lambda l, n: flashcp_plan(l, n)[0]
    plans = [planner(DOC_LENS, N_CP) for _ in range(B)]
    stack, encs = encode_plan_batch(plans, align=16)
    perm = stack["perm"]
    C_pad = perm.shape[1]

    def permute2(x, fill=0):
        out = np.full((B, C_pad) + x.shape[2:], fill, x.dtype)
        ok = perm >= 0
        for b in range(B):
            out[b, ok[b]] = x[b][perm[b][ok[b]]]
        return out

    batch2 = {
        "tokens": jnp.asarray(permute2(tokens_packed)),
        "labels": jnp.asarray(permute2(labels_packed, fill=-1)),
        **{k: jnp.asarray(v) for k, v in stack.items() if k != "perm"},
    }
    for k, v in extra.items():
        batch2[k] = jnp.asarray(permute2(v))

    mesh = make_mesh((DATA, N_CP), ("data", "model"))
    strategy = "contiguous" if cfg.family in ("hybrid", "ssm") else "flashcp"
    with set_mesh(mesh):
        ctx2 = make_cp_context(
            mesh, {k: batch2[k] for k in ("doc", "pos", "send_idx",
                                          "gath_doc", "gath_pos")},
            strategy=strategy, impl="xla", batch_axes=("data",),
            head_dim=cfg.resolved_head_dim, q_chunk=64)

        @jax.jit
        def cp_loss_and_gn(p, b):
            (l, _), g = jax.value_and_grad(
                lambda pp: loss_fn(pp, cfg, ctx2, b, remat=False),
                has_aux=True)(p)
            return l, global_norm(g)

        loss2, gn2 = cp_loss_and_gn(params, batch2)

    print(f"{arch}: local loss={float(loss1):.6f} cp loss={float(loss2):.6f}"
          f" | gnorm {gn1:.4f} vs {float(gn2):.4f}")
    np.testing.assert_allclose(float(loss2), float(loss1), rtol=2e-4)
    np.testing.assert_allclose(float(gn2), gn1, rtol=2e-3)


def main():
    run_case("olmoe_1b_7b")       # dense attention + EP MoE
    run_case("jamba_v0_1_52b")    # hybrid: mamba islands + MoE + attention
    run_case("starcoder2_3b")     # plain dense GQA
    print("TRAIN_PARITY_PASS")


if __name__ == "__main__":
    main()
