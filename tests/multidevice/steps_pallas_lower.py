"""Pallas-impl CP train/prefill steps lower AOT (regression for the dead
path where step builders never threaded visit tables into
``make_cp_context(impl="pallas")``).

Run in a subprocess with 8 simulated CPU devices; interpret-mode kernels
so the Pallas calls lower on the CPU backend.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))


from repro.compat import make_mesh, set_mesh
from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeConfig, reduce_for_smoke
from repro.launch.steps import build_prefill_step, build_train_step

SHAPE = ShapeConfig("smoke", seq_len=1024, global_batch=2, kind="train")


def main():
    cfg = reduce_for_smoke(get_config("starcoder2_3b"))
    mesh = make_mesh((2, 4), ("data", "model"))

    for overlap in ("chunked", "none"):
        for grid in ("flat", "rect"):
            run = RunConfig(arch=cfg.name, shape="smoke",
                            cp_strategy="flashcp",
                            attention_impl="pallas", cp_overlap=overlap,
                            kernel_grid=grid, remat=False)
            with set_mesh(mesh):
                bundle = build_train_step(cfg, mesh, run, SHAPE,
                                          interpret=True)
                lowered = bundle.lower()
                text = lowered.as_text()
                assert "custom_call" in text or "while" in text
                print(f"OK train_step pallas overlap={overlap} "
                      f"grid={grid} ({len(text)} chars)")

                pbundle = build_prefill_step(cfg, mesh, run, SHAPE,
                                             interpret=True)
                pbundle.lower()
                print(f"OK prefill_step pallas overlap={overlap} "
                      f"grid={grid}")

    print("STEPS_PALLAS_LOWER_PASS")


if __name__ == "__main__":
    main()
