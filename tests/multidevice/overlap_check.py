"""Overlapped-vs-monolithic CP execution parity (run in a subprocess with
8 simulated CPU devices — see tests/test_overlap.py).

For {flashcp, allgather, ring} x {xla, pallas-interpret rect grid,
pallas-interpret flat work-queue grid} x CP in {2, 4} on a multi-doc
plan: the chunked-exchange engine must match the monolithic island
(values AND gradients, tolerance-bounded), plan metadata must be bitwise
identical between the two executions, and the monolithic reference
itself is anchored to the single-device oracle.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, set_mesh
from repro.planner.baselines import BASELINE_PLANNERS
from repro.core.cp_attention import make_cp_context
from repro.data.packing import doc_ids_and_positions
from repro.kernels.ref import mha_reference
from repro.planner import emit_visit_tables, encode_plan_batch

C, B, HQ, HKV, D = 256, 2, 4, 2, 8
DOC_LENS = np.array([70, 23, 100, 40, 23], dtype=np.int64)
BQ = BK = 16
ATOL = 2e-4
GTOL = 5e-4


def permute(x, perm, axis=2):
    safe = np.maximum(perm, 0)
    shp = [1] * x.ndim
    shp[0], shp[axis] = perm.shape[0], perm.shape[1]
    out = np.take_along_axis(x, safe.reshape(shp), axis=axis)
    return out * (perm >= 0).reshape(shp)


def run_ctx(mesh, ctx, qp, kp, vp):
    sh = NamedSharding(mesh, P("data", None, "model", None))
    qj, kj, vj = (jax.device_put(jnp.asarray(x), sh) for x in (qp, kp, vp))
    out = np.asarray(jax.jit(ctx.attn)(qj, kj, vj))

    def loss(q, k, v):
        return jnp.sum(ctx.attn(q, k, v).astype(jnp.float32) ** 2)

    grads = jax.jit(jax.grad(loss, (0, 1, 2)))(qj, kj, vj)
    return out, tuple(np.asarray(g) for g in grads)


def main():
    rng = np.random.default_rng(0)
    gdoc, gpos = doc_ids_and_positions(DOC_LENS)
    gdoc = np.tile(gdoc, (B, 1)).astype(np.int32)
    gpos = np.tile(gpos, (B, 1)).astype(np.int32)
    q0 = rng.standard_normal((B, HQ, C, D)).astype(np.float32)
    k0 = rng.standard_normal((B, HKV, C, D)).astype(np.float32)
    v0 = rng.standard_normal((B, HKV, C, D)).astype(np.float32)
    ref = np.asarray(mha_reference(*map(jnp.asarray,
                                        (q0, k0, v0, gdoc, gpos, gdoc,
                                         gpos))))

    cases = [("flashcp", "flashcp"), ("llama3", "allgather"),
             ("ring_zigzag", "ring")]

    for cp in (2, 4):
        mesh = make_mesh((2, cp), ("data", "model"))
        for plan_name, strat in cases:
            plans = [BASELINE_PLANNERS[plan_name](DOC_LENS, cp)
                     for _ in range(B)]
            stack, _ = encode_plan_batch(plans, align=BQ)
            # plan metadata is identical regardless of execution overlap
            stack2, _ = encode_plan_batch(
                [BASELINE_PLANNERS[plan_name](DOC_LENS, cp)
                 for _ in range(B)], align=BQ)
            for key in stack:
                assert np.array_equal(stack[key], stack2[key]), \
                    f"plan metadata not bitwise-stable: {key}"
            perm = stack["perm"]
            qp = permute(q0, perm)
            kp = permute(k0, perm)
            vp = permute(v0, perm)
            ref_p = permute(ref, perm)
            needs_gath = strat == "flashcp"

            def tables_for(overlap, grid):
                return emit_visit_tables(
                    stack["doc"], stack["pos"],
                    stack["gath_doc"] if needs_gath else None,
                    stack["gath_pos"] if needs_gath else None,
                    num_workers=cp, strategy=strat, overlap=overlap,
                    grid=grid, block_q=BQ, block_k=BK)

            base = {k_: jnp.asarray(v_) for k_, v_ in stack.items()}
            runs = {}
            for impl, grid in (("xla", "rect"), ("pallas", "rect"),
                               ("pallas", "flat")):
                for overlap in ("none", "chunked"):
                    if impl == "pallas" and overlap == "none" \
                            and strat == "ring":
                        continue     # ring has no monolithic pallas form
                    arrays = dict(base)
                    if impl == "pallas":
                        arrays.update({k_: jnp.asarray(v_) for k_, v_ in
                                       tables_for(overlap, grid).items()})
                    with set_mesh(mesh):
                        ctx = make_cp_context(
                            mesh, arrays, strategy=strat, impl=impl,
                            batch_axes=("data",), head_dim=D, q_chunk=64,
                            overlap=overlap, interpret=(impl == "pallas"),
                            block_q=BQ, block_k=BK, grid=grid)
                        runs[(impl, grid, overlap)] = run_ctx(mesh, ctx, qp,
                                                              kp, vp)

            # monolithic xla anchors to the single-device oracle
            mono_out, mono_g = runs[("xla", "rect", "none")]
            np.testing.assert_allclose(mono_out, ref_p, atol=ATOL,
                                       rtol=ATOL,
                                       err_msg=f"{strat}/cp{cp} mono-vs-"
                                               "oracle")
            # every other (impl, grid, overlap) is parity-bounded
            for (impl, grid, overlap), (out, grads) in runs.items():
                if (impl, grid, overlap) == ("xla", "rect", "none"):
                    continue
                tag = f"{strat}/cp{cp}/{impl}/{grid}/{overlap}"
                np.testing.assert_allclose(out, mono_out, atol=ATOL,
                                           rtol=ATOL, err_msg=tag)
                for g, mg, nm in zip(grads, mono_g, "qkv"):
                    np.testing.assert_allclose(g, mg, atol=GTOL, rtol=GTOL,
                                               err_msg=f"{tag} d{nm}")

            # int8 quantized wire: monolithic gather + chunked hops
            # (quantization tolerance; STE gradients stay exact-formed)
            if strat == "allgather":
                for overlap in ("none", "chunked"):
                    with set_mesh(mesh):
                        ctx = make_cp_context(
                            mesh, base, strategy=strat, impl="xla",
                            batch_axes=("data",), head_dim=D, q_chunk=64,
                            overlap=overlap, kv_comm_dtype="int8")
                        out, grads = run_ctx(mesh, ctx, qp, kp, vp)
                    # full-KV wire quantization (vs flashcp's compact
                    # buffer) -> every attention weight is perturbed;
                    # grads amplify through the softmax
                    tag = f"{strat}/cp{cp}/int8/{overlap}"
                    np.testing.assert_allclose(out, mono_out, atol=5e-2,
                                               rtol=5e-2, err_msg=tag)
                    for g, mg, nm in zip(grads, mono_g, "qkv"):
                        np.testing.assert_allclose(
                            g, mg, atol=2e-1, rtol=2e-1,
                            err_msg=f"{tag} d{nm}")
            print(f"OK cp={cp} {strat:10s} "
                  f"({len(runs) - 1} variants vs monolithic)")

    print("OVERLAP_CHECK_PASS")


if __name__ == "__main__":
    main()
