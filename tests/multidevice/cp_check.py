"""Multi-device CP correctness check (run in a subprocess with 8 simulated
CPU devices — see tests/test_cp_distributed.py).

Validates, for every CP strategy, that distributed attention over a
FlashCP-permuted layout reproduces single-device full attention — values
AND gradients — and that the CP SSM scan matches the local scan.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.planner.baselines import BASELINE_PLANNERS
from repro.compat import make_mesh, set_mesh
from repro.core.cp_attention import make_cp_context
from repro.planner.encode import encode_plan_batch
from repro.planner.plan import validate_plan
from repro.kernels.ref import mha_reference
from repro.kernels.doc_attention import build_block_tables
from repro.data.packing import doc_ids_and_positions

C, N_CP, DATA = 512, 4, 2
B, HQ, HKV, D = 2, 4, 2, 16


def build_case(strategy, rng):
    doc_lens = np.array([100, 37, 200, 80, 95], dtype=np.int64)
    assert doc_lens.sum() == C
    plans = []
    for _ in range(B):
        plan = BASELINE_PLANNERS[strategy](doc_lens, N_CP)
        validate_plan(plan, require_equal_tokens=False)
        plans.append(plan)
    stack, encs = encode_plan_batch(plans, align=16)
    return doc_lens, stack, encs


def permute(x, perm, axis):
    """Gather x at positions perm along axis; zeros at -1."""
    safe = np.maximum(perm, 0)
    out = np.take_along_axis(
        x, safe.reshape(safe.shape[0], *([1] * (axis - 1)), safe.shape[1],
                        *([1] * (x.ndim - axis - 1))), axis=axis)
    mask = (perm >= 0).reshape(perm.shape[0], *([1] * (axis - 1)),
                               perm.shape[1], *([1] * (x.ndim - axis - 1)))
    return out * mask


def main():
    rng = np.random.default_rng(0)
    mesh = make_mesh((DATA, N_CP), ("data", "model"))

    doc_lens = np.array([100, 37, 200, 80, 95], dtype=np.int64)
    gdoc, gpos = doc_ids_and_positions(doc_lens)
    gdoc = np.tile(gdoc, (B, 1)).astype(np.int32)
    gpos = np.tile(gpos, (B, 1)).astype(np.int32)

    q0 = rng.standard_normal((B, HQ, C, D)).astype(np.float32)
    k0 = rng.standard_normal((B, HKV, C, D)).astype(np.float32)
    v0 = rng.standard_normal((B, HKV, C, D)).astype(np.float32)

    # single-device reference (original packed order)
    ref_out = np.asarray(mha_reference(*map(jnp.asarray,
                                            (q0, k0, v0, gdoc, gpos, gdoc,
                                             gpos))))

    def ref_loss(q, k, v):
        o = mha_reference(q, k, v, gdoc, gpos, gdoc, gpos)
        return jnp.sum(o.astype(jnp.float32) ** 2)

    ref_grads = jax.grad(ref_loss, (0, 1, 2))(*map(jnp.asarray, (q0, k0, v0)))

    strategies = [("flashcp", "xla"), ("flashcp", "pallas"),
                  ("flashcp", "xla-int8"), ("contiguous", "xla"),
                  ("llama3", "xla"), ("per_doc", "xla"),
                  ("ring_zigzag", "xla")]

    for strategy, impl in strategies:
        _, stack, encs = build_case(strategy, rng)
        perm = stack["perm"]
        C_pad = perm.shape[1]

        qp = permute(q0, perm, 2)
        kp = permute(k0, perm, 2)
        vp = permute(v0, perm, 2)

        arrays = {k_: jnp.asarray(v_) for k_, v_ in stack.items()}
        exec_strategy = {"llama3": "allgather", "per_doc": "allgather",
                         "ring_zigzag": "ring"}.get(strategy, strategy)

        tables = None
        if impl == "pallas":
            # host-built visit tables per (sample, rank), incl. self-mask
            t_loc = encs[0].t_loc
            buf = encs[0].buf_len
            kv_i, kv_n, q_i, q_n = [], [], [], []
            for bi, e in enumerate(encs):
                for j in range(N_CP):
                    qd = e.doc[j * t_loc:(j + 1) * t_loc][None]
                    qp_ = e.pos[j * t_loc:(j + 1) * t_loc][None]
                    gd = e.gath_doc.copy()
                    gd[j * buf:(j + 1) * buf] = -2
                    kd = np.concatenate([qd[0], gd])[None]
                    kp_ = np.concatenate([qp_[0], e.gath_pos])[None]
                    t = build_block_tables(qd, qp_, kd, kp_, block_q=16,
                                           block_k=16)
                    kv_i.append(t.kv_idx[0]); kv_n.append(t.kv_nvis[0])
                    q_i.append(t.q_idx[0]); q_n.append(t.q_nvis[0])
            VK = max(a.shape[-1] for a in kv_i)
            VQ = max(a.shape[-1] for a in q_i)

            def padlast(a, w):
                pad = np.repeat(a[:, -1:], w - a.shape[-1], axis=-1)
                return np.concatenate([a, pad], axis=-1)

            kv_i = np.stack([padlast(a, VK) for a in kv_i]).reshape(
                B, N_CP, -1, VK)
            q_i = np.stack([padlast(a, VQ) for a in q_i]).reshape(
                B, N_CP, -1, VQ)
            kv_n = np.stack(kv_n).reshape(B, N_CP, -1)
            q_n = np.stack(q_n).reshape(B, N_CP, -1)
            tables = tuple(map(jnp.asarray, (kv_i, kv_n, q_i, q_n)))

        kv_dtype = "int8" if impl == "xla-int8" else "native"
        real_impl = "xla" if impl == "xla-int8" else impl
        # the hand-built tables here use the monolithic concat layout;
        # chunked-overlap pallas is covered by overlap_check.py
        ov = "none" if impl == "pallas" else "chunked"
        with set_mesh(mesh):
            ctx = make_cp_context(
                mesh, arrays, strategy=exec_strategy, impl=real_impl,
                batch_axes=("data",), head_dim=D, q_chunk=64, overlap=ov,
                interpret=(impl == "pallas"), tables=tables,
                block_q=16, block_k=16, kv_comm_dtype=kv_dtype)

            sh = NamedSharding(mesh, P("data", None, "model", None))
            qj = jax.device_put(jnp.asarray(qp), sh)
            kj = jax.device_put(jnp.asarray(kp), sh)
            vj = jax.device_put(jnp.asarray(vp), sh)

            out = np.asarray(jax.jit(ctx.attn)(qj, kj, vj))

            def loss(q, k, v):
                o = ctx.attn(q, k, v)
                return jnp.sum(o.astype(jnp.float32) ** 2)

            grads = jax.jit(jax.grad(loss, (0, 1, 2)))(qj, kj, vj)

        # compare in plan order (int8 KV gather: quantization tolerance)
        atol = 3e-2 if impl == "xla-int8" else 2e-4
        ref_perm = permute(ref_out, perm, 2)
        np.testing.assert_allclose(out, ref_perm, atol=atol, rtol=atol,
                                   err_msg=f"{strategy}/{impl} fwd")
        # int8: STE backward is exact, but forward quantization perturbs
        # the attention weights the grads flow through -> looser tolerance
        gtol = 5e-2 if impl == "xla-int8" else 5e-4
        for g, rg, nm in zip(grads, ref_grads, "qkv"):
            rgp = permute(np.asarray(rg), perm, 2)
            np.testing.assert_allclose(np.asarray(g), rgp, atol=gtol,
                                       rtol=gtol,
                                       err_msg=f"{strategy}/{impl} d{nm}")
        print(f"OK {strategy:12s} impl={impl}")

    # ---- SSM island vs local scan ------------------------------------- #
    from repro.models.context import local_ssm_scan
    T = 256
    a = jnp.asarray(rng.uniform(0.5, 1.0, (B, T, 8)).astype(np.float32))
    a = a.at[:, 0].set(0.0).at[:, 97].set(0.0)   # doc resets
    x = jnp.asarray(rng.standard_normal((B, T, 8)).astype(np.float32))
    ref = np.asarray(local_ssm_scan(a, x))
    with set_mesh(mesh):
        ctx = make_cp_context(mesh, {"doc": jnp.zeros((B, T), jnp.int32),
                                     "pos": jnp.zeros((B, T), jnp.int32)},
                              strategy="ring", impl="xla",
                              batch_axes=("data",), head_dim=D)
        sh = NamedSharding(mesh, P("data", "model", None))
        out = np.asarray(jax.jit(ctx.ssm_scan)(jax.device_put(a, sh),
                                               jax.device_put(x, sh)))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5,
                               err_msg="ssm island")
    # gradient through the island
    def sloss(a, x):
        return jnp.sum(ctx.ssm_scan(a, x) ** 2)
    def rloss(a, x):
        return jnp.sum(local_ssm_scan(a, x) ** 2)
    with set_mesh(mesh):
        g = jax.jit(jax.grad(sloss, (0, 1)))(a, x)
    gr = jax.grad(rloss, (0, 1))(a, x)
    for gi, gri, nm in zip(g, gr, "ax"):
        np.testing.assert_allclose(np.asarray(gi), np.asarray(gri),
                                   atol=1e-4, rtol=1e-4,
                                   err_msg=f"ssm island d{nm}")
    print("OK ssm_island (+grads)")
    print("CP_CHECK_PASS")


if __name__ == "__main__":
    main()
