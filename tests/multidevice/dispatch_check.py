"""Adaptive-dispatch parity across mesh tilings (run in a subprocess with
8 simulated CPU devices — CI tier-2).

One fixed document pool, dispatched at CP 2 (4 groups on the re-tiled
(4, 2) mesh) and CP 4 (2 groups on (2, 4) — the static full-axis tiling
of the base DP2 × CP4 mesh).  For each degree, the grouped execution
must match (loss AND gradients, tolerance-bounded):

* the single-device oracle (local context over the full ragged batch);
* the single-group baseline (the same batch on a (1, cp) mesh — no
  group axis);

and the two degrees must match *each other* (content-keyed token
streams make the underlying data identical).  The oracle itself must
equal the manual token-weighted combination of per-row losses — the
ragged-group normalization contract of DESIGN.md §Dispatch.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.compat import make_mesh, set_mesh
from repro.configs import get_config, reduce_for_smoke
from repro.core.cp_attention import make_cp_context
from repro.data.pipeline import PipelineConfig, make_dispatch_batch
from repro.dispatch import DispatchConfig
from repro.launch.mesh import make_group_mesh
from repro.models import init_params, loss_fn, make_local_context
from repro.optim import global_norm

D, M, SEQS, C = 2, 4, 4, 512
RTOL_LOSS = 2e-4
RTOL_GN = 2e-3


def loss_and_gnorm(params, cfg, ctx, batch):
    @jax.jit
    def lg(p, b):
        (l, _), grads = jax.value_and_grad(
            lambda pp: loss_fn(pp, cfg, ctx, b, remat=False),
            has_aux=True)(p)
        return l, global_norm(grads)

    l, gn = lg(params, batch)
    return float(l), float(gn)


def main():
    cfg = dataclasses.replace(reduce_for_smoke(get_config("starcoder2_3b")),
                              dtype="float32")
    pipe = PipelineConfig(dataset="pile", context_len=C,
                          batch_per_host=SEQS, cp_size=M,
                          strategy="flashcp", vocab_size=cfg.vocab_size,
                          seed=23, align=16)
    params = init_params(jax.random.PRNGKey(0), cfg)

    per_degree = {}
    for g in (2, 4):
        # bin_quantum = lcm(2, 4): packing is degree-invariant, so the
        # two tilings see bit-identical documents and tokens
        dcfg = DispatchConfig(data=D, model=M, seqs=SEQS, fixed_cp=g,
                              bin_quantum=4)
        b = make_dispatch_batch(pipe, dcfg, step=0)
        assert len(set(b["seq_tokens"].tolist())) > 1, \
            "mix not ragged — the token-weighting under test is trivial"
        arrays = {k: jnp.asarray(v) for k, v in b.items() if k != "stats"}
        tok_lab = {k: arrays[k] for k in ("tokens", "labels")}
        plan_keys = {k: arrays[k] for k in ("doc", "pos", "send_idx",
                                            "gath_doc", "gath_pos")}

        # single-device oracle over the ragged batch
        ctx0 = make_local_context(arrays["doc"], arrays["pos"], q_chunk=64)
        ref_l, ref_gn = loss_and_gnorm(params, cfg, ctx0, tok_lab)

        # token-weighted combination of per-row losses == the oracle
        m = (b["labels"] >= 0).sum(1).astype(np.float64)
        rows = []
        for r in range(SEQS):
            ctx_r = make_local_context(arrays["doc"][r:r + 1],
                                       arrays["pos"][r:r + 1], q_chunk=64)
            rows.append(loss_and_gnorm(
                params, cfg, ctx_r,
                {k: v[r:r + 1] for k, v in tok_lab.items()})[0])
        weighted = float(np.dot(rows, m) / m.sum())
        np.testing.assert_allclose(ref_l, weighted, rtol=1e-5,
                                   err_msg=f"cp{g} token-weighted combine")

        # grouped execution on the re-tiled mesh vs single-group baseline
        for mesh, tag in ((make_group_mesh(D, M, g), f"groups({8//g},{g})"),
                          (make_mesh((1, g), ("data", "model")),
                           f"single(1,{g})")):
            with set_mesh(mesh):
                ctx = make_cp_context(
                    mesh, plan_keys, strategy="flashcp", impl="xla",
                    batch_axes=("data",), head_dim=cfg.resolved_head_dim,
                    q_chunk=64)
                l, gn = loss_and_gnorm(params, cfg, ctx, tok_lab)
            np.testing.assert_allclose(l, ref_l, rtol=RTOL_LOSS,
                                       err_msg=f"cp{g} {tag} loss")
            np.testing.assert_allclose(gn, ref_gn, rtol=RTOL_GN,
                                       err_msg=f"cp{g} {tag} gnorm")
            print(f"OK cp={g} {tag}: loss {l:.6f} (oracle {ref_l:.6f}) "
                  f"gnorm {gn:.4f}")
        per_degree[g] = (ref_l, ref_gn)

    # the two tilings of the same pool agree with each other: dispatch at
    # cp=2 vs the static full-axis tiling (cp=4, groups == DP ranks)
    (l2, g2), (l4, g4) = per_degree[2], per_degree[4]
    np.testing.assert_allclose(l2, l4, rtol=RTOL_LOSS,
                               err_msg="cp2-vs-cp4 loss")
    np.testing.assert_allclose(g2, g4, rtol=RTOL_GN,
                               err_msg="cp2-vs-cp4 gnorm")
    print(f"OK cp2-vs-cp4: loss {l2:.6f} vs {l4:.6f}")

    print("DISPATCH_CHECK_PASS")


if __name__ == "__main__":
    main()
