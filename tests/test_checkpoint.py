"""Checkpoint manager: roundtrip, atomic commit, retention, async, elastic
reshard-on-restore."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 4))
                                        .astype(np.float32)),
                       "nested": {"b": jnp.arange(5, dtype=jnp.int32)}},
            "opt": {"count": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(7, st)
    step, restored, manifest = mgr.restore()
    assert step == 7 and manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 5, 9):
        mgr.save(s, _state(s))
    assert mgr.latest_step() == 9
    assert mgr.all_steps() == [5, 9]          # keep=2 garbage-collected


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _state(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3
    _, restored, _ = mgr.restore(3)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_state()["params"]["w"]))


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp staging dirs are never listed as checkpoints."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_000000004.123.9.tmp"))
    assert mgr.all_steps() == []
    with pytest.raises(FileNotFoundError):
        mgr.restore()


def test_reshard_on_restore(tmp_path):
    """Restoring with target shardings places arrays (elastic restore)."""
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    dev = jax.devices()[0]
    shardings = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev),
                             st)
    _, restored, _ = mgr.restore(1, shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf, jax.Array)
    np.testing.assert_array_equal(np.asarray(leaf),
                                  np.asarray(jax.tree.leaves(st)[0]))


def test_overwrite_same_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, _state(0))
    mgr.save(2, _state(1))
    _, restored, _ = mgr.restore(2)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_state(1)["params"]["w"]))
