"""Checkpoint manager: roundtrip, atomic commit, retention, async, elastic
reshard-on-restore."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 4))
                                        .astype(np.float32)),
                       "nested": {"b": jnp.arange(5, dtype=jnp.int32)}},
            "opt": {"count": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(7, st)
    step, restored, manifest = mgr.restore()
    assert step == 7 and manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 5, 9):
        mgr.save(s, _state(s))
    assert mgr.latest_step() == 9
    assert mgr.all_steps() == [5, 9]          # keep=2 garbage-collected


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _state(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3
    _, restored, _ = mgr.restore(3)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_state()["params"]["w"]))


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp staging dirs are never listed as checkpoints."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_000000004.123.9.tmp"))
    assert mgr.all_steps() == []
    with pytest.raises(FileNotFoundError):
        mgr.restore()


def test_reshard_on_restore(tmp_path):
    """Restoring with target shardings places arrays (elastic restore)."""
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    dev = jax.devices()[0]
    shardings = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev),
                             st)
    _, restored, _ = mgr.restore(1, shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf, jax.Array)
    np.testing.assert_array_equal(np.asarray(leaf),
                                  np.asarray(jax.tree.leaves(st)[0]))


def test_overwrite_same_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, _state(0))
    mgr.save(2, _state(1))
    _, restored, _ = mgr.restore(2)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_state(1)["params"]["w"]))


# --------------------------------------------------------------------- #
# crash-safety (DESIGN.md §Recovery)
# --------------------------------------------------------------------- #
def _fail_writes(mgr, monkeypatch):
    def boom(self, step, host_state, extra, tmp):
        os.makedirs(tmp, exist_ok=True)           # stage partially...
        raise OSError("disk full")                # ...then die pre-commit

    monkeypatch.setattr(CheckpointManager, "_write", boom)


def test_async_save_error_reraised_not_silent(tmp_path, monkeypatch):
    """A failed background save surfaces on the next wait() — never
    silently vanishes — and the manager recovers for the next save."""
    mgr = CheckpointManager(str(tmp_path))
    _fail_writes(mgr, monkeypatch)
    mgr.save(1, _state(), blocking=False)
    with pytest.raises(RuntimeError, match="checkpoint save failed"):
        mgr.wait()
    mgr.wait()                                    # error is consumed once
    monkeypatch.undo()
    mgr.save(2, _state())
    assert mgr.latest_step() == 2


def test_async_save_error_reraised_by_next_save(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))
    _fail_writes(mgr, monkeypatch)
    mgr.save(1, _state(), blocking=False)
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="checkpoint save failed"):
        mgr.save(2, _state())
    mgr.save(2, _state())                         # manager still usable
    assert mgr.latest_step() == 2


def test_crash_mid_save_keeps_previous_checkpoint(tmp_path, monkeypatch):
    """Atomicity: a save that dies before commit leaves the previous
    checkpoint as latest, restorable, with no staging leftovers."""
    mgr = CheckpointManager(str(tmp_path))
    st = _state(1)
    mgr.save(1, st)
    _fail_writes(mgr, monkeypatch)
    with pytest.raises(RuntimeError):
        mgr.save(2, _state(2))
    assert mgr.latest_step() == 1
    assert mgr.all_steps() == [1]
    assert not [n for n in os.listdir(str(tmp_path)) if n.endswith(".tmp")]
    _, restored, _ = mgr.restore()
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    monkeypatch.undo()
    mgr.save(2, _state(2))
    assert mgr.latest_step() == 2


def test_gc_never_collects_just_written_step(tmp_path):
    """A directory reused across runs can hold stale higher-numbered
    steps; retention-by-number must not delete the checkpoint the new
    run just committed (and LATEST still points at)."""
    stale = CheckpointManager(str(tmp_path), keep=2)
    for s in (7, 8):
        stale.save(s, _state(s))                  # previous run's leftovers

    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, _state(3))                        # new run, smaller step
    assert mgr.latest_step() == 3                 # LATEST written last wins
    _, restored, _ = mgr.restore()
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(_state(3)["params"]["w"]))


def test_dangling_latest_pointer_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    import shutil
    shutil.rmtree(os.path.join(str(tmp_path), "step_000000002"))
    assert mgr.latest_step() == 1                 # LATEST=2 is dangling
    step, restored, _ = mgr.restore()
    assert step == 1


def test_stale_tmp_from_dead_process_swept(tmp_path):
    """Staging dirs whose embedded pid is dead are GC'd at construction;
    this process's own in-flight staging dirs are kept."""
    import subprocess
    import sys

    proc = subprocess.Popen([sys.executable, "-c", "pass"])
    proc.wait()                                   # reaped: pid is dead
    dead = os.path.join(str(tmp_path), f"step_000000001.{proc.pid}.7.tmp")
    mine = os.path.join(str(tmp_path),
                        f"step_000000002.{os.getpid()}.7.tmp")
    junk = os.path.join(str(tmp_path), "step_000000003.zz.tmp")
    for d in (dead, mine, junk):
        os.makedirs(d)
    CheckpointManager(str(tmp_path))
    assert not os.path.exists(dead)
    assert os.path.exists(mine)
    assert not os.path.exists(junk)               # unparseable pid: swept
