"""Model-zoo tests: per-arch reduced-config smoke (fwd + train grad +
decode, shape and finiteness asserts) and mixer-level equivalence oracles
(chunkwise Mamba == sequential decode; mLSTM chunkwise == step decode;
MoE local dispatch == dense expert sum)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.data.packing import doc_ids_and_positions
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, make_local_context)

B, T = 2, 64
DOC_LENS = np.array([24, 40])


def _batch(cfg, rng):
    doc, pos = doc_ids_and_positions(DOC_LENS)
    doc = np.tile(doc, (B, 1)).astype(np.int32)
    pos = np.tile(pos, (B, 1)).astype(np.int32)
    tokens = rng.integers(0, cfg.vocab_size, (B, T)).astype(np.int32)
    labels = tokens.copy()
    labels[:, [23, 63]] = -1
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.frontend == "audio_frames":
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((B, T, cfg.d_model)).astype(np.float32))
    if cfg.frontend == "vit_patches":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, T, cfg.d_model)).astype(np.float32))
        pm = np.zeros((B, T), bool)
        pm[:, :cfg.num_patch_tokens] = True
        batch["patch_mask"] = jnp.asarray(pm)
    return batch, jnp.asarray(doc), jnp.asarray(pos)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    """Reduced config of the same family: one forward + train grad on CPU,
    asserting output shapes and no NaNs; one decode step."""
    cfg = reduce_for_smoke(ARCHS[arch])
    rng = np.random.default_rng(0)
    batch, doc, pos = _batch(cfg, rng)
    ctx = make_local_context(doc, pos, q_chunk=32)
    params = init_params(jax.random.PRNGKey(0), cfg)

    logits, aux = forward(params, cfg, ctx, batch, remat=False)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, ctx, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch

    cache = init_cache(cfg, B, 16)
    db = ({"tokens": jnp.zeros((B,), jnp.int32)}
          if cfg.frontend != "audio_frames"
          else {"frame_embeds": jnp.asarray(
              rng.standard_normal((B, cfg.d_model)).astype(np.float32))})
    lg, cache2 = decode_step(params, cfg, cache, db,
                             jnp.zeros((B,), jnp.int32))
    assert lg.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    # cache must actually change
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert changed


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_exact_assigned_dimensions(arch):
    """The full configs carry the exact assignment dimensions."""
    cfg = ARCHS[arch]
    spec = {
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "granite_34b": (88, 6144, 48, 1, 24576, 49152),
        "starcoder2_7b": (32, 4608, 36, 4, 18432, 49152),
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec


def test_moe_configs():
    assert (ARCHS["olmoe_1b_7b"].num_experts,
            ARCHS["olmoe_1b_7b"].top_k) == (64, 8)
    assert (ARCHS["dbrx_132b"].num_experts,
            ARCHS["dbrx_132b"].top_k) == (16, 4)
    assert (ARCHS["jamba_v0_1_52b"].num_experts,
            ARCHS["jamba_v0_1_52b"].top_k,
            ARCHS["jamba_v0_1_52b"].attn_every) == (16, 2, 8)


def test_param_counts_plausible():
    def b(x):
        return ARCHS[x].param_count() / 1e9
    assert 2.5 < b("starcoder2_3b") < 3.8
    assert 6.0 < b("starcoder2_7b") < 8.5
    assert 28 < b("qwen3_32b") < 37
    assert 100 < b("dbrx_132b") < 150
    assert 40 < b("jamba_v0_1_52b") < 60
    assert 0.25 < b("xlstm_350m") < 0.55
    assert 6.0 < ARCHS["olmoe_1b_7b"].param_count() / 1e9 < 8.0
    assert ARCHS["olmoe_1b_7b"].active_param_count() \
        < 0.35 * ARCHS["olmoe_1b_7b"].param_count()


# --------------------------------------------------------------------- #
# mixer oracles
# --------------------------------------------------------------------- #
def test_mamba_parallel_equals_sequential():
    from repro.models.ssm import (mamba_apply, mamba_cache_init,
                                  mamba_decode, mamba_init)
    d, ds, dc = 32, 8, 4
    p = mamba_init(jax.random.PRNGKey(0), d, expand=2, d_state=ds, d_conv=dc)
    doc, pos = doc_ids_and_positions(np.array([50, 78]))
    doc = jnp.asarray(np.tile(doc, (B, 1)).astype(np.int32))
    pos = jnp.asarray(np.tile(pos, (B, 1)).astype(np.int32))
    ctx = make_local_context(doc, pos)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 128, d)) * 0.5

    y_par = mamba_apply(p, x, ctx, d_state=ds, d_conv=dc, chunk=16)

    cache = mamba_cache_init(B, d, expand=2, d_state=ds, d_conv=dc,
                             dtype=jnp.float32)
    outs = []
    for t in range(128):
        r = (np.asarray(pos[:, t]) == 0).astype(np.float32)
        cache = {"conv": cache["conv"] * (1 - r[:, None, None]),
                 "ssm": cache["ssm"] * (1 - r[:, None, None])}
        o, cache = mamba_decode(p, x[:, t], cache, d_state=ds, d_conv=dc)
        outs.append(o)
    y_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-3)


def test_mlstm_chunkwise_equals_stepwise():
    from repro.models.xlstm import (mlstm_apply, mlstm_cache_init,
                                    mlstm_decode, mlstm_init)
    d, H = 32, 4
    p = mlstm_init(jax.random.PRNGKey(0), d, H)
    Tl = 128
    doc = jnp.zeros((B, Tl), jnp.int32)
    pos = jnp.asarray(np.tile(np.arange(Tl, dtype=np.int32), (B, 1)))
    ctx = make_local_context(doc, pos)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, Tl, d)) * 0.5

    y_par = mlstm_apply(p, x, ctx, num_heads=H)
    cache = mlstm_cache_init(B, d, H, expand=2, dtype=jnp.float32)
    outs = []
    for t in range(Tl):
        o, cache = mlstm_decode(p, x[:, t], cache, num_heads=H)
        outs.append(o)
    y_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=3e-4, rtol=3e-3)


def test_moe_matches_dense_reference():
    """Local dispatch with ample capacity == explicit per-token expert sum."""
    from repro.models.moe import moe_apply, moe_init
    d, f, E, K = 16, 32, 4, 2
    p = moe_init(jax.random.PRNGKey(0), d, f, E, "glu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    out, aux = moe_apply(p, x, None, top_k=K, capacity_factor=8.0,
                         kind="glu")

    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, K)
    gates = topv / topv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(E):
        h = jax.nn.silu(x @ p["wg"][e]) * (x @ p["wi"][e])
        y = h @ p["wo"][e]
        w = jnp.where(topi == e, gates, 0.0).sum(-1)
        ref = ref + y * w[..., None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_slstm_reset_blocks_state():
    from repro.models.xlstm import slstm_apply, slstm_init
    d = 16
    p = slstm_init(jax.random.PRNGKey(0), d)
    pos = np.tile(np.arange(32, dtype=np.int32), (1, 1))
    pos[:, 16:] = np.arange(16)           # reset at t=16
    ctx = make_local_context(jnp.zeros((1, 32), jnp.int32),
                             jnp.asarray(pos))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, d))
    y1 = slstm_apply(p, x, ctx)
    # changing tokens before the reset must not affect tokens after it
    x2 = x.at[:, :16].set(jax.random.normal(jax.random.PRNGKey(2),
                                            (1, 16, d)))
    y2 = slstm_apply(p, x2, ctx)
    np.testing.assert_allclose(np.asarray(y1[:, 16:]),
                               np.asarray(y2[:, 16:]), atol=1e-6)
