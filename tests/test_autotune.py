"""Autotuner search contract (DESIGN.md §Autotune).

Properties (hypothesis where available, fixed-seed fallback otherwise):

* **prune preserves the optimum** — when the predictor ranks like the
  measurement, the two-stage search returns the brute-force argmin for
  any frontier size; with ``top_k >= |space|`` it returns the measured
  argmin for *any* (even adversarial) predictor;
* **admissibility** — every enumerated candidate passes the dispatcher's
  own divisibility checks (``g | model`` axis, ``C % g == 0``, quantum
  alignment) and the planner registry's family capability filter;
* **monotonicity** — more modeled comm volume never predicts less comm
  time, later-arriving payloads never reduce exposed comm, higher
  imbalance never predicts lower step time; int8 wire never costs more
  comm time than native end-to-end;
* **determinism** — same inputs give byte-identical search results in
  one process and across processes;
* **cache round-trip** — a hit reproduces the payload without
  re-measuring; corrupt or version-skewed entries read as misses.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.autotune import (DEFAULT_SPACE, Candidate, ModelDims, ResultCache,
                            SearchSpace, TuneProblem, brute_force,
                            candidate_admissible, candidate_degrees,
                            comm_seconds, enumerate_candidates,
                            measure_candidate, pipeline_exposed, predict,
                            prune_topk, scale_by_imbalance, signature_key,
                            spearman, tune, tune_signature)
from repro.dispatch import DispatchConfig, cp_degree_options
from repro.planner import available_planners, get_planner

DIMS = ModelDims(num_heads=4, kv_heads=2, head_dim=32, d_model=128, d_ff=512)

#: small spaces used by the search properties (<= 64 points)
SMALL_SPACE = SearchSpace(strategies=("flashcp", "llama3"),
                          grids=("flat",), dispatch_targets=(1.1, 1.3))
XLA_PROBLEM = TuneProblem(data=1, model=2, context_len=512, seqs=2,
                          quantum=1, attention_impl="xla", family="dense")
PALLAS_PROBLEM = TuneProblem(data=1, model=2, context_len=1024, seqs=2,
                             quantum=128, attention_impl="pallas",
                             family="dense")


def _pool(seed=0, n=24, lo=16, hi=200):
    return np.random.default_rng(seed).integers(lo, hi, n).astype(np.int64)


# --------------------------------------------------------------------- #
# enumeration: admissibility + determinism + canonicalization
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("problem", [
    XLA_PROBLEM,
    PALLAS_PROBLEM,
    TuneProblem(data=2, model=2, context_len=2048, seqs=4, quantum=16,
                attention_impl="xla", family="dense"),
    TuneProblem(data=1, model=4, context_len=1024, seqs=4, quantum=16,
                attention_impl="xla", family="hybrid"),
])
def test_enumerated_candidates_are_admissible(problem):
    cands = enumerate_candidates(problem)
    assert cands, "space unexpectedly empty"
    for cand in cands:
        assert candidate_admissible(cand, problem)
        assert cand.cp_strategy in available_planners()
        degrees = candidate_degrees(cand, problem)
        assert degrees
        for g in degrees:
            # the dispatcher's divisibility contract, re-derived
            assert problem.model % g == 0
            assert problem.context_len % g == 0
            assert (problem.context_len // g) % max(problem.quantum, 1) == 0
        # family capability: recurrent families only get order-preserving
        # planners
        if problem.family in ("hybrid", "ssm"):
            assert get_planner(cand.cp_strategy).info.preserves_token_order


def test_enumeration_is_deterministic_and_deduplicated():
    a = enumerate_candidates(PALLAS_PROBLEM)
    b = enumerate_candidates(PALLAS_PROBLEM)
    assert a == b
    keys = [c.key() for c in a]
    assert keys == sorted(keys)
    assert len(keys) == len(set(keys))


def test_canonicalization_pins_inert_knobs():
    # non-pallas run never lowers tables: the grid knob must be pinned
    for cand in enumerate_candidates(XLA_PROBLEM):
        assert cand.kernel_grid == "flat"
        if cand.dispatch == "off":
            assert cand.dispatch_target_imbalance == pytest.approx(1.1)
    # a 1x1 mesh moves no KV: comm knobs pinned
    solo = TuneProblem(data=1, model=1, context_len=512, seqs=1,
                       quantum=1, attention_impl="xla", family="dense")
    for cand in enumerate_candidates(solo):
        assert cand.cp_overlap == "chunked"
        assert cand.kv_comm_dtype == "native"


def test_emitted_degrees_match_strict_dispatcher():
    # strict=False mirrors the raising path wherever that path succeeds
    for cand in enumerate_candidates(PALLAS_PROBLEM):
        fixed = 0 if cand.dispatch == "adaptive" else PALLAS_PROBLEM.model
        mult = get_planner(cand.cp_strategy).info.context_multiple
        cfg = DispatchConfig(
            data=PALLAS_PROBLEM.data, model=PALLAS_PROBLEM.model,
            seqs=PALLAS_PROBLEM.seqs,
            target_imbalance=cand.dispatch_target_imbalance,
            min_cp=1, fixed_cp=fixed, quantum=PALLAS_PROBLEM.quantum,
            bin_quantum=mult * PALLAS_PROBLEM.model if mult > 1 else 1)
        assert candidate_degrees(cand, PALLAS_PROBLEM) == \
            cp_degree_options(cfg, PALLAS_PROBLEM.context_len)


# --------------------------------------------------------------------- #
# prune preserves the optimum
# --------------------------------------------------------------------- #
def _synthetic_cost(seed):
    """Deterministic synthetic cost model keyed by candidate identity."""
    def fn(cand, pool, problem, dims):
        h = abs(hash((seed,) + cand.key())) % 10_000
        est = predict(cand, pool, problem, dims)
        return type(est)(**{**est.as_dict(), "step_s": 1e-6 * (1 + h)})
    return fn


def _prune_case(seed, k):
    pool = _pool(seed)
    cands = enumerate_candidates(XLA_PROBLEM, SMALL_SPACE)
    assert 1 < len(cands) <= 64
    cost = _synthetic_cost(seed)
    # predictor == measurement: pruning can never drop the optimum
    res = tune(pool, XLA_PROBLEM, DIMS, space=SMALL_SPACE, top_k=k,
               predict_fn=cost, measure_fn=cost)
    costs = [cost(c, pool, XLA_PROBLEM, DIMS) for c in cands]
    opt, opt_cost = brute_force(cands, costs)
    assert res.best == opt
    assert res.best_measured["step_s"] == pytest.approx(opt_cost.step_s)

    # adversarial predictor, full-width frontier: still exact (the
    # brute-force escape hatch)
    adversary = _synthetic_cost(seed + 1)
    res_full = tune(pool, XLA_PROBLEM, DIMS, space=SMALL_SPACE,
                    top_k=len(cands), predict_fn=adversary, measure_fn=cost)
    assert res_full.best == opt


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 64))
    def test_prune_preserves_optimum(seed, k):
        _prune_case(seed, k)
else:
    @pytest.mark.parametrize("seed,k",
                             [(0, 1), (1, 2), (2, 8), (3, 64), (4, 3),
                              (5, 16)])
    def test_prune_preserves_optimum(seed, k):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _prune_case(seed, k)


def test_prune_topk_deterministic_order():
    pool = _pool(3)
    cands = enumerate_candidates(XLA_PROBLEM, SMALL_SPACE)
    ests = [predict(c, pool, XLA_PROBLEM, DIMS) for c in cands]
    front = prune_topk(cands, ests, 5)
    assert len(front) == 5
    scored = [(e.step_s, c.key()) for c, e in front]
    assert scored == sorted(scored)
    # input order must not matter
    rev = prune_topk(cands[::-1], ests[::-1], 5)
    assert [c.key() for c, _ in rev] == [c.key() for c, _ in front]


# --------------------------------------------------------------------- #
# monotonicity
# --------------------------------------------------------------------- #
def _monotone_case(seed):
    rng = np.random.default_rng(seed)
    # comm_seconds: non-decreasing in wire bytes
    a, b = sorted(rng.uniform(0, 1e9, 2))
    assert comm_seconds(a) <= comm_seconds(b)

    # pipeline_exposed: raising any hop's comm never lowers exposed;
    # raising any hop's compute never raises it
    hops = int(rng.integers(1, 6))
    comm = rng.uniform(0, 1e-3, hops)
    comp = rng.uniform(0, 1e-3, hops)
    base = pipeline_exposed(comm, comp)
    i = int(rng.integers(hops))
    bump = float(rng.uniform(0, 1e-3))
    more_comm = comm.copy()
    more_comm[i] += bump
    assert pipeline_exposed(more_comm, comp) >= base - 1e-18
    more_comp = comp.copy()
    more_comp[i] += bump
    assert pipeline_exposed(comm, more_comp) <= base + 1e-18

    # scale_by_imbalance: non-decreasing in both arguments
    t = float(rng.uniform(0, 1e-2))
    i1, i2 = sorted(rng.uniform(1.0, 3.0, 2))
    assert scale_by_imbalance(t, i1) <= scale_by_imbalance(t, i2)
    assert scale_by_imbalance(t, i1) >= t


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_cost_primitives_monotone(seed):
        _monotone_case(seed)
else:
    @pytest.mark.parametrize("seed", range(12))
    def test_cost_primitives_monotone(seed):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _monotone_case(seed)


@pytest.mark.parametrize("fn", [predict, measure_candidate])
def test_int8_wire_never_costs_more_comm(fn):
    pool = _pool(7)
    base = Candidate(cp_strategy="flashcp", dispatch="off",
                     kv_comm_dtype="native")
    quant = Candidate(cp_strategy="flashcp", dispatch="off",
                      kv_comm_dtype="int8")
    a = fn(base, pool, XLA_PROBLEM, DIMS)
    b = fn(quant, pool, XLA_PROBLEM, DIMS)
    assert b.comm_bytes <= a.comm_bytes
    assert b.comm_s <= a.comm_s


def test_more_comm_volume_never_predicts_less_comm_time():
    # scale the pool's doc count up: more cross-rank KV, never less
    # predicted comm time at a fixed config
    cand = Candidate(cp_strategy="llama3", cp_overlap="none",
                     dispatch="off")
    small = predict(cand, _pool(11, n=8), XLA_PROBLEM, DIMS)
    large = predict(cand, _pool(11, n=32), XLA_PROBLEM, DIMS)
    assert large.comm_bytes >= small.comm_bytes
    assert large.comm_s >= small.comm_s


# --------------------------------------------------------------------- #
# determinism
# --------------------------------------------------------------------- #
def test_search_deterministic_in_process():
    pool = _pool(5)
    a = tune(pool, PALLAS_PROBLEM, DIMS, top_k=4)
    b = tune(pool, PALLAS_PROBLEM, DIMS, top_k=4)
    assert a.to_json() == b.to_json()
    assert a.run_config == b.run_config


_SUBPROC_SNIPPET = """
import numpy as np
from repro.autotune import ModelDims, SearchSpace, TuneProblem, tune
pool = np.random.default_rng(5).integers(16, 200, 24).astype(np.int64)
problem = TuneProblem(data=1, model=2, context_len=512, seqs=2,
                      quantum=1, attention_impl="xla", family="dense")
dims = ModelDims(num_heads=4, kv_heads=2, head_dim=32, d_model=128,
                 d_ff=512)
space = SearchSpace(strategies=("flashcp", "llama3"), grids=("flat",),
                    dispatch_targets=(1.1, 1.3))
print(tune(pool, problem, dims, space=space, top_k=4).to_json())
"""


def test_search_deterministic_across_processes():
    root = Path(__file__).resolve().parent.parent
    outs = []
    for _ in range(2):
        proc = subprocess.run(
            [sys.executable, "-c", _SUBPROC_SNIPPET],
            capture_output=True, text=True, timeout=300,
            env={**os.environ, "PYTHONPATH": str(root / "src"),
                 "PYTHONHASHSEED": "random", "JAX_PLATFORMS": "cpu"})
        assert proc.returncode == 0, proc.stderr
        outs.append(proc.stdout.strip().splitlines()[-1])
    assert outs[0] == outs[1]
    payload = json.loads(outs[0])
    assert payload["best"]["cp_strategy"] in available_planners()


# --------------------------------------------------------------------- #
# result cache
# --------------------------------------------------------------------- #
def test_cache_round_trip(tmp_path):
    pool = _pool(9)
    cache = ResultCache(tmp_path)
    first = tune(pool, XLA_PROBLEM, DIMS, space=SMALL_SPACE, top_k=4,
                 cache=cache)
    assert not first.cached
    assert cache.misses == 1

    def never(*_a, **_k):
        raise AssertionError("cache hit must not re-measure")

    second = tune(pool, XLA_PROBLEM, DIMS, space=SMALL_SPACE, top_k=4,
                  cache=cache, predict_fn=never, measure_fn=never)
    assert second.cached
    assert second.to_json() == first.to_json()
    assert second.run_config == first.run_config


def test_cache_corrupt_and_version_skew_read_as_misses(tmp_path):
    pool = _pool(9)
    cache = ResultCache(tmp_path)
    first = tune(pool, XLA_PROBLEM, DIMS, space=SMALL_SPACE, top_k=4,
                 cache=cache)
    entry = tmp_path / f"tune_{first.key}.json"
    assert entry.exists()

    entry.write_text("{not json")
    redone = tune(pool, XLA_PROBLEM, DIMS, space=SMALL_SPACE, top_k=4,
                  cache=cache)
    assert not redone.cached
    assert redone.to_json() == first.to_json()

    stale = json.loads(entry.read_text())
    stale["version"] = -1
    entry.write_text(json.dumps(stale))
    redone2 = tune(pool, XLA_PROBLEM, DIMS, space=SMALL_SPACE, top_k=4,
                   cache=cache)
    assert not redone2.cached


def test_signature_quantizes_lengths():
    pool = np.array([100, 200, 300], dtype=np.int64)
    same_bucket = np.array([97, 193, 290], dtype=np.int64)  # ceil to 64s
    other = np.array([100, 200, 900], dtype=np.int64)
    key = signature_key(tune_signature(XLA_PROBLEM, DIMS, pool,
                                       DEFAULT_SPACE))
    # identical buckets but different raw totals -> distinct keys (the
    # total-token term); identical pools always collide
    assert key == signature_key(tune_signature(XLA_PROBLEM, DIMS, pool,
                                               DEFAULT_SPACE))
    assert key != signature_key(tune_signature(XLA_PROBLEM, DIMS, other,
                                               DEFAULT_SPACE))
    sig_a = tune_signature(XLA_PROBLEM, DIMS, pool, DEFAULT_SPACE)
    sig_b = tune_signature(XLA_PROBLEM, DIMS, same_bucket, DEFAULT_SPACE)
    assert sig_a["pool"]["qlens"] == sig_b["pool"]["qlens"]


def test_disabled_cache_never_persists(tmp_path):
    cache = ResultCache(None)
    res = tune(_pool(2), XLA_PROBLEM, DIMS, space=SMALL_SPACE, top_k=2,
               cache=cache)
    assert not res.cached
    assert cache.hits == 0
    assert not list(tmp_path.iterdir())


# --------------------------------------------------------------------- #
# end-to-end: tuned RunConfig is applicable and spearman is sane
# --------------------------------------------------------------------- #
def test_tuned_run_config_round_trips():
    from repro.configs import RunConfig, run_config_from_dict

    res = tune(_pool(4), XLA_PROBLEM, DIMS, space=SMALL_SPACE, top_k=4,
               base_run=RunConfig(arch="starcoder2_3b", seed=7))
    run = run_config_from_dict(res.run_config)
    assert isinstance(run, RunConfig)
    assert run.arch == "starcoder2_3b"
    assert run.seed == 7
    assert run.cp_strategy == res.best.cp_strategy
    assert run.cp_overlap == res.best.cp_overlap
    assert run.kernel_grid == res.best.kernel_grid
    assert run.dispatch == res.best.dispatch
    assert run.kv_comm_dtype == res.best.kv_comm_dtype


def test_spearman_basics():
    assert spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
    assert spearman([1.0, 1.0], [1.0, 1.0]) == pytest.approx(1.0)
    assert spearman([1.0, 1.0], [1.0, 2.0]) == pytest.approx(0.0)
