"""Plan-encoding tests: the host-side arrays the device program consumes."""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from repro.planner.baselines import BASELINE_PLANNERS
from repro.planner.heuristic import flashcp_plan
from repro.planner.encode import (encode_plan, encode_plan_batch,
                                  pick_buffer_bucket, trivial_plan)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), cp=st.sampled_from([2, 4, 8]))
def test_encoding_invariants(seed, cp):
    rng = np.random.default_rng(seed)
    context = 64 * cp * int(rng.integers(1, 8))
    cuts = np.sort(rng.choice(np.arange(1, context),
                              int(rng.integers(0, 12)), replace=False))
    lens = np.diff(np.concatenate([[0], cuts, [context]]))
    lens = lens[lens > 0]
    plan, _ = flashcp_plan(lens, cp)
    enc = encode_plan(plan)

    # perm covers every packed position exactly once
    valid = enc.perm[enc.perm >= 0]
    assert len(valid) == context
    assert np.array_equal(np.sort(valid), np.arange(context))

    # metadata consistent with the packed layout
    starts = np.concatenate([[0], np.cumsum(lens)])[:-1]
    ok = enc.perm >= 0
    assert np.array_equal(enc.doc[ok],
                          np.searchsorted(np.cumsum(lens), enc.perm[ok],
                                          side="right"))
    assert np.array_equal(enc.pos[ok], enc.perm[ok] - starts[enc.doc[ok]])

    # send buffer: exactly the non-last-shard tokens, within capacity
    nl = plan.nonlast_tokens_per_worker()
    for j in range(cp):
        sent = enc.send_idx[j][enc.send_idx[j] >= 0]
        assert len(sent) == nl[j]
        assert len(sent) <= enc.buf_len
        # gathered metadata matches the local tokens it points at
        gd = enc.gath_doc[j * enc.buf_len: j * enc.buf_len + len(sent)]
        assert np.array_equal(gd, enc.doc[j * enc.t_loc + sent])
    assert enc.comm_tokens == plan.comm_tokens()


def test_bucketing():
    assert pick_buffer_bucket(1, 4096) == 128
    assert pick_buffer_bucket(129, 4096) == 256
    assert pick_buffer_bucket(10_000, 4096) == 4096  # capped at local KV


def test_batch_encoding_shares_shapes():
    lens = [np.array([500, 300, 224]), np.array([1024])]
    plans = [flashcp_plan(l, 4)[0] for l in lens]
    stack, encs = encode_plan_batch(plans, align=16)
    assert stack["doc"].shape == stack["pos"].shape
    assert stack["send_idx"].shape[0] == 2
    assert encs[0].buf_len == encs[1].buf_len
    assert encs[0].t_loc == encs[1].t_loc


def test_trivial_plan_zero_comm():
    enc = encode_plan(trivial_plan(1024))
    assert enc.comm_tokens == 0
    assert np.all(enc.send_idx == -1)


@pytest.mark.parametrize("strategy", ["llama3", "per_doc", "contiguous"])
def test_baseline_plans_encode(strategy):
    lens = np.array([700, 100, 1000, 248])
    plan = BASELINE_PLANNERS[strategy](lens, 4)
    enc = encode_plan(plan, align=8)
    valid = enc.perm[enc.perm >= 0]
    assert np.array_equal(np.sort(valid), np.arange(2048))
