"""Property tests for the recurrence substrate (hypothesis): the fused
selective scan and the chunked ssm scan must equal the naive sequential
recurrence for arbitrary shapes, chunk sizes, resets and initial states."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need the hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from repro.models.context import local_selective_scan, local_ssm_scan


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), B=st.integers(1, 3),
       T=st.sampled_from([8, 24, 64]), chunk=st.sampled_from([4, 16, 64]),
       with_init=st.booleans())
def test_ssm_scan_matches_naive(seed, B, T, chunk, with_init):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.2, 1.0, (B, T, 5)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((B, T, 5)).astype(np.float32))
    init = jnp.asarray(rng.standard_normal((B, 5)).astype(np.float32)) \
        if with_init else None

    h = np.asarray(init) if with_init else np.zeros((B, 5), np.float32)
    ref = []
    for t in range(T):
        h = np.asarray(a[:, t]) * h + np.asarray(x[:, t])
        ref.append(h.copy())
    ref = np.stack(ref, 1)

    out = local_ssm_scan(a, x, init=init, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), B=st.integers(1, 2),
       T=st.sampled_from([16, 48, 64]), chunk=st.sampled_from([8, 32]),
       di=st.sampled_from([4, 8]), S=st.sampled_from([2, 4]),
       with_init=st.booleans())
def test_selective_scan_matches_naive(seed, B, T, chunk, di, S, with_init):
    rng = np.random.default_rng(seed)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, T, di)).astype(np.float32))
    A = jnp.asarray(-rng.uniform(0.1, 2.0, (di, S)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, T, S)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, T, S)).astype(np.float32))
    xf = jnp.asarray(rng.standard_normal((B, T, di)).astype(np.float32))
    reset = np.ones((B, T), np.float32)
    reset[:, 0] = 0.0
    if T > 20:
        reset[:, 17] = 0.0            # mid-sequence document boundary
    init = jnp.asarray(rng.standard_normal((B, di, S)).astype(np.float32)) \
        if with_init else None

    # naive recurrence
    h = np.asarray(init) if with_init else np.zeros((B, di, S), np.float32)
    ys = []
    for t in range(T):
        a_t = np.exp(np.asarray(dt[:, t])[..., None] * np.asarray(A)) \
            * reset[:, t][:, None, None]
        h = a_t * h + (np.asarray(dt[:, t]) * np.asarray(xf[:, t])
                       )[..., None] * np.asarray(Bm[:, t])[:, None, :]
        ys.append(np.einsum("bds,bs->bd", h, np.asarray(Cm[:, t])))
    ref = np.stack(ys, 1)

    out = local_selective_scan(dt, A, Bm, Cm, xf, jnp.asarray(reset),
                               chunk=chunk, init_state=init)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5, rtol=2e-4)

    # summary mode agrees with the naive final state
    pA, hS = local_selective_scan(dt, A, Bm, Cm, xf, jnp.asarray(reset),
                                  chunk=chunk, summary_only=True)
    if not with_init:
        np.testing.assert_allclose(np.asarray(hS), h, atol=2e-5, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_bnb_proven_optimal_on_tiny_instances(seed):
    """When the search exhausts the tree, the result must dominate every
    explicitly-enumerated whole-doc assignment."""
    import itertools
    from repro.planner.ilp import bnb_plan, _evaluate

    rng = np.random.default_rng(seed)
    n, N = 5, 2
    cuts = np.sort(rng.choice(np.arange(1, 256), n - 1, replace=False))
    lens = np.diff(np.concatenate([[0], cuts, [256]]))
    lens = lens[lens > 0]
    res = bnb_plan(lens, N, lambda_comm=0.5, max_nodes=500_000)
    if not res.proven_optimal:
        return
    best = min(_evaluate(np.asarray(lens, np.int64), list(asg), N, 0.5)[0]
               for asg in itertools.product(range(N), repeat=len(lens)))
    assert res.objective <= best + 1e-9
