"""Multi-device CP correctness, run in subprocesses so the 8 simulated CPU
devices never leak into this process's JAX runtime.

* cp_check.py     — every CP strategy (flashcp xla+pallas, contiguous,
  llama3, per_doc, ring zigzag) matches the single-device oracle: values
  and gradients; the SSM boundary-exchange island matches the local scan.
* train_parity.py — a full CP train step (loss + grads through the model)
  matches the single-device run on the same logical batch.
"""

import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


def _run(script: str) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "multidevice", script)],
        capture_output=True, text=True, timeout=1200, env=env)
    assert proc.returncode == 0, \
        f"{script} failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n" \
        f"STDERR:\n{proc.stderr[-4000:]}"
    return proc.stdout


@pytest.mark.slow
def test_cp_strategies_match_oracle():
    out = _run("cp_check.py")
    assert "CP_CHECK_PASS" in out


@pytest.mark.slow
def test_cp_train_step_matches_single_device():
    out = _run("train_parity.py")
    assert "TRAIN_PARITY_PASS" in out
