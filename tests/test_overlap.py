"""Overlapped CP execution engine: merge-substrate properties, kernel
partial modes, vectorized visit-table parity, the planner table emitter,
the exposed-communication schedule model, and the multi-device /
AOT-lowering subprocess checks."""

import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.cp_attention import (NEG, finalize_partial, merge_partials)
from repro.kernels.doc_attention import build_block_tables
from repro.kernels.ops import doc_attention_xla, doc_flash_attention
from repro.kernels.ref import mha_reference
from repro.launch.hlo_analysis import schedule_model
from repro.planner import emit_visit_tables, visit_table_shapes

HERE = os.path.dirname(__file__)
RNG = np.random.default_rng(0)


def _sorted_layout(B, T, lens, pad=0):
    d = np.concatenate([np.full(l, i, np.int32) for i, l in enumerate(lens)]
                       + ([np.full(pad, -1, np.int32)] if pad else []))
    p = np.concatenate([np.arange(l, dtype=np.int32) for l in lens]
                       + ([np.zeros(pad, np.int32)] if pad else []))
    assert d.shape[0] == T
    return np.tile(d, (B, 1)), np.tile(p, (B, 1))


def _rand_layout(B, Tq, Tk, n_docs, seed=0, q_pad=0, kv_pad=0):
    rng = np.random.default_rng(seed)
    kv_doc = np.sort(rng.integers(0, n_docs, (B, Tk)).astype(np.int32), 1)
    kv_pos = np.zeros_like(kv_doc)
    for b in range(B):
        for d in np.unique(kv_doc[b]):
            m = kv_doc[b] == d
            kv_pos[b, m] = np.arange(m.sum())
    idx = np.sort(rng.choice(Tk, Tq, replace=False))
    q_doc, q_pos = kv_doc[:, idx].copy(), kv_pos[:, idx].copy()
    if q_pad:
        q_doc[:, -q_pad:] = -1
    if kv_pad:
        kv_doc[:, -kv_pad:] = -1
    return q_doc, q_pos, kv_doc, kv_pos


# --------------------------------------------------------------------- #
# merge substrate
# --------------------------------------------------------------------- #
def _random_partials(rng, n, shape, with_empty=True):
    parts = []
    for i in range(n):
        o = rng.standard_normal((*shape, 8)).astype(np.float32)
        m = rng.uniform(-3, 3, shape).astype(np.float32)
        l = rng.uniform(0.1, 4.0, shape).astype(np.float32)
        if with_empty and i % 3 == 2:      # empty partial (nothing visible)
            o = np.zeros_like(o)
            m = np.full(shape, NEG, np.float32)
            l = np.zeros(shape, np.float32)
        parts.append((jnp.asarray(o), jnp.asarray(m), jnp.asarray(l)))
    return parts


def test_merge_order_invariance():
    """Online-LSE merging is associative/commutative to fp tolerance:
    any merge order yields the same finalized output."""
    rng = np.random.default_rng(1)
    for trial in range(10):
        n = int(rng.integers(2, 7))
        parts = _random_partials(rng, n, (2, 3, 5))
        base = np.asarray(finalize_partial(merge_partials(parts),
                                           jnp.float32))
        for _ in range(4):
            order = rng.permutation(n)
            out = np.asarray(finalize_partial(
                merge_partials([parts[i] for i in order]), jnp.float32))
            np.testing.assert_allclose(out, base, atol=1e-5, rtol=1e-5)


def test_merge_all_empty_is_zero():
    rng = np.random.default_rng(2)
    parts = _random_partials(rng, 3, (1, 2, 4))
    empty = [(jnp.zeros_like(o), jnp.full_like(m, NEG), jnp.zeros_like(l))
             for o, m, l in parts]
    out = np.asarray(finalize_partial(merge_partials(empty), jnp.float32))
    assert np.all(out == 0)


def test_merge_mixed_forms_match_single_pass():
    """The normalized (o, lse, 1) Pallas form and the raw (o, m, l) XLA
    form merge interchangeably to the unsplit reference."""
    qd, qp, kd, kp = _rand_layout(2, 64, 64, 3, seed=3)
    q = jnp.asarray(RNG.standard_normal((2, 4, 64, 16)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((2, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((2, 2, 64, 16)).astype(np.float32))
    jqd, jqp, jkd, jkp = map(jnp.asarray, (qd, qp, kd, kp))
    ref = mha_reference(q, k, v, jqd, jqp, jkd, jkp)

    S = 32
    xla_part = doc_attention_xla(q, k[:, :, :S], v[:, :, :S], jqd, jqp,
                                 jkd[:, :S], jkp[:, :S], q_chunk=16,
                                 partial=True)
    tabs = build_block_tables(qd, qp, kd[:, S:], kp[:, S:], block_q=16,
                              block_k=16)
    o, lse = doc_flash_attention(q, k[:, :, S:], v[:, :, S:], jqd, jqp,
                                 jkd[:, S:], jkp[:, S:], tabs,
                                 interpret=True, partial=True)
    m = jnp.maximum(lse, NEG)
    pl_part = (o.astype(jnp.float32), m, jnp.ones_like(m))
    out = finalize_partial(merge_partials([xla_part, pl_part]), q.dtype)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------- #
# kernel partial modes (fwd + grad, incl. the d-lse backward path)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_partial_mode_matches_oracle(impl):
    qd, qp, kd, kp = _rand_layout(2, 64, 64, 4, seed=4, q_pad=3)
    q = jnp.asarray(RNG.standard_normal((2, 4, 64, 16)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((2, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((2, 2, 64, 16)).astype(np.float32))
    jqd, jqp, jkd, jkp = map(jnp.asarray, (qd, qp, kd, kp))

    def merged(q, k, v):
        parts = []
        for lo, hi in ((0, 32), (32, 64)):
            if impl == "pallas":
                tabs = build_block_tables(qd, qp, kd[:, lo:hi],
                                          kp[:, lo:hi], block_q=16,
                                          block_k=16)
                o, lse = doc_flash_attention(
                    q, k[:, :, lo:hi], v[:, :, lo:hi], jqd, jqp,
                    jkd[:, lo:hi], jkp[:, lo:hi], tabs, interpret=True,
                    partial=True)
                m = jnp.maximum(lse, NEG)
                parts.append((o.astype(jnp.float32), m, jnp.ones_like(m)))
            else:
                parts.append(doc_attention_xla(
                    q, k[:, :, lo:hi], v[:, :, lo:hi], jqd, jqp,
                    jkd[:, lo:hi], jkp[:, lo:hi], q_chunk=16,
                    partial=True))
        return finalize_partial(merge_partials(parts), q.dtype)

    ref = mha_reference(q, k, v, jqd, jqp, jkd, jkp)
    np.testing.assert_allclose(np.asarray(merged(q, k, v)),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)

    g = jax.grad(lambda *a: jnp.sum(merged(*a).astype(jnp.float32) ** 2),
                 (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(
        mha_reference(*a, jqd, jqp, jkd, jkp).astype(jnp.float32) ** 2),
        (0, 1, 2))(q, k, v)
    for a, b, nm in zip(g, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4,
                                   err_msg=f"{impl} d{nm}")


# --------------------------------------------------------------------- #
# vectorized build_block_tables vs the legacy builder
# --------------------------------------------------------------------- #
def _assert_tables_equal(a, b, msg):
    for n in ("kv_idx", "kv_nvis", "q_idx", "q_nvis"):
        np.testing.assert_array_equal(getattr(a, n), getattr(b, n),
                                      err_msg=f"{msg}:{n}")
    assert abs(a.visited_frac - b.visited_frac) < 1e-12, msg
    assert abs(a.full_frac - b.full_frac) < 1e-12, msg


def test_block_tables_vectorized_matches_legacy_random():
    """Dense-fallback path (unsorted layouts): exact equality."""
    rng = np.random.default_rng(5)
    for trial in range(15):
        B = int(rng.integers(1, 3))
        kd = rng.integers(-1, 5, (B, 128)).astype(np.int32)
        kp = rng.integers(0, 60, (B, 128)).astype(np.int32)
        qd = rng.integers(-1, 5, (B, 64)).astype(np.int32)
        qp = rng.integers(0, 60, (B, 64)).astype(np.int32)
        a = build_block_tables(qd, qp, kd, kp, block_q=16, block_k=16)
        b = build_block_tables(qd, qp, kd, kp, block_q=16, block_k=16,
                               legacy=True)
        _assert_tables_equal(a, b, f"rand{trial}")


def test_block_tables_vectorized_matches_legacy_sorted():
    """Interval fast path (plan-ordered layouts, incl. padding)."""
    rng = np.random.default_rng(6)
    for trial in range(15):
        nd = int(rng.integers(1, 8))
        lens = rng.multinomial(256 - 16, np.ones(nd) / nd)
        lens = [int(x) for x in lens if x] or [240]
        lens[-1] += 240 - sum(lens)
        d, p = _sorted_layout(2, 256, lens, pad=16)
        a = build_block_tables(d, p, d, p, block_q=16, block_k=16)
        b = build_block_tables(d, p, d, p, block_q=16, block_k=16,
                               legacy=True)
        _assert_tables_equal(a, b, f"sorted{trial}")


def test_block_tables_vectorized_matches_legacy_segmented():
    """Concat layouts (flashcp [local | buffers], incl. -2 self mask)
    autosplit into monotone segments and stay exact."""
    d1, p1 = _sorted_layout(1, 128, [70, 58])
    d2, p2 = _sorted_layout(1, 128, [50, 60], pad=18)
    kd = np.concatenate([d1, np.full_like(d2, -2), d2], axis=1)
    kp = np.concatenate([p1, p2, p2], axis=1)
    a = build_block_tables(d1, p1, kd, kp, block_q=16, block_k=16)
    b = build_block_tables(d1, p1, kd, kp, block_q=16, block_k=16,
                           legacy=True)
    _assert_tables_equal(a, b, "segmented")


# --------------------------------------------------------------------- #
# planner table emitter
# --------------------------------------------------------------------- #
def _enc(cp, lens=(70, 23, 100, 40, 23), B=2):
    from repro.planner.baselines import BASELINE_PLANNERS
    from repro.planner import encode_plan_batch
    plans = [BASELINE_PLANNERS["flashcp"](np.asarray(lens, np.int64), cp)
             for _ in range(B)]
    return encode_plan_batch(plans, align=16)


def test_emitter_mono_matches_per_rank_build():
    cp = 4
    stack, encs = _enc(cp)
    tabs = emit_visit_tables(stack["doc"], stack["pos"],
                             stack["gath_doc"], stack["gath_pos"],
                             num_workers=cp, strategy="flashcp",
                             overlap="none", block_q=16, block_k=16,
                             pad_to="exact")
    t_loc = encs[0].t_loc
    buf = encs[0].buf_len
    B = stack["doc"].shape[0]
    for b in range(B):
        for j in range(cp):
            qd = stack["doc"][b, j * t_loc:(j + 1) * t_loc][None]
            qp = stack["pos"][b, j * t_loc:(j + 1) * t_loc][None]
            gd = stack["gath_doc"][b].copy()
            gd[j * buf:(j + 1) * buf] = -2
            kd = np.concatenate([qd[0], gd])[None]
            kp = np.concatenate([qp[0], stack["gath_pos"][b]])[None]
            ref = build_block_tables(qd, qp, kd, kp, block_q=16,
                                     block_k=16)
            got_nvis = tabs["tab_kv_nvis"][b, j]
            np.testing.assert_array_equal(got_nvis, ref.kv_nvis[0])
            V = ref.kv_idx.shape[-1]
            np.testing.assert_array_equal(
                tabs["tab_kv_idx"][b, j][:, :V], ref.kv_idx[0])


def test_emitter_chunked_hop_mapping():
    """Hop h of rank r must be the table of (q_r, payload of rank
    (r - 1 - h) mod N) — the ppermute rotation the engine performs."""
    cp = 4
    stack, encs = _enc(cp)
    tabs = emit_visit_tables(stack["doc"], stack["pos"],
                             stack["gath_doc"], stack["gath_pos"],
                             num_workers=cp, strategy="flashcp",
                             overlap="chunked", block_q=16, block_k=16,
                             pad_to="exact")
    t_loc = encs[0].t_loc
    buf = encs[0].buf_len
    b = 0
    for r in range(cp):
        qd = stack["doc"][b, r * t_loc:(r + 1) * t_loc][None]
        qp = stack["pos"][b, r * t_loc:(r + 1) * t_loc][None]
        for h in range(cp - 1):
            src = (r - 1 - h) % cp
            kd = stack["gath_doc"][b, src * buf:(src + 1) * buf][None]
            kp = stack["gath_pos"][b, src * buf:(src + 1) * buf][None]
            ref = build_block_tables(qd, qp, kd, kp, block_q=16,
                                     block_k=16)
            np.testing.assert_array_equal(tabs["tab_hop_kv_nvis"][b, r, h],
                                          ref.kv_nvis[0])
            V = ref.kv_idx.shape[-1]
            np.testing.assert_array_equal(
                tabs["tab_hop_kv_idx"][b, r, h][:, :V], ref.kv_idx[0])


def test_emitter_full_pad_matches_spec_shapes():
    cp = 4
    stack, encs = _enc(cp)
    B = stack["doc"].shape[0]
    for overlap in ("none", "chunked"):
        tabs = emit_visit_tables(stack["doc"], stack["pos"],
                                 stack["gath_doc"], stack["gath_pos"],
                                 num_workers=cp, strategy="flashcp",
                                 overlap=overlap, block_q=16, block_k=16,
                                 pad_to="full")
        shapes = visit_table_shapes(B, cp, encs[0].t_loc, encs[0].buf_len,
                                    strategy="flashcp", overlap=overlap,
                                    block_q=16, block_k=16)
        for key, shape in shapes.items():
            assert tabs[key].shape == shape, (key, tabs[key].shape, shape)


def test_emitter_cache_hits():
    cp = 2
    stack, _ = _enc(cp)
    kw = dict(num_workers=cp, strategy="flashcp", overlap="chunked",
              block_q=16, block_k=16)
    a = emit_visit_tables(stack["doc"], stack["pos"], stack["gath_doc"],
                          stack["gath_pos"], **kw)
    b = emit_visit_tables(stack["doc"], stack["pos"], stack["gath_doc"],
                          stack["gath_pos"], **kw)
    for key in a:
        assert a[key] is b[key], f"cache miss on identical metadata: {key}"


# --------------------------------------------------------------------- #
# exposed-communication schedule model
# --------------------------------------------------------------------- #
_BLOCKING_HLO = """\
ENTRY %main (p0: f32[1024,1024], p1: f32[1024,1024]) -> f32[1024,1024] {
  %p0 = f32[1024,1024] parameter(0)
  %p1 = f32[1024,1024] parameter(1)
  %ag = f32[1024,1024] all-gather(%p0), replica_groups={{0,1,2,3}}
  %d0 = f32[1024,1024] dot(%ag, %p1), lhs_contracting_dims={1}
  ROOT %d1 = f32[1024,1024] dot(%d0, %p1), lhs_contracting_dims={1}
}
"""

_OVERLAPPED_HLO = """\
ENTRY %main (p0: f32[1024,1024], p1: f32[1024,1024]) -> f32[1024,1024] {
  %p0 = f32[1024,1024] parameter(0)
  %p1 = f32[1024,1024] parameter(1)
  %cp = f32[1024,1024] collective-permute(%p0), source_target_pairs={{0,1}}
  %d0 = f32[1024,1024] dot(%p0, %p1), lhs_contracting_dims={1}
  ROOT %d1 = f32[1024,1024] dot(%cp, %d0), lhs_contracting_dims={1}
}
"""


def test_schedule_model_blocking_vs_overlapped():
    blocking = schedule_model(_BLOCKING_HLO)
    overlapped = schedule_model(_OVERLAPPED_HLO)
    # blocking: the gather gates all compute -> fully exposed
    assert blocking.exposed_comm_s == pytest.approx(
        blocking.comm_busy_s, rel=1e-6)
    # overlapped: the permute flies under the first dot -> hidden
    assert overlapped.exposed_comm_s < 0.2 * overlapped.comm_busy_s
    assert blocking.collective_count == 1
    assert overlapped.collective_count == 1


# --------------------------------------------------------------------- #
# multi-device + AOT subprocess checks
# --------------------------------------------------------------------- #
def _run(script: str) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "multidevice", script)],
        capture_output=True, text=True, timeout=1200, env=env)
    assert proc.returncode == 0, \
        f"{script} failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n" \
        f"STDERR:\n{proc.stderr[-4000:]}"
    return proc.stdout


@pytest.mark.slow
def test_overlap_parity_all_strategies():
    out = _run("overlap_check.py")
    assert "OVERLAP_CHECK_PASS" in out


@pytest.mark.slow
def test_pallas_train_step_lowers_aot():
    out = _run("steps_pallas_lower.py")
    assert "STEPS_PALLAS_LOWER_PASS" in out
