"""Planner-subsystem tests: golden parity vs the frozen seed
implementations, the registry API, the plan cache, and the worker pool.

These tests are deliberately hypothesis-free so they always collect; the
property suites in test_planner.py / test_plan_exec.py cover the same
structures generatively when hypothesis is installed.
"""

import numpy as np
import pytest

from repro.planner import (PlanCache, ShardArrays, ShardingPlan,
                           available_planners, encode_plan,
                           encode_plan_batch, flashcp_plan, get_planner,
                           merge_adjacent_shards, plan_many, planner_info,
                           validate_plan)
from repro.planner import reference as ref
from repro.planner.plan import Shard
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence


def _key(plan):
    return sorted((int(s.doc_id), int(s.start), int(s.length), int(s.worker))
                  for s in plan.shards)


def _ref_key(plan):
    return sorted((s.doc_id, s.start, s.length, s.worker)
                  for s in plan.shards)


# --------------------------------------------------------------------- #
# golden parity: registry planners == seed implementations
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dataset", ["wlb_llm", "pile", "redpajama"])
@pytest.mark.parametrize("cp", [2, 4, 8])
def test_planners_match_seed_implementations(dataset, cp):
    rng = make_rng(17)
    for _ in range(3):
        lens = pack_sequence(dataset, 16384, rng)
        for name in ("flashcp", "llama3", "per_doc", "ring_zigzag",
                     "contiguous"):
            plan = get_planner(name)(lens, cp)
            seed = ref.REFERENCE_PLANNERS[name](lens, cp)
            assert _key(plan) == _ref_key(seed), \
                f"{name} diverged from seed on {dataset}/cp{cp}"
            assert plan.comm_style == seed.comm_style
            assert plan.comm_tokens() == seed.comm_tokens()
            np.testing.assert_array_equal(plan.tokens_per_worker(),
                                          seed.tokens_per_worker())
            assert plan.imbalance_ratio() == \
                pytest.approx(seed.imbalance_ratio())


def test_flashcp_parity_across_seeds():
    for seed in range(6):
        rng = make_rng(seed)
        lens = pack_sequence("wlb_llm", 32768, rng)
        plan, stats = flashcp_plan(lens, 8)
        golden = ref.ref_flashcp_plan(lens, 8)
        assert _key(plan) == _ref_key(golden)
        validate_plan(plan, token_tolerance=8)


def test_encoding_matches_seed_encoder():
    rng = make_rng(3)
    lens_a = pack_sequence("pile", 16384, rng)
    lens_b = pack_sequence("pile", 16384, rng)
    new = [flashcp_plan(lens_a, 8)[0], flashcp_plan(lens_b, 8)[0]]
    old = [ref.ref_flashcp_plan(lens_a, 8), ref.ref_flashcp_plan(lens_b, 8)]

    e_new = encode_plan(new[0], align=16)
    e_old = ref.ref_encode_plan(old[0], align=16)
    for f in ("perm", "doc", "pos", "send_idx", "gath_doc", "gath_pos"):
        np.testing.assert_array_equal(getattr(e_new, f), getattr(e_old, f),
                                      err_msg=f)
    assert (e_new.t_loc, e_new.buf_len, e_new.comm_tokens) == \
        (e_old.t_loc, e_old.buf_len, e_old.comm_tokens)

    s_new, _ = encode_plan_batch(new, align=16)
    s_old, _ = ref.ref_encode_plan_batch(old, align=16)
    for k in s_new:
        np.testing.assert_array_equal(s_new[k], s_old[k], err_msg=k)
        assert s_new[k].dtype == s_old[k].dtype


# --------------------------------------------------------------------- #
# registry API
# --------------------------------------------------------------------- #
def test_registry_unknown_planner_lists_available():
    with pytest.raises(KeyError) as ei:
        get_planner("definitely_not_a_planner")
    msg = str(ei.value)
    for name in available_planners():
        assert name in msg


def test_registry_aliases_and_metadata():
    assert get_planner("ring") is get_planner("ring_zigzag")
    assert planner_info("flashcp").supports_target_ratio
    assert planner_info("flashcp").order_invariant
    assert planner_info("llama3").exec_style == "allgather"
    assert not planner_info("llama3").order_invariant
    assert planner_info("contiguous").preserves_token_order
    assert planner_info("bnb").cost_hint == "exponential"
    # planners declaring equal-token plans actually emit them
    lens = np.asarray([700, 100, 1000, 248])
    for name in available_planners():
        info = planner_info(name)
        plan = get_planner(name)(lens, 4)
        validate_plan(plan, require_equal_tokens=info.needs_equal_tokens,
                      token_tolerance=4)
        assert plan.comm_style == info.comm_style


def test_effective_strategy_uses_registry_capabilities():
    from repro.launch.steps import effective_strategy, exec_strategy_of

    class Cfg:
        family = "hybrid"

    assert effective_strategy(Cfg, "flashcp") == "contiguous"
    assert effective_strategy(Cfg, "contiguous") == "contiguous"
    Cfg.family = "dense"
    assert effective_strategy(Cfg, "flashcp") == "flashcp"
    assert exec_strategy_of("per_doc") == "allgather"
    assert exec_strategy_of("ring") == "ring"
    assert exec_strategy_of("flashcp") == "flashcp"


# --------------------------------------------------------------------- #
# PlanCache
# --------------------------------------------------------------------- #
def test_plan_cache_exact_hit_is_plan_identical():
    cache = PlanCache("flashcp", 8)
    rng = make_rng(0)
    lens = pack_sequence("wlb_llm", 8192, rng)
    cold = cache.plan(lens)
    assert cache.stats.misses == 1 and cache.stats.hits == 0
    # cold-path result equals an uncached plan bit-for-bit
    direct, _ = flashcp_plan(lens, 8)
    assert _key(cold) == _key(direct)
    hot = cache.plan(lens)
    assert cache.stats.hits == 1
    assert _key(hot) == _key(cold)
    np.testing.assert_array_equal(hot.doc_lens, cold.doc_lens)


def test_plan_cache_order_invariant_permutation_hit():
    """flashcp is order-invariant: a permuted doc mix hits the cache and
    the returned plan is relabelled into the query's packing order."""
    cache = PlanCache("flashcp", 4)
    lens = np.asarray([512, 1024, 256, 256])
    cache.plan(lens)
    perm = np.asarray([1024, 256, 512, 256])
    plan = cache.plan(perm)
    assert cache.stats.hits == 1
    np.testing.assert_array_equal(plan.doc_lens, perm)
    validate_plan(plan)


def test_plan_cache_position_dependent_planner_keys_on_order():
    cache = PlanCache("llama3", 4)
    cache.plan(np.asarray([512, 1024, 256, 256]))
    cache.plan(np.asarray([1024, 256, 512, 256]))
    # llama3 cuts by packed position: permuted mix must NOT hit
    assert cache.stats.misses == 2 and cache.stats.hits == 0
    plan = cache.plan(np.asarray([512, 1024, 256, 256]))
    assert cache.stats.hits == 1
    validate_plan(plan)


def test_plan_cache_signature_quantization_adapts():
    cache = PlanCache("flashcp", 4, granularity=64)
    a = np.asarray([1000, 500, 300, 248])          # sums 2048
    b = np.asarray([990, 505, 310, 243])           # same quantized buckets
    ka, _ = cache.signature(a)
    kb, _ = cache.signature(b)
    assert ka == kb
    cache.plan(a)
    adapted = cache.plan(b)
    assert cache.stats.quantized_hits == 1
    np.testing.assert_array_equal(adapted.doc_lens, b)
    validate_plan(adapted, token_tolerance=4)


def test_plan_cache_lru_eviction_and_stats():
    cache = PlanCache("flashcp", 2, max_entries=2)
    mixes = [np.asarray([256, 256]), np.asarray([384, 128]),
             np.asarray([512 - 32, 32])]
    for m in mixes:
        cache.plan(m)
    assert cache.stats.evictions == 1
    assert len(cache) == 2
    cache.plan(mixes[0])                           # evicted -> miss again
    assert cache.stats.misses == 4
    assert cache.stats.hit_rate == pytest.approx(0.0)
    cache.plan(mixes[0])
    assert cache.stats.hits == 1


# --------------------------------------------------------------------- #
# ShardArrays / pool
# --------------------------------------------------------------------- #
def test_shard_arrays_accounting_matches_objects():
    shards = [Shard(0, 0, 100, 1), Shard(0, 100, 300, 0),
              Shard(1, 0, 112, 1)]
    plan = ShardingPlan(doc_lens=np.asarray([400, 112]), shards=shards,
                        num_workers=2)
    np.testing.assert_array_equal(plan.tokens_per_worker(), [300, 212])
    w = plan.workload_per_worker()
    assert w[0] == sum(s.workload() for s in shards if s.worker == 0)
    np.testing.assert_array_equal(plan.nonlast_tokens_per_worker(),
                                  [0, 100])
    assert plan.comm_tokens() == 100
    assert plan.shards_of_worker(1) == [shards[0], shards[2]]


def test_merge_adjacent_shards_vectorized():
    merged = merge_adjacent_shards([
        Shard(0, 64, 64, 1), Shard(0, 0, 64, 1), Shard(0, 128, 10, 0),
        Shard(1, 0, 8, 0),
    ])
    assert merged == [Shard(0, 0, 128, 1), Shard(0, 128, 10, 0),
                      Shard(1, 0, 8, 0)]
    assert ShardArrays.empty().merged().to_shards() == []


def test_plan_many_preserves_order():
    mixes = [np.asarray([256, 256]), np.asarray([128, 384]),
             np.asarray([512 - 8, 8])]
    plans = plan_many(lambda l: flashcp_plan(l, 2)[0], mixes, workers=2)
    for lens, plan in zip(mixes, plans):
        np.testing.assert_array_equal(plan.doc_lens, lens)
        validate_plan(plan)
