"""Serving-resilience tests (DESIGN.md §Serving-resilience): bounded
deadline-aware admission (strict-FIFO backoff, look-ahead + starvation
guard, shed-order correctness — properties via hypothesis where
available, fixed-seed fallback otherwise), fault-quarantine chaos
regressions (NaN logits, stuck slots) that fail on the pre-fix engine,
engine snapshot/kill/drain-restore bitwise parity, the step-cap and
duplicate-rid satellite bugfixes, and the outcome/latency
observability counters."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs import get_config, reduce_for_smoke
from repro.serve import (AdmissionConfig, ChaosInjector, EngineKilled,
                         Request, Scheduler, ServeEngine, parse_chaos)
from repro.serve.resilience import (deadline_slack, estimate_steps,
                                    shed_key)


def _smoke(arch="starcoder2_3b"):
    return reduce_for_smoke(get_config(arch))


def _req(rid, n=8, max_new=4, **kw):
    return Request(rid=rid, tokens=np.arange(n, dtype=np.int32),
                   max_new=max_new, **kw)


# ===================================================================== #
# admission: config + slack math
# ===================================================================== #
def test_admission_config_validates_policy():
    with pytest.raises(ValueError):
        AdmissionConfig(policy="lifo")


def test_estimate_and_slack():
    # 2 prompt chunks (the last yields token 1) + 3 decode steps
    assert estimate_steps(prompt_len=10, max_new=4, prefill_chunk=8) == 5
    r = _req(0, n=10, max_new=4, deadline_steps=9)
    r.submit_step = 2
    assert deadline_slack(r, clock=2, prefill_chunk=8) == 4
    assert deadline_slack(r, clock=7, prefill_chunk=8) == -1
    r2 = _req(1, n=10, max_new=4)            # no deadline: infinite slack
    assert deadline_slack(r2, 100, 8) == float("inf")


def test_shed_order_priority_then_slack_then_newest():
    # lowest priority sheds first; among equals, least slack; among
    # those, the newest arrival (highest rid)
    a = _req(0, n=8, max_new=4, deadline_steps=30, priority=1)
    b = _req(1, n=8, max_new=4, deadline_steps=5, priority=0)
    c = _req(2, n=8, max_new=4, deadline_steps=50, priority=0)
    d = _req(3, n=8, max_new=4, deadline_steps=5, priority=0)
    victim = min([a, b, c, d], key=lambda r: shed_key(r, 0, 8))
    assert victim is d                        # same (0, slack) as b, newer


# ===================================================================== #
# admission: FIFO backoff, look-ahead, starvation guard
# ===================================================================== #
def test_strict_fifo_head_blocks_everything():
    sc = Scheduler(2, 64, admission=AdmissionConfig(lookahead=0))
    for rid in range(3):
        assert sc.submit(_req(rid))
    placed = sc.admit(lambda r: None if r.rid == 0 else {})
    assert placed == []                       # head-of-line blocking
    assert [r.rid for r in sc.queue] == [0, 1, 2]


def test_lookahead_admits_past_blocked_head():
    sc = Scheduler(2, 64, admission=AdmissionConfig(lookahead=2))
    for rid in range(3):
        sc.submit(_req(rid))
    placed = sc.admit(lambda r: None if r.rid == 0 else {})
    assert [r.rid for _, r in placed] == [1, 2]
    assert [r.rid for r in sc.queue] == [0]   # head keeps its turn


def test_lookahead_is_bounded():
    sc = Scheduler(4, 64, admission=AdmissionConfig(lookahead=1))
    for rid in range(4):
        sc.submit(_req(rid))
    # rids 0 and 1 both blocked: probing stops after lookahead+1
    # blocked requests, so 2 and 3 stay queued despite free slots
    placed = sc.admit(lambda r: None if r.rid < 2 else {})
    assert placed == []
    assert [r.rid for r in sc.queue] == [0, 1, 2, 3]


def test_starvation_guard_pauses_lookahead_until_head_places():
    sc = Scheduler(1, 64, admission=AdmissionConfig(
        lookahead=4, starvation_limit=3))
    for rid in range(8):
        sc.submit(_req(rid))
    blocked = lambda r: None if r.rid == 0 else {}
    jumped = []
    for _ in range(3):                        # 3 jumps allowed
        placed = sc.admit(blocked)
        assert len(placed) == 1
        jumped.append(placed[0][1].rid)
        sc.slots[0] = None                    # free the slot (test-only)
    assert jumped == [1, 2, 3]
    # guard engaged: look-ahead is suspended, the head blocks admission
    assert sc.admit(blocked) == []
    assert sc.admit(blocked) == []
    # the head becomes placeable: it admits first, guard resets
    placed = sc.admit(lambda r: {})
    assert placed[0][1].rid == 0
    assert sc._head_skips == 0


def _admission_invariants_case(seed, n_ops):
    """Random submit/admit/retire traffic against a random unplaceable
    set: no request is ever lost or duplicated, the queue bound holds,
    strict FIFO admits in arrival order, and look-ahead never jumps a
    request over more than ``lookahead`` older waiting requests."""
    rng = np.random.default_rng(seed)
    lookahead = int(rng.integers(0, 4))
    max_queue = int(rng.integers(0, 6))
    policy = "deadline" if rng.integers(2) else "fifo"
    sc = Scheduler(2, 64, admission=AdmissionConfig(
        max_queue=max_queue, policy=policy, lookahead=lookahead,
        starvation_limit=4))
    unplaceable: set[int] = set()
    place = lambda r: None if r.rid in unplaceable else {}
    next_rid = 0
    submitted = []
    for _ in range(n_ops):
        op = rng.integers(3)
        if op == 0:
            rid = next_rid
            next_rid += 1
            if rng.integers(4) == 0:
                unplaceable.add(rid)
            sc.submit(_req(rid, n=int(1 + rng.integers(8)),
                           deadline_steps=int(rng.integers(-1, 20))))
            submitted.append(rid)
        elif op == 1:
            placed = sc.admit(place)
            queued = [r.rid for r in sc.queue]
            for _, r in placed:
                older_waiting = sum(1 for q in queued if q < r.rid)
                assert older_waiting <= lookahead, \
                    (r.rid, queued, lookahead)
            if lookahead == 0 and placed and queued:
                assert max(r.rid for _, r in placed) < min(queued)
        else:
            sc.clock += 1
            for s in list(sc.active_slots):
                if rng.integers(2):
                    sc.abort(s, "test retire", kind="test")
        if max_queue:
            assert len(sc.queue) <= max_queue
    tracked = [r.rid for r in sc.queue] \
        + [sc.slots[s].request.rid for s in sc.active_slots] \
        + list(sc.finished)
    assert len(tracked) == len(set(tracked)), "request double-tracked"
    assert set(tracked) == set(submitted), "request lost"


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_ops=st.integers(1, 80))
    def test_admission_invariants(seed, n_ops):
        _admission_invariants_case(seed, n_ops)
else:
    @pytest.mark.parametrize("seed,n_ops",
                             [(0, 40), (1, 80), (2, 17), (3, 66),
                              (4, 80), (5, 55), (6, 29), (7, 80)])
    def test_admission_invariants(seed, n_ops):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _admission_invariants_case(seed, n_ops)


# ===================================================================== #
# admission: overload shedding
# ===================================================================== #
def test_fifo_overflow_sheds_incoming():
    sc = Scheduler(1, 64, admission=AdmissionConfig(max_queue=2))
    assert sc.submit(_req(0)) and sc.submit(_req(1))
    assert not sc.submit(_req(2))
    assert sc.finished[2]["status"] == "shed"
    assert sc.outcomes["shed"] == {"queue_full": 1}
    assert [r.rid for r in sc.queue] == [0, 1]


def test_deadline_overflow_sheds_least_slack_victim():
    sc = Scheduler(1, 64, admission=AdmissionConfig(
        max_queue=2, policy="deadline"))
    sc.submit(_req(0, deadline_steps=50))
    sc.submit(_req(1, deadline_steps=6))      # least slack: the victim
    assert sc.submit(_req(2, deadline_steps=50))   # admitted in its place
    assert sc.finished[1]["status"] == "shed"
    assert "least-slack" in sc.finished[1]["reason"]
    assert [r.rid for r in sc.queue] == [0, 2]


def test_deadline_expired_in_queue_sheds_on_admit():
    sc = Scheduler(1, 64, admission=AdmissionConfig(policy="deadline"))
    sc.submit(_req(0, n=8, max_new=4, deadline_steps=100))
    sc.submit(_req(1, n=8, max_new=4, deadline_steps=5))
    sc.clock = 30                 # rid 1's deadline is long gone
    placed = sc.admit(lambda r: {})
    assert [r.rid for _, r in placed] == [0]
    assert sc.finished[1]["status"] == "shed"
    assert sc.outcomes["shed"] == {"deadline_expired": 1}


# ===================================================================== #
# satellite bugfixes
# ===================================================================== #
def test_duplicate_rid_keeps_earlier_request():
    sc = Scheduler(1, 64)
    a, b = _req(7, n=8), _req(7, n=4)
    assert sc.submit(a) is True
    assert sc.submit(b) is False              # refused, not clobbered
    assert len(sc.queue) == 1 and sc.queue[0] is a
    assert sc.outcomes["rejected"] == {"duplicate_rid": 1}
    assert sc.duplicates[0]["rid"] == 7
    # the sharper pre-fix failure: a duplicate of an already-*finished*
    # rid used to overwrite that request's results entry
    sc.admit(lambda r: {})
    sc.start(0, first_token=3)
    sc.record(np.full((1,), 5), [0])          # runs a to completion...
    sc.record(np.full((1,), 5), [0])
    sc.record(np.full((1,), 5), [0])
    done = sc.finished[7]
    assert done["status"] == "ok" and len(done["tokens"]) == 4
    assert sc.submit(_req(7, n=4)) is False
    assert sc.finished[7] is done             # entry untouched


def test_step_cap_aborts_instead_of_dropping():
    """Pre-fix, run(max_steps) hitting the cap silently dropped every
    in-flight and queued request from the results dict."""
    cfg = _smoke()
    eng = ServeEngine(cfg, num_slots=2, max_len=64, prefill_chunk=16)
    eng.warmup(prompt_len=32)
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                   max_new=6)
    res = eng.run(max_steps=4)
    assert set(res) == {0, 1, 2, 3}, "requests lost at the step cap"
    statuses = {r: res[r]["status"] for r in res}
    assert all(v == "aborted" for v in statuses.values())
    # in-flight slots keep their partial tokens; queued ones never ran
    assert any("never admitted" in res[r]["reason"] for r in res)
    assert any(len(res[r]["tokens"]) > 0 for r in res)
    assert sum(eng.stats["aborted_by_reason"].values()) == 4


# ===================================================================== #
# chaos: fault quarantine
# ===================================================================== #
@pytest.fixture(scope="module")
def chaos_workload():
    cfg = _smoke()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(4)]
    kw = dict(num_slots=2, max_len=48, prefill_chunk=8)

    def run(chaos=None, watchdog=True, **extra):
        eng = ServeEngine(cfg, chaos=chaos, watchdog=watchdog,
                          **{**kw, **extra})
        eng.warmup(prompt_len=24)
        for p in prompts:
            eng.submit(p, max_new=5)
        return eng, eng.run(max_steps=300)

    _, baseline = run()
    assert all(baseline[r]["status"] == "ok" for r in baseline)
    return {"cfg": cfg, "prompts": prompts, "run": run,
            "baseline": baseline}


def _assert_healthy_bitwise(results, baseline, poisoned):
    for r in baseline:
        if r in poisoned:
            continue
        assert results[r]["status"] == "ok", (r, results[r])
        assert np.array_equal(results[r]["tokens"],
                              baseline[r]["tokens"]), \
            f"healthy request {r} diverged from the uninjected run"


def test_nan_decode_aborts_only_poisoned(chaos_workload):
    w = chaos_workload
    eng, res = w["run"](chaos=ChaosInjector(nan_logits={1: 6}))
    assert res[1]["status"] == "aborted"
    assert "non-finite" in res[1]["reason"]
    _assert_healthy_bitwise(res, w["baseline"], {1})
    assert eng.stats["aborted_by_reason"] == {"nan_logits": 1}


def test_nan_prefill_aborts_before_prefix_insert(chaos_workload):
    w = chaos_workload
    # poisoned from step 0: the fault fires on the request's *prefill*
    # final chunk, and its blocks must not reach the prefix cache
    eng, res = w["run"](chaos=ChaosInjector(nan_logits={0: 0}))
    assert res[0]["status"] == "aborted"
    assert "prefill" in res[0]["reason"]
    assert len(res[0]["tokens"]) == 0
    _assert_healthy_bitwise(res, w["baseline"], {0})
    if eng.prefix is not None:
        # every pool block referenced by the trie must be the cache's
        # own (refcount >= 1) — an aborted request leaks nothing
        for bid in eng.prefix._lru:
            assert eng.pool.refcount(bid) >= 1


def test_nan_served_silently_without_watchdog(chaos_workload):
    """The pre-fix engine: with the watchdog disabled, the poisoned
    request completes with status "ok" — NaN-sampled garbage is served
    to the caller with no signal anything went wrong."""
    w = chaos_workload
    _, res = w["run"](chaos=ChaosInjector(nan_logits={1: 6}),
                      watchdog=False)
    assert res[1]["status"] == "ok"           # silently corrupt


def test_stuck_slot_watchdog_aborts(chaos_workload):
    w = chaos_workload
    eng, res = w["run"](chaos=ChaosInjector(stuck={2: 4}),
                        stall_patience=4)
    assert res[2]["status"] == "aborted"
    assert "no scheduler progress" in res[2]["reason"]
    _assert_healthy_bitwise(res, w["baseline"], {2})
    assert eng.stats["aborted_by_reason"] == {"stall": 1}
    # quarantine bounded the damage: the run ended well before the cap
    assert eng.stats["steps"] < 100


def test_chaos_delay_is_counted(chaos_workload):
    w = chaos_workload
    eng, res = w["run"](chaos=ChaosInjector(delays={3: 0.05}))
    assert all(res[r]["status"] == "ok" for r in res)
    assert eng.stats["chaos_delay_s"] == pytest.approx(0.05)


def test_parse_chaos_specs():
    ch = parse_chaos(["1:6", "3:2"], ["2:8"], ["5:0.25"], kill_at=9)
    assert ch.nan_logits == {1: 6, 3: 2}
    assert ch.stuck == {2: 8}
    assert ch.delays == {5: 0.25}
    assert ch.kill_at == 9
    assert parse_chaos([], [], [], kill_at=-1) is None


# ===================================================================== #
# snapshot / restore
# ===================================================================== #
@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_kill_restore_bitwise_parity(tmp_path, layout):
    """Mid-decode kill -> restore on a fresh engine: every request
    finishes, tokens bitwise-equal to the uninterrupted run — including
    temperature sampling (per-request RNG counters restore)."""
    cfg = _smoke()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(4)]
    kw = dict(num_slots=2, max_len=48, prefill_chunk=8, kv_layout=layout)

    def submit_all(eng):
        for p in prompts:
            eng.submit(p, max_new=5, temperature=1.0, top_k=8)

    ref = ServeEngine(cfg, **kw)
    ref.warmup(prompt_len=24)
    submit_all(ref)
    expected = ref.run()

    snap = str(tmp_path / f"snap_{layout}")
    killed = ServeEngine(cfg, chaos=ChaosInjector(kill_at=7), **kw)
    killed.warmup(prompt_len=24)
    submit_all(killed)
    with pytest.raises(EngineKilled):
        killed.run(snapshot_every=3, snapshot_dir=snap)

    eng = ServeEngine(cfg, **kw)
    eng.warmup(prompt_len=24)
    step = eng.restore_snapshot(snap)
    assert step == 6                          # latest multiple of 3
    res = eng.run()
    assert set(res) == set(expected), "request lost across restore"
    for r in expected:
        assert res[r]["status"] == "ok"
        assert np.array_equal(res[r]["tokens"], expected[r]["tokens"]), r


def test_drain_restore_finishes_inflight(tmp_path):
    cfg = _smoke()
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(3)]
    kw = dict(num_slots=2, max_len=48, prefill_chunk=8)
    ref = ServeEngine(cfg, **kw)
    ref.warmup(prompt_len=24)
    for p in prompts:
        ref.submit(p, max_new=5)
    expected = ref.run()

    snap = str(tmp_path / "drain")
    d = ServeEngine(cfg, **kw)
    d.warmup(prompt_len=24)
    for p in prompts:
        d.submit(p, max_new=5)
    d.run(drain_at=5, snapshot_dir=snap)
    assert d.sched.has_work                   # drained mid-flight

    eng = ServeEngine(cfg, **kw)
    eng.warmup(prompt_len=24)
    eng.restore_snapshot(snap)
    res = eng.run()
    for r in expected:
        assert np.array_equal(res[r]["tokens"], expected[r]["tokens"]), r


def test_restore_rejects_geometry_mismatch(tmp_path):
    cfg = _smoke()
    eng = ServeEngine(cfg, num_slots=2, max_len=48, prefill_chunk=8)
    eng.snapshot(str(tmp_path))
    other = ServeEngine(cfg, num_slots=4, max_len=48, prefill_chunk=8)
    with pytest.raises(ValueError, match="geometry"):
        other.restore_snapshot(str(tmp_path))


# ===================================================================== #
# look-ahead under real pool pressure
# ===================================================================== #
def test_lookahead_fixes_head_of_line_blocking_in_engine():
    """A pool-hogging head backs off; a small request behind it fits.
    Strict FIFO serves it only after the head; look-ahead serves it
    immediately — both complete everything."""
    cfg = _smoke()
    rng = np.random.default_rng(7)
    big = [rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
           for _ in range(2)]
    small = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    def run(lookahead):
        eng = ServeEngine(cfg, num_slots=2, max_len=48, prefill_chunk=8,
                          num_blocks=4, prefix_cache=False,
                          admit_lookahead=lookahead)
        eng.warmup(prompt_len=40)
        a = eng.submit(big[0], max_new=8)     # 3 blocks: admits
        b = eng.submit(big[1], max_new=8)     # 3 blocks: backs off
        c = eng.submit(small, max_new=4)      # 1 block: fits now
        res = eng.run()
        assert all(res[r]["status"] == "ok" for r in (a, b, c))
        return res, (a, b, c)

    res_la, (a, b, c) = run(lookahead=4)
    res_fifo, _ = run(lookahead=0)
    # look-ahead: the small request finishes before the blocked big one
    # — and before the head itself
    assert res_la[c]["finish_step"] < res_la[b]["finish_step"]
    assert res_la[c]["finish_step"] < res_la[a]["finish_step"]
    # strict FIFO: it cannot start until the head retires and frees the
    # pool, so it finishes after the head
    assert res_fifo[c]["finish_step"] > res_fifo[a]["finish_step"]
    # and look-ahead strictly improves the small request's latency
    assert res_la[c]["latency_steps"] < res_fifo[c]["latency_steps"]


# ===================================================================== #
# observability
# ===================================================================== #
def test_latency_fields_and_percentiles(chaos_workload):
    w = chaos_workload
    eng, res = w["run"]()
    for r in res.values():
        assert {"submit_step", "finish_step", "latency_steps",
                "latency_s", "deadline_steps", "deadline_met"} <= set(r)
        assert r["latency_steps"] == r["finish_step"] - r["submit_step"]
        assert r["deadline_met"]              # no deadline + ok = met
    lat = eng.latency_percentiles()
    assert lat["n"] == len(res)
    steps = sorted(r["latency_steps"] for r in res.values())
    assert steps[0] <= lat["p50_steps"] <= lat["p99_steps"] <= steps[-1]
    # counters are live views of the scheduler's outcome dicts
    assert eng.stats["rejected_by_reason"] is eng.sched.outcomes["rejected"]
    assert eng.stats["shed_by_reason"] is eng.sched.outcomes["shed"]
    assert eng.stats["aborted_by_reason"] is eng.sched.outcomes["aborted"]


def test_deadline_met_recorded_on_completion(chaos_workload):
    w = chaos_workload
    cfg = w["cfg"]
    eng = ServeEngine(cfg, num_slots=2, max_len=48, prefill_chunk=8)
    eng.warmup(prompt_len=24)
    eng.submit(w["prompts"][0], max_new=5, deadline_steps=200)
    eng.submit(w["prompts"][1], max_new=5, deadline_steps=1)
    res = eng.run()
    assert res[0]["status"] == "ok" and res[0]["deadline_met"]
    assert res[1]["status"] == "ok" and not res[1]["deadline_met"]
