"""repro.analysis regression suite (DESIGN.md §Static-analysis).

Two halves per layer: the checkers pass *clean* on real builder
outputs, and every class of injected violation is caught by its
expected rule id — the rule ids are the contract CI reports on, so a
rename or a silently-dead check fails here.
"""

import importlib
import warnings

import numpy as np
import pytest

from repro.analysis import errors, format_findings
from repro.analysis.findings import RULES, Finding
from repro.analysis.hlo_audit import (CommBudget, audit_collectives,
                                      audit_donation, audit_host_transfers,
                                      audit_numerics, collective_totals,
                                      kv_exchange_budget)
from repro.analysis.lint import lint_source
from repro.analysis.plan_check import (check_block_tables, check_encoding,
                                       check_plan, check_serve_state,
                                       check_work_queue)
from repro.kernels.doc_attention import (FLAG_LAST, FLAG_VALID,
                                         build_block_tables,
                                         build_work_queue)
from repro.launch.hlo_analysis import (analyze_hlo, collect_collectives,
                                       schedule_model)
from repro.planner import encode_plan
from repro.planner.registry import get_planner

DOC_LENS = np.asarray([300, 120, 260, 180, 164], dtype=np.int64)  # sum 1024
N = 4


def rule_ids(findings):
    return {f.rule for f in findings}


@pytest.fixture(scope="module")
def flashcp_plan():
    return get_planner("flashcp")(DOC_LENS, N)


@pytest.fixture(scope="module")
def flashcp_enc(flashcp_plan):
    return encode_plan(flashcp_plan)


def _rank_metadata(enc, n):
    """Blocking flashcp layout: [local | gathered w/ self-masked]."""
    ld = enc.doc.reshape(n, enc.t_loc)
    lp = enc.pos.reshape(n, enc.t_loc)
    L = enc.gath_doc.shape[-1]
    gd = np.broadcast_to(enc.gath_doc, (n, L)).copy()
    seg = np.arange(L) // enc.buf_len
    gd[seg[None, :] == np.arange(n)[:, None]] = -2
    gp = np.broadcast_to(enc.gath_pos, (n, L))
    return (ld, lp, np.concatenate([ld, gd], -1),
            np.concatenate([lp, gp], -1))


# ------------------------------------------------------------------ #
# findings plumbing
# ------------------------------------------------------------------ #
def test_finding_registry():
    f = Finding("PLAN001", "error", "here", "msg", hint="do x")
    assert "PLAN001" in f.render() and "do x" in f.render()
    with pytest.raises(AssertionError):
        Finding("NOPE999", "error", "here", "msg")
    assert all(RULES[r] for r in RULES)     # every rule has an invariant


# ------------------------------------------------------------------ #
# Layer 1: clean on real builder outputs
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("name", ["flashcp", "contiguous", "llama3",
                                  "per_doc"])
def test_clean_planner_outputs(name):
    planner = get_planner(name)
    plan = planner(DOC_LENS, N)
    fs = check_plan(plan,
                    require_equal_tokens=planner.info.needs_equal_tokens)
    assert not fs, format_findings(fs)
    enc = encode_plan(plan)
    fs = check_encoding(plan, enc)
    assert not fs, format_findings(fs)


def test_clean_tables_and_queues(flashcp_enc):
    ld, lp, kd, kp = _rank_metadata(flashcp_enc, N)
    t = build_block_tables(ld, lp, kd, kp, block_q=128, block_k=128)
    fs = check_block_tables(ld, lp, kd, kp, t.kv_idx, t.kv_nvis,
                            block_q=128, block_k=128)
    fs += check_work_queue(t.kv_idx, t.kv_nvis, t.fq_row, t.fq_col,
                           t.fq_flags)
    fs += check_work_queue(t.q_idx, t.q_nvis, t.rq_row, t.rq_col,
                           t.rq_flags)
    assert not fs, format_findings(fs)


# ------------------------------------------------------------------ #
# Layer 1: injected violations, by expected rule id
# ------------------------------------------------------------------ #
def test_double_covered_token_is_plan001():
    plan = get_planner("flashcp")(DOC_LENS, N)
    plan.arrays.length[0] += 1          # shard 0 now overlaps its neighbor
    fs = check_plan(plan, require_equal_tokens=False)
    assert "PLAN001" in rule_ids(fs)


def test_coverage_gap_is_plan001():
    plan = get_planner("flashcp")(DOC_LENS, N)
    plan.arrays.length[0] -= 1
    fs = check_plan(plan, require_equal_tokens=False)
    assert "PLAN001" in rule_ids(fs)


def test_out_of_range_shard_is_plan002():
    plan = get_planner("flashcp")(DOC_LENS, N)
    plan.arrays.worker[0] = N + 3
    fs = check_plan(plan)
    assert rule_ids(fs) == {"PLAN002"}   # range errors preempt the rest


def test_unequal_tokens_is_plan003():
    plan = get_planner("contiguous")(DOC_LENS, N)
    moved = plan.arrays.worker[0]
    plan.arrays.worker[0] = (moved + 1) % N   # coverage intact, Eq.2 broken
    fs = check_plan(plan, require_equal_tokens=True)
    assert "PLAN003" in rule_ids(fs)


def test_imbalance_bound_is_plan004(flashcp_plan):
    bad = flashcp_plan.imbalance_ratio() * 0.5
    fs = check_plan(flashcp_plan, max_imbalance=bad)
    assert "PLAN004" in rule_ids(fs)


def test_corrupt_perm_is_enc001(flashcp_plan, flashcp_enc):
    import copy
    enc = copy.deepcopy(flashcp_enc)
    valid = np.flatnonzero(enc.perm >= 0)
    enc.perm[valid[0]] = enc.perm[valid[1]]    # duplicate packed position
    fs = check_encoding(flashcp_plan, enc)
    assert "ENC001" in rule_ids(fs)


def test_dropped_send_is_enc005(flashcp_plan, flashcp_enc):
    import copy
    enc = copy.deepcopy(flashcp_enc)
    j, s = np.unravel_index(int(np.argmax(enc.send_idx >= 0)),
                            enc.send_idx.shape)
    enc.send_idx[j, s:] = np.roll(enc.send_idx[j, s:], -1)
    enc.send_idx[j, -1] = -1                  # drop one sent token
    flat = j * enc.buf_len + s
    enc.gath_doc[flat:(j + 1) * enc.buf_len] = np.roll(
        enc.gath_doc[flat:(j + 1) * enc.buf_len], -1)
    enc.gath_pos[flat:(j + 1) * enc.buf_len] = np.roll(
        enc.gath_pos[flat:(j + 1) * enc.buf_len], -1)
    enc.gath_doc[(j + 1) * enc.buf_len - 1] = -1
    enc.gath_pos[(j + 1) * enc.buf_len - 1] = 0
    fs = check_encoding(flashcp_plan, enc)
    assert "ENC005" in rule_ids(fs)


def test_pruned_table_block_is_tab001(flashcp_enc):
    ld, lp, kd, kp = _rank_metadata(flashcp_enc, N)
    t = build_block_tables(ld, lp, kd, kp, block_q=128, block_k=128)
    # drop q-block 0's diagonal visit (kv-block 0 holds the query tokens
    # themselves, so causal self-visibility makes it provably required)
    idx, nvis = t.kv_idx.copy(), t.kv_nvis.copy()
    assert idx[0, 0, 0] == 0 and nvis[0, 0] > 0
    idx[0, 0, :-1] = idx[0, 0, 1:]
    nvis[0, 0] -= 1
    fs = check_block_tables(ld, lp, kd, kp, idx, nvis,
                            block_q=128, block_k=128)
    assert "TAB001" in rule_ids(fs)


def test_misflagged_queue_is_wq001(flashcp_enc):
    ld, lp, kd, kp = _rank_metadata(flashcp_enc, N)
    t = build_block_tables(ld, lp, kd, kp, block_q=128, block_k=128)
    flags = t.fq_flags.copy()
    b, s = np.unravel_index(int(np.argmax((flags & FLAG_LAST) > 0)),
                            flags.shape)
    flags[b, s] &= ~FLAG_LAST          # output never written back
    fs = check_work_queue(t.kv_idx, t.kv_nvis, t.fq_row, t.fq_col, flags)
    assert "WQ001" in rule_ids(fs)


def test_non_lpt_order_is_wq002():
    # two rows, visit counts 2 and 1 — schedule the short row first
    idx = np.asarray([[[0, 1], [1, 0]]], dtype=np.int32)
    nvis = np.asarray([[2, 1]], dtype=np.int32)
    row, col, flags = build_work_queue(idx, nvis)
    assert not check_work_queue(idx, nvis, row, col, flags)
    assert row[0].tolist() == [0, 0, 1]             # LPT: long row first
    perm = np.asarray([2, 0, 1])                    # row 1's step first
    fs = check_work_queue(idx, nvis, row[:, perm], col[:, perm],
                          flags[:, perm])
    assert "WQ002" in rule_ids(fs)


def test_dropped_visit_is_wq003(flashcp_enc):
    ld, lp, kd, kp = _rank_metadata(flashcp_enc, N)
    t = build_block_tables(ld, lp, kd, kp, block_q=128, block_k=128)
    col = t.fq_col.copy()
    b, s = np.unravel_index(int(np.argmax((t.fq_flags & FLAG_VALID) > 0)),
                            col.shape)
    col[b, s] = (col[b, s] + 1) % t.kv_idx.shape[-1]   # visit wrong block
    fs = check_work_queue(t.kv_idx, t.kv_nvis, t.fq_row, col, t.fq_flags)
    assert "WQ003" in rule_ids(fs)


# ------------------------------------------------------------------ #
# Layer 1: serve block-table conservation
# ------------------------------------------------------------------ #
def _serve_scenario():
    from repro.serve.block_pool import BlockPool
    from repro.serve.prefix import PrefixCache
    pool = BlockPool(num_blocks=16, block_size=4)
    pc = PrefixCache(block_size=4)
    tokens = list(range(50, 62))             # 3 full blocks
    a = pool.alloc(4)
    pc.insert(tokens, a[:3], pool)
    shared = pc.match(tokens)
    pool.retain(shared)
    b = shared + pool.alloc(1)
    return pool, pc, {"a": list(a), "b": list(b)}


def test_serve_scenario_clean():
    pool, pc, tables = _serve_scenario()
    assert not check_serve_state(pool, tables, pc)
    pool.release(tables.pop("a"))
    assert not check_serve_state(pool, tables, pc)


def test_leaked_reference_is_srv002():
    pool, pc, tables = _serve_scenario()
    pool.retain([tables["a"][0]])            # reference with no holder
    fs = check_serve_state(pool, tables, pc)
    assert "SRV002" in rule_ids(fs)


def test_unregistered_sharing_is_srv001():
    pool, pc, tables = _serve_scenario()
    tables["c"] = [tables["a"][3]]           # alias a's unique block
    pool.retain(tables["c"])
    fs = check_serve_state(pool, tables, pc)
    assert "SRV001" in rule_ids(fs)


def test_out_of_range_block_is_srv003():
    pool, pc, tables = _serve_scenario()
    tables["a"][-1] = 99
    fs = check_serve_state(pool, tables, pc)
    assert "SRV003" in rule_ids(fs)


# ------------------------------------------------------------------ #
# Layer 2: HLO audit on synthetic modules
# ------------------------------------------------------------------ #
NESTED_WHILE_HLO = """\
HloModule nested, input_output_alias={ {0}: (0, {}, may-alias) }

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

%inner_cond (ip: f32[1024]) -> pred[] {
  %ip = f32[1024]{0} parameter(0)
  ROOT %lt = pred[] constant(true)
}

%inner_body (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%sum
}

%outer_cond (oq: f32[1024]) -> pred[] {
  %oq = f32[1024]{0} parameter(0)
  ROOT %lt2 = pred[] constant(true)
}

%outer_body (q: f32[1024]) -> f32[1024] {
  %q = f32[1024]{0} parameter(0)
  ROOT %w2 = f32[1024]{0} while(%q), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"2"}}
}

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  ROOT %w1 = f32[1024]{0} while(%x), condition=%outer_cond, body=%outer_body, backend_config={"known_trip_count":{"n":"3"}}
}
"""

#: all-reduce of 4096B over a 4-group: wire 2*4096*3/4 = 6144 per trip
AR_WIRE = 6144.0


def test_collect_collectives_nested_trips():
    colls = collect_collectives(NESTED_WHILE_HLO)
    assert len(colls) == 1
    c = colls[0]
    assert c.kind == "all-reduce" and c.group_size == 4
    assert c.trips == 6.0                          # 3 outer x 2 inner
    assert collective_totals(NESTED_WHILE_HLO) == {
        "all-reduce": AR_WIRE * 6}
    assert analyze_hlo(NESTED_WHILE_HLO).collective_wire_bytes == \
        pytest.approx(AR_WIRE * 6)


def test_schedule_model_edge_cases():
    # empty program
    empty = schedule_model("")
    assert empty.makespan_s == 0.0 and empty.collective_count == 0.0
    assert analyze_hlo("").flops == 0.0
    assert collect_collectives("") == []
    # collective-only program: all comm time is exposed
    coll_only = ("ENTRY %m (x: f32[1024]) -> f32[1024] {\n"
                 "  %x = f32[1024]{0} parameter(0)\n"
                 "  ROOT %ar = f32[1024]{0} all-reduce(%x), "
                 "replica_groups={{0,1,2,3}}, to_apply=%sum\n}\n")
    s = schedule_model(coll_only, wire_per_s=1.0)
    assert s.collective_count == 1
    assert s.compute_busy_s == 0.0
    assert s.makespan_s > 0
    assert s.exposed_comm_s == pytest.approx(s.makespan_s)
    # nested while: both streams serialize, trips multiply through
    s = schedule_model(NESTED_WHILE_HLO, wire_per_s=1.0)
    assert s.collective_count == 6
    assert s.comm_busy_s == pytest.approx(AR_WIRE * 6)


def test_unpredicted_collective_is_hlo101():
    budget = CommBudget(allowed={"collective-permute": 1e9})
    fs = audit_collectives(NESTED_WHILE_HLO, budget)
    assert rule_ids(fs) == {"HLO101"}
    assert errors(fs)


def test_planted_all_gather_is_hlo101(flashcp_enc):
    """The acceptance-criteria injection: a stray all-gather planted in
    an otherwise budget-clean program is caught as HLO101."""
    budget = kv_exchange_budget(flashcp_enc.buf_len, N, 2, 64,
                                dtype_bytes=4, overlap="chunked")
    kind = next(iter(budget.allowed))
    clean = ("ENTRY %m (x: f32[128]) -> f32[128] {\n"
             "  %x = f32[128]{0} parameter(0)\n"
             f"  ROOT %cp = f32[128]{{0}} {kind}(%x), "
             "source_target_pairs={{0,1},{1,2},{2,3},{3,0}}\n}\n")
    assert not audit_collectives(clean, budget)
    planted = clean.replace(
        "ENTRY %m (x: f32[128]) -> f32[128] {\n",
        "ENTRY %m (x: f32[128]) -> f32[128] {\n"
        "  %ag = f32[4,2,8192,64]{3,2,1,0} all-gather(%x), "
        "replica_groups=[1,4], dimensions={1}\n")
    fs = audit_collectives(planted, budget)
    assert "HLO101" in rule_ids(fs)


def test_over_budget_is_hlo102():
    budget = CommBudget(allowed={"all-reduce": AR_WIRE * 3})  # half the real
    fs = audit_collectives(NESTED_WHILE_HLO, budget)
    assert rule_ids(fs) == {"HLO102"}


def test_full_gather_is_hlo103():
    text = ("ENTRY %m (x: f32[256]) -> f32[1024] {\n"
            "  %x = f32[256]{0} parameter(0)\n"
            "  ROOT %ag = f32[1024]{0} all-gather(%x), "
            "replica_groups=[1,4], dimensions={0}\n}\n")
    budget = CommBudget(allowed={"all-gather": 1e9},
                        full_gather_bytes=4096)
    fs = audit_collectives(text, budget)
    assert "HLO103" in rule_ids(fs)


def test_f64_is_hlo104():
    fs = audit_numerics("  %c = f64[8]{0} convert(%b)")
    assert rule_ids(fs) == {"HLO104"}
    assert not audit_numerics("  %c = f32[8]{0} convert(%b)")


def test_host_transfer_is_hlo105():
    fs = audit_host_transfers("  %o = token[] outfeed(%a, %t)")
    assert rule_ids(fs) == {"HLO105"}
    fs = audit_host_transfers(
        '  %cc = f32[2]{0} custom-call(%a), '
        'custom_call_target="xla_python_cpu_callback"')
    assert rule_ids(fs) == {"HLO105"}
    assert not audit_host_transfers("  %s = f32[2]{0} add(%a, %b)")


def test_lost_donation_is_hlo106():
    # params 0 and 2 aliased; the step builder donated 0, 1 and 2
    text = ("HloModule m, input_output_alias={ {0}: (0, {}, may-alias), "
            "{1}: (2, {}, may-alias) }\n\n"
            "ENTRY %m (a: f32[1024], b: f32[1024], c: f32[1024]) "
            "-> f32[1024] {\n"
            "  %a = f32[1024]{0} parameter(0)\n"
            "  %b = f32[1024]{0} parameter(1)\n"
            "  %c = f32[1024]{0} parameter(2)\n"
            "  ROOT %s = f32[1024]{0} add(%a, %b)\n}\n")
    fs = audit_donation(text, expect_params=[0, 1, 2])
    assert [f.rule for f in fs] == ["HLO106"]
    assert "parameter 1" in fs[0].message
    assert not audit_donation(text, expect_params=[0, 2])
    # advisory mode: the big non-donated param is a warning
    fs = audit_donation(text, min_bytes=4096)
    assert fs and all(f.severity == "warning" for f in fs)


def test_kv_exchange_budget_matches_comm_model():
    from repro.core.workload import comm_bytes
    b = kv_exchange_budget(128, 4, 2, 16, dtype_bytes=4, fwd_and_bwd=True,
                           batch=1, layers=4)
    payload = 4 * comm_bytes(128, 4, 2, 16, dtype_bytes=4,
                             fwd_and_bwd=True)
    meta = comm_bytes(128, 4, 1, 1, dtype_bytes=4, fwd_and_bwd=False)
    assert b.allowed == {"collective-permute": float(payload + meta)}
    b = kv_exchange_budget(256, 4, 2, 16, overlap="none")
    assert set(b.allowed) == {"all-gather"}


# ------------------------------------------------------------------ #
# Layer 3: source lint
# ------------------------------------------------------------------ #
PLANNER_PATH = "src/repro/planner/fake.py"


def test_unseeded_shuffle_is_rng001():
    src = "import random\n\ndef plan(xs):\n    random.shuffle(xs)\n"
    fs = lint_source(src, PLANNER_PATH)
    assert "RNG001" in rule_ids(fs)
    seeded = ("import random\n\ndef plan(xs):\n"
              "    random.Random(0).shuffle(xs)\n")
    assert "RNG001" not in rule_ids(lint_source(seeded, PLANNER_PATH))
    # outside planner/dispatch the rule does not fire
    assert "RNG001" not in rule_ids(lint_source(src, "src/repro/x.py"))


def test_unseeded_default_rng_is_rng001():
    src = "import numpy as np\n\ndef plan():\n    return np.random.default_rng()\n"
    assert "RNG001" in rule_ids(lint_source(src, PLANNER_PATH))
    src = "import numpy as np\n\ndef plan():\n    return np.random.default_rng(0)\n"
    assert "RNG001" not in rule_ids(lint_source(src, PLANNER_PATH))


def test_set_iteration_is_rng002():
    src = "def plan(xs):\n    for x in set(xs):\n        x\n"
    assert "RNG002" in rule_ids(lint_source(src, PLANNER_PATH))
    src = "def plan(xs):\n    for x in sorted(set(xs)):\n        x\n"
    assert "RNG002" not in rule_ids(lint_source(src, PLANNER_PATH))


def test_traced_branch_in_kernel_is_ker001():
    src = ("def attn_kernel(q_ref, k_ref, o_ref):\n"
           "    x = q_ref[0, 0]\n"
           "    if x > 0:\n"
           "        o_ref[0, 0] = x\n")
    fs = lint_source(src, "src/repro/kernels/fake.py")
    assert "KER001" in rule_ids(fs)
    ok = ("def attn_kernel(q_ref, k_ref, o_ref, *, block: int):\n"
          "    if block > 128:\n"
          "        o_ref[0, 0] = q_ref[0, 0]\n")
    assert "KER001" not in rule_ids(
        lint_source(ok, "src/repro/kernels/fake.py"))


def test_shim_import_is_dep001():
    src = "from repro.core.plan import ShardingPlan\n\nShardingPlan\n"
    assert "DEP001" in rule_ids(lint_source(src, "src/repro/launch/x.py"))
    # the shims themselves may re-export
    assert "DEP001" not in rule_ids(
        lint_source(src, "src/repro/core/plan.py"))
    ok = "from repro.planner.plan import ShardingPlan\n\nShardingPlan\n"
    assert "DEP001" not in rule_ids(lint_source(ok, "src/repro/launch/x.py"))


def test_hygiene_rules():
    assert "HYG001" in rule_ids(lint_source("import os\n", "x.py"))
    assert "HYG002" in rule_ids(
        lint_source("def f(xs=[]):\n    return xs\n", "x.py"))
    assert "HYG003" in rule_ids(
        lint_source("def f(list):\n    return list\n", "x.py"))
    clean = "import os\n\n\ndef f(xs=()):\n    return os.name, xs\n"
    assert not lint_source(clean, "x.py")


def test_noqa_suppression():
    src = "import os  # noqa: HYG001\n"
    assert not lint_source(src, "x.py")
    src = "import os  # noqa\n"
    assert not lint_source(src, "x.py")


def test_repo_is_lint_clean():
    from pathlib import Path

    from repro.analysis.lint import default_targets, lint_paths
    root = Path(__file__).resolve().parent.parent
    fs = lint_paths(default_targets(root), root=root)
    assert not fs, format_findings(fs)


# ------------------------------------------------------------------ #
# deprecated shims
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("mod", ["plan", "heuristic", "baselines", "ilp",
                                 "plan_exec"])
def test_core_shims_warn_on_import(mod):
    shim = importlib.import_module(f"repro.core.{mod}")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        importlib.reload(shim)
    assert any(issubclass(x.category, DeprecationWarning) for x in w), mod
