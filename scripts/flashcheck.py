#!/usr/bin/env python
"""flashcheck — static plan/HLO/source verifier (DESIGN.md §Static-analysis).

Proves the comm-efficiency and determinism invariants *before anything
runs*: every check here is host-side numpy, AST walking, or AOT HLO
inspection — no step is ever executed.

Three layers:

1. **Plan checks** (``repro.analysis.plan_check``) — run every
   registered-planner output for the whole config zoo through the
   structural invariants: exact-once coverage, Eq.2 equal tokens,
   causal-closure of the Eq.5 compact exchange, block-table soundness
   against the dense causal-visibility oracle, work-queue flag/LPT
   discipline, and serve block-pool refcount conservation.
2. **Autotune space checks** (``repro.autotune.space``) — the tuner's
   candidate enumeration must be deterministic (two runs, bit-identical
   keys, sorted, deduplicated) and every emitted candidate must pass
   its own re-derivable admissibility predicate (registered strategy,
   family filter, at least one dispatcher-approved CP degree).
3. **HLO audit** (``repro.analysis.hlo_audit``) — opt-in via
   ``--hlo-attn`` / ``--hlo-train`` (subprocesses with a simulated
   device mesh): the lowered programs' collectives must match the
   analytic comm budget byte-for-byte (1% slack).
4. **Source lint** (``repro.analysis.lint``) — unseeded RNG and
   set-order dependence in planner/dispatch/autotune code, traced-value
   python branches in Pallas kernel bodies, deprecated-shim imports,
   import hygiene.

Exit status 0 = no error-severity findings; 1 = at least one.

Usage::

    python scripts/flashcheck.py              # lint + full plan sweep
    python scripts/flashcheck.py --fast       # lint + 2-arch plan spot
    python scripts/flashcheck.py --hlo-attn   # + attention-island audit
    python scripts/flashcheck.py --hlo-train  # + train-step audit
"""

from __future__ import annotations

import argparse
import hashlib
import os
import subprocess
import sys
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import errors, format_findings  # noqa: E402
from repro.analysis.lint import default_targets, lint_paths  # noqa: E402
from repro.analysis.plan_check import (  # noqa: E402
    check_block_tables, check_encoding, check_plan, check_serve_state,
    check_work_queue)

CONTEXT_LEN = 1024
CP_DEGREES = (1, 2, 4)
BLOCK = 128
FAST_ARCHS = ("starcoder2_3b", "xlstm_350m")

#: loose declared bound for the balanced planners (PLAN004); FlashCP's
#: Eq.3 objective lands far below this on every zoo mix — tripping it
#: means the balancer regressed, not that the mix is adversarial.
BALANCED_IMBALANCE = 1.5


def arch_doc_mix(arch: str, context_len: int = CONTEXT_LEN) -> np.ndarray:
    """Deterministic per-arch document-length mix summing to the context.

    Seeded from a stable digest of the arch name (``hash()`` is
    process-salted), lognormal-ish so every arch exercises a different
    long-tail shape."""
    seed = int.from_bytes(
        hashlib.blake2b(arch.encode(), digest_size=4).digest(), "little")
    rng = np.random.default_rng(seed)
    lens: list[int] = []
    left = context_len
    while left > 0:
        d = int(min(max(rng.lognormal(mean=4.5, sigma=1.0), 8), left))
        lens.append(d)
        left -= d
    return np.asarray(lens, dtype=np.int64)


def _flashcp_kv_metadata(enc, num_workers: int):
    """Per-rank KV metadata of the blocking flashcp layout:
    ``[local | gathered-with-self-masked]`` (mirrors the device concat in
    :func:`repro.planner.encode.emit_visit_tables`)."""
    N = num_workers
    t_loc, buf = enc.t_loc, enc.buf_len
    ld = enc.doc.reshape(N, t_loc)
    lp = enc.pos.reshape(N, t_loc)
    L = enc.gath_doc.shape[-1]
    gd = np.broadcast_to(enc.gath_doc, (N, L)).copy()
    seg = np.arange(L) // buf
    gd[seg[None, :] == np.arange(N)[:, None]] = -2
    gp = np.broadcast_to(enc.gath_pos, (N, L))
    kd = np.concatenate([ld, gd], axis=-1)
    kp = np.concatenate([lp, gp], axis=-1)
    return ld, lp, kd, kp


def check_config(arch: str, cp: int) -> list:
    """Layer-1 sweep for one (arch, CP degree): plan, encoding, rect
    tables, and both flat work queues, under the strategy the step
    builder would actually pick for the family."""
    from repro.configs import get_config
    from repro.kernels.doc_attention import build_block_tables
    from repro.launch.steps import effective_strategy
    from repro.planner import encode_plan
    from repro.planner.registry import get_planner

    cfg = get_config(arch)
    strategy = effective_strategy(cfg, "flashcp")
    planner = get_planner(strategy)
    doc_lens = arch_doc_mix(arch)
    ctx = f"{arch}/cp{cp}/{strategy}"

    plan = planner(doc_lens, cp)
    max_imb = BALANCED_IMBALANCE if strategy in ("flashcp", "bnb") else None
    out = check_plan(plan, max_imbalance=max_imb,
                     require_equal_tokens=planner.info.needs_equal_tokens,
                     context=f"{ctx}/plan")
    enc = encode_plan(plan)
    out += check_encoding(plan, enc, context=f"{ctx}/encoding")

    if planner.info.comm_style == "flashcp":
        ld, lp, kd, kp = _flashcp_kv_metadata(enc, cp)
    else:  # full-exchange baselines attend the whole packed sequence
        t_loc = enc.t_loc
        ld = enc.doc.reshape(cp, t_loc)
        lp = enc.pos.reshape(cp, t_loc)
        kd = np.broadcast_to(enc.doc, (cp, enc.doc.shape[0]))
        kp = np.broadcast_to(enc.pos, (cp, enc.pos.shape[0]))

    t = build_block_tables(ld, lp, kd, kp, block_q=BLOCK, block_k=BLOCK)
    out += check_block_tables(ld, lp, kd, kp, t.kv_idx, t.kv_nvis,
                              block_q=BLOCK, block_k=BLOCK,
                              context=f"{ctx}/rect")
    out += check_work_queue(t.kv_idx, t.kv_nvis,
                            t.fq_row, t.fq_col, t.fq_flags,
                            context=f"{ctx}/flat-fq")
    out += check_work_queue(t.q_idx, t.q_nvis,
                            t.rq_row, t.rq_col, t.rq_flags,
                            context=f"{ctx}/flat-rq")
    return out


def check_serve_scenario() -> list:
    """SRV001-SRV003 over a live prefix-sharing scenario: two requests
    sharing a cached 3-block prefix, then the first request draining."""
    from repro.serve.block_pool import BlockPool
    from repro.serve.prefix import PrefixCache

    pool = BlockPool(num_blocks=32, block_size=16)
    pc = PrefixCache(block_size=16)
    tokens = list(range(100, 148))                   # 3 full blocks

    blocks_a = pool.alloc(4)                         # prefix + 1 unique
    pc.insert(tokens, blocks_a[:3], pool)
    shared = pc.match(tokens)
    pool.retain(shared)
    blocks_b = shared + pool.alloc(2)
    tables = {"req_a": list(blocks_a), "req_b": list(blocks_b)}
    out = check_serve_state(pool, tables, pc, context="serve/steady")

    pool.release(tables.pop("req_a"))                # req_a drains
    out += check_serve_state(pool, tables, pc, context="serve/drained")
    return out


def check_autotune() -> list:
    """Layer-2 sweep over small CPU-mesh search spaces: enumeration must
    be deterministic (sorted, deduplicated, stable across calls) and
    every emitted candidate must pass its own admissibility predicate
    (TUNE001/TUNE002)."""
    from repro.analysis.findings import Finding
    from repro.autotune import (TuneProblem, candidate_admissible,
                                candidate_degrees, enumerate_candidates)

    problems = {
        "xla-2way": TuneProblem(data=1, model=2, context_len=512, seqs=2,
                                quantum=1, attention_impl="xla"),
        "pallas-2way": TuneProblem(data=1, model=2, context_len=CONTEXT_LEN,
                                   seqs=2, quantum=BLOCK,
                                   attention_impl="pallas"),
        "hybrid-4way": TuneProblem(data=1, model=4, context_len=2048,
                                   seqs=2, quantum=BLOCK,
                                   attention_impl="pallas",
                                   family="hybrid"),
    }
    out: list = []
    for name, problem in problems.items():
        cands = enumerate_candidates(problem)
        keys = [c.key() for c in cands]
        if keys != sorted(set(keys)):
            out.append(Finding(
                "TUNE001", "error", f"autotune/{name}",
                "enumeration is not sorted+deduplicated by Candidate.key",
                hint="enumerate_candidates must emit sorted unique keys"))
        rerun = [c.key() for c in enumerate_candidates(problem)]
        if rerun != keys:
            out.append(Finding(
                "TUNE001", "error", f"autotune/{name}",
                f"two enumerations disagree ({len(keys)} vs "
                f"{len(rerun)} candidates)",
                hint="enumeration must depend only on (problem, space)"))
        for cand in cands:
            if not candidate_admissible(cand, problem):
                out.append(Finding(
                    "TUNE002", "error", f"autotune/{name}",
                    f"enumerated candidate fails admissibility: "
                    f"{cand.key()}",
                    hint="enumerate_candidates must filter through "
                         "candidate_admissible"))
                continue
            degrees = candidate_degrees(cand, problem)
            bad = [g for g in degrees
                   if problem.model % g or problem.context_len % g]
            if not degrees or bad:
                out.append(Finding(
                    "TUNE002", "error", f"autotune/{name}",
                    f"candidate {cand.key()} has invalid degrees "
                    f"{bad or degrees}",
                    hint="cp_degree_options must enforce g | model and "
                         "g | context"))
    return out


def run_lint() -> list:
    return lint_paths(default_targets(ROOT), root=ROOT)


def run_hlo(which: str) -> int:
    """Run one HLO audit phase in a subprocess (it forces its own
    simulated device count before importing jax)."""
    script = ROOT / "tests" / "multidevice" / "hlo_audit_check.py"
    proc = subprocess.run(
        [sys.executable, str(script), which], cwd=ROOT,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src"),
             "JAX_PLATFORMS": "cpu"})
    return proc.returncode


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="flashcheck", description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="lint + plan checks on two small archs only "
                         "(CI tier-1 profile)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the source-lint layer")
    ap.add_argument("--hlo-attn", action="store_true",
                    help="also audit the lowered flashcp attention island "
                         "(subprocess, simulated 4-way CP)")
    ap.add_argument("--hlo-train", action="store_true",
                    help="also audit the lowered smoke train step "
                         "(subprocess, simulated 2x4 mesh)")
    args = ap.parse_args(argv)

    findings = []
    n_configs = 0

    if not args.no_lint:
        lint = run_lint()
        findings += lint
        print(f"[lint] {len(list(default_targets(ROOT)))} files, "
              f"{len(errors(lint))} errors / "
              f"{len(lint) - len(errors(lint))} warnings")

    from repro.configs import ARCHS
    archs = FAST_ARCHS if args.fast else tuple(ARCHS)
    cps = CP_DEGREES[:2] if args.fast else CP_DEGREES
    for arch in archs:
        for cp in cps:
            fs = check_config(arch, cp)
            findings += fs
            n_configs += 1
            if errors(fs):
                print(f"[plan] {arch} cp={cp}: "
                      f"{len(errors(fs))} errors")
    print(f"[plan] {n_configs} configs "
          f"({len(archs)} archs x CP{list(cps)}), "
          f"{len(errors(findings))} total errors so far")

    fs = check_autotune()
    findings += fs
    print(f"[autotune] 3 search spaces: {len(errors(fs))} errors")

    if not args.fast:
        fs = check_serve_scenario()
        findings += fs
        print(f"[serve] prefix-sharing scenario: "
              f"{len(errors(fs))} errors")

    rc = 0
    for flag, phase in ((args.hlo_attn, "attn"), (args.hlo_train, "train")):
        if flag:
            print(f"[hlo] auditing {phase} program (subprocess)...")
            rc |= run_hlo(phase)

    if findings:
        print()
        print(format_findings(findings))
    errs = errors(findings)
    print(f"\nflashcheck: {len(errs)} error(s), "
          f"{len(findings) - len(errs)} warning(s)")
    return 1 if errs or rc else 0


if __name__ == "__main__":
    sys.exit(main())
