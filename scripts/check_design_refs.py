#!/usr/bin/env python
"""Docs-integrity check: every ``DESIGN.md §<id>`` reference in ``src/``
and ``scripts/`` must resolve to a real heading in DESIGN.md.

The source tree cites design sections by stable id (``DESIGN.md §4``,
``DESIGN.md §Arch-applicability``); this check keeps those citations from
dangling when sections move or the doc is edited.  Run directly (CI
tier-1) or through ``tests/test_docs_integrity.py``.

Exit status 0 = all references resolve; 1 = dangling references (each
printed with file:line).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

#: a section id: everything after '§' drawn from [A-Za-z0-9_-]
REF_RE = re.compile(r"DESIGN\.md\s+§([A-Za-z0-9_][A-Za-z0-9_-]*)")
HEADING_RE = re.compile(r"^#{1,6}\s+§([A-Za-z0-9_][A-Za-z0-9_-]*)",
                        re.MULTILINE)


def collect_refs(src: Path) -> dict[str, list[str]]:
    """section id -> ["path:line", ...] over every .py file in a tree."""
    refs: dict[str, list[str]] = {}
    if not src.is_dir():
        return refs
    for path in sorted(src.rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        for lineno, line in enumerate(
                path.read_text(encoding="utf-8").splitlines(), 1):
            for m in REF_RE.finditer(line):
                refs.setdefault(m.group(1), []).append(
                    f"{path.relative_to(ROOT)}:{lineno}")
    return refs


def design_anchors(design: Path) -> set[str]:
    if not design.exists():
        return set()
    return set(HEADING_RE.findall(design.read_text(encoding="utf-8")))


def check(root: Path = ROOT
          ) -> tuple[dict[str, list[str]], set[str], dict[str, list[str]]]:
    """Returns (dangling refs, available anchors, all refs)."""
    refs: dict[str, list[str]] = {}
    for sub in ("src", "scripts"):
        for sec, sites in collect_refs(root / sub).items():
            refs.setdefault(sec, []).extend(sites)
    anchors = design_anchors(root / "DESIGN.md")
    dangling = {sec: sites for sec, sites in refs.items()
                if sec not in anchors}
    return dangling, anchors, refs


def main() -> int:
    dangling, anchors, refs = check()
    n_sites = sum(len(s) for s in refs.values())
    if dangling:
        print(f"DESIGN.md reference check FAILED "
              f"(headings found: {sorted(anchors)})")
        for sec, sites in sorted(dangling.items()):
            for site in sites:
                print(f"  dangling §{sec}  at {site}")
        return 1
    print(f"DESIGN.md reference check OK: {n_sites} reference(s) to "
          f"{len(refs)} section(s), all resolved "
          f"({len(anchors)} headings available)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
