"""Render EXPERIMENTS.md sections (§Dry-run, §Roofline) from
dryrun_results.json.  Re-run after each dry-run sweep; §Perf is maintained
by hand (it is the hypothesis->change->measure log)."""

import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def fmt_table(recs, mesh):
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | strategy | peak GiB/dev | compute (s) | "
        "memory (s) | collective (s) | dominant | useful FLOPs | "
        "CP all-gather GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                       f"skipped (sub-quadratic required) | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | ERROR | | | | "
                       f"{r.get('error','')[:40]} | | |")
            continue
        rf = r["roofline"]
        mem = r["memory"]["peak_bytes_per_device"] / 2 ** 30
        uf = r.get("useful_flops_frac")
        ag = r["collectives"]["by_kind"].get("all-gather", 0) / 2 ** 30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('strategy','')} | "
            f"{mem:.2f} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | {rf['dominant']} | "
            f"{uf:.2f} | {ag:.2f} |" if uf else
            f"| {r['arch']} | {r['shape']} | {r.get('strategy','')} | "
            f"{mem:.2f} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | {rf['dominant']} | — | {ag:.2f} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(ROOT, "dryrun_results.json")
    recs = json.load(open(path))
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    err = [r for r in recs if r["status"] not in ("ok", "skip")]
    print(f"## Matrix status: {len(ok)} compiled, {len(skip)} documented "
          f"skips, {len(err)} errors\n")
    print("### Single-pod 16x16 (256 chips) — baseline roofline table\n")
    print(fmt_table(recs, "16x16"))
    print("\n### Multi-pod 2x16x16 (512 chips)\n")
    print(fmt_table(recs, "2x16x16"))


if __name__ == "__main__":
    main()
