#!/usr/bin/env python
"""Standalone config autotuner CLI (DESIGN.md §Autotune).

Searches the run-config knob space (``cp_strategy``, ``cp_overlap``,
``kernel_grid``, ``dispatch`` + target, ``kv_comm_dtype``) for one
(arch, mesh, length-profile) triple with the two-stage
predict-prune-measure search of :mod:`repro.autotune`, prints the
measured frontier as a ranked table, and optionally writes the tuned
:class:`repro.configs.RunConfig` as JSON.

The same search backs ``train.py --autotune``; this entry point exists
to tune ahead of time (and to warm the shared ``--cache-dir``) without
constructing a training run.

    PYTHONPATH=src python scripts/autotune.py --arch starcoder2_3b \
        --smoke --mesh 1x2 --seq-len 512 --batch 2 \
        --cache-dir /tmp/tune_cache --out /tmp/tuned.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--smoke", action="store_true",
                    help="size-reduced arch config (CPU-scale dims)")
    ap.add_argument("--mesh", default="1x1", help="DxM")
    ap.add_argument("--attention-impl", default="xla",
                    choices=["xla", "pallas"])
    ap.add_argument("--dataset", default="wlb_llm")
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=8,
                    help="measured-trial frontier size")
    ap.add_argument("--cache-dir", default="",
                    help="content-addressed result cache ('' = off)")
    ap.add_argument("--out", default="",
                    help="write the tuned RunConfig JSON here")
    args = ap.parse_args()

    import dataclasses

    from repro.autotune import autotune_run
    from repro.configs import RunConfig, get_config, reduce_for_smoke

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    data, model = (int(x) for x in args.mesh.split("x"))
    run = RunConfig(arch=args.arch, attention_impl=args.attention_impl,
                    seed=args.seed)
    tuned_run, result = autotune_run(
        run, cfg, data=data, model=model, context_len=args.seq_len,
        seqs=args.batch, dataset=args.dataset, cache_dir=args.cache_dir,
        top_k=args.top_k)

    src = "cache hit" if result.cached else \
        f"searched {result.n_candidates} candidates"
    print(f"[autotune] {src} (key {result.key})")
    hdr = (f"{'rank':>4} {'strategy':12} {'overlap':8} {'grid':5} "
           f"{'dispatch':9} {'target':>6} {'dtype':7} "
           f"{'pred_us':>9} {'meas_us':>9} {'deg':>3}")
    print(hdr)
    print("-" * len(hdr))
    ranked = sorted(result.frontier,
                    key=lambda f: f["measured"]["step_s"])
    for rank, f in enumerate(ranked, 1):
        c = f["candidate"]
        print(f"{rank:>4} {c['cp_strategy']:12} {c['cp_overlap']:8} "
              f"{c['kernel_grid']:5} {c['dispatch']:9} "
              f"{c['dispatch_target_imbalance']:>6.2f} "
              f"{c['kv_comm_dtype']:7} "
              f"{f['predicted']['step_s'] * 1e6:>9.2f} "
              f"{f['measured']['step_s'] * 1e6:>9.2f} "
              f"{f['measured']['cp_degree']:>3}")
    print(f"[autotune] frontier predicted-vs-measured spearman "
          f"{result.spearman_frontier:.3f}")
    b = result.best
    print(f"[autotune] best: {b.cp_strategy}/{b.cp_overlap}/"
          f"{b.kernel_grid}/{b.dispatch}/{b.kv_comm_dtype} "
          f"({result.best_measured['step_s'] * 1e6:.2f}us modeled)")

    if args.out:
        payload = {"tuned": dataclasses.asdict(tuned_run),
                   "key": result.key,
                   "best_measured": result.best_measured,
                   "spearman_frontier": result.spearman_frontier}
        Path(args.out).write_text(json.dumps(payload, indent=1,
                                             sort_keys=True))
        print(f"[autotune] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
