"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scan-over-layers models where ~all work lives inside loops.  This module
parses the partitioned HLO text and rolls costs up through the call graph,
multiplying loop bodies by their ``known_trip_count``:

  * flops   — 2 * prod(result dims) * prod(contracted dims) per dot;
  * bytes   — operand + result bytes per top-level op (post-fusion, so a
              fusion counts once — matching XLA's bytes-accessed notion);
  * collectives — wire bytes per kind (all-gather, all-reduce,
              reduce-scatter, all-to-all, collective-permute), with
              replica-group-aware factors.

All quantities are per-device (the module text is the partitioned module).

:func:`schedule_model` additionally list-schedules the instruction graph
on a two-resource machine (one compute stream, one collective stream) to
estimate **exposed communication**: collectives overlap any compute whose
operands do not depend on them, so a blocking all-gather feeding all
attention math is fully exposed, while a ppermute chain interleaved with
per-hop attention hides behind it.  While bodies are scheduled recursively
and multiplied by their trip count.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HloCost", "schedule_model", "ScheduleCost",
           "Collective", "collect_collectives"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^\s*((?:\([^)]*\)|[^ (]+))\s*([\w\-]+)\(")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|condition|body|select|scatter)=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"={:]+n[\\"]*:[\\"]*(\d+)')
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)?\)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "conditional", "call",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(typestr: str) -> list[int]:
    m = _SHAPE_RE.search(typestr)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


@dataclasses.dataclass
class _Op:
    """One instruction, for the schedule model."""
    var: str
    opcode: str
    flops: float
    bytes: float
    wire: float                        # >0 => collective
    deps: tuple
    while_target: str | None = None
    trip: int = 1
    fusion_targets: tuple = ()
    # collective details (set iff wire > 0), for collect_collectives
    coll_kind: str = ""
    result_bytes: int = 0
    group: int = 1


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    excluded_bytes: float = 0.0        # ops matched by exclude filter
    coll: dict | None = None
    calls: list | None = None          # [(comp_name, trip_mult)]
    fused_calls: list | None = None    # flops-only (fusion subcomps)
    ops: list | None = None            # [_Op] in program (SSA) order


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_wire_bytes: float
    collective_by_kind: dict[str, float]
    collective_count: float
    # bytes attributable to ops whose results a fused attention kernel
    # keeps in VMEM (logits-sized intermediates) — subtract for the
    # kernel-adjusted memory term
    vmem_resident_bytes: float = 0.0
    # collective wire bytes with the CPU-backend bf16-upcast artifact
    # removed: CPU XLA has no native bf16 dot, so it converts weights to
    # f32 *before* the FSDP all-gather; TPU gathers the bf16 original.
    # Gathers whose operand is a convert fusion are counted at half size.
    collective_wire_bytes_tpu: float = 0.0


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _collective_wire(kind: str, result_bytes: int, g: int) -> float:
    g = max(g, 1)
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-reduce":
        return result_bytes * 2 * (g - 1) / g
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute: one hop


def _parse_computations(text: str, exclude_result_bytes=frozenset()
                        ) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    symbols: dict[str, str] = {}
    entry: str | None = None

    for raw in text.splitlines():
        if raw.startswith("%") or raw.startswith("ENTRY"):
            header = raw
            name = header.split(" ", 1)[0]
            if name == "ENTRY":
                name = header.split(" ", 2)[1]
            name = name.rstrip("(").strip()
            cur = _Comp(name=name, coll={}, calls=[], fused_calls=[],
                        ops=[])
            comps[cur.name] = cur
            if header.startswith("ENTRY"):
                entry = cur.name
            symbols = {}
            # parameter types from the signature
            for pm in re.finditer(r"(%?[\w.\-]+):\s*([a-z0-9]+\[[\d,]*\])",
                                  header):
                symbols["%" + pm.group(1).lstrip("%")] = pm.group(2)
            continue
        if cur is None:
            continue
        line = raw.strip()
        if not line or line == "}":
            continue
        m = _DEF_RE.match(raw)
        if not m:
            continue
        var, rest = m.group(1), m.group(2)
        # result type = leading type expression
        tm = re.match(r"((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^\s]*))\s+"
                      r"([\w\-]+)", rest)
        if not tm:
            continue
        typestr, opcode = tm.group(1), tm.group(2)
        symbols[var] = typestr
        result_bytes = _type_bytes(typestr)

        # operands (types looked up in the symbol table)
        operand_bytes = 0
        max_operand = 0
        args = ""
        paren = rest.find("(", rest.find(opcode))
        j = paren
        if paren != -1:
            depth, j = 0, paren
            for j in range(paren, len(rest)):
                if rest[j] == "(":
                    depth += 1
                elif rest[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            args = rest[paren + 1: j]
            excluded_operand_bytes = 0
            for ref in re.findall(r"%[\w.\-]+", args):
                b = _type_bytes(symbols.get(ref, ""))
                operand_bytes += b
                max_operand = max(max_operand, b)
                if b in exclude_result_bytes:
                    excluded_operand_bytes += b

        # called computations
        trip = 1
        tmt = _TRIP_RE.search(rest)
        if tmt:
            trip = int(tmt.group(1))
        op_while = None
        op_fused = []
        for cm in _CALLED_RE.finditer(rest):
            target = cm.group(1)
            if opcode == "fusion":
                cur.fused_calls.append(target)
                op_fused.append(target)
            elif opcode == "while":
                cur.calls.append((target, trip))
                if "body=" in rest and f"body={target}" in rest:
                    op_while = target
                elif op_while is None and "body=" not in rest:
                    op_while = target
            else:
                cur.fused_calls.append(target)
                op_fused.append(target)

        # flops: dot ops (works inside fusion subcomputations too)
        op_flops = 0.0
        if opcode == "dot":
            dims = _shape_dims(typestr)
            out = 1
            for d in dims:
                out *= d
            contract = 1
            lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            refs = re.findall(r"%[\w.\-]+", args)
            if lc and refs:
                lhs_dims = _shape_dims(symbols.get(refs[0], ""))
                for idx in lc.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            op_flops = 2.0 * out * contract
            cur.flops += op_flops

        # bytes + collectives (top-level, post-fusion).  Slicing/update ops
        # touch only the slice, not the whole operand (matching XLA's
        # cost-analysis special cases).
        op_bytes_sched = 0.0
        if opcode not in _SKIP_BYTES_OPS:
            if opcode in ("dynamic-slice", "slice", "gather", "broadcast",
                          "reverse", "pad"):
                op_bytes = 2.0 * result_bytes
                excl = op_bytes if result_bytes in exclude_result_bytes \
                    else 0.0
            elif opcode in ("dynamic-update-slice", "scatter"):
                # touches the update region twice; base is aliased
                op_bytes = 2.0 * max(operand_bytes - max_operand, 0)
                excl = 0.0
            else:
                op_bytes = result_bytes + operand_bytes
                excl = excluded_operand_bytes + (
                    result_bytes if result_bytes in exclude_result_bytes
                    else 0)
            cur.bytes += op_bytes
            cur.excluded_bytes += min(excl, op_bytes)
            op_bytes_sched = op_bytes
        base = opcode.replace("-start", "").replace("-done", "")
        op_wire = 0.0
        op_group = 1
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            op_group = _group_size(rest)
            wire = _collective_wire(base, result_bytes, op_group)
            op_wire = wire
            cur.coll[base] = cur.coll.get(base, 0.0) + wire
            cur.coll["_count"] = cur.coll.get("_count", 0.0) + 1
            # TPU-adjusted: f32-upcast-then-gather is a CPU lowering of a
            # bf16 dot; the TPU wire carries bf16.
            tpu_wire = wire / 2 if ("convert" in args and "f32" in typestr
                                    ) else wire
            cur.coll["_tpu"] = cur.coll.get("_tpu", 0.0) + tpu_wire

        cur.ops.append(_Op(
            var=var, opcode=opcode, flops=op_flops, bytes=op_bytes_sched,
            wire=op_wire, deps=tuple(re.findall(r"%[\w.\-]+", args)),
            while_target=op_while if opcode == "while" else None,
            trip=trip, fusion_targets=tuple(op_fused),
            coll_kind=base if op_wire > 0.0 else "",
            result_bytes=result_bytes if op_wire > 0.0 else 0,
            group=op_group))

    return comps, entry


def analyze_hlo(text: str, entry: str | None = None,
                exclude_result_bytes=frozenset()) -> HloCost:
    comps, found_entry = _parse_computations(
        text, exclude_result_bytes=frozenset(exclude_result_bytes))
    if entry is None:
        entry = found_entry
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:   # empty / unparseable module: zero cost
        return HloCost(flops=0.0, bytes=0.0, collective_wire_bytes=0.0,
                       collective_by_kind={}, collective_count=0.0)

    memo: dict[str, tuple] = {}

    def roll(name: str) -> tuple:
        """(flops, bytes, excluded, coll) incl. callees x multiplicity."""
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return 0.0, 0.0, 0.0, {}
        fl, by, ex = c.flops, c.bytes, c.excluded_bytes
        coll = dict(c.coll or {})
        for target, trip in c.calls or []:
            f2, b2, e2, c2 = roll(target)
            fl += trip * f2
            by += trip * b2
            ex += trip * e2
            for k, v in c2.items():
                coll[k] = coll.get(k, 0.0) + trip * v
        for target in c.fused_calls or []:
            f2, _, _, c2 = roll(target)  # fused: flops only, bytes counted
            fl += f2                     # at the fusion op itself
            for k, v in c2.items():
                coll[k] = coll.get(k, 0.0) + v
        memo[name] = (fl, by, ex, coll)
        return memo[name]

    fl, by, ex, coll = roll(entry)
    count = coll.pop("_count", 0.0)
    tpu = coll.pop("_tpu", 0.0)
    return HloCost(flops=fl, bytes=by,
                   collective_wire_bytes=sum(coll.values()),
                   collective_by_kind=coll, collective_count=count,
                   vmem_resident_bytes=ex, collective_wire_bytes_tpu=tpu)


# --------------------------------------------------------------------- #
# per-collective extraction (the static auditor's raw material)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Collective:
    """One collective instruction, with its execution multiplicity.

    ``trips`` is the product of enclosing ``while`` trip counts along the
    call path from the entry — ``wire_bytes * trips`` is this
    instruction's total contribution to the module's wire traffic, so
    ``sum(c.wire_bytes * c.trips)`` equals
    :attr:`HloCost.collective_wire_bytes`.
    """

    kind: str            # all-gather | all-reduce | reduce-scatter | ...
    var: str             # SSA name, e.g. "%all-gather.3"
    computation: str     # enclosing computation name
    result_bytes: int
    wire_bytes: float    # per execution, replica-group-aware
    group_size: int
    trips: float         # total multiplicity through while nesting


def collect_collectives(text: str, entry: str | None = None
                        ) -> list[Collective]:
    """Every collective in the module, with while-trip multiplicities.

    Walks the call graph from the entry computation: ``while`` calls
    multiply the body's multiplicity by the known trip count; fusions,
    calls, and conditional branches inherit their caller's (conditionals
    conservatively count both branches).  Computations unreachable from
    the entry contribute nothing.
    """
    comps, found_entry = _parse_computations(text)
    if entry is None:
        entry = found_entry
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:   # empty / unparseable module: no collectives
        return []

    mult: dict[str, float] = {}

    def walk(name: str, m: float, depth: int = 0) -> None:
        c = comps.get(name)
        if c is None or depth > 64:     # depth guard: HLO has no recursion
            return
        mult[name] = mult.get(name, 0.0) + m
        for target, trip in c.calls or []:
            walk(target, m * trip, depth + 1)
        for target in c.fused_calls or []:
            walk(target, m, depth + 1)

    walk(entry, 1.0)

    out: list[Collective] = []
    for name, m in mult.items():
        for op in comps[name].ops or []:
            if op.wire > 0.0:
                out.append(Collective(
                    kind=op.coll_kind, var=op.var, computation=name,
                    result_bytes=op.result_bytes, wire_bytes=op.wire,
                    group_size=op.group, trips=m))
    return out


# --------------------------------------------------------------------- #
# two-resource overlap schedule (exposed-communication model)
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class ScheduleCost:
    """List-schedule estimate of one executable's step time.

    ``exposed_comm_s = makespan_s - compute_busy_s``: the part of the
    critical path where the compute stream sits idle waiting on
    collectives.  A blocking exchange exposes its full wire time; a
    pipelined exchange only the residue its per-hop compute cannot cover.
    """
    makespan_s: float
    compute_busy_s: float
    comm_busy_s: float
    exposed_comm_s: float
    collective_count: float


def schedule_model(text: str, *, flops_per_s: float = 100e9,
                   bytes_per_s: float = 100e9, wire_per_s: float = 25e9,
                   entry: str | None = None) -> ScheduleCost:
    """Dependency-aware two-resource schedule of the (partitioned) HLO.

    Instructions run in SSA order on a compute stream (duration =
    max(flops, bytes) roofline) or, for collectives, a communication
    stream (duration = wire bytes); an op starts when its operands are
    done and its stream is free, so independent comm and compute overlap
    exactly as XLA's latency-hiding scheduler allows.  ``while`` ops
    recurse (body schedule x trip count) and serialize both streams —
    conservative for loops whose first transfer could prefetch, which
    only *understates* the win of overlapped execution.

    The default rates model a CPU-mesh harness; ratios between two
    programs are the meaningful output, not absolute seconds.
    """
    comps, found_entry = _parse_computations(text)
    if entry is None:
        entry = found_entry
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:   # empty / unparseable module: zero-length schedule
        return ScheduleCost(makespan_s=0.0, compute_busy_s=0.0,
                            comm_busy_s=0.0, exposed_comm_s=0.0,
                            collective_count=0.0)

    flops_memo: dict[str, float] = {}

    def flops_of(name: str) -> float:
        if name in flops_memo:
            return flops_memo[name]
        c = comps.get(name)
        if c is None:
            return 0.0
        flops_memo[name] = 0.0     # cycle guard
        fl = c.flops
        for target, trip in c.calls or []:
            fl += trip * flops_of(target)
        for target in c.fused_calls or []:
            fl += flops_of(target)
        flops_memo[name] = fl
        return fl

    sched_memo: dict[str, tuple] = {}

    def sched(name: str) -> tuple:
        """(makespan, compute_busy, comm_busy, collective_count)."""
        if name in sched_memo:
            return sched_memo[name]
        c = comps.get(name)
        if c is None or not c.ops:
            return 0.0, 0.0, 0.0, 0.0
        sched_memo[name] = (0.0, 0.0, 0.0, 0.0)   # cycle guard
        finish: dict[str, float] = {}
        t_cu = t_cm = 0.0
        busy_cu = busy_cm = n_coll = 0.0
        for op in c.ops:
            ready = max((finish.get(d, 0.0) for d in op.deps), default=0.0)
            if op.while_target is not None:
                m2, cb2, mb2, nc2 = sched(op.while_target)
                dur = op.trip * m2
                # occupy only the streams the body actually uses: a
                # collective-free loop leaves the comm stream open for
                # concurrent transfers (and vice versa)
                if mb2 > 0.0 and cb2 > 0.0:
                    start = max(ready, t_cu, t_cm)
                    t_cu = t_cm = start + dur
                elif mb2 > 0.0:
                    start = max(ready, t_cm)
                    t_cm = start + dur
                else:
                    start = max(ready, t_cu)
                    t_cu = start + dur
                busy_cu += op.trip * cb2
                busy_cm += op.trip * mb2
                n_coll += op.trip * nc2
            elif op.wire > 0.0:
                dur = op.wire / wire_per_s
                start = max(ready, t_cm)
                t_cm = start + dur
                busy_cm += dur
                n_coll += 1
            elif op.opcode.endswith("-done"):
                start, dur = ready, 0.0     # async completion marker
            else:
                fl = op.flops + sum(flops_of(t) for t in op.fusion_targets)
                dur = max(fl / flops_per_s, op.bytes / bytes_per_s)
                start = max(ready, t_cu)
                t_cu = start + dur
                busy_cu += dur
            finish[op.var] = start + dur
        makespan = max(max(finish.values(), default=0.0), t_cu, t_cm)
        sched_memo[name] = (makespan, busy_cu, busy_cm, n_coll)
        return sched_memo[name]

    m, cb, mb, nc = sched(entry)
    return ScheduleCost(makespan_s=m, compute_busy_s=cb, comm_busy_s=mb,
                        exposed_comm_s=max(0.0, m - cb),
                        collective_count=nc)
