"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scan-over-layers models where ~all work lives inside loops.  This module
parses the partitioned HLO text and rolls costs up through the call graph,
multiplying loop bodies by their ``known_trip_count``:

  * flops   — 2 * prod(result dims) * prod(contracted dims) per dot;
  * bytes   — operand + result bytes per top-level op (post-fusion, so a
              fusion counts once — matching XLA's bytes-accessed notion);
  * collectives — wire bytes per kind (all-gather, all-reduce,
              reduce-scatter, all-to-all, collective-permute), with
              replica-group-aware factors.

All quantities are per-device (the module text is the partitioned module).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^\s*((?:\([^)]*\)|[^ (]+))\s*([\w\-]+)\(")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|condition|body|select|scatter)=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"={:]+n[\\"]*:[\\"]*(\d+)')
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)?\)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "conditional", "call",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(typestr: str) -> list[int]:
    m = _SHAPE_RE.search(typestr)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    excluded_bytes: float = 0.0        # ops matched by exclude filter
    coll: dict | None = None
    calls: list | None = None          # [(comp_name, trip_mult)]
    fused_calls: list | None = None    # flops-only (fusion subcomps)


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_wire_bytes: float
    collective_by_kind: dict[str, float]
    collective_count: float
    # bytes attributable to ops whose results a fused attention kernel
    # keeps in VMEM (logits-sized intermediates) — subtract for the
    # kernel-adjusted memory term
    vmem_resident_bytes: float = 0.0
    # collective wire bytes with the CPU-backend bf16-upcast artifact
    # removed: CPU XLA has no native bf16 dot, so it converts weights to
    # f32 *before* the FSDP all-gather; TPU gathers the bf16 original.
    # Gathers whose operand is a convert fusion are counted at half size.
    collective_wire_bytes_tpu: float = 0.0


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _collective_wire(kind: str, result_bytes: int, g: int) -> float:
    g = max(g, 1)
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-reduce":
        return result_bytes * 2 * (g - 1) / g
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute: one hop


def _parse_computations(text: str, exclude_result_bytes=frozenset()
                        ) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    symbols: dict[str, str] = {}
    entry: str | None = None

    for raw in text.splitlines():
        if raw.startswith("%") or raw.startswith("ENTRY"):
            header = raw
            name = header.split(" ", 1)[0]
            if name == "ENTRY":
                name = header.split(" ", 2)[1]
            name = name.rstrip("(").strip()
            cur = _Comp(name=name, coll={}, calls=[], fused_calls=[])
            comps[cur.name] = cur
            if header.startswith("ENTRY"):
                entry = cur.name
            symbols = {}
            # parameter types from the signature
            for pm in re.finditer(r"(%?[\w.\-]+):\s*([a-z0-9]+\[[\d,]*\])",
                                  header):
                symbols["%" + pm.group(1).lstrip("%")] = pm.group(2)
            continue
        if cur is None:
            continue
        line = raw.strip()
        if not line or line == "}":
            continue
        m = _DEF_RE.match(raw)
        if not m:
            continue
        var, rest = m.group(1), m.group(2)
        # result type = leading type expression
        tm = re.match(r"((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^\s]*))\s+"
                      r"([\w\-]+)", rest)
        if not tm:
            continue
        typestr, opcode = tm.group(1), tm.group(2)
        symbols[var] = typestr
        result_bytes = _type_bytes(typestr)

        # operands (types looked up in the symbol table)
        operand_bytes = 0
        max_operand = 0
        args = ""
        paren = rest.find("(", rest.find(opcode))
        j = paren
        if paren != -1:
            depth, j = 0, paren
            for j in range(paren, len(rest)):
                if rest[j] == "(":
                    depth += 1
                elif rest[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            args = rest[paren + 1: j]
            excluded_operand_bytes = 0
            for ref in re.findall(r"%[\w.\-]+", args):
                b = _type_bytes(symbols.get(ref, ""))
                operand_bytes += b
                max_operand = max(max_operand, b)
                if b in exclude_result_bytes:
                    excluded_operand_bytes += b

        # called computations
        trip = 1
        tmt = _TRIP_RE.search(rest)
        if tmt:
            trip = int(tmt.group(1))
        for cm in _CALLED_RE.finditer(rest):
            target = cm.group(1)
            if opcode == "fusion":
                cur.fused_calls.append(target)
            elif opcode == "while":
                cur.calls.append((target, trip))
            else:
                cur.fused_calls.append(target)

        # flops: dot ops (works inside fusion subcomputations too)
        if opcode == "dot":
            dims = _shape_dims(typestr)
            out = 1
            for d in dims:
                out *= d
            contract = 1
            lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            refs = re.findall(r"%[\w.\-]+", args)
            if lc and refs:
                lhs_dims = _shape_dims(symbols.get(refs[0], ""))
                for idx in lc.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            cur.flops += 2.0 * out * contract

        # bytes + collectives (top-level, post-fusion).  Slicing/update ops
        # touch only the slice, not the whole operand (matching XLA's
        # cost-analysis special cases).
        if opcode not in _SKIP_BYTES_OPS:
            if opcode in ("dynamic-slice", "slice", "gather", "broadcast",
                          "reverse", "pad"):
                op_bytes = 2.0 * result_bytes
                excl = op_bytes if result_bytes in exclude_result_bytes \
                    else 0.0
            elif opcode in ("dynamic-update-slice", "scatter"):
                # touches the update region twice; base is aliased
                op_bytes = 2.0 * max(operand_bytes - max_operand, 0)
                excl = 0.0
            else:
                op_bytes = result_bytes + operand_bytes
                excl = excluded_operand_bytes + (
                    result_bytes if result_bytes in exclude_result_bytes
                    else 0)
            cur.bytes += op_bytes
            cur.excluded_bytes += min(excl, op_bytes)
        base = opcode.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            wire = _collective_wire(base, result_bytes, _group_size(rest))
            cur.coll[base] = cur.coll.get(base, 0.0) + wire
            cur.coll["_count"] = cur.coll.get("_count", 0.0) + 1
            # TPU-adjusted: f32-upcast-then-gather is a CPU lowering of a
            # bf16 dot; the TPU wire carries bf16.
            tpu_wire = wire / 2 if ("convert" in args and "f32" in typestr
                                    ) else wire
            cur.coll["_tpu"] = cur.coll.get("_tpu", 0.0) + tpu_wire

    return comps, entry


def analyze_hlo(text: str, entry: str | None = None,
                exclude_result_bytes=frozenset()) -> HloCost:
    comps, found_entry = _parse_computations(
        text, exclude_result_bytes=frozenset(exclude_result_bytes))
    if entry is None:
        entry = found_entry
    if entry is None:  # pragma: no cover
        entry = next(iter(comps))

    memo: dict[str, tuple] = {}

    def roll(name: str) -> tuple:
        """(flops, bytes, excluded, coll) incl. callees x multiplicity."""
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return 0.0, 0.0, 0.0, {}
        fl, by, ex = c.flops, c.bytes, c.excluded_bytes
        coll = dict(c.coll or {})
        for target, trip in c.calls or []:
            f2, b2, e2, c2 = roll(target)
            fl += trip * f2
            by += trip * b2
            ex += trip * e2
            for k, v in c2.items():
                coll[k] = coll.get(k, 0.0) + trip * v
        for target in c.fused_calls or []:
            f2, _, _, c2 = roll(target)  # fused: flops only, bytes counted
            fl += f2                     # at the fusion op itself
            for k, v in c2.items():
                coll[k] = coll.get(k, 0.0) + v
        memo[name] = (fl, by, ex, coll)
        return memo[name]

    fl, by, ex, coll = roll(entry)
    count = coll.pop("_count", 0.0)
    tpu = coll.pop("_tpu", 0.0)
    return HloCost(flops=fl, bytes=by,
                   collective_wire_bytes=sum(coll.values()),
                   collective_by_kind=coll, collective_count=count,
                   vmem_resident_bytes=ex, collective_wire_bytes_tpu=tpu)
