"""Step builders: assemble jit-able train / prefill / decode steps with
shardings and dry-run input specs for any (arch x shape x mesh x strategy).

Used by the training driver, the serving driver, and the multi-pod dry-run
(which lowers these steps against ShapeDtypeStructs).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.cp_attention import make_cp_context
from repro.planner import get_planner, pick_buffer_bucket
from repro.models import decode_step as model_decode_step
from repro.models import forward, init_cache, init_params, loss_fn
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_tree, warmup_cosine)
from repro.runtime.sharding import (batch_axes_of, batch_specs, cache_specs,
                                    param_shardings)

__all__ = ["effective_strategy", "train_input_specs", "decode_input_specs",
           "build_train_step", "build_prefill_step", "build_decode_step",
           "StepBundle"]


def effective_strategy(cfg: ModelConfig, requested: str) -> str:
    """Recurrent-state architectures need token order preserved across CP
    ranks; planners declare that capability in their registry metadata
    (``PlannerInfo.preserves_token_order``) — anything else is swapped for
    contiguous sharding (sharding-aware comm still applies).
    See DESIGN.md §Arch-applicability."""
    if cfg.family in ("hybrid", "ssm"):
        # unknown names raise here (listing registered planners) instead
        # of being silently replaced by contiguous.
        if get_planner(requested).info.preserves_token_order:
            return requested
        return "contiguous"
    return requested


def exec_strategy_of(plan_strategy: str) -> str:
    """Execution-strategy name for the device-side CP context, resolved
    from the planner registry (unknown names pass through for custom
    execution styles)."""
    try:
        return get_planner(plan_strategy).info.exec_style
    except KeyError:
        return plan_strategy


def default_buf_len(seq_len: int, cp: int) -> int:
    """Static Eq.5 bucket for fixed-shape lowering: half the local KV
    (representative of measured FlashCP savings; the pipeline may emit any
    bucket <= full local KV at runtime)."""
    return pick_buffer_bucket(max(seq_len // (2 * cp), 1), seq_len // cp)


# --------------------------------------------------------------------- #
# input specs (dry-run stand-ins; the pipeline produces matching arrays)
# --------------------------------------------------------------------- #
def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, cp: int,
                      *, strategy: str = "flashcp",
                      buf_len: int | None = None,
                      attention_impl: str = "xla",
                      overlap: str = "chunked",
                      grid: str = "flat",
                      block_q: int = 128,
                      block_k: int = 128,
                      dispatch: bool = False) -> dict[str, Any]:
    B, C = shape.global_batch, shape.seq_len
    N = cp
    buf = buf_len or default_buf_len(C, N)
    f32, i32 = jnp.float32, jnp.int32
    bf16 = jnp.dtype(cfg.dtype)
    s = {
        "tokens": jax.ShapeDtypeStruct((B, C), i32),
        "labels": jax.ShapeDtypeStruct((B, C), i32),
        "doc": jax.ShapeDtypeStruct((B, C), i32),
        "pos": jax.ShapeDtypeStruct((B, C), i32),
    }
    if dispatch:
        # ragged dispatch batches: per-row valid tokens + CP subgroup id
        s["seq_tokens"] = jax.ShapeDtypeStruct((B,), i32)
        s["group_id"] = jax.ShapeDtypeStruct((B,), i32)
    if exec_strategy_of(strategy) in ("flashcp", "contiguous"):
        s["send_idx"] = jax.ShapeDtypeStruct((B, N, buf), i32)
        s["gath_doc"] = jax.ShapeDtypeStruct((B, N * buf), i32)
        s["gath_pos"] = jax.ShapeDtypeStruct((B, N * buf), i32)
    if attention_impl == "pallas" and cfg.uses_attention:
        from repro.core.cp_attention import resolve_overlap
        from repro.planner import visit_table_shapes
        exec_strat = exec_strategy_of(strategy)
        shapes = visit_table_shapes(
            B, N, C // N, buf, strategy=exec_strat,
            overlap=resolve_overlap(exec_strat, attention_impl, overlap),
            block_q=block_q, block_k=block_k, grid=grid)
        s.update({k: jax.ShapeDtypeStruct(v, i32)
                  for k, v in shapes.items()})
    if cfg.frontend == "audio_frames":
        s["frame_embeds"] = jax.ShapeDtypeStruct((B, C, cfg.d_model), bf16)
        del s["tokens"]
    if cfg.frontend == "vit_patches":
        s["patch_embeds"] = jax.ShapeDtypeStruct((B, C, cfg.d_model), bf16)
        s["patch_mask"] = jax.ShapeDtypeStruct((B, C), jnp.bool_)
    return s


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    B = shape.global_batch
    bf16 = jnp.dtype(cfg.dtype)
    batch = {"pos_t": jax.ShapeDtypeStruct((B,), jnp.int32)}
    if cfg.frontend == "audio_frames":
        batch["frame_embeds"] = jax.ShapeDtypeStruct((B, cfg.d_model), bf16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg, B, shape.seq_len))
    return {"batch": batch, "cache": cache}


# --------------------------------------------------------------------- #
@dataclasses.dataclass
class StepBundle:
    """A jit-ready step with its shardings (AOT-lowerable)."""
    fn: Any
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: tuple
    donate_argnums: tuple = ()

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        return jitted.lower(*self.abstract_inputs)


def _plan_keys(batch):
    return {k: batch[k] for k in batch
            if k in ("doc", "pos", "send_idx", "gath_doc", "gath_pos")
            or k.startswith("tab_")}


def _abstract_state(cfg: ModelConfig, rng=None):
    """Abstract (no-allocation) params + optimizer state."""
    rng = jax.random.PRNGKey(0) if rng is None else rng
    params = jax.eval_shape(functools.partial(init_params, rng=rng, cfg=cfg))
    opt = jax.eval_shape(adamw_init, params)
    return params, opt


# --------------------------------------------------------------------- #
def build_train_step(cfg: ModelConfig, mesh, run: RunConfig,
                     shape: ShapeConfig, *, abstract: bool = True,
                     q_chunk: int = 512, block_q: int = 128,
                     block_k: int = 128,
                     interpret: bool = False,
                     accum: int = 1) -> StepBundle:
    """One fused (loss + grad + optimizer) training step.

    ``accum > 1`` splits the batch into that many micro-batches and
    accumulates gradients before the single optimizer update — the
    elastic recovery path (DESIGN.md §Recovery) uses it to preserve the
    global batch after a mesh shrink (ElasticPlan.accum_factor).  Rows
    are group-major on the batch axis; micro ``m`` takes rows
    ``[g*spg + m*spg/accum, g*spg + (m+1)*spg/accum)`` of every group
    ``g`` (a sharding-preserving reshape — each device slices its own
    rows locally).  Gradients are token-weighted across micros, so the
    accumulated update equals the fused one on the same batch: the
    global masked CE mean is ``Σ ce_sum / Σ tokens`` either way.
    """
    plan_strategy = effective_strategy(cfg, run.cp_strategy)
    exec_strategy = exec_strategy_of(plan_strategy)
    baxes = batch_axes_of(mesh)
    cp = mesh.shape["model"]
    if accum > 1:
        G = mesh.shape["data"]
        B_total = shape.global_batch
        assert B_total % (G * accum) == 0, \
            (f"accum {accum} needs per-group rows divisible: "
             f"batch {B_total}, groups {G}")

    def _micro(batch, m):
        """Micro-batch m: a sharding-preserving strided row slice."""
        def sl(v):
            B = v.shape[0]
            x = v.reshape((G, accum, B // (G * accum)) + v.shape[1:])
            return x[:, m].reshape((B // accum,) + v.shape[1:])
        return {k: sl(v) for k, v in batch.items()}

    def _ctx_of(batch):
        return make_cp_context(
            mesh, _plan_keys(batch), strategy=exec_strategy,
            impl=run.attention_impl, batch_axes=baxes,
            head_dim=cfg.resolved_head_dim, q_chunk=q_chunk,
            overlap=run.cp_overlap, interpret=interpret,
            block_q=block_q, block_k=block_k, grid=run.kernel_grid,
            kv_comm_dtype=run.kv_comm_dtype)

    def _loss_and_grads(params, batch):
        # loss_fn's CE is a *global* masked mean: sum(ce * mask) /
        # sum(mask) over the whole (possibly ragged) batch, so dispatch
        # groups of unequal token counts are token-weighted — a group
        # holding 30% of the step's valid tokens contributes 30% of the
        # loss and of the gradient, never 1/n_groups.
        ctx = _ctx_of(batch)
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, ctx, batch, remat=run.remat),
            has_aux=True)(params)

    def train_step(params, opt_state, batch, step):
        if accum == 1:
            (loss, metrics), grads = _loss_and_grads(params, batch)
        else:
            g_sum, metr_sum = None, None
            loss_sum = tok_sum = 0.0
            for m in range(accum):
                mb = _micro(batch, m)
                (l_m, metr_m), g_m = _loss_and_grads(params, mb)
                tok = jnp.sum(mb["labels"] >= 0).astype(jnp.float32)
                add = lambda a, b: a + b    # noqa: E731
                g_m = jax.tree.map(lambda g: g * tok, g_m)
                g_sum = g_m if g_sum is None else \
                    jax.tree.map(add, g_sum, g_m)
                metr_m = jax.tree.map(lambda v: v * tok, metr_m)
                metr_sum = metr_m if metr_sum is None else \
                    jax.tree.map(add, metr_sum, metr_m)
                loss_sum = loss_sum + l_m * tok
                tok_sum = tok_sum + tok
            denom = jnp.maximum(tok_sum, 1.0)
            grads = jax.tree.map(lambda g: g / denom, g_sum)
            metrics = jax.tree.map(lambda v: v / denom, metr_sum)
            loss = loss_sum / denom
        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        if run.grad_compression != "none":
            grads, _ = compress_tree(grads, jax.tree.map(
                lambda g: jnp.zeros_like(g, jnp.float32), grads),
                run.grad_compression)
        lr = warmup_cosine(step, base_lr=run.lr,
                           warmup_steps=run.warmup_steps,
                           total_steps=run.total_steps)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         weight_decay=run.weight_decay)
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                       "tokens": jnp.sum(batch["labels"] >= 0),
                       **metrics}
        return params, opt_state, out_metrics

    params_s, opt_s = _abstract_state(cfg)
    batch_s = train_input_specs(cfg, shape, cp, strategy=plan_strategy,
                                attention_impl=run.attention_impl,
                                overlap=run.cp_overlap,
                                grid=run.kernel_grid,
                                block_q=block_q, block_k=block_k,
                                dispatch=(run.dispatch != "off"))
    p_shard = param_shardings(mesh, params_s)
    o_shard = param_shardings(mesh, opt_s)
    b_spec = batch_specs(mesh, {k: v.shape for k, v in batch_s.items()})
    b_shard = {k: NamedSharding(mesh, s) for k, s in b_spec.items()}
    scalar = NamedSharding(mesh, P())

    return StepBundle(
        fn=train_step,
        in_shardings=(p_shard, o_shard, b_shard, scalar),
        out_shardings=(p_shard, o_shard, None),
        abstract_inputs=(params_s, opt_s, batch_s,
                         jax.ShapeDtypeStruct((), jnp.int32)),
        donate_argnums=(0, 1),
    )


def build_prefill_step(cfg: ModelConfig, mesh, run: RunConfig,
                       shape: ShapeConfig, *, q_chunk: int = 512,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = False) -> StepBundle:
    plan_strategy = effective_strategy(cfg, run.cp_strategy)
    exec_strategy = exec_strategy_of(plan_strategy)
    baxes = batch_axes_of(mesh)
    cp = mesh.shape["model"]

    def prefill_step(params, batch):
        ctx = make_cp_context(
            mesh, _plan_keys(batch), strategy=exec_strategy,
            impl=run.attention_impl, batch_axes=baxes,
            head_dim=cfg.resolved_head_dim, q_chunk=q_chunk,
            overlap=run.cp_overlap, interpret=interpret,
            block_q=block_q, block_k=block_k, grid=run.kernel_grid,
            kv_comm_dtype=run.kv_comm_dtype)
        logits, _ = forward(params, cfg, ctx, batch, remat=run.remat)
        # serving prefill returns the last-position logits per sequence
        return logits[:, -1, :]

    params_s, _ = _abstract_state(cfg)
    batch_s = train_input_specs(cfg, shape, cp, strategy=plan_strategy,
                                attention_impl=run.attention_impl,
                                overlap=run.cp_overlap,
                                grid=run.kernel_grid,
                                block_q=block_q, block_k=block_k)
    batch_s.pop("labels")
    p_shard = param_shardings(mesh, params_s)
    b_spec = batch_specs(mesh, {k: v.shape for k, v in batch_s.items()})
    b_shard = {k: NamedSharding(mesh, s) for k, s in b_spec.items()}

    return StepBundle(
        fn=prefill_step,
        in_shardings=(p_shard, b_shard),
        out_shardings=None,
        abstract_inputs=(params_s, batch_s),
    )


def build_decode_step(cfg: ModelConfig, mesh, run: RunConfig,
                      shape: ShapeConfig) -> StepBundle:
    baxes = batch_axes_of(mesh)

    # the fused flash kernel has no GSPMD partitioning rule: with the
    # cache S axis sharded over ``model`` the pjit path needs the
    # shard_map LSE-merge island (tests/multidevice/decode_cp_check.py),
    # so meshes that actually shard the cache keep the dense oracle here;
    # in-process shard emulation lives in ServeEngine(attn_shards=)
    impl = run.decode_impl if mesh.shape.get("model", 1) == 1 else "dense"

    def decode(params, cache, batch):
        logits, new_cache = model_decode_step(params, cfg, cache,
                                              batch, batch["pos_t"],
                                              attn_impl=impl)
        return logits, new_cache

    params_s, _ = _abstract_state(cfg)
    specs = decode_input_specs(cfg, shape)
    p_shard = param_shardings(mesh, params_s)
    c_shard = cache_specs(mesh, specs["cache"])
    b = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    B = specs["batch"]["pos_t"].shape[0]
    need = int(np.prod([mesh.shape[a] for a in
                        (b if isinstance(b, tuple) else (b,))])) if b else 1
    Bk = b if (b and B % need == 0) else None
    b_shard = {k: NamedSharding(mesh, P(*([Bk] + [None] * (v.ndim - 1))))
               for k, v in specs["batch"].items()}

    return StepBundle(
        fn=decode,
        in_shardings=(p_shard, c_shard, b_shard),
        out_shardings=(None, c_shard),
        abstract_inputs=(params_s, specs["cache"], specs["batch"]),
        donate_argnums=(1,),
    )
