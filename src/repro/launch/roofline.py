"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Per (arch x shape x mesh) the dry-run records, from the *partitioned*
module (all quantities per-device):

  compute term    = HLO_FLOPs / peak_FLOP/s        (197 TFLOP/s bf16, v5e)
  memory term     = HLO_bytes / HBM_bw             (819 GB/s)
  collective term = collective_wire_bytes / ICI_bw (~50 GB/s/link)

``cost_analysis()`` provides FLOPs and bytes-accessed; collective bytes are
NOT in cost_analysis, so we parse the compiled HLO text and sum, per
collective kind, the bytes each device actually puts on the wire:

  all-gather       result x (g-1)/g      (receives g-1 remote shards)
  reduce-scatter   operand x (g-1)/g
  all-reduce       result x 2(g-1)/g     (ring: reduce-scatter + all-gather)
  all-to-all       result x (g-1)/g
  collective-permute  result             (one hop)

The dominant term identifies the bottleneck the §Perf loop iterates on.
MODEL_FLOPS (6·N_active·D for training; 2·N_active·D for inference) over
HLO_FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
import re


__all__ = ["HW", "CollectiveStats", "collective_stats", "roofline_terms",
           "model_flops"]

HW = {
    "peak_flops": 197e12,   # bf16 / chip (TPU v5e)
    "hbm_bw": 819e9,        # B/s
    "ici_bw": 50e9,         # B/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[\d,]*\][^ ]*(?:,\s*)?)+)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: int                   # per-device bytes on the wire
    by_kind: dict[str, int]
    count: int


def collective_stats(hlo_text: str) -> CollectiveStats:
    by_kind: dict[str, int] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # started ops are counted once at -start/plain form
        typestr, kind = m.group(1), m.group(2)
        result = _shape_bytes(typestr)
        g = _group_size(line)
        if kind == "all-gather":
            wire = result * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            wire = result * (g - 1)          # operand = result*g
        elif kind == "all-reduce":
            wire = result * 2 * (g - 1) // max(g, 1)
        elif kind == "all-to-all":
            wire = result * (g - 1) // max(g, 1)
        else:  # collective-permute
            wire = result
        by_kind[kind] = by_kind.get(kind, 0) + wire
        count += 1
    return CollectiveStats(wire_bytes=sum(by_kind.values()),
                           by_kind=by_kind, count=count)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


# --------------------------------------------------------------------- #
def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: dict = HW) -> dict:
    t_c = flops_per_dev / hw["peak_flops"]
    t_m = bytes_per_dev / hw["hbm_bw"]
    t_n = coll_bytes_per_dev / hw["ici_bw"]
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                   key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_n)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dominant,
        # fraction of the step the compute roofline would occupy if the
        # dominant term were fully overlapped with compute
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
    }


def model_flops(cfg, shape, *, per_device_tokens: int | None = None,
                num_devices: int = 256) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference),
    per device."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    return mult * n_active * tokens / num_devices
