import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell, on the single-pod 16x16 mesh
AND the 2x16x16 multi-pod mesh:

    lowered  = jax.jit(step, in_shardings, out_shardings).lower(**specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())      # proves it fits
    print(compiled.cost_analysis())        # FLOPs/bytes for the roofline

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the framework.  Results stream to ``dryrun_results.json``
(incremental, resumable with --skip-done) and feed EXPERIMENTS.md §Dry-run
and §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_32b \
        --shape train_4k --multi-pod both --strategy flashcp
"""

import argparse
import json
import time
import traceback


from repro.compat import set_mesh
from repro.configs import ARCHS, SHAPES, RunConfig, get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, roofline_terms
from repro.launch.steps import (build_decode_step, build_prefill_step,
                                build_train_step, effective_strategy)


def _logits_sized(cfg, shape, mesh) -> set[int]:
    """Result-byte sizes of attention-logits intermediates — what the
    Pallas kernel keeps in VMEM (kernel-adjusted memory term)."""
    if not cfg.uses_attention or shape.kind == "decode":
        return set()
    from repro.launch.steps import default_buf_len
    data = mesh.size // mesh.shape["model"]
    cp = mesh.shape["model"]
    b_loc = max(shape.global_batch // data, 1)
    tq = shape.seq_len // cp
    tk = tq + cp * default_buf_len(shape.seq_len, cp)
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    n = b_loc * hq * tq
    sizes = set()
    for tk_ in (tk, shape.seq_len):          # flashcp buffer or full gather
        for dt in (4, 2, 1):                 # f32 / bf16 / pred masks
            sizes.add(n * tk_ * dt)
            sizes.add(b_loc * tq * tk_ * dt)  # doc-mask tensors
    return sizes

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "dryrun_results.json")


def cell_skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("long_500k needs sub-quadratic attention; skipped for pure "
                "full-attention arch (DESIGN.md §Arch-applicability)")
    return None


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             strategy: str = "flashcp", q_chunk: int = 512,
             remat: bool = True, kv_comm_dtype: str = "native") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t0 = time.time()

    reason = cell_skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "strategy": strategy, "status": "skip", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    run = RunConfig(arch=arch, shape=shape_name, cp_strategy=strategy,
                    attention_impl="xla", remat=remat,
                    kv_comm_dtype=kv_comm_dtype)

    if shape.kind == "train":
        bundle = build_train_step(cfg, mesh, run, shape, q_chunk=q_chunk)
    elif shape.kind == "prefill":
        bundle = build_prefill_step(cfg, mesh, run, shape, q_chunk=q_chunk)
    else:
        bundle = build_decode_step(cfg, mesh, run, shape)

    with set_mesh(mesh):
        lowered = bundle.lower()
        compiled = lowered.compile()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = analyze_hlo(compiled.as_text(),
                      exclude_result_bytes=_logits_sized(cfg, shape, mesh))

    n_dev = mesh.size
    flops = hlo.flops                      # trip-count-aware, per device
    bytes_acc = hlo.bytes
    bytes_kernel_adj = hlo.bytes - hlo.vmem_resident_bytes
    terms = roofline_terms(flops, bytes_kernel_adj,
                           hlo.collective_wire_bytes_tpu)
    terms["memory_s_xla_attention"] = bytes_acc / 819e9
    terms["collective_s_raw_cpu_hlo"] = hlo.collective_wire_bytes / 50e9
    mf = model_flops(cfg, shape, num_devices=n_dev)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": effective_strategy(cfg, strategy),
        "status": "ok",
        "seconds": round(time.time() - t0, 1),
        "devices": n_dev,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "cost": {"flops_per_device": flops,
                 "bytes_per_device": bytes_acc,
                 "bytes_kernel_adjusted": bytes_kernel_adj,
                 "xla_cost_analysis_flops_loopbody_once":
                     float(ca.get("flops", 0.0))},
        "collectives": {
            "wire_bytes_per_device": hlo.collective_wire_bytes,
            "wire_bytes_tpu_adjusted": hlo.collective_wire_bytes_tpu,
            "count": hlo.collective_count,
            "by_kind": {k: round(v) for k, v in
                        hlo.collective_by_kind.items()}},
        "roofline": terms,
        "model_flops_per_device": mf,
        "useful_flops_frac": (mf / flops) if flops else None,
    }
    return rec


def load_results(path: str) -> list[dict]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return []


def save_results(path: str, recs: list[dict]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(recs, f, indent=1)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="flashcp")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-comm-dtype", default="native",
                    choices=["native", "int8"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    recs = load_results(args.results)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("strategy", ""))
            for r in recs if r.get("status") in ("ok", "skip")}

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                mesh_name = "2x16x16" if mp else "16x16"
                strat = effective_strategy(get_config(arch), args.strategy)
                key = (arch, shape, mesh_name, strat)
                if args.skip_done and key in done:
                    continue
                print(f"--- {arch} x {shape} x {mesh_name} [{strat}]",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   strategy=args.strategy,
                                   q_chunk=args.q_chunk,
                                   remat=not args.no_remat,
                                   kv_comm_dtype=args.kv_comm_dtype)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "strategy": args.strategy, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(rec["error"], flush=True)
                    if args.fail_fast:
                        recs = [r for r in recs if
                                (r["arch"], r["shape"], r["mesh"],
                                 r.get("strategy", "")) != key]
                        recs.append(rec)
                        save_results(args.results, recs)
                        raise
                else:
                    if rec["status"] == "ok":
                        mem = rec["memory"]["peak_bytes_per_device"] / 2**30
                        rf = rec["roofline"]
                        print(f"    ok in {rec['seconds']}s | "
                              f"peak {mem:.2f} GiB/dev | "
                              f"compute {rf['compute_s']*1e3:.1f}ms "
                              f"mem {rf['memory_s']*1e3:.1f}ms "
                              f"coll {rf['collective_s']*1e3:.1f}ms "
                              f"-> {rf['dominant']}", flush=True)
                    else:
                        print(f"    skip: {rec['reason']}", flush=True)
                recs = [r for r in recs if
                        (r["arch"], r["shape"], r["mesh"],
                         r.get("strategy", "")) != key]
                recs.append(rec)
                save_results(args.results, recs)

    print(f"\n{len(recs)} records; {failures} failures")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
