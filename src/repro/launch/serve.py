"""Serving driver: batched prefill + decode with a CP-sharded KV cache.

Demonstrates the inference side of the framework: requests are batched,
prefilled through the CP forward pass, then decoded token-by-token with the
distributed flash-decode attention (cache sequence axis sharded over the
``model`` mesh axis; XLA partitions the LSE merge).

CPU-scale example:

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2_3b \
        --smoke --requests 4 --prompt-len 64 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs import get_config, reduce_for_smoke
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import decode_step, init_cache, init_params
from repro.models.context import make_local_context
from repro.models.transformer import forward
from repro.data.packing import doc_ids_and_positions


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if args.mesh == "prod":
        mesh = make_production_mesh()
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_local_mesh(d, m)

    B = args.requests
    Tp = args.prompt_len
    S = Tp + args.gen
    rng = np.random.default_rng(0)

    with set_mesh(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)

        # ---- prefill: one packed doc per request ---------------------- #
        doc, pos = doc_ids_and_positions(np.asarray([Tp]))
        doc = jnp.asarray(np.tile(doc, (B, 1)).astype(np.int32))
        pos = jnp.asarray(np.tile(pos, (B, 1)).astype(np.int32))
        ctx = make_local_context(doc, pos, q_chunk=min(128, Tp))
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, Tp)).astype(np.int32))}
        if cfg.frontend == "audio_frames":
            batch["frame_embeds"] = jnp.asarray(
                rng.standard_normal((B, Tp, cfg.d_model)).astype(np.float32))
        if cfg.frontend == "vit_patches":
            batch["patch_embeds"] = jnp.zeros((B, Tp, cfg.d_model))
            pm = np.zeros((B, Tp), bool)
            pm[:, :min(cfg.num_patch_tokens, Tp)] = True
            batch["patch_mask"] = jnp.asarray(pm)

        t0 = time.time()
        logits, _ = jax.jit(lambda p, b: forward(p, cfg, ctx, b,
                                                 remat=False))(params, batch)
        nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
        t_prefill = time.time() - t0

        # ---- replay prompt into the cache then decode ----------------- #
        cache = init_cache(cfg, B, S)
        dec = jax.jit(lambda p, c, b, t: decode_step(p, cfg, c, b, t))

        def db(tok, t):
            b = {}
            if cfg.frontend == "audio_frames":
                b["frame_embeds"] = jnp.zeros((B, cfg.d_model))
            else:
                b["tokens"] = tok
            return b

        for t in range(Tp):
            _, cache = dec(params, cache,
                           db(batch["tokens"][:, t] if "tokens" in batch
                              else None, t),
                           jnp.full((B,), t, jnp.int32))

        generated = [np.asarray(nxt)]
        t0 = time.time()
        tok = nxt
        for t in range(Tp, S - 1):
            logits, cache = dec(params, cache, db(tok, t),
                                jnp.full((B,), t, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            generated.append(np.asarray(tok))
        t_decode = time.time() - t0
        n_gen = len(generated)

    toks_s = B * n_gen / max(t_decode, 1e-9)
    print(f"[serve] prefill {Tp} toks x {B} reqs in {t_prefill:.2f}s; "
          f"decoded {n_gen} steps x {B} reqs in {t_decode:.2f}s "
          f"({toks_s:.1f} tok/s)")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tokens": np.stack(generated, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
