"""Serving driver: paged-KV continuous-batching engine CLI.

Builds a :class:`repro.serve.ServeEngine`, submits a ragged mix of
requests, and drains it: budgeted cache-writing prefill + ragged
flash-decode over a paged KV block pool (``--kv-layout dense`` keeps
the per-slot stripe layout as the parity oracle; ``--decode-impl
dense`` selects the XLA softmax attention oracle), greedy or
temperature/top-k sampling, and slot admission/retirement mid-flight
(more requests than ``--slots`` exercises continuous batching).
``--shared-prefix N`` prepends the same N-token system prompt to every
request, exercising prefix-cache block sharing; ``--serial`` disables
the unified token-budget step (prefill drains before any decode — the
stall baseline).

Prefill and decode are timed and counted separately — the prompt tokens
and the prefill-produced first token are *prefill* output; decode tok/s
covers decode steps only.

CPU-scale example:

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2_3b \
        --smoke --requests 4 --prompt-len 64 --gen 16 --shared-prefix 16

``--attn-shards N`` splits the dense-layout decode cache into N
LSE-merged segments — the in-process form of the CP-sharded cache merge
(the shard_map form is checked in tests/multidevice/decode_cp_check.py).

Resilience (DESIGN.md §Serving-resilience): ``--max-queue`` bounds the
queue and ``--admission deadline`` sheds the least-slack request under
overload (``--deadline N`` attaches an N-step deadline to every
request); ``--chaos-nan RID:STEP`` / ``--chaos-stuck RID:STEP`` /
``--chaos-delay STEP:SECONDS`` inject faults the watchdog must
quarantine; ``--kill-at STEP`` with ``--snapshot-every N
--snapshot-dir D`` kills the engine mid-run and restores it from the
latest snapshot in-process (``--drain-at STEP`` is the orderly
variant: snapshot + stop + restore).  Every submitted request ends in
the results dict — ok, rejected, shed, or aborted; nothing is lost.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.configs.base import RunConfig
from repro.serve import EngineKilled, ServeEngine, parse_chaos

_RC = RunConfig()   # serve defaults live on RunConfig (single source)


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)

    B = args.requests
    Tp = args.prompt_len
    gen = args.gen
    slots = getattr(args, "slots", 0) or min(B, 8)
    ragged = getattr(args, "ragged", True)
    rng = np.random.default_rng(getattr(args, "seed", 0))

    shared_prefix = getattr(args, "shared_prefix", 0)

    # ragged prompt mix: lengths in [Tp/4, Tp], one request at the full Tp
    lens = np.full((B,), Tp, np.int64)
    if ragged and B > 1:
        lens[1:] = rng.integers(max(1, Tp // 4), Tp + 1, (B - 1,))
    lens = np.maximum(lens, shared_prefix + 1)
    max_len = int(lens.max() + gen)

    chaos = parse_chaos(getattr(args, "chaos_nan", ()),
                        getattr(args, "chaos_stuck", ()),
                        getattr(args, "chaos_delay", ()),
                        kill_at=getattr(args, "kill_at", -1))
    snapshot_dir = getattr(args, "snapshot_dir", "")
    snapshot_every = getattr(args, "snapshot_every", 0)
    drain_at = getattr(args, "drain_at", -1)

    def build(with_chaos):
        return ServeEngine(
            cfg, num_slots=slots, max_len=max_len,
            prefill_chunk=getattr(args, "prefill_chunk", 64),
            decode_impl=getattr(args, "decode_impl", "flash"),
            attn_shards=getattr(args, "attn_shards", 1),
            seed=getattr(args, "seed", 0),
            kv_layout=getattr(args, "kv_layout", _RC.kv_layout),
            block_size=getattr(args, "block_size", _RC.serve_block_size),
            num_blocks=getattr(args, "num_blocks", 0),
            token_budget=getattr(args, "token_budget",
                                 _RC.serve_token_budget),
            prefix_cache=getattr(args, "prefix_cache", True),
            unified=getattr(args, "unified", True),
            max_queue=getattr(args, "max_queue", _RC.serve_max_queue),
            admission=getattr(args, "admission", _RC.serve_admission),
            admit_lookahead=getattr(args, "admit_lookahead",
                                    _RC.serve_admit_lookahead),
            watchdog=getattr(args, "watchdog", True),
            stall_patience=getattr(args, "stall_patience",
                                   _RC.serve_stall_patience),
            chaos=with_chaos)

    eng = build(chaos)
    eng.warmup(prompt_len=int(lens.max()))

    sys_prompt = rng.integers(0, cfg.vocab_size, shared_prefix) \
        .astype(np.int32)
    temperature = getattr(args, "temperature", 0.0)
    top_k = getattr(args, "top_k", 0)
    deadline = getattr(args, "deadline", -1)
    for i in range(B):
        frames = None
        if cfg.frontend == "audio_frames":
            # the request's *real* frame embeddings — these reach the KV
            # cache through prefill (the old driver replayed zeros)
            frames = rng.standard_normal(
                (int(lens[i]), cfg.d_model)).astype(np.float32)
        toks = rng.integers(0, cfg.vocab_size,
                            int(lens[i]) - shared_prefix).astype(np.int32)
        eng.submit(np.concatenate([sys_prompt, toks]),
                   max_new=gen, temperature=temperature, top_k=top_k,
                   frames=frames, deadline_steps=deadline)

    max_steps = getattr(args, "max_steps", 100_000)
    t0 = time.perf_counter()
    restored_from = None
    try:
        results = eng.run(max_steps=max_steps,
                          snapshot_every=snapshot_every,
                          snapshot_dir=snapshot_dir or None,
                          drain_at=drain_at)
        interrupted = drain_at >= 0 and eng.sched.has_work
        if interrupted:
            print(f"[serve] drained at step {eng.stats['steps']} "
                  f"into {snapshot_dir}")
    except EngineKilled as e:
        print(f"[serve] {e}; restoring from {snapshot_dir}")
        interrupted = True
    if interrupted:
        # restart-from-snapshot round trip, in-process: a fresh engine
        # (no chaos — the fault fired) resumes the in-flight work
        eng = build(None)
        eng.warmup(prompt_len=int(lens.max()))
        step = eng.restore_snapshot(snapshot_dir)
        restored_from = step
        print(f"[serve] restored snapshot at step {step}; resuming")
        results = eng.run(max_steps=max_steps)
    wall = time.perf_counter() - t0

    # the resilience contract: every submitted request terminates in
    # the results dict — a lost rid is a bug, fail loudly
    missing = [r for r in range(B) if r not in results]
    assert not missing, f"requests lost from results: {missing}"

    s = eng.stats
    tp = eng.throughput()
    print(f"[serve] {cfg.name}: {B} requests ({slots} slots, "
          f"prompts {lens.min()}..{lens.max()}, gen {gen}, "
          f"kv_layout={eng.layout}, decode_impl={eng.decode_impl})")
    print(f"[serve] prefill: {s['prefill_tokens']} prompt tokens "
          f"({s['prefill_chunk_tokens']} computed, "
          f"{s['prefill_cached_tokens']} prefix-cached) in "
          f"{s['prefill_steps']} chunk steps + "
          f"{s['prefill_decode_steps']} replay steps, "
          f"{s['prefill_s']:.2f}s ({tp['prefill_tok_s']:.1f} computed "
          f"tok/s, {tp['prefill_effective_tok_s']:.1f} effective)")
    print(f"[serve] decode:  {s['decode_tokens']} tokens in "
          f"{s['decode_steps']} steps, {s['decode_s']:.2f}s "
          f"({tp['decode_tok_s']:.1f} tok/s); wall {wall:.2f}s; "
          f"stalled decode steps {s['stalled_decode_steps']}")
    if eng.layout == "paged":
        ps = eng.pool.stats()
        print(f"[serve] pool:    {ps['allocated']}/{ps['num_blocks']} "
              f"blocks live (peak {ps['peak_allocated']}, block_size "
              f"{ps['block_size']}), cow {s['cow_copies']}, "
              f"backoffs {s['admission_backoffs']}")
        if eng.prefix is not None:
            xs = eng.prefix.stats()
            print(f"[serve] prefix:  {xs['nodes']} cached blocks, "
                  f"hit rate {xs['hit_rate']:.2f} "
                  f"({xs['hit_tokens']} tokens skipped)")
    statuses = {}
    for r in results.values():
        statuses[r["status"]] = statuses.get(r["status"], 0) + 1
    lat = eng.latency_percentiles()
    print(f"[serve] outcomes: {statuses}; "
          f"rejected {s['rejected_by_reason']}, "
          f"shed {s['shed_by_reason']}, "
          f"aborted {s['aborted_by_reason']}")
    print(f"[serve] latency (ok): p50 {lat['p50_steps']:.0f} steps / "
          f"{lat['p50_s'] * 1e3:.0f} ms, p99 {lat['p99_steps']:.0f} "
          f"steps / {lat['p99_s'] * 1e3:.0f} ms"
          + (f"; restored from step {restored_from}"
             if restored_from is not None else ""))
    return {"results": results, "stats": dict(s), "throughput": tp,
            "prompt_lens": lens, "kv_layout": eng.layout,
            "latency": lat, "restored_from": restored_from,
            "pool": None if eng.pool is None else eng.pool.stats(),
            "prefix": None if eng.prefix is None else eng.prefix.stats(),
            "tokens": {r: results[r]["tokens"] for r in results
                       if results[r]["status"] == "ok"}}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=0,
                    help="cache slots (0 = min(requests, 8))")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    dest="prefill_chunk")
    ap.add_argument("--decode-impl", choices=("flash", "dense"),
                    default="flash", dest="decode_impl")
    ap.add_argument("--attn-shards", type=int, default=1,
                    dest="attn_shards")
    ap.add_argument("--kv-layout", choices=("auto", "paged", "dense"),
                    default=_RC.kv_layout, dest="kv_layout")
    ap.add_argument("--block-size", type=int,
                    default=_RC.serve_block_size, dest="block_size",
                    help="tokens per paged KV block")
    ap.add_argument("--num-blocks", type=int, default=0,
                    dest="num_blocks",
                    help="pool blocks (0 = dense-equivalent capacity)")
    ap.add_argument("--token-budget", type=int,
                    default=_RC.serve_token_budget, dest="token_budget",
                    help="tokens per unified step "
                         "(0 = slots + prefill_chunk)")
    ap.add_argument("--no-prefix-cache", action="store_false",
                    dest="prefix_cache",
                    help="disable cross-request prefix block sharing")
    ap.add_argument("--serial", action="store_false", dest="unified",
                    help="drain prefill before decode (stall baseline)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    dest="shared_prefix",
                    help="shared system-prompt tokens per request")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0, dest="top_k")
    ap.add_argument("--uniform", action="store_false", dest="ragged",
                    help="all prompts at --prompt-len (default: ragged)")
    ap.add_argument("--seed", type=int, default=0)
    # resilience (DESIGN.md §Serving-resilience)
    ap.add_argument("--max-queue", type=int,
                    default=_RC.serve_max_queue, dest="max_queue",
                    help="queue bound (0 = unbounded)")
    ap.add_argument("--admission", choices=("fifo", "deadline"),
                    default=_RC.serve_admission,
                    help="overload policy: shed incoming (fifo) or "
                         "least-slack (deadline)")
    ap.add_argument("--admit-lookahead", type=int,
                    default=_RC.serve_admit_lookahead,
                    dest="admit_lookahead",
                    help="requests that may jump a pool-blocked head "
                         "(0 = strict FIFO)")
    ap.add_argument("--deadline", type=int, default=-1,
                    help="deadline_steps attached to every request "
                         "(-1 = none)")
    ap.add_argument("--no-watchdog", action="store_false",
                    dest="watchdog",
                    help="disable fault quarantine (pre-resilience "
                         "engine)")
    ap.add_argument("--stall-patience", type=int,
                    default=_RC.serve_stall_patience,
                    dest="stall_patience")
    ap.add_argument("--chaos-nan", action="append", default=[],
                    dest="chaos_nan", metavar="RID:STEP",
                    help="poison a request's logits to NaN from STEP on")
    ap.add_argument("--chaos-stuck", action="append", default=[],
                    dest="chaos_stuck", metavar="RID:STEP",
                    help="drop a request's planned work from STEP on")
    ap.add_argument("--chaos-delay", action="append", default=[],
                    dest="chaos_delay", metavar="STEP:SECONDS",
                    help="inject a latency spike at STEP")
    ap.add_argument("--kill-at", type=int, default=-1, dest="kill_at",
                    help="raise EngineKilled at this step (restore "
                         "needs --snapshot-every + --snapshot-dir)")
    ap.add_argument("--drain-at", type=int, default=-1, dest="drain_at",
                    help="orderly drain: snapshot + stop at this step, "
                         "then restore and finish in-process")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    dest="snapshot_every",
                    help="snapshot the engine every N steps")
    ap.add_argument("--snapshot-dir", default="", dest="snapshot_dir")
    ap.add_argument("--max-steps", type=int, default=100_000,
                    dest="max_steps")
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
