"""End-to-end fault-tolerant training driver.

Wires together: data pipeline (FlashCP planning per batch) -> pjit'd
train step (CP attention islands, FSDP params) -> AdamW -> async
checkpointing -> fault-tolerance supervision (restart / elastic shrink)
-> straggler-adaptive planner targets.

CPU-scale example (quickstart-sized model, real training):

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2_3b \
        --smoke --steps 20 --seq-len 512 --batch 2 --mesh 1x1

Production shapes lower through the same path (see launch/dryrun.py for
the no-hardware variant).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.compat import set_mesh
from repro.configs import RunConfig, get_config, reduce_for_smoke
from repro.configs.base import ShapeConfig
from repro.data.pipeline import (PipelineConfig, Prefetcher,
                                 make_batch, make_dispatch_batch)
from repro.launch.mesh import (make_group_mesh, make_local_mesh,
                               make_production_mesh)
from repro.launch.steps import build_train_step, effective_strategy
from repro.planner import get_planner
from repro.models import init_params
from repro.optim import adamw_init
from repro.runtime import (FailurePolicy, StragglerMonitor, TrainingFailure,
                           run_with_recovery)
from repro.runtime.sharding import batch_axes_of, param_shardings


def device_put_batch(batch, shardings):
    out = {}
    for k, v in batch.items():
        if k == "stats" or k == "perm":
            continue
        out[k] = jax.device_put(jnp.asarray(v), shardings.get(k))
    return out


def _train_dispatch(args, cfg, run: RunConfig, mesh_axes) -> dict:
    """Adaptive-dispatch training loop (DESIGN.md §Dispatch).

    Per step, the dispatcher sizes the CP subgroups from the batch's
    document-length profile; the device grid is re-tiled with
    :func:`make_group_mesh` and one jitted step per degree is built
    lazily (at most ``log2(model)`` executables — the same bucketing
    argument as the Eq. 5 buffer).  A degree switch re-shards
    params/optimizer onto the new tiling (a rare, amortized device_put:
    degrees are sticky while the data mix is).  The per-step loss is
    token-weighted across groups by construction — the global masked CE
    mean divides by the step's global valid-token count.

    Fault injection / elastic resharding stay on the legacy path; this
    loop supports checkpointing, ``--resume`` (the dispatch stream is a
    pure function of (seed, step), so a restarted run replays exactly),
    and prefetch.
    """
    from repro.dispatch import DispatchConfig

    D, M = mesh_axes
    align = 128 if run.attention_impl == "pallas" \
        else (1 if D * M == 1 else 16)
    dcfg = DispatchConfig(
        data=D, model=M, seqs=args.batch,
        target_imbalance=run.dispatch_target_imbalance,
        min_cp=run.dispatch_min_cp, quantum=align)
    strategy = effective_strategy(cfg, run.cp_strategy)
    pipe_cfg = PipelineConfig(
        dataset=args.dataset, context_len=args.seq_len,
        batch_per_host=args.batch, cp_size=M, strategy=strategy,
        vocab_size=cfg.vocab_size, seed=run.seed, align=align,
        emit_tables=(run.attention_impl == "pallas" and cfg.uses_attention),
        table_overlap=run.cp_overlap, table_grid=run.kernel_grid)
    shape = ShapeConfig("dispatch", args.seq_len, args.batch, "train")

    bundles: dict[int, tuple] = {}

    def degree(g: int):
        if g not in bundles:
            mesh_g = make_group_mesh(D, M, g)
            bundle = build_train_step(cfg, mesh_g, run, shape,
                                      q_chunk=args.q_chunk)
            step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                              out_shardings=bundle.out_shardings,
                              donate_argnums=bundle.donate_argnums)
            bundles[g] = (mesh_g, bundle, step_fn)
        return bundles[g]

    ckpt = CheckpointManager(run.checkpoint_dir, keep=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
    it = Prefetcher(pipe_cfg, start_step=start, dispatch=dcfg) \
        if args.prefetch else None
    pending = next(it) if it else make_dispatch_batch(pipe_cfg, dcfg, start)
    g0 = pending["stats"]["dispatch"]["cp_degree"]
    mesh0, bundle0, _ = degree(g0)
    p_shard, o_shard, _, _ = bundle0.in_shardings
    with set_mesh(mesh0):
        if start:
            # the pipeline is a pure function of (seed, step), so the
            # resumed stream replays exactly; state reshards onto the
            # first resumed batch's degree
            start, state, _ = ckpt.restore(
                shardings={"params": p_shard, "opt": o_shard})
            print(f"[train] resumed from step {start}")
        else:
            params = jax.device_put(
                init_params(jax.random.PRNGKey(run.seed), cfg), p_shard)
            opt = jax.device_put(adamw_init(params), o_shard)
            state = {"params": params, "opt": opt}
    cur_g = g0
    losses = []
    switches = 0

    for step in range(start, args.steps):
        t0 = time.time()
        batch = pending if pending is not None else (
            next(it) if it else make_dispatch_batch(pipe_cfg, dcfg, step))
        pending = None
        ds = batch["stats"]["dispatch"]
        g = ds["cp_degree"]
        mesh_g, bundle_g, step_fn = degree(g)
        if g != cur_g:
            p_s, o_s, _, _ = bundle_g.in_shardings
            state = {"params": jax.device_put(state["params"], p_s),
                     "opt": jax.device_put(state["opt"], o_s)}
            cur_g = g
            switches += 1
        _, _, b_shard, _ = bundle_g.in_shardings
        with set_mesh(mesh_g):
            db = device_put_batch(batch, b_shard)
            db = {k: v for k, v in db.items()
                  if k in bundle_g.abstract_inputs[2]}
            p, o, metrics = step_fn(state["params"], state["opt"], db,
                                    jnp.asarray(step, jnp.int32))
        state = {"params": p, "opt": o}
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"cp {g} groups {ds['n_groups']} "
                  f"tok_imb {ds['token_imbalance']:.3f} "
                  f"work_imb {ds['work_imbalance']:.3f} "
                  f"tokens {int(metrics['tokens'])} "
                  f"{time.time()-t0:.2f}s", flush=True)
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            ckpt.save(step + 1, state, blocking=False)

    ckpt.save(args.steps, state, blocking=True)
    if it:
        it.close()
    print(f"[train] dispatch: {switches} degree switches over "
          f"{args.steps} steps; degrees used: {sorted(bundles)}")
    return {"final_step": args.steps, "losses": losses}


def train(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if args.mesh == "prod":
        mesh = make_production_mesh()
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_local_mesh(d, m)
    cp = mesh.shape["model"]

    # dispatch flags default off for programmatic callers (SimpleNamespace)
    dispatch = getattr(args, "dispatch", False)
    run = RunConfig(arch=args.arch, cp_strategy=args.strategy,
                    attention_impl=args.attention_impl, lr=args.lr,
                    total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                    grad_compression=args.grad_compression,
                    checkpoint_dir=args.checkpoint_dir, remat=not args.no_remat,
                    dispatch="adaptive" if dispatch else "off",
                    dispatch_target_imbalance=getattr(args, "dispatch_target",
                                                      1.1),
                    dispatch_min_cp=getattr(args, "dispatch_min_cp", 1))
    shape = ShapeConfig("custom", args.seq_len, args.batch, "train")
    # resolve through the planner registry: unknown --strategy fails fast
    # with the list of registered planners.
    get_planner(run.cp_strategy)
    if dispatch:
        return _train_dispatch(args, cfg, run,
                               (mesh.shape["data"], mesh.shape["model"]))
    strategy = effective_strategy(cfg, run.cp_strategy)

    pipe_cfg = PipelineConfig(
        dataset=args.dataset, context_len=args.seq_len,
        batch_per_host=args.batch, cp_size=cp, strategy=strategy,
        vocab_size=cfg.vocab_size, seed=run.seed,
        buf_len=None if cp == 1 else None,
        # pallas tables need block-divisible rank slices
        align=128 if run.attention_impl == "pallas"
        else (1 if cp == 1 else 16),
        emit_tables=(run.attention_impl == "pallas" and cfg.uses_attention),
        table_overlap=run.cp_overlap, table_grid=run.kernel_grid)

    bundle = build_train_step(cfg, mesh, run, shape, q_chunk=args.q_chunk)
    p_shard, o_shard, b_shard, _ = bundle.in_shardings

    with set_mesh(mesh):
        params = init_params(jax.random.PRNGKey(run.seed), cfg)
        params = jax.device_put(params, p_shard)
        opt = jax.device_put(adamw_init(params), o_shard)
        step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings,
                          donate_argnums=bundle.donate_argnums)

        ckpt = CheckpointManager(run.checkpoint_dir, keep=2)
        straggler = StragglerMonitor()
        policy = FailurePolicy(min_hosts=1)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            start, state, _ = ckpt.restore(
                shardings={"params": p_shard, "opt": o_shard})
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start}")

        state = {"params": params, "opt": opt}
        losses = []
        it = Prefetcher(pipe_cfg, start_step=start) if args.prefetch \
            else None

        def one_step(step: int) -> None:
            nonlocal state
            t0 = time.time()
            if args.fail_at == step and policy.restarts == 0:
                raise TrainingFailure("injected failure", failed_hosts=[])
            batch = next(it) if it else make_batch(pipe_cfg, step)
            db = device_put_batch(batch, b_shard)
            # tolerate missing optional keys for this strategy
            db = {k: v for k, v in db.items() if k in
                  bundle.abstract_inputs[2]}
            p, o, metrics = step_fn(state["params"], state["opt"], db,
                                    jnp.asarray(step, jnp.int32))
            state = {"params": p, "opt": o}
            loss = float(metrics["loss"])
            losses.append(loss)
            straggler.record_step(time.time() - t0)
            if step % args.log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"ce {float(metrics['ce']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"imb {batch['stats']['imbalance']:.3f} "
                      f"comm_tok {batch['stats']['comm_tokens']} "
                      f"{time.time()-t0:.2f}s", flush=True)
            if args.ckpt_every and step and step % args.ckpt_every == 0:
                ckpt.save(step + 1, state, blocking=False)

        def on_restore(action, failed_hosts):
            nonlocal state
            latest = ckpt.latest_step()
            if latest is None:
                state = {"params": jax.device_put(
                    init_params(jax.random.PRNGKey(run.seed), cfg), p_shard)}
                state["opt"] = jax.device_put(adamw_init(state["params"]),
                                              o_shard)
                return 0
            s, st, _ = ckpt.restore(
                shardings={"params": p_shard, "opt": o_shard})
            state = st
            print(f"[train] restored step {s} after {action.value}")
            return s

        final = run_with_recovery(one_step, start_step=start,
                                  total_steps=args.steps, policy=policy,
                                  on_restore=on_restore)
        ckpt.save(final, state, blocking=True)
        if it:
            it.close()
    return {"final_step": final, "losses": losses}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--mesh", default="1x1", help="DxM or 'prod'")
    ap.add_argument("--strategy", default="flashcp")
    ap.add_argument("--attention-impl", default="xla")
    ap.add_argument("--dataset", default="wlb_llm")
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--q-chunk", type=int, default=128)
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--prefetch", action="store_true")
    ap.add_argument("--dispatch", action="store_true",
                    help="adaptive DP×CP token dispatch (per-batch CP "
                         "group sizing + cross-rank balancing)")
    ap.add_argument("--dispatch-target", type=float, default=1.1,
                    help="max cross-group token/workload imbalance before "
                         "the dispatcher escalates the CP degree")
    ap.add_argument("--dispatch-min-cp", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (FT test)")
    args = ap.parse_args()
    out = train(args)
    print(f"[train] done at step {out['final_step']}; "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
