"""End-to-end fault-tolerant training driver.

Wires together: data pipeline (FlashCP planning per batch) -> pjit'd
train step (CP attention islands, FSDP params) -> AdamW -> async
checkpointing -> elastic degree-replanning supervision (restart /
shrink-to-survivors, DESIGN.md §Recovery) -> straggler-adaptive planner
targets and capacity-proportional dispatch.

CPU-scale example (quickstart-sized model, real training):

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2_3b \
        --smoke --steps 20 --seq-len 512 --batch 2 --mesh 1x1

Fault-injection example (lose host 3 of a simulated 4-host 2x4 grid at
step 6; the run shrinks the data axis, reshards the checkpoint onto the
survivors and finishes):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --smoke --mesh 2x4 \
        --hosts 4 --batch 8 --steps 12 --ckpt-every 2 --dispatch \
        --fail-at 6:3

Autotune example (search the run-config knobs with the cost-model
tuner before training; ``--autotune-dry`` prints the pick and exits):

    PYTHONPATH=src python -m repro.launch.train --smoke --mesh 1x2 \
        --batch 2 --autotune --autotune-dry

Production shapes lower through the same path (see launch/dryrun.py for
the no-hardware variant).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.compat import set_mesh
from repro.configs import RunConfig, get_config, reduce_for_smoke
from repro.configs.base import ShapeConfig
from repro.data.pipeline import (PipelineConfig, Prefetcher,
                                 make_batch, make_dispatch_batch)
from repro.launch.mesh import (make_group_mesh, make_local_mesh,
                               make_production_mesh)
from repro.launch.steps import build_train_step, effective_strategy
from repro.planner import get_planner
from repro.models import init_params
from repro.optim import adamw_init
from repro.runtime import (ElasticSupervisor, FailureInjector, FailurePolicy,
                           HostTopology, StragglerMonitor, StragglerSim,
                           parse_fail_spec, parse_straggle_specs)


def device_put_batch(batch, shardings):
    out = {}
    for k, v in batch.items():
        if k == "stats" or k == "perm":
            continue
        out[k] = jax.device_put(jnp.asarray(v), shardings.get(k))
    return out


def _ft_setup(args, n_dev: int, model_axis: int):
    """Fault-tolerance plumbing shared by both train loops.

    Builds the simulated host topology (``--hosts``, default one host per
    data row), the failure policy (min_hosts = hosts needed to still hold
    the model/CP axis after a shrink), the straggler monitor, and the
    injection hooks (``--fail-at STEP[:HOSTS]``, ``--straggle
    HOST:FACTOR``).  See DESIGN.md §Recovery.
    """
    fail_step, fail_hosts = parse_fail_spec(getattr(args, "fail_at", -1))
    factors = parse_straggle_specs(getattr(args, "straggle", None))
    hosts = getattr(args, "hosts", 0) or max(n_dev // model_axis, 1)
    if n_dev % hosts:
        raise ValueError(f"--hosts {hosts} must divide the device "
                         f"count {n_dev}")
    dph = n_dev // hosts
    for h in list(fail_hosts) + list(factors):
        if not 0 <= h < hosts:
            raise ValueError(f"host {h} out of range for --hosts {hosts}")
    topology = HostTopology(num_hosts=hosts, devices_per_host=dph)
    policy = FailurePolicy(
        min_hosts=max(1, -(-model_axis // dph)),
        max_restarts=getattr(args, "max_restarts", 10))
    monitor = StragglerMonitor()
    injector = FailureInjector(fail_step, fail_hosts)
    sim = StragglerSim(factors)
    return topology, policy, monitor, injector, sim


def _effective_accum(batch: int, groups: int, accum: int) -> int:
    """Grad-accumulation factor actually usable at this tiling: micro
    slicing needs ``batch % (groups * accum) == 0``; otherwise run the
    whole batch in one micro-step (global batch is preserved either way —
    accum only relieves per-step residency)."""
    return accum if accum > 1 and batch % (groups * accum) == 0 else 1


def _train_dispatch(args, cfg, run: RunConfig, mesh_axes) -> dict:
    """Adaptive-dispatch training loop (DESIGN.md §Dispatch, §Recovery).

    Per step, the dispatcher sizes the CP subgroups from the batch's
    document-length profile; the device grid is re-tiled with
    :func:`make_group_mesh` and one jitted step per (tiling, degree,
    accum) is built lazily.  A degree switch re-shards params/optimizer
    onto the new tiling (a rare, amortized device_put: degrees are sticky
    while the data mix is).  The per-step loss is token-weighted across
    groups by construction — the global masked CE mean divides by the
    step's global valid-token count.

    Supervision wraps the loop: an injected (or, on a cluster, detected)
    :class:`TrainingFailure` naming lost hosts triggers an elastic
    shrink — the supervisor re-derives the surviving grid, the dispatch
    config's data axis shrinks with it, state restores from the latest
    checkpoint *resharded* onto the first resumed batch's degree, and the
    deterministic (seed, step) stream replays to the failure point.
    ``plan.accum_factor`` micro-batches each step when the shrunk grid
    must preserve the global batch.  Straggler wall-times feed per-host
    speed EMAs; the dispatcher LPT-balances *completion time* with them
    (capacity-proportional placement) and jitter tightens its imbalance
    target.
    """
    from repro.dispatch import DispatchConfig

    D, M = mesh_axes
    topology, policy, monitor, injector, sim = _ft_setup(args, D * M, M)
    supervisor = ElasticSupervisor(topology, policy, data=D, model=M,
                                   monitor=monitor)
    align = 128 if run.attention_impl == "pallas" \
        else (1 if D * M == 1 else 16)
    strategy = effective_strategy(cfg, run.cp_strategy)
    pipe_cfg = PipelineConfig(
        dataset=args.dataset, context_len=args.seq_len,
        batch_per_host=args.batch, cp_size=M, strategy=strategy,
        vocab_size=cfg.vocab_size, seed=run.seed, align=align,
        emit_tables=(run.attention_impl == "pallas" and cfg.uses_attention),
        table_overlap=run.cp_overlap, table_grid=run.kernel_grid)
    shape = ShapeConfig("dispatch", args.seq_len, args.batch, "train")

    # mutable current-topology state; on_restore rewrites it on a shrink
    cur = {
        "data": D, "devices": None, "accum": 1, "key": None,
        "dcfg": DispatchConfig(
            data=D, model=M, seqs=args.batch,
            target_imbalance=run.dispatch_target_imbalance,
            min_cp=run.dispatch_min_cp, quantum=align),
    }
    bundles: dict[tuple, tuple] = {}

    def bundle_key(g: int) -> tuple:
        groups = cur["data"] * M // g
        return (cur["data"], g,
                _effective_accum(args.batch, groups, cur["accum"]))

    def degree(g: int):
        key = bundle_key(g)
        if key not in bundles:
            mesh_g = make_group_mesh(cur["data"], M, g,
                                     devices=cur["devices"])
            bundle = build_train_step(cfg, mesh_g, run, shape,
                                      q_chunk=args.q_chunk, accum=key[2])
            step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                              out_shardings=bundle.out_shardings,
                              donate_argnums=bundle.donate_argnums)
            bundles[key] = (mesh_g, bundle, step_fn)
        return key, bundles[key]

    ckpt = CheckpointManager(run.checkpoint_dir, keep=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()

    def make_stream(step):
        """(prefetcher-or-None, first batch) starting at ``step``."""
        if args.prefetch:
            pf = Prefetcher(pipe_cfg, start_step=step, dispatch=cur["dcfg"],
                            speeds_fn=supervisor.device_speeds)
            return pf, next(pf)
        return None, make_dispatch_batch(pipe_cfg, cur["dcfg"], step,
                                         device_speeds=
                                         supervisor.device_speeds())

    it, first = make_stream(start)
    pending = {"batch": first}
    g0 = first["stats"]["dispatch"]["cp_degree"]
    key0, (mesh0, bundle0, _) = degree(g0)
    p_shard, o_shard = bundle0.in_shardings[:2]
    with set_mesh(mesh0):
        if start:
            # the pipeline is a pure function of (seed, step), so the
            # resumed stream replays exactly; state reshards onto the
            # first resumed batch's degree
            start, state, _ = ckpt.restore(
                shardings={"params": p_shard, "opt": o_shard})
            print(f"[train] resumed from step {start}")
        else:
            params = jax.device_put(
                init_params(jax.random.PRNGKey(run.seed), cfg), p_shard)
            opt = jax.device_put(adamw_init(params), o_shard)
            state = {"params": params, "opt": opt}
    cur["key"] = key0
    losses = []
    switches = [0]

    def one_step(step: int) -> None:
        nonlocal state
        t0 = time.time()
        injector.maybe_fail(step)
        if pending["batch"] is not None:
            batch, pending["batch"] = pending["batch"], None
        elif it is not None:
            batch = next(it)
        else:
            batch = make_dispatch_batch(pipe_cfg, cur["dcfg"], step,
                                        device_speeds=
                                        supervisor.device_speeds())
        ds = batch["stats"]["dispatch"]
        g = ds["cp_degree"]
        key, (mesh_g, bundle_g, step_fn) = degree(g)
        if key != cur["key"]:
            p_s, o_s = bundle_g.in_shardings[:2]
            state = {"params": jax.device_put(state["params"], p_s),
                     "opt": jax.device_put(state["opt"], o_s)}
            cur["key"] = key
            switches[0] += 1
        b_shard = bundle_g.in_shardings[2]
        with set_mesh(mesh_g):
            db = device_put_batch(batch, b_shard)
            db = {k: v for k, v in db.items()
                  if k in bundle_g.abstract_inputs[2]}
            p, o, metrics = step_fn(state["params"], state["opt"], db,
                                    jnp.asarray(step, jnp.int32))
        state = {"params": p, "opt": o}
        loss = float(metrics["loss"])
        losses.append(loss)
        # feed measured (straggler-inflated, if simulated) wall times into
        # the per-host speed EMAs; under jitter, tighten the dispatcher's
        # imbalance target (live only on the non-prefetch path — the
        # prefetch thread samples speeds but holds its config)
        sim.observe(monitor, time.time() - t0,
                    supervisor.surviving_hosts())
        if it is None:
            tgt = round(monitor.adjusted_target(), 2)
            if abs(tgt - cur["dcfg"].target_imbalance) > 1e-9:
                cur["dcfg"] = dataclasses.replace(
                    cur["dcfg"], target_imbalance=tgt)
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"cp {g} groups {ds['n_groups']} "
                  f"tok_imb {ds['token_imbalance']:.3f} "
                  f"work_imb {ds['work_imbalance']:.3f} "
                  f"tokens {int(metrics['tokens'])} "
                  f"{time.time()-t0:.2f}s", flush=True)
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            ckpt.save(step + 1, state, blocking=False)

    def on_restore(action, plan):
        nonlocal state, it
        try:
            ckpt.wait()         # settle any in-flight async save
        except RuntimeError as err:
            print(f"[train] pending checkpoint save failed: {err}")
        if it is not None:
            it.close()
        if plan is not None:    # elastic shrink: retile over survivors
            cur["data"] = plan.data_axis
            cur["devices"] = [jax.devices()[i] for i in plan.devices]
            cur["accum"] = plan.accum_factor
            cur["dcfg"] = dataclasses.replace(cur["dcfg"],
                                              data=plan.data_axis)
            bundles.clear()
        resume = ckpt.latest_step() or 0
        it, first = make_stream(resume)
        pending["batch"] = first
        g = first["stats"]["dispatch"]["cp_degree"]
        key, (mesh_g, bundle_g, _) = degree(g)
        p_s, o_s = bundle_g.in_shardings[:2]
        with set_mesh(mesh_g):
            if ckpt.latest_step() is not None:
                _, st, _ = ckpt.restore(
                    shardings={"params": p_s, "opt": o_s})
                state = st
            else:
                params = jax.device_put(
                    init_params(jax.random.PRNGKey(run.seed), cfg), p_s)
                state = {"params": params,
                         "opt": jax.device_put(adamw_init(params), o_s)}
        cur["key"] = key
        print(f"[train] restored step {resume} after {action.value} "
              f"(mesh {cur['data']}x{M}, accum {key[2]})", flush=True)
        return resume

    final = supervisor.run(one_step, start_step=start,
                           total_steps=args.steps, on_restore=on_restore)
    ckpt.save(final, state, blocking=True)
    if it:
        it.close()
    print(f"[train] dispatch: {switches[0]} degree switches over "
          f"{args.steps} steps; tilings used: {sorted(bundles)}")
    return {"final_step": final, "losses": losses,
            "recoveries": policy.restarts,
            "dead_hosts": sorted(supervisor.dead),
            "mesh": (cur["data"], M), "accum": cur["accum"],
            "degree_switches": switches[0]}


def train(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if args.mesh == "prod":
        mesh = make_production_mesh()
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_local_mesh(d, m)
    cp = mesh.shape["model"]
    d_axis = mesh.shape["data"]

    # dispatch flags default off for programmatic callers (SimpleNamespace)
    dispatch = getattr(args, "dispatch", False)
    run = RunConfig(arch=args.arch, cp_strategy=args.strategy,
                    attention_impl=args.attention_impl, lr=args.lr,
                    total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                    grad_compression=args.grad_compression,
                    checkpoint_dir=args.checkpoint_dir, remat=not args.no_remat,
                    dispatch="adaptive" if dispatch else "off",
                    dispatch_target_imbalance=getattr(args, "dispatch_target",
                                                      1.1),
                    dispatch_min_cp=getattr(args, "dispatch_min_cp", 1))
    shape = ShapeConfig("custom", args.seq_len, args.batch, "train")
    # resolve through the planner registry: unknown --strategy fails fast
    # with the list of registered planners.
    get_planner(run.cp_strategy)
    if getattr(args, "autotune", False):
        from repro.autotune import autotune_run
        run, tuned = autotune_run(
            run, cfg, data=d_axis, model=cp, context_len=args.seq_len,
            seqs=args.batch, dataset=args.dataset,
            cache_dir=getattr(args, "autotune_cache", ""),
            top_k=getattr(args, "autotune_topk", 8))
        dispatch = run.dispatch == "adaptive"
        print(f"[autotune] {'cache hit' if tuned.cached else 'searched'} "
              f"{tuned.n_candidates} candidates (top-{tuned.top_k} "
              f"measured): {run.cp_strategy}/{run.cp_overlap}/"
              f"{run.kernel_grid}/{run.dispatch}/{run.kv_comm_dtype} "
              f"frontier_rho={tuned.spearman_frontier:.2f}", flush=True)
        if getattr(args, "autotune_dry", False):
            return {"final_step": 0, "losses": [],
                    "autotune": {"best": tuned.best.as_dict(),
                                 "key": tuned.key, "cached": tuned.cached,
                                 "n_candidates": tuned.n_candidates},
                    "run_config": tuned.run_config}
    if dispatch:
        return _train_dispatch(args, cfg, run, (d_axis, cp))
    strategy = effective_strategy(cfg, run.cp_strategy)

    topology, policy, monitor, injector, sim = _ft_setup(args, d_axis * cp,
                                                         cp)
    supervisor = ElasticSupervisor(topology, policy, data=d_axis, model=cp,
                                   monitor=monitor)

    pipe_cfg = PipelineConfig(
        dataset=args.dataset, context_len=args.seq_len,
        batch_per_host=args.batch, cp_size=cp, strategy=strategy,
        vocab_size=cfg.vocab_size, seed=run.seed,
        buf_len=None if cp == 1 else None,
        # pallas tables need block-divisible rank slices
        align=128 if run.attention_impl == "pallas"
        else (1 if cp == 1 else 16),
        emit_tables=(run.attention_impl == "pallas" and cfg.uses_attention),
        table_overlap=run.cp_overlap, table_grid=run.kernel_grid)

    def build(mesh_, accum: int):
        bundle = build_train_step(cfg, mesh_, run, shape,
                                  q_chunk=args.q_chunk, accum=accum)
        step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings,
                          donate_argnums=bundle.donate_argnums)
        return bundle, step_fn

    bundle, step_fn = build(mesh, 1)
    cur = {"mesh": mesh, "bundle": bundle, "step_fn": step_fn,
           "accum": 1, "pipe": pipe_cfg}
    p_shard, o_shard = bundle.in_shardings[:2]

    ckpt = CheckpointManager(run.checkpoint_dir, keep=2)
    start = 0
    with set_mesh(mesh):
        if args.resume and ckpt.latest_step() is not None:
            start, state, _ = ckpt.restore(
                shardings={"params": p_shard, "opt": o_shard})
            print(f"[train] resumed from step {start}")
        else:
            params = jax.device_put(
                init_params(jax.random.PRNGKey(run.seed), cfg), p_shard)
            state = {"params": params,
                     "opt": jax.device_put(adamw_init(params), o_shard)}
    losses = []
    it = Prefetcher(pipe_cfg, start_step=start) if args.prefetch else None

    def one_step(step: int) -> None:
        nonlocal state
        t0 = time.time()
        injector.maybe_fail(step)
        batch = next(it) if it else make_batch(cur["pipe"], step)
        bundle_c = cur["bundle"]
        with set_mesh(cur["mesh"]):
            db = device_put_batch(batch, bundle_c.in_shardings[2])
            # tolerate missing optional keys for this strategy
            db = {k: v for k, v in db.items() if k in
                  bundle_c.abstract_inputs[2]}
            p, o, metrics = cur["step_fn"](state["params"], state["opt"],
                                           db, jnp.asarray(step, jnp.int32))
        state = {"params": p, "opt": o}
        loss = float(metrics["loss"])
        losses.append(loss)
        # straggler loop: per-host wall times (inflated when simulated)
        # feed the speed EMAs; jitter tightens the planner's target
        # imbalance for subsequent batches (live on the non-prefetch path)
        sim.observe(monitor, time.time() - t0,
                    supervisor.surviving_hosts())
        if it is None:
            tgt = round(monitor.adjusted_target(), 2)
            if abs(tgt - cur["pipe"].target_imbalance) > 1e-9:
                cur["pipe"] = dataclasses.replace(
                    cur["pipe"], target_imbalance=tgt)
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"imb {batch['stats']['imbalance']:.3f} "
                  f"comm_tok {batch['stats']['comm_tokens']} "
                  f"{time.time()-t0:.2f}s", flush=True)
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            ckpt.save(step + 1, state, blocking=False)

    def on_restore(action, plan):
        nonlocal state, it
        try:
            ckpt.wait()
        except RuntimeError as err:
            print(f"[train] pending checkpoint save failed: {err}")
        if it is not None:
            it.close()
            it = None
        if plan is not None:    # elastic shrink onto the survivors
            devs = [jax.devices()[i] for i in plan.devices]
            mesh_new = make_local_mesh(plan.data_axis, cp, devices=devs)
            accum = _effective_accum(args.batch, plan.data_axis,
                                     plan.accum_factor)
            bundle_new, fn_new = build(mesh_new, accum)
            cur.update(mesh=mesh_new, bundle=bundle_new, step_fn=fn_new,
                       accum=accum)
        p_s, o_s = cur["bundle"].in_shardings[:2]
        with set_mesh(cur["mesh"]):
            latest = ckpt.latest_step()
            if latest is None:
                params = jax.device_put(
                    init_params(jax.random.PRNGKey(run.seed), cfg), p_s)
                state = {"params": params,
                         "opt": jax.device_put(adamw_init(params), o_s)}
                resume = 0
            else:
                resume, st, _ = ckpt.restore(
                    shardings={"params": p_s, "opt": o_s})
                state = st
        if args.prefetch:
            # the replayed stream is a pure function of (seed, step):
            # rebuild the prefetcher at the resume step (the old thread's
            # queue had run ahead of the failure)
            it = Prefetcher(cur["pipe"], start_step=resume)
        print(f"[train] restored step {resume} after {action.value} "
              f"(mesh {cur['mesh'].shape['data']}x{cp}, "
              f"accum {cur['accum']})", flush=True)
        return resume

    final = supervisor.run(one_step, start_step=start,
                           total_steps=args.steps, on_restore=on_restore)
    with set_mesh(cur["mesh"]):
        ckpt.save(final, state, blocking=True)
    if it:
        it.close()
    return {"final_step": final, "losses": losses,
            "recoveries": policy.restarts,
            "dead_hosts": sorted(supervisor.dead),
            "mesh": (cur["mesh"].shape["data"], cp),
            "accum": cur["accum"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--mesh", default="1x1", help="DxM or 'prod'")
    ap.add_argument("--strategy", default="flashcp")
    ap.add_argument("--attention-impl", default="xla")
    ap.add_argument("--dataset", default="wlb_llm")
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--q-chunk", type=int, default=128)
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--prefetch", action="store_true")
    ap.add_argument("--dispatch", action="store_true",
                    help="adaptive DP×CP token dispatch (per-batch CP "
                         "group sizing + cross-rank balancing)")
    ap.add_argument("--dispatch-target", type=float, default=1.1,
                    help="max cross-group token/workload imbalance before "
                         "the dispatcher escalates the CP degree")
    ap.add_argument("--dispatch-min-cp", type=int, default=1)
    ap.add_argument("--autotune", action="store_true",
                    help="search run-config knobs (strategy/overlap/grid/"
                         "dispatch/kv dtype) with the cost-model autotuner "
                         "before training (DESIGN.md §Autotune)")
    ap.add_argument("--autotune-cache", default="",
                    help="directory for the content-addressed tune result "
                         "cache ('' = no persistence)")
    ap.add_argument("--autotune-topk", type=int, default=8,
                    help="measured-trial frontier size")
    ap.add_argument("--autotune-dry", action="store_true",
                    help="tune and print the selected config, skip training")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--fail-at", default="", metavar="STEP[:HOSTS]",
                    help="inject a failure at STEP; ':h1,h2' marks those "
                         "hosts lost (elastic-shrink path) instead of a "
                         "transient fault (restart path)")
    ap.add_argument("--straggle", action="append", default=None,
                    metavar="HOST:FACTOR",
                    help="simulate HOST running FACTOR× slower "
                         "(repeatable; feeds the straggler monitor)")
    ap.add_argument("--hosts", type=int, default=0,
                    help="simulated host count for fault injection "
                         "(0 = one host per data row)")
    ap.add_argument("--max-restarts", type=int, default=10)
    args = ap.parse_args()
    out = train(args)
    if out["losses"]:
        print(f"[train] done at step {out['final_step']}; "
              f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
    else:
        print(f"[train] done at step {out['final_step']} "
              f"(no training steps ran)")


if __name__ == "__main__":
    main()
