"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model").

``model`` is the context-parallel (CP) axis — FlashCP distributes sequence
tokens over it; parameters are additionally fully sharded over every axis
(FSDP, runtime/sharding.py).  A *function*, not a module constant: importing
this module must never touch JAX device state.  Construction goes through
:mod:`repro.compat` so it works across JAX versions.
"""

from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_local_mesh", "make_group_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, *, devices=None):
    """Small mesh for tests/examples on host devices.

    ``devices``: optional explicit device list — the elastic recovery
    path (DESIGN.md §Recovery) rebuilds the mesh over the *surviving*
    devices after a host loss, so the grid must not silently fall back
    to the default (dead hosts included) enumeration.
    """
    return make_mesh((data, model), ("data", "model"), devices=devices)


def make_group_mesh(data: int, model: int, cp_degree: int, *, devices=None):
    """Re-tile a ``data x model`` device grid into CP subgroups.

    The adaptive dispatcher (DESIGN.md §Dispatch) runs each step at a CP
    degree sized to the batch's document-length profile: the same
    ``data * model`` devices are re-tiled into ``data * model / cp_degree``
    groups of ``cp_degree`` devices, keeping the canonical ("data",
    "model") axis names so every downstream consumer (FSDP parameter
    layout, batch specs, CP attention islands) works unchanged — the
    group axis *is* the "data" axis of the re-tiled mesh.

    ``cp_degree`` must divide the ``model`` axis so each subgroup is a
    contiguous slice of a single CP row (physically adjacent devices on
    the production torus) and never straddles a data row.

    ``devices``: optional explicit device list (elastic recovery re-tiles
    the *surviving* grid after a host loss, DESIGN.md §Recovery).
    """
    if cp_degree < 1 or model % cp_degree:
        raise ValueError(
            f"cp_degree {cp_degree} does not divide model axis {model}")
    return make_mesh((data * model // cp_degree, cp_degree),
                     ("data", "model"), devices=devices)
