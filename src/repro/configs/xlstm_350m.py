"""xlstm-350m [ssm] — 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

No attention, hence no KV exchange: FlashCP's technique is inapplicable
(DESIGN.md §Arch-applicability).  CP uses contiguous sequence sharding with
associative chunk-summary state exchange only.  One sLSTM block per 4
(the rest mLSTM); d_ff=0 means the recurrent blocks carry their own
up/down projections (expand factor 2) and there is no separate FFN.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm_350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    slstm_every=4,
    mamba_expand=2,
)
