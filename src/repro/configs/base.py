"""Config system: model architecture, input shapes, mesh, and run options.

Plain frozen dataclasses (no external deps).  Every assigned architecture
gets one module in this package defining ``CONFIG``; the registry in
``repro.configs`` resolves ``--arch`` names.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ModelConfig", "ShapeConfig", "RunConfig", "SHAPES",
           "reduce_for_smoke", "run_config_to_dict", "run_config_from_dict"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mlp: Literal["glu", "gelu"] = "glu"   # silu-GLU (llama) vs plain gelu MLP
    # --- MoE ----------------------------------------------------------- #
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1                # apply MoE FFN every k-th layer
    capacity_factor: float = 1.25
    # --- hybrid (jamba): 1 attention layer per `attn_every` layers ----- #
    attn_every: int = 0               # 0 -> attention everywhere
    attn_offset: int = 4              # which layer inside the period is attn
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # --- xLSTM --------------------------------------------------------- #
    slstm_every: int = 0              # 1 sLSTM block per k blocks (rest mLSTM)
    # --- modality frontend (stubbed per spec) -------------------------- #
    frontend: Literal["none", "audio_frames", "vit_patches"] = "none"
    num_patch_tokens: int = 256       # vlm: image tokens at sequence start
    # --- numerics ------------------------------------------------------ #
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500K-token decode (SSM/hybrid families)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included)."""
        d, dff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        attn = q + kv + o

        if self.mlp == "glu":
            ffn_dense = 3 * d * dff
        else:
            ffn_dense = 2 * d * dff

        di = d * self.mamba_expand
        dt_rank = max(1, d // 16)
        mamba = (2 * d * di                         # in_proj
                 + self.mamba_d_conv * di + di      # conv
                 + di * (dt_rank + 2 * self.mamba_d_state)   # x_proj
                 + dt_rank * di + di                # dt_proj
                 + di * self.mamba_d_state + di     # A_log, D
                 + di * d)                          # out_proj
        mlstm = 2 * d * di + 3 * di * di \
            + 2 * di * self.num_heads + di * d
        slstm = 5 * d * d

        total = 0
        for layer in range(L):
            is_attn = self.attn_every == 0 or \
                (layer % self.attn_every == self.attn_offset)
            if self.family == "ssm":
                is_slstm = self.slstm_every and \
                    layer % self.slstm_every == self.slstm_every - 1
                total += slstm if is_slstm else mlstm
            elif is_attn:
                total += attn
            else:  # mamba mixer
                total += mamba
            is_moe = self.num_experts > 0 and (layer % self.moe_every == self.moe_every - 1)
            if dff > 0:
                if is_moe:
                    total += self.num_experts * ffn_dense + d * self.num_experts
                else:
                    total += ffn_dense
            total += 2 * d  # norms
        total += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # head
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        d, dff = self.d_model, self.d_ff
        ffn_dense = (3 if self.mlp == "glu" else 2) * d * dff
        n_moe_layers = sum(
            1 for layer in range(self.num_layers)
            if layer % self.moe_every == self.moe_every - 1)
        inactive = n_moe_layers * (self.num_experts - self.top_k) * ffn_dense
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


#: the assigned input-shape set (applies to every architecture)
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs besides the model itself."""
    arch: str = "starcoder2_3b"
    shape: str = "train_4k"
    dataset: str = "wlb_llm"
    cp_strategy: Literal["flashcp", "llama3", "per_doc", "ring", "contiguous"] = "flashcp"
    attention_impl: Literal["xla", "pallas"] = "xla"
    # decode attention: fused flash-decode kernel (default) vs the XLA
    # dense-softmax parity oracle (models/attention.py::attn_decode)
    decode_impl: Literal["flash", "dense"] = "flash"
    # serving KV layout: "paged" = global block pool with per-request
    # block tables + prefix sharing (serve/block_pool.py); "dense" =
    # per-slot stripes (parity oracle, recurrent archs); "auto" picks
    # paged whenever the arch supports it
    kv_layout: Literal["auto", "paged", "dense"] = "auto"
    # tokens per paged KV block (the pool allocation granule)
    serve_block_size: int = 16
    # tokens one engine step may spend across prefill chunks + decodes
    # (SplitFuse-style unified step; 0 = num_slots + prefill_chunk)
    serve_token_budget: int = 0
    # serving resilience (DESIGN.md §Serving-resilience): queue bound
    # (0 = unbounded), overload policy ("fifo" sheds the incoming
    # request, "deadline" sheds the least-slack one), and how many
    # placeable requests may jump a pool-blocked head (0 = strict FIFO)
    serve_max_queue: int = 0
    serve_admission: Literal["fifo", "deadline"] = "fifo"
    serve_admit_lookahead: int = 4
    # consecutive planned-but-no-progress engine steps before the
    # watchdog aborts a slot's request
    serve_stall_patience: int = 8
    # chunked = overlapped KV exchange (ppermute hops merged via online
    # LSE); none = the monolithic blocking-collective islands
    cp_overlap: Literal["chunked", "none"] = "chunked"
    # Pallas kernel schedule: flat = flattened 1D work-queue grid (one
    # step per actual visit, LPT row order); rect = the padded
    # rectangular visit grid (parity baseline)
    kernel_grid: Literal["flat", "rect"] = "flat"
    target_imbalance: float = 1.05
    # adaptive DP×CP token dispatch (DESIGN.md §Dispatch): "adaptive"
    # re-tiles the mesh into per-batch-sized CP subgroups and globally
    # LPT-balances documents across them; batches become ragged
    # (per-row valid-token counts in ``seq_tokens``), and the loss
    # normalization is token-weighted across groups — the global
    # masked-mean CE divides by the *global* valid-token count, so
    # groups holding fewer tokens contribute proportionally, never
    # per-group-averaged.
    dispatch: Literal["off", "adaptive"] = "off"
    dispatch_target_imbalance: float = 1.1
    dispatch_min_cp: int = 1
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    # distributed-training options
    grad_compression: Literal["none", "topk", "int8"] = "none"
    kv_comm_dtype: Literal["native", "int8"] = "native"
    remat: bool = True
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


def run_config_to_dict(run: RunConfig) -> dict:
    """JSON-serializable field dict of a :class:`RunConfig` (every field
    is a scalar, so ``asdict`` round-trips losslessly)."""
    return dataclasses.asdict(run)


def run_config_from_dict(d: dict) -> RunConfig:
    """Inverse of :func:`run_config_to_dict`.  Unknown keys are ignored so
    tuned configs written by a newer tuner still load (the autotuner's
    cache stores these dicts — DESIGN.md §Autotune)."""
    known = {f.name for f in dataclasses.fields(RunConfig)}
    return RunConfig(**{k: v for k, v in d.items() if k in known})


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving its family topology
    (GQA ratio, MoE top-k, hybrid interleave, frontend)."""
    kv = max(1, min(cfg.num_kv_heads, 2))
    heads = max(kv, min(cfg.num_heads, 4))
    heads = (heads // kv) * kv or kv
    return dataclasses.replace(
        cfg,
        num_layers=min(cfg.num_layers, 4 if cfg.attn_every == 0 else cfg.attn_every),
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff > 0 else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4),
        top_k=min(cfg.top_k, 2),
        num_patch_tokens=min(cfg.num_patch_tokens, 16),
        mamba_d_state=8,
        dtype="float32",
    )
