"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8 [arXiv:2409.02060; hf].  Every layer is MoE.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe_1b_7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    mlp="glu",
    num_experts=64,
    top_k=8,
    moe_every=1,
)
