"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, code [arXiv:2405.04324; hf].

Granite-34B-code is gpt_bigcode-style: MQA (kv=1) with a plain 2-matrix
gelu FFN (d_ff = 4d), which lands the analytic count at ~34B.  MQA means
the CP KV exchange is 48x smaller than a Q exchange — FlashCP's
sharding-aware savings still compound on top.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite_34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    mlp="gelu",
)
