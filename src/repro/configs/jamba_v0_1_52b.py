"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave
[arXiv:2403.19887; hf].

Jamba period-8 blocks: one attention layer (offset 4) per 8 layers, the
rest Mamba; MoE FFN every 2nd layer.  Because Mamba is sequence-recurrent,
CP for this arch uses *contiguous* sequence sharding with FlashCP's
sharding-aware communication (see DESIGN.md §Arch-applicability); boundary
SSM state crosses CP ranks via an associative chunk-summary exchange.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    mlp="glu",
    num_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,
    attn_offset=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)
