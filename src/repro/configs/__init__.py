"""Architecture registry: ``--arch <id>`` resolution.

All ten assigned architectures (plus aliases with dashes).  Each module
defines ``CONFIG: ModelConfig`` with the exact published dimensions.
"""

from __future__ import annotations

from .base import (ModelConfig, RunConfig, ShapeConfig, SHAPES,
                   reduce_for_smoke, run_config_from_dict,
                   run_config_to_dict)

from . import (
    dbrx_132b,
    granite_34b,
    internvl2_1b,
    jamba_v0_1_52b,
    musicgen_medium,
    olmoe_1b_7b,
    qwen3_32b,
    starcoder2_3b,
    starcoder2_7b,
    xlstm_350m,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        musicgen_medium, qwen3_32b, granite_34b, starcoder2_7b,
        starcoder2_3b, olmoe_1b_7b, dbrx_132b, internvl2_1b,
        jamba_v0_1_52b, xlstm_350m,
    )
}


def get_config(name: str) -> ModelConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[key]


__all__ = ["ModelConfig", "RunConfig", "ShapeConfig", "SHAPES", "ARCHS",
           "get_config", "reduce_for_smoke", "run_config_to_dict",
           "run_config_from_dict"]
