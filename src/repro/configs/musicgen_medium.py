"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf].  The EnCodec frontend is a stub: ``input_specs()``
provides precomputed frame embeddings (per assignment spec).  MusicGen uses
a plain (non-GLU) transformer decoder.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    mlp="gelu",
    frontend="audio_frames",
)
