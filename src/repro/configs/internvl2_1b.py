"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2 [arXiv:2404.16821; hf].

Per the assignment spec only the LM backbone is modeled; the InternViT
frontend is a stub whose ``input_specs()`` provides precomputed patch
embeddings (256 image tokens forming the leading document of the packed
sequence).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    mlp="glu",
    frontend="vit_patches",
    num_patch_tokens=256,
)
