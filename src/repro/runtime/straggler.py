"""Straggler detection and mitigation.

FlashCP's load balancing is itself the first line of defence (the slowest
CP worker bounds the step, §3.1) — the planner equalizes attention work
*within* a step.  This module adds the *across-step* loop:

* per-step wall-time EMA + variance tracking;
* when jitter (p95/median) exceeds ``jitter_threshold``, the monitor
  tightens the planner's target imbalance ratio R (more aggressive
  balancing buys back the straggler slack) down to ``min_target``;
* when a specific host is persistently slow (hardware degradation), it is
  reported for eviction via the fault-tolerance path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerMonitor:
    window: int = 50
    jitter_threshold: float = 1.15
    min_target: float = 1.01
    max_target: float = 1.10
    _times: list[float] = dataclasses.field(default_factory=list)
    target_imbalance: float = 1.05

    def record_step(self, seconds: float) -> None:
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)

    @property
    def jitter(self) -> float:
        if len(self._times) < 10:
            return 1.0
        t = np.asarray(self._times)
        med = float(np.median(t))
        return float(np.percentile(t, 95)) / max(med, 1e-9)

    def adjusted_target(self) -> float:
        """Planner target imbalance R for the next step."""
        j = self.jitter
        if j > self.jitter_threshold:
            self.target_imbalance = max(self.min_target,
                                        self.target_imbalance * 0.98)
        elif j < 1.05:
            self.target_imbalance = min(self.max_target,
                                        self.target_imbalance * 1.005)
        return self.target_imbalance
