"""Straggler detection and mitigation.

FlashCP's load balancing is itself the first line of defence (the slowest
CP worker bounds the step, §3.1) — the planner equalizes attention work
*within* a step.  This module adds the *across-step* loop:

* per-step wall-time EMA + variance tracking;
* when jitter (p95/median) exceeds ``jitter_threshold``, the monitor
  tightens the planner's target imbalance ratio R (more aggressive
  balancing buys back the straggler slack) down to ``min_target``;
* per-host step-time EMAs turn persistent slowness into *speed weights*
  (``host_speeds``) that the adaptive dispatcher feeds into its
  capacity-proportional LPT (DESIGN.md §Recovery) — a host at speed 0.5
  gets half the workload instead of bounding every step;
* a host whose speed stays below ``slow_speed`` for ``slow_patience``
  consecutive observations is reported by :meth:`slow_hosts` for
  eviction via the fault-tolerance path (hardware degradation, not
  jitter).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerMonitor:
    window: int = 50
    jitter_threshold: float = 1.15
    min_target: float = 1.01
    max_target: float = 1.10
    #: EMA smoothing for per-host step times (higher = more reactive)
    host_alpha: float = 0.25
    #: a host below this relative speed is a persistent-straggler
    #: candidate (hardware degradation, not step jitter)
    slow_speed: float = 0.6
    #: consecutive slow observations before :meth:`slow_hosts` reports
    slow_patience: int = 5
    _times: list[float] = dataclasses.field(default_factory=list)
    target_imbalance: float = 1.05
    _host_ema: dict[int, float] = dataclasses.field(default_factory=dict)
    _slow_streak: dict[int, int] = dataclasses.field(default_factory=dict)

    def record_step(self, seconds: float) -> None:
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)

    @property
    def jitter(self) -> float:
        if len(self._times) < 10:
            return 1.0
        t = np.asarray(self._times)
        med = float(np.median(t))
        return float(np.percentile(t, 95)) / max(med, 1e-9)

    def adjusted_target(self) -> float:
        """Planner target imbalance R for the next step."""
        j = self.jitter
        if j > self.jitter_threshold:
            self.target_imbalance = max(self.min_target,
                                        self.target_imbalance * 0.98)
        elif j < 1.05:
            self.target_imbalance = min(self.max_target,
                                        self.target_imbalance * 1.005)
        return self.target_imbalance

    # ------------------------------------------------------------- #
    # per-host speed tracking (feeds the dispatcher's weighted LPT)
    # ------------------------------------------------------------- #
    def record_host_step(self, host: int, seconds: float) -> None:
        """One host's wall time for the step just finished."""
        prev = self._host_ema.get(host)
        a = self.host_alpha
        ema = seconds if prev is None else (1.0 - a) * prev + a * seconds
        self._host_ema[host] = ema
        fastest = min(self._host_ema.values())
        speed = fastest / max(ema, 1e-12)
        if speed < self.slow_speed:
            self._slow_streak[host] = self._slow_streak.get(host, 0) + 1
        else:
            self._slow_streak[host] = 0

    def host_speeds(self, hosts) -> np.ndarray:
        """Relative speed in (0, 1] per host, 1.0 = fastest observed.

        Unobserved hosts default to 1.0 (assume healthy until measured);
        the result is normalized so the fastest listed host is 1.0 —
        exactly the ``speeds`` contract of
        :func:`repro.dispatch.lpt_assign`.
        """
        hosts = list(hosts)
        if not self._host_ema:
            return np.ones(len(hosts), np.float64)
        fastest = min(self._host_ema.values())
        out = np.asarray(
            [fastest / max(self._host_ema.get(h, fastest), 1e-12)
             for h in hosts], np.float64)
        return out / out.max()

    def slow_hosts(self, hosts=None) -> list[int]:
        """Hosts persistently below ``slow_speed`` — eviction candidates
        for the fault-tolerance path."""
        pool = self._slow_streak if hosts is None else \
            {h: self._slow_streak.get(h, 0) for h in hosts}
        return sorted(h for h, n in pool.items()
                      if n >= self.slow_patience)
