from .elastic import ElasticPlan, shrink_mesh_shape
from .fault_tolerance import (FailureAction, FailurePolicy, HeartbeatMonitor,
                              TrainingFailure, run_with_recovery)
from .recovery import (ElasticSupervisor, FailureInjector, HostTopology,
                       RecoveryPlan, StragglerSim, parse_fail_spec,
                       parse_straggle_specs, replan_after_failure)
from .sharding import (batch_axes_of, batch_specs, cache_specs, named,
                       param_shardings)
from .straggler import StragglerMonitor

__all__ = ["ElasticPlan", "shrink_mesh_shape", "FailureAction",
           "FailurePolicy", "HeartbeatMonitor", "TrainingFailure",
           "run_with_recovery", "batch_axes_of", "batch_specs",
           "cache_specs", "named", "param_shardings", "StragglerMonitor",
           "ElasticSupervisor", "FailureInjector", "HostTopology",
           "RecoveryPlan", "StragglerSim", "parse_fail_spec",
           "parse_straggle_specs", "replan_after_failure"]
