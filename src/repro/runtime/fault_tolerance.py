"""Fault tolerance: failure detection + restart/elastic policy.

The coordinator-side pieces that make thousand-node runs survivable:

* :class:`HeartbeatMonitor` — tracks per-host heartbeats; hosts silent for
  ``timeout_s`` are declared failed.  (In the container, failures are
  injected by tests; on a real cluster heartbeats arrive over the
  coordination service.)
* :class:`FailurePolicy` — decides between RESTART (same topology, reload
  latest checkpoint) and ELASTIC_SHRINK (drop failed hosts, rebuild the
  mesh from survivors, reshard-on-restore) based on spare capacity.
* :func:`run_with_recovery` — the supervision loop used by
  ``launch/train.py``: run the step function, catch device/runtime
  failures, apply the policy, resume from the last checkpoint with the
  deterministic data pipeline replayed to the same step.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Callable

__all__ = ["HeartbeatMonitor", "FailurePolicy", "FailureAction",
           "run_with_recovery", "TrainingFailure"]


class TrainingFailure(RuntimeError):
    """Raised (or injected) when a step fails due to a lost host/device."""

    def __init__(self, msg: str, failed_hosts: list[int] | None = None):
        super().__init__(msg)
        self.failed_hosts = failed_hosts or []


class FailureAction(enum.Enum):
    RESTART = "restart"              # same topology, reload checkpoint
    ELASTIC_SHRINK = "elastic_shrink"  # rebuild mesh without failed hosts
    ABORT = "abort"


@dataclasses.dataclass
class HeartbeatMonitor:
    num_hosts: int
    timeout_s: float = 60.0
    _last: dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, t: float | None = None) -> None:
        self._last[host] = time.monotonic() if t is None else t

    def failed_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [h for h in range(self.num_hosts)
                if now - self._last.get(h, -1e30) > self.timeout_s]

    def healthy(self, now: float | None = None) -> bool:
        return not self.failed_hosts(now)


@dataclasses.dataclass
class FailurePolicy:
    min_hosts: int                  # smallest mesh that still fits the model
    max_restarts: int = 10
    restarts: int = 0

    def decide(self, alive_hosts: int, failed: list[int]) -> FailureAction:
        """RESTART / ELASTIC_SHRINK / ABORT for one failure event.

        ``alive_hosts`` must be the *real* survivor count (total hosts
        minus every host lost so far) — :func:`run_with_recovery` and
        :class:`repro.runtime.recovery.ElasticSupervisor` thread it
        through from the heartbeat/failure set.  The restart budget is
        charged only when a recovery attempt is actually granted; an
        ABORT verdict never burns a slot (aborting is free, retrying is
        not).
        """
        if alive_hosts < self.min_hosts:
            return FailureAction.ABORT
        if self.restarts >= self.max_restarts:
            return FailureAction.ABORT
        self.restarts += 1
        if failed:
            return FailureAction.ELASTIC_SHRINK
        return FailureAction.RESTART


def run_with_recovery(
    step_fn: Callable[[int], Any],
    *,
    start_step: int,
    total_steps: int,
    policy: FailurePolicy,
    on_restore: Callable[[FailureAction, list[int]], int],
    logger: Callable[[str], None] = print,
    num_hosts: int | None = None,
    monitor: HeartbeatMonitor | None = None,
) -> int:
    """Supervised step loop.  ``step_fn(step)`` runs one training step;
    ``on_restore(action, failed_hosts)`` reloads state (and possibly
    rebuilds the mesh), returning the step to resume from.  Returns the
    final step reached.

    The policy sees the *real* survivor count: hosts named by each
    :class:`TrainingFailure` (plus any the heartbeat ``monitor`` has
    declared dead) accumulate into a dead set, and ``alive = num_hosts -
    len(dead)`` is what :meth:`FailurePolicy.decide` judges against
    ``min_hosts``.  ``num_hosts`` defaults to the monitor's host count,
    else to ``policy.min_hosts`` (the degenerate legacy contract for
    callers that never lose hosts — alive then equals min_hosts, so
    host-less failures still RESTART).

    For degree-replanning recovery (mesh shrink + resharded restore) use
    :class:`repro.runtime.recovery.ElasticSupervisor`, which layers the
    surviving-topology bookkeeping on top of this loop's semantics.
    """
    if num_hosts is None:
        num_hosts = monitor.num_hosts if monitor is not None \
            else policy.min_hosts
    step = start_step
    dead: set[int] = set()
    while step < total_steps:
        try:
            step_fn(step)
            step += 1
        except TrainingFailure as e:
            dead.update(e.failed_hosts)
            if monitor is not None:
                dead.update(monitor.failed_hosts())
            alive = num_hosts - len(dead)
            action = policy.decide(alive, e.failed_hosts)
            logger(f"[ft] step {step} failed ({e}); alive={alive}; "
                   f"action={action.value}")
            if action == FailureAction.ABORT:
                raise
            step = on_restore(action, e.failed_hosts)
    return step
