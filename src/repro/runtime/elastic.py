"""Elastic mesh management: rebuild a production mesh after host loss.

On a TPU pod slice, losing a host removes a rectangle of chips; the
recovery strategy (consistent with reshard-on-restore checkpoints) is to
choose the largest supported mesh shape that fits the surviving chip count
and re-layout.  ``shrink_mesh_shape`` picks that shape; the training driver
then rebuilds the mesh, re-applies sharding rules, and restores the latest
checkpoint onto the new topology (checkpoint/manager.py handles the
resharding transparently).

The data-parallel axis shrinks first (model/context axes are constrained
by memory and the CP plan); global batch is preserved by gradient
accumulation over ``accum_factor`` micro-steps.
"""

from __future__ import annotations

import dataclasses

__all__ = ["shrink_mesh_shape", "ElasticPlan"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    accum_factor: int          # grad-accumulation to preserve global batch


def shrink_mesh_shape(alive_chips: int, *, model_axis: int,
                      axis_names=("data", "model"),
                      old_data_axis: int | None = None) -> ElasticPlan:
    """Largest power-of-two data axis that fits the surviving chips while
    keeping the model/CP axis intact."""
    if alive_chips < model_axis:
        raise ValueError(
            f"cannot keep model axis {model_axis} with {alive_chips} chips")
    data = 1
    while data * 2 * model_axis <= alive_chips:
        data *= 2
    accum = 1
    if old_data_axis is not None and old_data_axis > data:
        accum = (old_data_axis + data - 1) // data
    return ElasticPlan(mesh_shape=(data, model_axis),
                       axis_names=tuple(axis_names), accum_factor=accum)
