"""Sharding rules: FSDP-style parameter layout + activation specs.

Parameters are fully sharded across every available mesh axis (ZeRO-3):
each leaf gets its largest divisible dims assigned greedily to the mesh
axes, so a 132B-parameter model fits v5e HBM (DESIGN.md §5).  XLA SPMD
inserts the per-layer all-gathers.  Stacked scan leaves (leading
``n_periods`` dim) never shard dim 0.

Activations: batch over ``data`` (and ``pod``); sequence over ``model``
(the CP axis).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_shardings", "batch_specs", "batch_axes_of",
           "named", "cache_specs"]


def batch_axes_of(mesh: Mesh):
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _leaf_spec(shape, mesh: Mesh, *, skip_dim0: bool) -> P:
    axes = sorted(mesh.axis_names, key=lambda a: -mesh.shape[a])
    dims: list[Any] = [None] * len(shape)
    start = 1 if skip_dim0 and len(shape) > 1 else 0
    used_dims: set[int] = set()
    for ax in axes:
        size = mesh.shape[ax]
        if size == 1:
            continue
        # largest not-yet-sharded dim divisible by this axis
        cand = [i for i in range(start, len(shape))
                if i not in used_dims and shape[i] % size == 0
                and shape[i] >= size]
        if not cand:
            # try stacking onto an already-sharded dim
            for i in sorted(used_dims, key=lambda i: -shape[i]):
                cur = dims[i] if isinstance(dims[i], tuple) else (dims[i],)
                prod = int(np.prod([mesh.shape[a] for a in cur])) * size
                if shape[i] % prod == 0:
                    dims[i] = cur + (ax,)
                    break
            continue
        best = max(cand, key=lambda i: shape[i])
        dims[best] = ax
        used_dims.add(best)
    return P(*dims)


def _expert_spec(shape, mesh: Mesh) -> P:
    """Expert-parallel leaves (nP, E, d, f): E over ``model`` (the EP
    all-to-all in the MoE island expects this layout), remaining axes
    greedily over data/pod."""
    dims: list[Any] = [None] * len(shape)
    e_dim = 1 if len(shape) >= 4 else 0
    if shape[e_dim] % mesh.shape["model"] == 0:
        dims[e_dim] = "model"
    rest = [a for a in mesh.axis_names if a != "model"
            and mesh.shape[a] > 1]
    used = {e_dim}
    for ax in sorted(rest, key=lambda a: -mesh.shape[a]):
        cand = [i for i in range(e_dim + 1, len(shape))
                if i not in used and shape[i] % mesh.shape[ax] == 0]
        if cand:
            best = max(cand, key=lambda i: shape[i])
            dims[best] = ax
            used.add(best)
    return P(*dims)


def param_shardings(mesh: Mesh, params):
    """NamedSharding tree for a param/optimizer pytree (path-aware)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    def one(path, leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        keys = [getattr(k, "key", str(k)) for k in path]
        if "moe" in keys and keys[-1] in ("wi", "wg", "wo") \
                and leaf.ndim >= 3:
            return NamedSharding(mesh, _expert_spec(leaf.shape, mesh))
        # stacked-scan leaves: leading small period dim stays unsharded
        skip0 = leaf.ndim >= 2
        return NamedSharding(mesh, _leaf_spec(leaf.shape, mesh,
                                              skip_dim0=skip0))

    return treedef.unflatten([one(p, l) for p, l in flat])


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def batch_specs(mesh: Mesh, batch_shapes: dict) -> dict:
    """PartitionSpecs for the training batch dict (by key convention)."""
    b = batch_axes_of(mesh)
    B = b if len(b) > 1 else (b[0] if b else None)
    specs = {}
    for key, shape in batch_shapes.items():
        ndim = len(shape)
        bsz = shape[0] if ndim else 1
        Bk = B
        # batch not divisible (e.g. long_500k batch=1) -> replicate batch
        if Bk is not None:
            need = int(np.prod([mesh.shape[a] for a in
                                (Bk if isinstance(Bk, tuple) else (Bk,))]))
            if bsz % need != 0:
                Bk = None
        if key in ("tokens", "labels", "doc", "pos", "perm"):
            specs[key] = P(Bk, "model")
        elif key in ("frame_embeds", "patch_embeds"):
            specs[key] = P(Bk, "model", None)
        elif key == "patch_mask":
            specs[key] = P(Bk, "model")
        elif key == "send_idx":
            specs[key] = P(Bk, "model", None)
        elif key in ("gath_doc", "gath_pos"):
            specs[key] = P(Bk, None)
        elif key in ("seq_tokens", "group_id"):
            # ragged dispatch batches (DESIGN.md §Dispatch): per-row valid
            # token counts / CP-subgroup ids ride the batch axis so each
            # group sees its own rows' raggedness
            specs[key] = P(Bk)
        elif key.startswith("tab_"):
            # per-rank Pallas visit tables: rank dim over the CP axis
            specs[key] = P(*([Bk, "model"] + [None] * (ndim - 2)))
        else:
            specs[key] = P(*([Bk] + [None] * (ndim - 1)))
    return specs


def cache_specs(mesh: Mesh, cache) -> dict:
    """Decode caches: batch over data axes; the big axis over ``model``.

    KV caches (nP, B, Hkv, S, D) shard S; SSM/conv states shard their
    feature axis when divisible.
    """
    b = batch_axes_of(mesh)
    B = b if len(b) > 1 else (b[0] if b else None)
    msize = mesh.shape["model"]

    def one(leaf):
        shape = leaf.shape
        # leading dim is the period stack; dim 1 is batch
        dims = [None] * len(shape)
        need = int(np.prod([mesh.shape[a] for a in
                            (B if isinstance(B, tuple) else (B,))])) \
            if B else 1
        if len(shape) > 1 and B and shape[1] % need == 0:
            dims[1] = B
        # shard the largest remaining dim over model
        cand = [i for i in range(2, len(shape))
                if shape[i] % msize == 0 and shape[i] >= msize]
        if cand:
            best = max(cand, key=lambda i: shape[i])
            dims[best] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, cache)
