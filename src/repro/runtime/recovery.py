"""Elastic degree-replanning recovery (DESIGN.md §Recovery).

The supervision layer that turns a lost or slow rank into a *dispatch
decision* instead of a job restart.  On a :class:`TrainingFailure` naming
failed hosts, the supervisor

1. accumulates the dead set and asks :class:`FailurePolicy` for a verdict
   against the **real** survivor count;
2. on ELASTIC_SHRINK, re-derives the surviving topology
   (:func:`replan_after_failure`): the surviving device list, the shrunk
   ``data`` axis (model/CP axis kept — it is constrained by memory and the
   CP plan, :func:`repro.runtime.elastic.shrink_mesh_shape`), and the
   gradient-accumulation factor that preserves the global batch;
3. hands the plan to the training driver's ``on_restore``, which rebuilds
   the (group) mesh over the survivors, restores the latest checkpoint
   with reshard-on-load, and resumes — the data pipeline is a pure
   function of ``(seed, step)``, so the replayed stream is bit-identical
   to the resume step.

The adaptive dispatcher is the natural shrink mechanism: it already
re-tiles the mesh to any admissible CP degree per step, so recovery is
"same loop, smaller device grid".  Failure *injection* for tests/CI lives
here too (:func:`parse_fail_spec` / :class:`FailureInjector` for
``--fail-at STEP[:HOSTS]``, :func:`parse_straggle_specs` /
:class:`StragglerSim` for ``--straggle HOST:FACTOR``) — in the container
failures are injected; on a real cluster the heartbeat monitor raises the
same :class:`TrainingFailure`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .elastic import ElasticPlan, shrink_mesh_shape
from .fault_tolerance import (FailureAction, FailurePolicy, TrainingFailure)
from .straggler import StragglerMonitor

__all__ = ["HostTopology", "RecoveryPlan", "replan_after_failure",
           "parse_fail_spec", "parse_straggle_specs", "FailureInjector",
           "StragglerSim", "ElasticSupervisor"]


@dataclasses.dataclass(frozen=True)
class HostTopology:
    """Static host → device mapping (contiguous flat device ranges).

    Host ``h`` owns devices ``[h * devices_per_host, (h + 1) *
    devices_per_host)`` — the TPU-pod convention where losing a host
    removes a contiguous rectangle of chips.
    """

    num_hosts: int
    devices_per_host: int

    @property
    def num_devices(self) -> int:
        return self.num_hosts * self.devices_per_host

    def host_of_device(self, device: int) -> int:
        return device // self.devices_per_host

    def surviving_hosts(self, dead: set[int] | list[int]) -> list[int]:
        dead = set(dead)
        return [h for h in range(self.num_hosts) if h not in dead]

    def surviving_devices(self, dead: set[int] | list[int]) -> list[int]:
        """Flat device ids owned by surviving hosts, ascending."""
        return [d for h in self.surviving_hosts(dead)
                for d in range(h * self.devices_per_host,
                               (h + 1) * self.devices_per_host)]


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    """One shrink decision: everything ``on_restore`` needs to rebuild."""

    surviving_hosts: list[int]
    devices: list[int]          # surviving flat device ids
    data_axis: int              # shrunk data axis (model axis kept)
    model_axis: int
    #: grad-accumulation micro-steps preserving the global batch when the
    #: surviving devices cannot hold the old per-step batch resident
    #: (ElasticPlan.accum_factor)
    accum_factor: int
    elastic: ElasticPlan

    @property
    def n_devices(self) -> int:
        return len(self.devices)


def replan_after_failure(topology: HostTopology, dead: set[int] | list[int],
                         *, data: int, model: int) -> RecoveryPlan:
    """Derive the surviving topology after losing ``dead`` hosts.

    The model/CP axis is kept intact (the dispatcher re-derives admissible
    CP *degrees* as divisors of it on the shrunk mesh); the data axis
    shrinks to the largest power of two that fits the survivors, and
    ``accum_factor`` records the micro-batching that preserves the global
    batch.  Raises ``ValueError`` when the survivors cannot hold the
    model axis — the supervisor maps that to ABORT.
    """
    devices = topology.surviving_devices(dead)
    plan = shrink_mesh_shape(len(devices), model_axis=model,
                             old_data_axis=data)
    new_data = plan.mesh_shape[0]
    # the mesh uses the first data*model survivors (a contiguous prefix
    # keeps subgroups physically adjacent on the torus)
    used = devices[:new_data * model]
    return RecoveryPlan(
        surviving_hosts=topology.surviving_hosts(dead),
        devices=used,
        data_axis=new_data,
        model_axis=model,
        accum_factor=plan.accum_factor,
        elastic=plan,
    )


# --------------------------------------------------------------------- #
# failure / straggler injection (tests, CI smokes, benchmarks)
# --------------------------------------------------------------------- #
def parse_fail_spec(spec) -> tuple[int, list[int]]:
    """Parse ``--fail-at STEP[:HOSTS]`` → ``(step, failed_hosts)``.

    ``"12"`` → ``(12, [])`` (transient failure, RESTART path);
    ``"12:1,3"`` → ``(12, [1, 3])`` (lost hosts, ELASTIC_SHRINK path);
    ``-1`` / ``""`` / ``None`` → ``(-1, [])`` (no injection).  Accepts an
    int for backward compatibility with programmatic callers.
    """
    if spec is None:
        return -1, []
    if isinstance(spec, int):
        return spec, []
    spec = str(spec).strip()
    if not spec:
        return -1, []
    step_s, _, hosts_s = spec.partition(":")
    step = int(step_s)
    hosts = [int(h) for h in hosts_s.split(",") if h.strip()] \
        if hosts_s else []
    return step, hosts


def parse_straggle_specs(specs) -> dict[int, float]:
    """Parse repeated ``--straggle HOST:FACTOR`` → ``{host: factor}``.

    A factor of 2.0 simulates a host running 2x slower than nominal.
    """
    out: dict[int, float] = {}
    for s in specs or []:
        host_s, _, fac_s = str(s).partition(":")
        if not fac_s:
            raise ValueError(f"--straggle expects HOST:FACTOR, got {s!r}")
        fac = float(fac_s)
        if fac < 1.0:
            raise ValueError(f"straggle factor must be >= 1.0, got {s!r}")
        out[int(host_s)] = fac
    return out


@dataclasses.dataclass
class FailureInjector:
    """Raises one :class:`TrainingFailure` when the loop reaches
    ``fail_step`` (idempotent: replayed steps after recovery pass)."""

    fail_step: int = -1
    fail_hosts: list[int] = dataclasses.field(default_factory=list)
    fired: bool = False

    def maybe_fail(self, step: int) -> None:
        if step == self.fail_step and not self.fired:
            self.fired = True
            raise TrainingFailure(
                f"injected failure at step {step}"
                + (f" (lost hosts {self.fail_hosts})" if self.fail_hosts
                   else ""),
                failed_hosts=list(self.fail_hosts))


@dataclasses.dataclass(frozen=True)
class StragglerSim:
    """Synthetic per-host step times for straggler injection.

    In the single-process container every host's work executes in the one
    measured wall time; the simulator inflates it per host by the
    configured factor — exactly the signal a real per-host heartbeat
    would carry — and the step time becomes the max over hosts (the
    straggler bounds the step).
    """

    factors: dict[int, float] = dataclasses.field(default_factory=dict)

    def host_time(self, host: int, base_seconds: float) -> float:
        return base_seconds * self.factors.get(host, 1.0)

    def step_time(self, base_seconds: float, hosts) -> float:
        return max((self.host_time(h, base_seconds) for h in hosts),
                   default=base_seconds)

    def observe(self, monitor: StragglerMonitor, base_seconds: float,
                hosts) -> float:
        """Feed one step's per-host times into ``monitor``; returns the
        simulated (straggler-bounded) step time."""
        for h in hosts:
            monitor.record_host_step(h, self.host_time(h, base_seconds))
        t = self.step_time(base_seconds, hosts)
        monitor.record_step(t)
        return t


# --------------------------------------------------------------------- #
# supervision
# --------------------------------------------------------------------- #
class ElasticSupervisor:
    """Failure supervision with degree-replanning shrink.

    Wraps a step loop (either train path): runs ``step_fn(step)``,
    catches :class:`TrainingFailure`, accumulates the dead-host set,
    decides RESTART / ELASTIC_SHRINK / ABORT against the real survivor
    count, and on shrink hands the driver a :class:`RecoveryPlan` for the
    surviving topology.  ``on_restore(action, plan)`` (plan is ``None``
    for RESTART) reloads the checkpoint — resharded onto the new mesh for
    a shrink — and returns the step to resume from; the deterministic
    pipeline replays ``[resume, failure)`` bit-identically.
    """

    def __init__(self, topology: HostTopology, policy: FailurePolicy, *,
                 data: int, model: int,
                 monitor: StragglerMonitor | None = None,
                 logger: Callable[[str], None] = print):
        assert topology.num_devices == data * model, \
            (topology, data, model)
        self.topology = topology
        self.policy = policy
        self.monitor = monitor
        self.logger = logger
        self.data = data
        self.model = model
        self.dead: set[int] = set()
        self.plan: RecoveryPlan | None = None   # latest shrink, if any

    # ----------------------------------------------------------------- #
    @property
    def alive_hosts(self) -> int:
        return self.topology.num_hosts - len(self.dead)

    def surviving_hosts(self) -> list[int]:
        return self.topology.surviving_hosts(self.dead)

    def current_axes(self) -> tuple[int, int]:
        """(data, model) of the current (possibly shrunk) mesh."""
        if self.plan is not None:
            return self.plan.data_axis, self.plan.model_axis
        return self.data, self.model

    def device_speeds(self) -> np.ndarray | None:
        """Per-device speed factors for the *current* device list, from
        the straggler monitor's per-host EMAs (None without a monitor).

        Device ``d`` of the current flat order belongs to the ``d // dph``-th
        *surviving* host; speeds follow that mapping, so after a shrink
        the weights track the renumbered grid automatically.
        """
        if self.monitor is None:
            return None
        dph = self.topology.devices_per_host
        d_axis, m_axis = self.current_axes()
        n_dev = d_axis * m_axis
        hosts = self.surviving_hosts()
        speeds = self.monitor.host_speeds(hosts)
        dev = np.repeat(speeds, dph)[:n_dev]
        return dev if dev.size == n_dev else None

    # ----------------------------------------------------------------- #
    def run(self, step_fn: Callable[[int], None], *, start_step: int,
            total_steps: int,
            on_restore: Callable[[FailureAction, RecoveryPlan | None],
                                 int]) -> int:
        step = start_step
        while step < total_steps:
            try:
                step_fn(step)
                step += 1
            except TrainingFailure as e:
                self.dead.update(e.failed_hosts)
                action = self.policy.decide(self.alive_hosts,
                                            e.failed_hosts)
                self.logger(
                    f"[recovery] step {step} failed ({e}); "
                    f"alive {self.alive_hosts}/{self.topology.num_hosts}; "
                    f"action={action.value}")
                if action == FailureAction.ABORT:
                    raise
                plan = None
                if action == FailureAction.ELASTIC_SHRINK:
                    try:
                        plan = replan_after_failure(
                            self.topology, self.dead,
                            data=self.data, model=self.model)
                    except ValueError as ve:
                        self.logger(f"[recovery] shrink infeasible: {ve}")
                        raise e from ve
                    self.plan = plan
                    self.logger(
                        f"[recovery] shrink -> mesh "
                        f"{plan.data_axis}x{plan.model_axis} on "
                        f"{plan.n_devices} surviving devices "
                        f"(accum {plan.accum_factor})")
                step = on_restore(action, plan)
        return step
