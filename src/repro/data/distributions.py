"""Synthetic document-length distributions for the paper's three datasets.

The original corpora are unavailable offline; per DESIGN.md §8 we synthesize
lengths from the published *shape* of each distribution:

* ``wlb_llm``   — the production Meta distribution released with WLB-LLM is
  highly skewed with extremely long documents (paper §4.2 "WLB-LLM is more
  skewed with extremely long documents").  Modeled as a lognormal body with
  a Pareto tail reaching the full context window.
* ``pile``      — The Pile: predominantly shorter web/academic documents.
* ``redpajama`` — RedPajama: CommonCrawl-dominated short docs mixed with a
  minority of long code/arXiv/book documents.

Lengths are in tokens.  All samplers are deterministic given a
``numpy.random.Generator``.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

__all__ = ["DATASETS", "sample_doc_length", "make_rng"]


def make_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.PCG64(seed))


def _lognormal(rng, mu, sigma, lo, hi):
    x = rng.lognormal(mean=mu, sigma=sigma)
    return int(np.clip(x, lo, hi))


def _wlb_llm(rng: np.random.Generator) -> int:
    # 90% lognormal body around ~2-3K tokens; 10% Pareto tail of very long
    # documents (up to the context window) — the skew WLB-LLM reports.
    if rng.random() < 0.10:
        x = (rng.pareto(1.1) + 1.0) * 8192.0
        return int(np.clip(x, 8192, 131072))
    return _lognormal(rng, mu=7.8, sigma=1.1, lo=64, hi=131072)


def _pile(rng: np.random.Generator) -> int:
    # mostly short documents (median ~1K tokens), thin tail.
    return _lognormal(rng, mu=6.9, sigma=1.0, lo=32, hi=65536)


def _redpajama(rng: np.random.Generator) -> int:
    # 85% short CommonCrawl/C4-style docs, 15% long code/arXiv/book docs.
    if rng.random() < 0.15:
        return _lognormal(rng, mu=9.2, sigma=0.9, lo=1024, hi=131072)
    return _lognormal(rng, mu=6.6, sigma=0.9, lo=32, hi=32768)


DATASETS: dict[str, Callable[[np.random.Generator], int]] = {
    "wlb_llm": _wlb_llm,
    "pile": _pile,
    "redpajama": _redpajama,
}


def sample_doc_length(dataset: str, rng: np.random.Generator) -> int:
    try:
        return DATASETS[dataset](rng)
    except KeyError:
        raise KeyError(f"unknown dataset {dataset!r}; have {sorted(DATASETS)}")
