"""Input packing (paper §2.1): concatenate documents into one context window.

Documents are drawn from a length distribution until the window is full; the
last document is truncated to fit (paper §4.1: "If the total length of the
input documents exceeds the context window size, the last document is
truncated to fit within the limit").
"""

from __future__ import annotations

import numpy as np

from .distributions import sample_doc_length

__all__ = ["pack_sequence", "sample_doc_pool", "doc_ids_and_positions"]


def pack_sequence(
    dataset: str,
    context_len: int,
    rng: np.random.Generator,
    *,
    min_doc_len: int = 16,
) -> np.ndarray:
    """Return an int64 array of document lengths summing exactly to
    ``context_len``."""
    lens: list[int] = []
    total = 0
    while total < context_len:
        d = sample_doc_length(dataset, rng)
        d = min(d, context_len - total)
        if d < min_doc_len and total + d < context_len:
            # merge ultra-short scraps into the previous document rather
            # than emitting degenerate docs (packing implementations do the
            # same to avoid 1-token documents).
            if lens:
                lens[-1] += d
            else:
                lens.append(d)
        else:
            lens.append(d)
        total += d
    out = np.asarray(lens, dtype=np.int64)
    assert out.sum() == context_len
    return out


def sample_doc_pool(
    dataset: str,
    budget_tokens: int,
    rng: np.random.Generator,
    *,
    max_doc_len: int | None = None,
    min_doc_len: int = 16,
    min_docs: int = 0,
) -> np.ndarray:
    """Sample one global step's document pool for the dispatcher.

    Unlike :func:`pack_sequence` (which fills a single window and
    truncates at the boundary), the pool keeps documents whole: sampling
    stops *before* the budget would be exceeded, so the dispatcher's
    bin packer — not the sampler — decides window placement, and the
    only truncation is the §Dispatch quantum trim.  Documents longer
    than ``max_doc_len`` (one window, typically) are clipped to it, since
    no bin could hold them whole; ultra-short scraps merge into the
    previous document exactly as the per-rank packer does.

    ``min_docs``: when the stop-before-exceed rule would end the pool
    with fewer documents (window-sized docs on a small budget), the
    overflowing document is truncated to the remaining budget instead —
    the same boundary truncation the per-rank packer applies — so every
    dispatcher bin can receive at least one document.
    """
    lens: list[int] = []
    total = 0
    while total < budget_tokens:
        d = sample_doc_length(dataset, rng)
        if max_doc_len is not None:
            d = min(d, max_doc_len)
        if total + d > budget_tokens:
            if len(lens) >= min_docs:
                break
            d = budget_tokens - total
            if d < min_doc_len:
                break
        if d < min_doc_len and lens:
            lens[-1] += d
        else:
            lens.append(d)
        total += d
    return np.asarray(lens, dtype=np.int64)


def doc_ids_and_positions(doc_lens: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-token document ids and intra-document positions for one packed
    sequence — the host-side ingredients of the document mask."""
    doc_ids = np.repeat(np.arange(len(doc_lens), dtype=np.int32), doc_lens)
    positions = np.concatenate([np.arange(d, dtype=np.int32) for d in doc_lens])
    return doc_ids, positions
