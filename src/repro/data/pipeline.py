"""Deterministic, host-sharded training data pipeline.

Per batch, per packed sequence:
  1. draw document lengths from the dataset distribution (seeded);
  2. resolve the configured CP planner through the
     :mod:`repro.planner` registry and plan (PlanCache-accelerated —
     replayed steps after a restart and recurring mixes hit the cache);
  3. encode the plan (permutation + comm metadata, vectorized single-pass
     batch encoding, :mod:`repro.planner.encode`);
  4. synthesize tokens and next-token labels (label masking at document
     finals and padding), all in *plan order*.

Determinism & elasticity: the stream for (seed, dp_rank, step) is a pure
function — after a failure the restarted pipeline replays exactly by
seeking ``start_step`` (used by the fault-tolerant training driver), and a
re-sharded (elastic) job re-splits ranks without touching earlier history.
The cache preserves this: exact-signature hits return plans identical to
a cold run (the first miss stores the planner's own output).

A background thread prefetches ``prefetch`` batches ahead of the consumer;
multi-sequence batches plan/encode through the planner worker pool.
"""

from __future__ import annotations

import dataclasses
import functools
import queue
import threading
from typing import Any, Iterator

import numpy as np

from repro.planner import (PlanCache, encode_plan_batch, get_planner,
                           plan_many)
from repro.planner.encode import PlanEncoding  # noqa: F401  (re-export)
from .distributions import make_rng
from .packing import pack_sequence

__all__ = ["PipelineConfig", "make_batch", "data_iterator", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    dataset: str = "wlb_llm"
    context_len: int = 131072
    batch_per_host: int = 1
    cp_size: int = 8
    strategy: str = "flashcp"
    vocab_size: int = 50304
    seed: int = 0
    buf_len: int | None = None   # fixed Eq.5 bucket (None -> per-batch)
    align: int = 128             # T_loc alignment (Pallas block size)
    target_imbalance: float = 1.05
    # planner-subsystem knobs
    cache_plans: bool = True
    cache_granularity: int = 1   # 1 = exact signatures (plan-identical)
    cache_entries: int = 256
    planner_workers: int = 0     # 0 = auto (serial on small hosts)
    # Pallas visit-table emission (attention_impl="pallas" steps)
    emit_tables: bool = False
    table_overlap: str = "chunked"   # matches RunConfig.cp_overlap
    table_grid: str = "flat"         # matches RunConfig.kernel_grid
    table_block_q: int = 128
    table_block_k: int = 128


@functools.lru_cache(maxsize=32)
def _planner_state(cfg: PipelineConfig):
    """(planner, kwargs, cache) resolved once per config."""
    planner = get_planner(cfg.strategy)
    kwargs = {}
    if planner.info.supports_target_ratio:
        kwargs["target_ratio"] = cfg.target_imbalance
    cache = PlanCache(planner, cfg.cp_size,
                      granularity=cfg.cache_granularity,
                      max_entries=cfg.cache_entries,
                      planner_kwargs=kwargs) if cfg.cache_plans else None
    return planner, kwargs, cache


def _plan(cfg: PipelineConfig, doc_lens):
    planner, kwargs, cache = _planner_state(cfg)
    if cache is not None:
        return cache.plan(doc_lens)
    return planner(doc_lens, cfg.cp_size, **kwargs)


def make_batch(cfg: PipelineConfig, step: int, dp_rank: int = 0,
               dp_size: int = 1) -> dict[str, Any]:
    """Build one host-local batch for (step, dp_rank)."""
    rng = make_rng(hash((cfg.seed, dp_rank, step)) % (2 ** 63))
    doc_lens_list = [pack_sequence(cfg.dataset, cfg.context_len, rng)
                     for _ in range(cfg.batch_per_host)]
    plans = plan_many(lambda lens: _plan(cfg, lens), doc_lens_list,
                      workers=cfg.planner_workers)

    stack, encs = encode_plan_batch(plans, buf_len=cfg.buf_len,
                                    align=cfg.align)
    B, C_pad = stack["perm"].shape

    # synthesize tokens in packed order, then permute to plan order.
    # Zipfian unigrams + repetition bigrams give the stream learnable
    # structure (uniform tokens would pin the loss at ln(vocab)).
    tokens = np.full((B, C_pad), -1, np.int32)
    labels = np.full((B, C_pad), -1, np.int32)
    for b, lens in enumerate(doc_lens_list):
        n_tok = int(lens.sum())
        packed = ((rng.zipf(1.3, n_tok) - 1) % cfg.vocab_size
                  ).astype(np.int32)
        rep = rng.random(n_tok) < 0.25
        rep[0] = False
        idx = np.arange(n_tok)
        prev = np.maximum(idx - 1, 0)
        packed = np.where(rep, packed[prev], packed)
        perm = stack["perm"][b]
        valid = perm >= 0
        tokens[b, valid] = packed[perm[valid]]
        # next-token labels: valid unless last token of its document
        nxt = perm + 1
        is_final = np.zeros_like(valid)
        ends = np.cumsum(lens) - 1
        is_final[valid] = np.isin(perm[valid], ends)
        lab_ok = valid & ~is_final
        labels[b, lab_ok] = packed[np.minimum(nxt[lab_ok],
                                              len(packed) - 1)]

    _, _, cache = _planner_state(cfg)
    batch = {k: v for k, v in stack.items()}
    if cfg.emit_tables:
        from repro.core.cp_attention import resolve_overlap
        from repro.planner import emit_visit_tables
        exec_style = get_planner(cfg.strategy).info.exec_style
        style_needs_gath = exec_style in ("flashcp", "contiguous")
        overlap = resolve_overlap(exec_style, "pallas", cfg.table_overlap)
        batch.update(emit_visit_tables(
            stack["doc"], stack["pos"],
            stack["gath_doc"] if style_needs_gath else None,
            stack["gath_pos"] if style_needs_gath else None,
            num_workers=cfg.cp_size, strategy=exec_style,
            overlap=overlap, grid=cfg.table_grid,
            block_q=cfg.table_block_q, block_k=cfg.table_block_k))
    batch["tokens"] = tokens
    batch["labels"] = labels
    batch["stats"] = {
        "comm_tokens": max(e.comm_tokens for e in encs),
        "buf_len": encs[0].buf_len,
        "t_loc": encs[0].t_loc,
        "imbalance": float(np.mean([e.imbalance for e in encs])),
        "num_docs": float(np.mean([len(l) for l in doc_lens_list])),
        "plan_cache_hit_rate":
            cache.stats.hit_rate if cache is not None else 0.0,
    }
    return batch


def data_iterator(cfg: PipelineConfig, start_step: int = 0, dp_rank: int = 0,
                  dp_size: int = 1) -> Iterator[dict[str, Any]]:
    step = start_step
    while True:
        yield make_batch(cfg, step, dp_rank, dp_size)
        step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue (skip-ahead capable)."""

    def __init__(self, cfg: PipelineConfig, start_step: int = 0,
                 dp_rank: int = 0, prefetch: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(cfg, start_step, dp_rank), daemon=True)
        self._thread.start()

    def _run(self, cfg, start_step, dp_rank):
        it = data_iterator(cfg, start_step, dp_rank)
        for batch in it:
            if self._stop.is_set():
                return
            self._q.put(batch)

    def __next__(self):
        return self._q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
