"""Deterministic, host-sharded training data pipeline.

Per batch, per packed sequence:
  1. draw document lengths from the dataset distribution (seeded);
  2. resolve the configured CP planner through the
     :mod:`repro.planner` registry and plan (PlanCache-accelerated —
     replayed steps after a restart and recurring mixes hit the cache);
  3. encode the plan (permutation + comm metadata, vectorized single-pass
     batch encoding, :mod:`repro.planner.encode`);
  4. synthesize tokens and next-token labels (label masking at document
     finals and padding), all in *plan order*.

Determinism & elasticity: the stream for (seed, dp_rank, step) is a pure
function — after a failure the restarted pipeline replays exactly by
seeking ``start_step`` (used by the fault-tolerant training driver), and a
re-sharded (elastic) job re-splits ranks without touching earlier history.
The cache preserves this: exact-signature hits return plans identical to
a cold run (the first miss stores the planner's own output).

A background thread prefetches ``prefetch`` batches ahead of the consumer;
multi-sequence batches plan/encode through the planner worker pool.

**Global-dispatch mode** (:func:`make_dispatch_batch`, DESIGN.md
§Dispatch): instead of every DP rank sampling independently, one seeded
pool of documents is drawn per global step and the
:mod:`repro.dispatch` dispatcher sizes the CP subgroups and LPT-balances
the pool across them; rows are emitted group-major so the batch axis
shards contiguously over the re-tiled mesh's group axis.  Per-group
batches may be *ragged* (bins keep documents whole), so each row carries
its valid-token count in ``seq_tokens`` and padded positions stay masked
(``labels == -1``).  The legacy per-rank stream is untouched — dispatch
off is bit-identical to previous releases.
"""

from __future__ import annotations

import dataclasses
import functools
import queue
import threading
from typing import Any, Iterator

import numpy as np

from repro.planner import (PlanCache, encode_plan_batch, get_planner,
                           plan_many)
from repro.planner.encode import PlanEncoding  # noqa: F401  (re-export)
from .distributions import make_rng
from .packing import pack_sequence, sample_doc_pool

__all__ = ["PipelineConfig", "make_batch", "make_dispatch_batch",
           "data_iterator", "dispatch_iterator", "Prefetcher"]

#: reserved dp_rank for the global-dispatch rng stream — real ranks are
#: always >= 0, so dispatch batches never collide with a per-rank stream.
DISPATCH_RANK = -1


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    dataset: str = "wlb_llm"
    context_len: int = 131072
    batch_per_host: int = 1
    cp_size: int = 8
    strategy: str = "flashcp"
    vocab_size: int = 50304
    seed: int = 0
    buf_len: int | None = None   # fixed Eq.5 bucket (None -> per-batch)
    align: int = 128             # T_loc alignment (Pallas block size)
    target_imbalance: float = 1.05
    # planner-subsystem knobs
    cache_plans: bool = True
    cache_granularity: int = 1   # 1 = exact signatures (plan-identical)
    cache_entries: int = 256
    planner_workers: int = 0     # 0 = auto (serial on small hosts)
    # Pallas visit-table emission (attention_impl="pallas" steps)
    emit_tables: bool = False
    table_overlap: str = "chunked"   # matches RunConfig.cp_overlap
    table_grid: str = "flat"         # matches RunConfig.kernel_grid
    table_block_q: int = 128
    table_block_k: int = 128


@functools.lru_cache(maxsize=32)
def _planner_state(cfg: PipelineConfig):
    """(planner, kwargs, cache) resolved once per config."""
    planner = get_planner(cfg.strategy)
    kwargs = {}
    if planner.info.supports_target_ratio:
        kwargs["target_ratio"] = cfg.target_imbalance
    cache = PlanCache(planner, cfg.cp_size,
                      granularity=cfg.cache_granularity,
                      max_entries=cfg.cache_entries,
                      planner_kwargs=kwargs) if cfg.cache_plans else None
    return planner, kwargs, cache


def _plan(cfg: PipelineConfig, doc_lens):
    planner, kwargs, cache = _planner_state(cfg)
    if cache is not None:
        return cache.plan(doc_lens)
    return planner(doc_lens, cfg.cp_size, **kwargs)


def _synthesize_row(tokens_row, labels_row, lens, perm, rng,
                    vocab_size: int) -> None:
    """Synthesize one sequence's tokens in packed order, then permute to
    plan order (writes into the supplied batch rows).

    Zipfian unigrams + repetition bigrams give the stream learnable
    structure (uniform tokens would pin the loss at ln(vocab)).  One zipf
    + one uniform draw per sequence — the draw order is part of the
    pipeline's determinism contract.
    """
    n_tok = int(lens.sum())
    packed = ((rng.zipf(1.3, n_tok) - 1) % vocab_size
              ).astype(np.int32)
    rep = rng.random(n_tok) < 0.25
    rep[0] = False
    idx = np.arange(n_tok)
    prev = np.maximum(idx - 1, 0)
    packed = np.where(rep, packed[prev], packed)
    valid = perm >= 0
    tokens_row[valid] = packed[perm[valid]]
    # next-token labels: valid unless last token of its document
    nxt = perm + 1
    is_final = np.zeros_like(valid)
    ends = np.cumsum(lens) - 1
    is_final[valid] = np.isin(perm[valid], ends)
    lab_ok = valid & ~is_final
    labels_row[lab_ok] = packed[np.minimum(nxt[lab_ok],
                                           len(packed) - 1)]


def _synthesize_tokens(doc_lens_list, perm_stack, rngs,
                       vocab_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Batch token synthesis.  ``rngs`` is either one shared Generator
    (legacy per-rank stream: rows draw sequentially, in row order) or a
    list of per-row Generators (dispatch: each row's stream is keyed to
    its *content*, so tokens are invariant to the LPT row order and to
    the chosen CP degree)."""
    B, C_pad = perm_stack.shape
    tokens = np.full((B, C_pad), -1, np.int32)
    labels = np.full((B, C_pad), -1, np.int32)
    for b, lens in enumerate(doc_lens_list):
        rng = rngs[b] if isinstance(rngs, list) else rngs
        _synthesize_row(tokens[b], labels[b], lens, perm_stack[b], rng,
                        vocab_size)
    return tokens, labels


def _emit_tables(cfg: PipelineConfig, stack: dict,
                 num_workers: int) -> dict[str, np.ndarray]:
    """Pallas visit tables for a batch-encoded stack at ``num_workers``."""
    from repro.core.cp_attention import resolve_overlap
    from repro.planner import emit_visit_tables
    exec_style = get_planner(cfg.strategy).info.exec_style
    style_needs_gath = exec_style in ("flashcp", "contiguous")
    overlap = resolve_overlap(exec_style, "pallas", cfg.table_overlap)
    return emit_visit_tables(
        stack["doc"], stack["pos"],
        stack["gath_doc"] if style_needs_gath else None,
        stack["gath_pos"] if style_needs_gath else None,
        num_workers=num_workers, strategy=exec_style,
        overlap=overlap, grid=cfg.table_grid,
        block_q=cfg.table_block_q, block_k=cfg.table_block_k)


def _batch_stats(encs, doc_lens_list, cache) -> dict:
    return {
        "comm_tokens": max(e.comm_tokens for e in encs),
        "buf_len": encs[0].buf_len,
        "t_loc": encs[0].t_loc,
        "imbalance": float(np.mean([e.imbalance for e in encs])),
        "num_docs": float(np.mean([len(l) for l in doc_lens_list])),
        "plan_cache_hit_rate":
            cache.stats.hit_rate if cache is not None else 0.0,
    }


def make_batch(cfg: PipelineConfig, step: int, dp_rank: int = 0,
               dp_size: int = 1) -> dict[str, Any]:
    """Build one host-local batch for (step, dp_rank)."""
    rng = make_rng(hash((cfg.seed, dp_rank, step)) % (2 ** 63))
    doc_lens_list = [pack_sequence(cfg.dataset, cfg.context_len, rng)
                     for _ in range(cfg.batch_per_host)]
    plans = plan_many(lambda lens: _plan(cfg, lens), doc_lens_list,
                      workers=cfg.planner_workers)

    stack, encs = encode_plan_batch(plans, buf_len=cfg.buf_len,
                                    align=cfg.align)
    tokens, labels = _synthesize_tokens(doc_lens_list, stack["perm"], rng,
                                        cfg.vocab_size)

    _, _, cache = _planner_state(cfg)
    batch = {k: v for k, v in stack.items()}
    if cfg.emit_tables:
        batch.update(_emit_tables(cfg, stack, cfg.cp_size))
    batch["tokens"] = tokens
    batch["labels"] = labels
    batch["stats"] = _batch_stats(encs, doc_lens_list, cache)
    return batch


def make_dispatch_batch(cfg: PipelineConfig, dcfg, step: int,
                        device_speeds=None) -> dict[str, Any]:
    """Build one *global* batch through the adaptive DP×CP dispatcher.

    One seeded document pool per step (all DP ranks see the same stream),
    dispatched by :func:`repro.dispatch.dispatch_step`: the CP degree
    adapts to the pool's length profile, rows are emitted group-major
    (row ``r`` belongs to subgroup ``r // seqs_per_group`` of the
    re-tiled mesh), and every row plans/encodes through the ordinary
    registry path at the chosen degree.  ``t_loc`` is pinned to
    ``C / cp`` so the batch keeps one static shape per degree even when
    bins are ragged; ``cfg.cp_size`` is ignored (the dispatcher owns the
    degree).

    Extra keys vs :func:`make_batch`: ``seq_tokens`` (per-row valid
    tokens — ragged rows pad with masked labels), ``group_id`` (per-row
    subgroup), and ``stats["dispatch"]`` (degree decision, imbalances,
    candidate table, pool profile).

    ``device_speeds`` (optional, length ``data * model``): measured
    relative device speeds from the straggler monitor — the dispatcher
    then LPT-balances *completion time* instead of raw load and sizes
    bin targets capacity-proportionally (DESIGN.md §Recovery).  Token
    content is unaffected (row streams are content-keyed), only the
    row→group placement shifts.
    """
    from repro.dispatch import dispatch_step

    rng = make_rng(hash((cfg.seed, DISPATCH_RANK, step)) % (2 ** 63))
    pool = sample_doc_pool(cfg.dataset, dcfg.seqs * cfg.context_len, rng,
                           max_doc_len=cfg.context_len,
                           min_docs=dcfg.seqs)
    dplan = dispatch_step(pool, dcfg, cfg.context_len,
                          device_speeds=device_speeds)
    g = dplan.cp_degree
    assert all(len(r) for r in dplan.rows), \
        "dispatch produced an empty sequence bin (pool too small for seqs)"

    gcfg = dataclasses.replace(cfg, cp_size=g)
    plans = plan_many(lambda lens: _plan(gcfg, lens), dplan.rows,
                      workers=cfg.planner_workers)
    stack, encs = encode_plan_batch(plans, buf_len=cfg.buf_len,
                                    t_loc=cfg.context_len // g,
                                    align=cfg.align)
    # per-row token streams keyed to row *content* (the pool documents in
    # the bin), so tokens are invariant to LPT row order and CP degree —
    # the same pool dispatched at any degree yields the same data.
    row_rngs = [make_rng(hash((cfg.seed, DISPATCH_RANK, step)
                              + tuple(int(i) for i in docs)) % (2 ** 63))
                for docs in dplan.row_docs]
    tokens, labels = _synthesize_tokens(dplan.rows, stack["perm"], row_rngs,
                                        cfg.vocab_size)

    _, _, cache = _planner_state(gcfg)
    batch = {k: v for k, v in stack.items()}
    if cfg.emit_tables:
        batch.update(_emit_tables(cfg, stack, g))
    batch["tokens"] = tokens
    batch["labels"] = labels
    batch["seq_tokens"] = np.asarray([int(r.sum()) for r in dplan.rows],
                                     np.int32)
    batch["group_id"] = dplan.group_of_row.astype(np.int32)
    batch["stats"] = _batch_stats(encs, dplan.rows, cache)
    batch["stats"]["dispatch"] = {**dplan.stats(),
                                  "profile": dplan.profile.as_dict()}
    return batch


def data_iterator(cfg: PipelineConfig, start_step: int = 0, dp_rank: int = 0,
                  dp_size: int = 1) -> Iterator[dict[str, Any]]:
    step = start_step
    while True:
        yield make_batch(cfg, step, dp_rank, dp_size)
        step += 1


def dispatch_iterator(cfg: PipelineConfig, dcfg, start_step: int = 0,
                      speeds_fn=None) -> Iterator[dict[str, Any]]:
    """Global-dispatch batch stream (one iterator per job, not per rank).

    ``speeds_fn``: optional zero-arg callable returning the current
    device-speed vector (or None) — sampled once per batch so a live
    straggler monitor can steer placement without rebuilding the stream.
    """
    step = start_step
    while True:
        speeds = speeds_fn() if speeds_fn is not None else None
        yield make_dispatch_batch(cfg, dcfg, step, device_speeds=speeds)
        step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue (skip-ahead capable).

    ``dispatch``: a :class:`repro.dispatch.DispatchConfig` switches the
    stream to global-dispatch batches (``dp_rank`` is then unused — the
    dispatcher is rank-global by construction).
    """

    def __init__(self, cfg: PipelineConfig, start_step: int = 0,
                 dp_rank: int = 0, prefetch: int = 2, dispatch=None,
                 speeds_fn=None):
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run,
            args=(cfg, start_step, dp_rank, dispatch, speeds_fn),
            daemon=True)
        self._thread.start()

    def _run(self, cfg, start_step, dp_rank, dispatch=None, speeds_fn=None):
        it = dispatch_iterator(cfg, dispatch, start_step, speeds_fn) \
            if dispatch is not None else \
            data_iterator(cfg, start_step, dp_rank)
        for batch in it:
            if self._stop.is_set():
                return
            self._q.put(batch)

    def __next__(self):
        return self._q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
