"""Mamba (selective SSM) mixer — the non-attention layers of Jamba.

Training path: parallel selective scan via ``ctx.ssm_scan`` (chunked
associative scan locally; the CP context adds cross-rank boundary-state
exchange).  Document resets: the decay coefficient is zeroed at intra-doc
position 0, so state never crosses a document boundary — composing cleanly
with FlashCP's packing semantics (a document kept whole on one CP worker
never even exchanges SSM state).

Decode path: single-step recurrence with (conv window, SSM state) carried
in the cache.

The (B, T, d_inner, d_state) scan operands are materialized functionally;
a fused Pallas selective-scan kernel is a recorded beyond-paper follow-up
(EXPERIMENTS.md §Perf) if the memory roofline term demands it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _he

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "mamba_cache_init"]


def mamba_init(rng, d: int, *, expand: int, d_state: int, d_conv: int):
    di = expand * d
    dt_rank = max(1, d // 16)
    rs = jax.random.split(rng, 6)
    return {
        "in_proj": _he(rs[0], (d, 2 * di), d),
        "conv_w": _he(rs[1], (d_conv, di), d_conv),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": _he(rs[2], (di, dt_rank + 2 * d_state), di),
        "dt_proj": _he(rs[3], (dt_rank, di), dt_rank),
        "dt_bias": jnp.full((di,), -2.0, jnp.float32),  # softplus ~ small dt
        "A_log": jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)
                         )[None, :].repeat(di, 0),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _he(rs[4], (di, d), di),
    }


def _causal_conv(p, x, pos, d_conv: int):
    """Depthwise causal conv with document resets.

    Contribution of x_{t-k} is masked unless the query token is at least k
    tokens into its document (pos >= k) — shifts crossing a CP-rank
    boundary become XLA halo exchanges under pjit.
    """
    w = p["conv_w"].astype(x.dtype)
    out = x * w[-1]
    for k in range(1, d_conv):
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :-k]
        ok = (pos >= k)[..., None].astype(x.dtype)
        out = out + shifted * ok * w[-1 - k]
    return out + p["conv_b"].astype(x.dtype)


def mamba_apply(p, x, ctx, *, d_state: int, d_conv: int, chunk: int = 64):
    """x (B, T, d) -> (B, T, d).

    Chunkwise selective scan: the (chunk, d_inner, d_state) scan operands
    are materialized one chunk at a time and contracted with C immediately,
    so only per-chunk boundary states (B, nc, di, S) survive — these go
    through ``ctx.ssm_scan`` (which also carries them across CP ranks).
    This is the functional analogue of Mamba's fused scan kernel; without
    it the full-T state tensor dominates the memory roofline.
    """
    B, T, d = x.shape
    di = p["in_proj"].shape[1] // 2
    dt_rank = p["dt_proj"].shape[0]

    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(p, x_in, ctx.pos, d_conv))

    proj = x_c @ p["x_proj"].astype(x.dtype)
    dt_r = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ p["dt_proj"] + p["dt_bias"])      # (B,T,di)

    A = -jnp.exp(p["A_log"])                                          # (di,S)
    # document reset: decay zeroed at pos==0 so no state crosses documents
    reset = (ctx.pos > 0).astype(jnp.float32)
    xf = x_c.astype(jnp.float32)                                      # (B,T,di)

    y = ctx.selective_scan(dt, A, Bm, Cm, xf, reset).astype(x.dtype)

    y = y + x_c * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


# ------------------------------------------------------------------ #
# decode
# ------------------------------------------------------------------ #
def mamba_cache_init(batch: int, d: int, *, expand: int, d_state: int,
                     d_conv: int, dtype):
    di = expand * d
    return {
        "conv": jnp.zeros((batch, d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, d_state), jnp.float32),
    }


def mamba_decode(p, x_t, cache, *, d_state: int, d_conv: int):
    """One token step.  x_t (B, d) -> (y (B, d), new cache)."""
    B, d = x_t.shape
    di = p["in_proj"].shape[1] // 2
    dt_rank = p["dt_proj"].shape[0]

    xz = x_t @ p["in_proj"].astype(x_t.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)

    w = p["conv_w"].astype(x_t.dtype)                    # (d_conv, di)
    window = jnp.concatenate([cache["conv"], x_in[:, None]], axis=1)
    x_c = jax.nn.silu(
        jnp.einsum("bkd,kd->bd", window, w) + p["conv_b"].astype(x_t.dtype))
    new_conv = window[:, 1:]

    proj = x_c @ p["x_proj"].astype(x_t.dtype)
    dt_r = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["dt_proj"] + p["dt_bias"])

    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)                        # (B, di, S)
    bx = (dt * x_c.astype(jnp.float32))[..., None] * Bm[:, None, :]
    h = a * cache["ssm"] + bx
    y = jnp.einsum("bds,bs->bd", h, Cm).astype(x_t.dtype)
    y = y + x_c * p["D"].astype(x_t.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x_t.dtype), {"conv": new_conv, "ssm": h}
