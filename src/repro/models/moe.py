"""Mixture-of-Experts FFN with expert parallelism.

Routing (top-k, gates, aux loss) runs in global view — elementwise over
tokens, trivially shardable.  Dispatch/combine runs through
``ctx.ep_dispatch``:

* local context: sort-based capacity-clipped dispatch on the host's tokens
  (Megablocks-style, XLA gather/scatter);
* CP context (core/cp_attention.py): the same local dispatch *per rank*
  followed by a ``jax.lax.all_to_all`` over the ``model`` mesh axis — the
  canonical EP exchange: tokens travel to the rank owning their expert
  (experts are sharded over ``model``), expert FFNs run batched, and a
  second all-to-all brings results home.

Aux load-balancing loss: the standard switch-transformer loss
``E * Σ_e f_e · p_e`` (f = routed token fraction, p = mean router prob).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _he

__all__ = ["moe_init", "moe_apply", "dispatch_local", "expert_ffn",
           "combine_local", "capacity"]


def moe_init(rng, d: int, d_ff: int, num_experts: int, kind: str):
    r0, r1, r2, r3 = jax.random.split(rng, 4)
    p = {
        "router": _he(r0, (d, num_experts), d),
        "wi": _he(r1, (num_experts, d, d_ff), d),
        "wo": _he(r3, (num_experts, d_ff, d), d_ff),
    }
    if kind == "glu":
        p["wg"] = _he(r2, (num_experts, d, d_ff), d)
    return p


def capacity(n_tokens: int, num_experts: int, top_k: int,
             capacity_factor: float) -> int:
    return int(max(1, -(-top_k * n_tokens * capacity_factor //
                        num_experts)))


# --------------------------------------------------------------------- #
# dispatch / combine primitives (operate on one rank's tokens)
# --------------------------------------------------------------------- #
def dispatch_local(xt, topi, gates, num_experts: int, cap: int):
    """xt (n, d); topi/gates (n, K) -> (buf (E, cap, d), slot, tok_s,
    gat_s, keep) for combine."""
    n, d = xt.shape
    K = topi.shape[-1]
    E = num_experts

    eid = topi.reshape(-1)
    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), K)
    gat = gates.reshape(-1)

    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s, gat_s = eid[order], tok[order], gat[order]
    counts = jnp.bincount(eid_s, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(n * K, dtype=jnp.int32) - starts[eid_s]
    keep = pos_in_e < cap
    slot = jnp.where(keep, eid_s * cap + pos_in_e, E * cap)

    buf = jnp.zeros((E * cap + 1, d), xt.dtype)
    buf = buf.at[slot].set(xt[tok_s])
    return buf[: E * cap].reshape(E, cap, d), slot, tok_s, gat_s, keep


def expert_ffn(buf, wi, wg, wo, kind: str):
    """buf (E_local, C, d) with per-expert weights (E_local, d, f)."""
    wi = wi.astype(buf.dtype)
    wo = wo.astype(buf.dtype)
    if kind == "glu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                   wg.astype(buf.dtype))) * \
            jnp.einsum("ecd,edf->ecf", buf, wi)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wi))
    return jnp.einsum("ecf,efd->ecd", h, wo)


def combine_local(y, slot, tok_s, gat_s, keep, n: int):
    """y (E, cap, d) -> (n, d) weighted combine."""
    E, cap, d = y.shape
    yf = jnp.concatenate([y.reshape(E * cap, d),
                          jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = yf[slot] * (gat_s * keep).astype(y.dtype)[:, None]
    return jnp.zeros((n, d), y.dtype).at[tok_s].add(contrib)


def local_ep_dispatch(x, topi, gates, params, *, kind: str,
                      capacity_factor: float):
    """Single-rank dispatch (no expert parallelism)."""
    B, T, d = x.shape
    E = params["wi"].shape[0]
    K = topi.shape[-1]
    n = B * T
    cap = capacity(n, E, K, capacity_factor)
    buf, slot, tok_s, gat_s, keep = dispatch_local(
        x.reshape(n, d), topi.reshape(n, K), gates.reshape(n, K), E, cap)
    y = expert_ffn(buf, params["wi"], params.get("wg"), params["wo"], kind)
    return combine_local(y, slot, tok_s, gat_s, keep, n).reshape(B, T, d)


# --------------------------------------------------------------------- #
def moe_apply(p, x, ctx, *, top_k: int, capacity_factor: float, kind: str):
    """x (B, T, d) -> (out (B, T, d), aux_loss scalar)."""
    B, T, d = x.shape
    E = p["router"].shape[-1]

    logits = x.astype(jnp.float32) @ p["router"]             # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)                 # (B, T, K)
    gates = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    frac = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(
        gates.reshape(-1)) / (B * T)
    aux = E * jnp.sum(frac * probs.mean((0, 1)))

    ep = ctx.extras.get("ep_dispatch") if ctx is not None else None
    if ep is None:
        out = local_ep_dispatch(x, topi.astype(jnp.int32), gates, p,
                                kind=kind, capacity_factor=capacity_factor)
    else:
        out = ep(x, topi.astype(jnp.int32), gates, p, kind=kind,
                 capacity_factor=capacity_factor)
    return out.astype(x.dtype), aux
