"""Execution context: the seam between model code and the CP runtime.

Model code (transformer blocks, Mamba, xLSTM) is written against this
protocol and never mentions meshes or collectives.  The runtime constructs:

* a **local** context (single device / no CP) — used by smoke tests, CPU
  examples and decode-per-device;
* a **CP** context (:mod:`repro.core.cp_attention`) whose ``attn`` performs
  FlashCP sharding-aware communication + document-masked flash attention
  inside a ``shard_map`` island, and whose ``ssm_scan`` performs local
  chunked scans with cross-rank boundary-state exchange.

Conventions:
* ``doc``/``pos`` are per-token metadata in *plan order* — the order tokens
  physically live in the (possibly CP-permuted) sequence buffers.
* ``attn(q, k, v)``: q (B, Hq, T, D); k, v (B, Hkv, T, D) -> (B, Hq, T, D).
* ``ssm_scan(a, x)``: elementwise recurrence h_t = a_t * h_{t-1} + x_t over
  the T axis of (B, T, ...) arrays.  Document resets are encoded by the
  caller as ``a_t = 0`` at document starts (pos == 0).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["ExecContext", "make_local_context", "local_ssm_scan"]


@dataclasses.dataclass
class ExecContext:
    doc: jax.Array
    pos: jax.Array
    attn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
    ssm_scan: Callable[[jax.Array, jax.Array], jax.Array]
    # fused chunkwise selective scan (Mamba): (dt, A, Bm, Cm, xf, reset)->y
    selective_scan: Callable | None = None
    # NamedSharding for (B, T, d) activations — anchors XLA's sharding
    # propagation on the residual stream (None in local mode)
    act_sharding: Any = None
    is_decode: bool = False
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)

    def constrain(self, x: jax.Array) -> jax.Array:
        if self.act_sharding is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.act_sharding)


# --------------------------------------------------------------------- #
# local (no-CP) implementations
# --------------------------------------------------------------------- #
def local_ssm_scan(a: jax.Array, x: jax.Array, *, init: jax.Array | None = None,
                   chunk: int = 64) -> jax.Array:
    """h_t = a_t * h_{t-1} + x_t along axis 1, chunk-rematerialized.

    ``init`` is h_{-1} (default zeros).  The chunked form bounds live memory
    to one chunk of (a, x, h) plus one boundary state per chunk — the XLA
    analogue of a fused scan kernel.
    """
    B, T = x.shape[:2]
    carry0 = jnp.zeros_like(x[:, 0]) if init is None else init

    if T % chunk != 0 or T <= chunk:
        x0 = x[:, 0] + a[:, 0] * carry0
        x = x.at[:, 0].set(x0)
        pair = jax.lax.associative_scan(_combine, (a, x), axis=1)
        return pair[1]

    nc = T // chunk
    a_c = a.reshape(B, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
    x_c = x.reshape(B, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        ac, xc = inp
        # inject carry into the first element, then scan inside the chunk
        x0 = xc[:, 0] + ac[:, 0] * carry
        xc = xc.at[:, 0].set(x0)
        _, h = jax.lax.associative_scan(_combine, (ac, xc), axis=1)
        return h[:, -1], h

    _, hs = jax.lax.scan(body, carry0, (a_c, x_c))
    return hs.swapaxes(0, 1).reshape(B, T, *x.shape[2:])


def _combine(left, right):
    a_l, x_l = left
    a_r, x_r = right
    return a_l * a_r, x_r + a_r * x_l


# --------------------------------------------------------------------- #
# fused chunkwise selective scan (Mamba)
# --------------------------------------------------------------------- #
def local_selective_scan(dt, A, Bm, Cm, xf, reset, *, chunk: int = 64,
                         init_state=None, summary_only: bool = False,
                         unroll: int = 8):
    """y_t = C_t · h_t with h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t.

    dt, xf (B, T, di); Bm, Cm (B, T, S); A (di, S); reset (B, T) — 0 at
    document starts.  Fused form (§Perf iteration 4): a *sequential*
    ``lax.scan`` over time builds the per-token decay/update on the fly
    inside checkpointed chunk bodies, so the (T, di, S) state tensors are
    never materialized — the only live state is the (B, di, S) carry plus
    one chunk of residuals during the backward recompute.  (The earlier
    associative-scan form materialized ~12 chunk-sized f32 tensors per
    Mamba layer and dominated Jamba's memory roofline.)

    ``init_state`` (B, di, S) seeds the recurrence (CP rank hand-off);
    ``summary_only`` returns (decay product, final state) for the CP
    prefix exchange without producing y.
    """
    B, T, di = dt.shape
    ck = chunk
    while T % ck:
        ck //= 2
    nc = T // ck

    def chunked(v):
        # (nc, ck, B, ...) — outer scan over chunks, inner over time
        return v.reshape(B, nc, ck, *v.shape[2:]) \
            .swapaxes(0, 1).swapaxes(1, 2)

    dt_c, Bm_c, Cm_c, xf_c, rs_c = map(chunked, (dt, Bm, Cm, xf, reset))
    h0 = jnp.zeros((B, di, A.shape[-1]), jnp.float32) \
        if init_state is None else init_state.astype(jnp.float32)

    if summary_only:
        @jax.checkpoint
        def chunk_sum(carry, inp):
            def step(c, sl):
                h, pA = c
                dtc, Bc, xc, rc = sl
                a = jnp.exp(dtc.astype(jnp.float32)[..., None] * A) \
                    * rc[:, None, None]
                h = a * h + (dtc * xc).astype(jnp.float32)[..., None] \
                    * Bc[:, None, :]
                return (h, pA * a), None
            return jax.lax.scan(step, carry, inp, unroll=unroll)[0], None

        ones = jnp.ones_like(h0)
        (h, pA), _ = jax.lax.scan(chunk_sum, (h0, ones),
                                  (dt_c, Bm_c, xf_c, rs_c))
        return pA, h

    @jax.checkpoint
    def chunk_body(h, inp):
        def step(h, sl):
            dtc, Bc, Cc, xc, rc = sl
            a = jnp.exp(dtc.astype(jnp.float32)[..., None] * A) \
                * rc[:, None, None]
            h = a * h + (dtc * xc).astype(jnp.float32)[..., None] \
                * Bc[:, None, :]
            y = jnp.einsum("bds,bs->bd", h, Cc.astype(jnp.float32))
            return h, y
        return jax.lax.scan(step, h, inp, unroll=unroll)

    h, ys = jax.lax.scan(chunk_body, h0, (dt_c, Bm_c, Cm_c, xf_c, rs_c))
    # ys (nc, ck, B, di) -> (B, T, di)
    return ys.swapaxes(1, 2).swapaxes(0, 1).reshape(B, T, di)


def make_local_context(doc: jax.Array, pos: jax.Array,
                       attention_impl: str = "xla",
                       interpret: bool = True,
                       q_chunk: int = 512,
                       grid: str = "flat") -> ExecContext:
    """Single-device context: full-sequence doc-masked attention.

    ``grid`` picks the Pallas kernel schedule (flattened work queue by
    default; ``"rect"`` for the rectangular baseline)."""
    from repro.kernels import ops as kops

    tabs_cache: list = []   # visit tables depend only on (doc, pos): built
    # once on first use instead of per attn call

    def attn(q, k, v):
        if attention_impl == "pallas":
            if not tabs_cache:
                import numpy as np
                from repro.kernels.doc_attention import build_block_tables
                tabs_cache.append(build_block_tables(
                    np.asarray(doc), np.asarray(pos),
                    np.asarray(doc), np.asarray(pos)))
            return kops.doc_flash_attention(q, k, v, doc, pos, doc, pos,
                                            tabs_cache[0], grid=grid,
                                            interpret=interpret)
        return kops.doc_attention_xla(q, k, v, doc, pos, doc, pos,
                                      q_chunk=q_chunk)

    return ExecContext(doc=doc, pos=pos, attn=attn,
                       ssm_scan=functools.partial(local_ssm_scan),
                       selective_scan=local_selective_scan)
