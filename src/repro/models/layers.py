"""Shared neural building blocks (pure functions over param dicts).

No flax/haiku offline — a minimal functional module style is used across
the framework: ``init_*`` builds nested param dicts; apply functions take
``(params, x, ...)``.  Compute dtype is driven by the input dtype; params
are stored fp32 (master) and cast at use (mixed precision).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init", "dense", "rmsnorm_init", "rmsnorm",
    "embed_init", "embed", "rope", "mlp_init", "mlp_apply",
    "cross_entropy",
]


def _he(rng, shape, fan_in):
    return (jax.random.normal(rng, shape, dtype=jnp.float32)
            * np.sqrt(1.0 / max(fan_in, 1)))


def dense_init(rng, d_in: int, d_out: int):
    return {"w": _he(rng, (d_in, d_out), d_in)}


def dense(p, x):
    w = p["w"].astype(x.dtype)
    return x @ w


def rmsnorm_init(d: int):
    return {"g": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["g"]).astype(x.dtype)


def embed_init(rng, vocab: int, d: int):
    return {"e": jax.random.normal(rng, (vocab, d), dtype=jnp.float32) * 0.02}


def embed(p, tokens, dtype):
    safe = jnp.maximum(tokens, 0)            # padding tokens may be -1
    out = jnp.take(p["e"], safe, axis=0).astype(dtype)
    return jnp.where((tokens >= 0)[..., None], out, 0.0)


def rope(x: jax.Array, pos: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding.  x (B, H, T, D); pos (B, T) *intra-document*
    positions — with packing each document restarts at 0, which is exactly
    the document-mask semantics."""
    B, H, T, D = x.shape
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,T,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------- #
# FFN: silu-GLU (llama family) or plain gelu MLP (starcoder2 / musicgen)
# --------------------------------------------------------------------- #
def mlp_init(rng, d: int, d_ff: int, kind: str):
    r1, r2, r3 = jax.random.split(rng, 3)
    if kind == "glu":
        return {"wi": _he(r1, (d, d_ff), d), "wg": _he(r2, (d, d_ff), d),
                "wo": _he(r3, (d_ff, d), d_ff)}
    return {"wi": _he(r1, (d, d_ff), d), "wo": _he(r3, (d_ff, d), d_ff)}


def mlp_apply(p, x, kind: str):
    if kind == "glu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over valid positions (labels < 0 are masked).

    logits (B, T, V); labels (B, T).  fp32 log-softmax for stability.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    ce = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_lm_loss(x: jax.Array, head_w: jax.Array, labels: jax.Array,
                    *, chunk: int = 128) -> jax.Array:
    """Fused head-projection + CE over token chunks (§Perf iteration 3).

    Never materializes the full (B, T, V) logits: each token chunk's
    logits live only inside a rematerialized chunk body.  For vocab ~150K
    this removes the largest single tensor of the training step (peak and
    HBM-traffic win); the extra cost is one recompute of the chunk logits
    in the backward pass.
    """
    B, T, d = x.shape
    if T % chunk != 0:
        chunk = T
    nc = T // chunk
    xc = x.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        xcb, lcb = inp
        logits = xcb @ head_w.astype(xcb.dtype)          # (B, chunk, V)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(
            lf, jnp.maximum(lcb, 0)[..., None], axis=-1)[..., 0]
        mask = (lcb >= 0).astype(jnp.float32)
        s, n = carry
        return (s + jnp.sum((lse - gold) * mask), n + jnp.sum(mask)), None

    (s, n), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                    jnp.zeros((), jnp.float32)), (xc, lc))
    return s / jnp.maximum(n, 1.0)
