"""Model assembly: decoder stacks for every assigned architecture family.

One parameterized decoder covers dense / MoE / hybrid (Jamba) / SSM (xLSTM)
/ audio / VLM families.  Layers are grouped into *periods* (the repeating
block pattern: 1 for homogeneous stacks, 8 for Jamba's 7-Mamba+1-attention,
4 for xLSTM's 3-mLSTM+1-sLSTM) and the period stack is executed with
``jax.lax.scan`` over stacked parameters — keeping HLO size (and hence
dry-run compile time and SPMD partitioning cost) independent of depth —
with optional rematerialization.

All model code is mesh-agnostic: distribution happens through pjit sharding
constraints (runtime/sharding.py) plus the ``ExecContext`` islands.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import (attn_apply, attn_cache_init, attn_decode,
                        attn_decode_paged, attn_init, attn_paged_cache_init,
                        attn_prefill, attn_prefill_paged)
from .context import ExecContext
from .layers import (chunked_lm_loss, cross_entropy, dense, dense_init,
                     embed, embed_init, mlp_apply, mlp_init, rmsnorm,
                     rmsnorm_init)
from .moe import moe_apply, moe_init
from .ssm import mamba_apply, mamba_cache_init, mamba_decode, mamba_init
from .xlstm import (mlstm_apply, mlstm_cache_init, mlstm_decode, mlstm_init,
                    slstm_apply, slstm_cache_init, slstm_decode, slstm_init)

__all__ = ["period_length", "block_kinds", "init_params", "forward",
           "loss_fn", "init_cache", "init_paged_cache", "decode_step",
           "prefill_forward", "supports_cached_prefill",
           "supports_paged_cache"]

AUX_LOSS_WEIGHT = 0.01


# --------------------------------------------------------------------- #
# block pattern
# --------------------------------------------------------------------- #
def period_length(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return max(cfg.slstm_every, 1)
    if cfg.attn_every > 0:
        import math
        return math.lcm(cfg.attn_every, cfg.moe_every)
    return cfg.moe_every if cfg.num_experts > 0 else 1


def block_kinds(cfg: ModelConfig) -> list[tuple[str, str]]:
    """Per-layer (mixer, ffn) kinds for one period."""
    P = period_length(cfg)
    kinds = []
    for j in range(P):
        if cfg.family == "ssm":
            mixer = "slstm" if j % cfg.slstm_every == cfg.slstm_every - 1 \
                else "mlstm"
        elif cfg.attn_every > 0:
            mixer = "attn" if j % cfg.attn_every == cfg.attn_offset else "mamba"
        else:
            mixer = "attn"
        if cfg.d_ff == 0:
            ffn = "none"
        elif cfg.num_experts > 0 and j % cfg.moe_every == cfg.moe_every - 1:
            ffn = "moe"
        else:
            ffn = "dense"
        kinds.append((mixer, ffn))
    return kinds


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _init_sub(rng, cfg: ModelConfig, mixer: str, ffn: str):
    r1, r2, r3 = jax.random.split(rng, 3)
    sub: dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model)}
    if mixer == "attn":
        sub["attn"] = attn_init(r1, cfg)
    elif mixer == "mamba":
        sub["mamba"] = mamba_init(r1, cfg.d_model, expand=cfg.mamba_expand,
                                  d_state=cfg.mamba_d_state,
                                  d_conv=cfg.mamba_d_conv)
    elif mixer == "mlstm":
        sub["mlstm"] = mlstm_init(r1, cfg.d_model, cfg.num_heads,
                                  expand=cfg.mamba_expand)
    elif mixer == "slstm":
        sub["slstm"] = slstm_init(r1, cfg.d_model)
    if ffn != "none":
        sub["norm2"] = rmsnorm_init(cfg.d_model)
        if ffn == "moe":
            sub["moe"] = moe_init(r2, cfg.d_model, cfg.d_ff, cfg.num_experts,
                                  cfg.mlp)
        else:
            sub["mlp"] = mlp_init(r2, cfg.d_model, cfg.d_ff, cfg.mlp)
    return sub


def init_params(rng, cfg: ModelConfig):
    kinds = block_kinds(cfg)
    P = period_length(cfg)
    n_periods = cfg.num_layers // P
    assert cfg.num_layers % P == 0, (cfg.num_layers, P)

    r_embed, r_layers, r_head = jax.random.split(rng, 3)

    def init_period(r):
        rs = jax.random.split(r, len(kinds))
        return {f"sub_{j}": _init_sub(rs[j], cfg, *kinds[j])
                for j in range(len(kinds))}

    period_rngs = jax.random.split(r_layers, n_periods)
    layers = jax.vmap(init_period)(period_rngs)

    params: dict[str, Any] = {"layers": layers,
                              "final_norm": rmsnorm_init(cfg.d_model)}
    if cfg.family != "audio":
        params["embed"] = embed_init(r_embed, cfg.vocab_size, cfg.d_model)
    if not cfg.tie_embeddings or cfg.family == "audio":
        params["lm_head"] = dense_init(r_head, cfg.d_model, cfg.vocab_size)
    # params are *stored* in the compute dtype (bf16 in production): the
    # FSDP all-gather then moves half the bytes (§Perf iteration 1); the
    # fp32 master lives in the optimizer state (optim/adamw.py).
    dtype = jnp.dtype(cfg.dtype)
    if dtype != jnp.float32:
        params = jax.tree.map(lambda p: p.astype(dtype), params)
    return params


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #
def inputs_to_embeds(params, cfg: ModelConfig, batch):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_frames":
        return batch["frame_embeds"].astype(dtype)
    x = embed(params["embed"], batch["tokens"], dtype)
    if cfg.frontend == "vit_patches":
        x = jnp.where(batch["patch_mask"][..., None],
                      batch["patch_embeds"].astype(dtype), x)
    return x


def _apply_sub(sub, cfg: ModelConfig, ctx: ExecContext, x, mixer: str,
               ffn: str):
    h = rmsnorm(sub["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        mx = attn_apply(sub["attn"], cfg, ctx, h)
    elif mixer == "mamba":
        mx = mamba_apply(sub["mamba"], h, ctx, d_state=cfg.mamba_d_state,
                         d_conv=cfg.mamba_d_conv)
    elif mixer == "mlstm":
        mx = mlstm_apply(sub["mlstm"], h, ctx, num_heads=cfg.num_heads)
    else:
        mx = slstm_apply(sub["slstm"], h, ctx)
    x = x + mx
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = rmsnorm(sub["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            f, aux = moe_apply(sub["moe"], h, ctx, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               kind=cfg.mlp)
        else:
            f = mlp_apply(sub["mlp"], h, cfg.mlp)
        x = x + f
    return x, aux


def forward(params, cfg: ModelConfig, ctx: ExecContext, batch,
            *, remat: bool = True):
    """batch -> (logits (B, T, vocab), aux_loss scalar).

    Remat policy: the residual stream between sublayers is saved; each
    sublayer's interior (attention logits, SSM scan operands, expert
    buffers) is rematerialized in the backward pass — peak memory is the
    *max* over sublayers rather than the sum over a period (critical for
    Jamba's 7-Mamba periods whose scan operands are large).
    """
    kinds = block_kinds(cfg)
    x = ctx.constrain(inputs_to_embeds(params, cfg, batch))

    def sub_fn(j):
        mixer, ffn = kinds[j]

        def apply(sub, x):
            y, a = _apply_sub(sub, cfg, ctx, x, mixer, ffn)
            return ctx.constrain(y), a

        return jax.checkpoint(apply) if remat else apply

    sub_fns = [sub_fn(j) for j in range(len(kinds))]

    def period_body(carry, period_params):
        x, aux = carry
        for j in range(len(kinds)):
            x, a = sub_fns[j](period_params[f"sub_{j}"], x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(period_body,
                               (x, jnp.zeros((), jnp.float32)),
                               params["layers"])

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if "lm_head" in params:
        logits = dense(params["lm_head"], x)
    else:
        logits = x @ params["embed"]["e"].T.astype(x.dtype)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, ctx: ExecContext, batch,
            *, remat: bool = True):
    logits, aux = forward(params, cfg, ctx, batch, remat=remat)
    ce = cross_entropy(logits, batch["labels"])
    return ce + AUX_LOSS_WEIGHT * aux, {"ce": ce, "aux": aux}


def loss_fn_chunked_head(params, cfg: ModelConfig, ctx: ExecContext, batch,
                         *, remat: bool = True, chunk: int = 128):
    """Loss with the fused chunked head (local/unsharded execution only:
    under CP the token axis is mesh-sharded and the logits are already
    distributed — see EXPERIMENTS.md §Perf iteration 3)."""
    kinds = block_kinds(cfg)
    x = ctx.constrain(inputs_to_embeds(params, cfg, batch))

    def sub(j):
        mixer, ffn = kinds[j]

        def apply(p, x):
            y, a = _apply_sub(p, cfg, ctx, x, mixer, ffn)
            return ctx.constrain(y), a
        return jax.checkpoint(apply) if remat else apply

    subs = [sub(j) for j in range(len(kinds))]

    def body(carry, pp):
        x, aux = carry
        for j in range(len(kinds)):
            x, a = subs[j](pp[f"sub_{j}"], x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["lm_head"]["w"] if "lm_head" in params \
        else params["embed"]["e"].T
    ce = chunked_lm_loss(x, head, batch["labels"], chunk=chunk)
    return ce + AUX_LOSS_WEIGHT * aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------- #
# prefill: forward pass that writes the KV cache directly
# --------------------------------------------------------------------- #
def supports_cached_prefill(cfg: ModelConfig) -> bool:
    """Cache-writing prefill needs every mixer to be attention (KV caches
    are written by position; recurrent mixers would need final-state
    extraction from the scan — those archs fall back to replay prefill)."""
    return all(mixer == "attn" for mixer, _ in block_kinds(cfg))


def supports_paged_cache(cfg: ModelConfig) -> bool:
    """Paged KV needs position-addressed caches in every mixer — i.e.
    attention-only stacks.  Recurrent mixers (Mamba/xLSTM) carry dense
    per-slot scan states that have no block structure; those archs keep
    the dense slot-stripe layout."""
    return supports_cached_prefill(cfg)


def prefill_forward(params, cfg: ModelConfig, cache, batch, pos, active,
                    *, with_logits: bool = True, block_tables=None,
                    block_size: int = 0, view_blocks: int = 0):
    """Forward one prompt chunk and write its KV into the cache in the
    same pass — no prompt replay through ``decode_step``.

    batch: the usual forward inputs for a (B, T) chunk ("tokens" or
    "frame_embeds"/"patch_*").  pos (B, T) int32: global cache positions
    of the chunk tokens.  active (B, T) bool: which tokens are real
    (False = padding past a short prompt or an idle slot — they neither
    write the cache nor influence outputs).  Returns (logits (B, T,
    vocab) or None, new cache).  Chunked calls with increasing ``pos``
    windows implement chunked prefill: each chunk attends the full
    cached prefix.  ``with_logits=False`` skips the lm_head — only the
    final chunk's last token ever feeds sampling, so earlier chunks
    need not pay the (T, vocab) projection.

    ``block_tables`` (B, nk) switches to the paged pool layout
    (``init_paged_cache``): chunk KV is scattered at table-resolved
    physical positions and attention runs over the request's gathered
    logical prefix of ``view_blocks`` blocks (``block_size`` tokens
    each) — see :func:`repro.models.attention.attn_prefill_paged`.

    MoE routing runs *drop-free* (capacity lifted to the chunk size):
    the decode path routes one token per step and never drops, so a
    capacity-clipped prefill would write KV inconsistent with the cache
    the decode path builds (the PR-3 root cause of the old decode-vs-
    forward xfail, now on the serving side).
    """
    assert supports_cached_prefill(cfg), \
        f"{cfg.name}: cache-writing prefill requires attention-only mixers"
    kinds = block_kinds(cfg)
    x = inputs_to_embeds(params, cfg, batch)
    # cap >= n for any routing needs capacity_factor >= E / top_k
    drop_free_cf = max(cfg.capacity_factor,
                       float(cfg.num_experts) / max(cfg.top_k, 1)) \
        if cfg.num_experts else cfg.capacity_factor

    def period_body(carry, scanned):
        x = carry
        period_params, period_cache = scanned
        new_cache = {}
        for j, (mixer, ffn) in enumerate(kinds):
            sub = period_params[f"sub_{j}"]
            h = rmsnorm(sub["norm1"], x, cfg.norm_eps)
            if block_tables is not None:
                mx, nc = attn_prefill_paged(
                    sub["attn"], cfg, h, pos, period_cache[f"sub_{j}"],
                    active, block_tables, block_size=block_size,
                    view_blocks=view_blocks)
            else:
                mx, nc = attn_prefill(sub["attn"], cfg, h, pos,
                                      period_cache[f"sub_{j}"], active)
            new_cache[f"sub_{j}"] = nc
            x = x + mx
            if ffn != "none":
                h = rmsnorm(sub["norm2"], x, cfg.norm_eps)
                if ffn == "moe":
                    f, _ = moe_apply(sub["moe"], h, None, top_k=cfg.top_k,
                                     capacity_factor=drop_free_cf,
                                     kind=cfg.mlp)
                else:
                    f = mlp_apply(sub["mlp"], h, cfg.mlp)
                x = x + f
        return x, new_cache

    x, new_cache = jax.lax.scan(period_body, x, (params["layers"], cache))
    if not with_logits:
        return None, new_cache
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if "lm_head" in params:
        logits = dense(params["lm_head"], x)
    else:
        logits = x @ params["embed"]["e"].T.astype(x.dtype)
    return logits, new_cache


# --------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------- #
def _sub_cache_init(cfg: ModelConfig, mixer: str, batch: int, max_len: int,
                    dtype):
    if mixer == "attn":
        return attn_cache_init(cfg, batch, max_len, dtype)
    if mixer == "mamba":
        return mamba_cache_init(batch, cfg.d_model, expand=cfg.mamba_expand,
                                d_state=cfg.mamba_d_state,
                                d_conv=cfg.mamba_d_conv, dtype=dtype)
    if mixer == "mlstm":
        return mlstm_cache_init(batch, cfg.d_model, cfg.num_heads,
                                expand=cfg.mamba_expand, dtype=dtype)
    return slstm_cache_init(batch, cfg.d_model, dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    kinds = block_kinds(cfg)
    P = period_length(cfg)
    n_periods = cfg.num_layers // P
    period = {f"sub_{j}": _sub_cache_init(cfg, kinds[j][0], batch, max_len,
                                          dtype)
              for j in range(len(kinds))}
    return jax.tree.map(
        lambda a: jnp.zeros((n_periods,) + a.shape, a.dtype), period)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int):
    """Global paged KV pool: per attention sub-layer
    (num_kv_heads, num_blocks * block_size, head_dim) — no slot axis.
    Requires :func:`supports_paged_cache` (attention-only mixers)."""
    assert supports_paged_cache(cfg), \
        f"{cfg.name}: paged KV requires attention-only mixers"
    dtype = jnp.dtype(cfg.dtype)
    kinds = block_kinds(cfg)
    P = period_length(cfg)
    n_periods = cfg.num_layers // P
    period = {f"sub_{j}": attn_paged_cache_init(cfg, num_blocks,
                                                block_size, dtype)
              for j in range(len(kinds))}
    return jax.tree.map(
        lambda a: jnp.zeros((n_periods,) + a.shape, a.dtype), period)


def decode_step(params, cfg: ModelConfig, cache, batch, pos_t, *,
                attn_impl: str = "flash", attn_shards: int = 1,
                block_k: int = 256, interpret: bool | None = None,
                block_tables=None, block_size: int = 0,
                write_mask=None):
    """One decode step.

    batch: {"tokens": (B,) int32} (or {"frame_embeds": (B, d)} for audio).
    pos_t: (B,) int32 current positions.  Returns (logits (B, vocab),
    new cache).

    ``attn_impl`` picks the decode attention: ``"flash"`` (default) is
    the fused flash-decode kernel with the cache split into
    ``attn_shards`` LSE-merged segments; ``"dense"`` the XLA softmax
    oracle (see :func:`repro.models.attention.attn_decode`).

    ``block_tables`` (B, nk) switches to the paged pool layout: the new
    token's KV scatters at its table-resolved physical position and
    attention indirects through the table (``attn_decode_paged``);
    ``write_mask`` (B,) bool drops pool writes for idle / prefilling
    rows (the pool has no row axis to mask after the fact).
    """
    dtype = jnp.dtype(cfg.dtype)
    kinds = block_kinds(cfg)
    if cfg.frontend == "audio_frames":
        x = batch["frame_embeds"].astype(dtype)
    else:
        x = embed(params["embed"], batch["tokens"], dtype)

    def period_body(x, scanned):
        period_params, period_cache = scanned
        new_cache = {}
        for j, (mixer, ffn) in enumerate(kinds):
            sub = period_params[f"sub_{j}"]
            c = period_cache[f"sub_{j}"]
            h = rmsnorm(sub["norm1"], x[:, None], cfg.norm_eps)[:, 0]
            if mixer == "attn" and block_tables is not None:
                mx, nc = attn_decode_paged(
                    sub["attn"], cfg, h, pos_t, c, block_tables,
                    write_mask, impl=attn_impl, block_size=block_size,
                    interpret=interpret)
            elif mixer == "attn":
                mx, nc = attn_decode(sub["attn"], cfg, h, pos_t, c,
                                     impl=attn_impl, shards=attn_shards,
                                     block_k=block_k, interpret=interpret)
            elif mixer == "mamba":
                mx, nc = mamba_decode(sub["mamba"], h,
                                      c, d_state=cfg.mamba_d_state,
                                      d_conv=cfg.mamba_d_conv)
            elif mixer == "mlstm":
                mx, nc = mlstm_decode(sub["mlstm"], h, c,
                                      num_heads=cfg.num_heads)
            else:
                mx, nc = slstm_decode(sub["slstm"], h, c)
            x = x + mx
            new_cache[f"sub_{j}"] = nc
            if ffn != "none":
                h = rmsnorm(sub["norm2"], x[:, None], cfg.norm_eps)
                if ffn == "moe":
                    f, _ = moe_apply(sub["moe"], h, None, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor,
                                     kind=cfg.mlp)
                else:
                    f = mlp_apply(sub["mlp"], h, cfg.mlp)
                x = x + f[:, 0]
        return x, new_cache

    x, new_cache = jax.lax.scan(period_body, x,
                                (params["layers"], cache))
    x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)[:, 0]
    if "lm_head" in params:
        logits = dense(params["lm_head"], x)
    else:
        logits = x @ params["embed"]["e"].T.astype(x.dtype)
    return logits, new_cache
