from .context import ExecContext, make_local_context, local_ssm_scan
from .transformer import (block_kinds, decode_step, forward, init_cache,
                          init_paged_cache, init_params, loss_fn,
                          period_length, prefill_forward,
                          supports_cached_prefill, supports_paged_cache)

__all__ = [
    "ExecContext", "make_local_context", "local_ssm_scan",
    "block_kinds", "decode_step", "forward", "init_cache",
    "init_paged_cache", "init_params", "loss_fn", "period_length",
    "prefill_forward", "supports_cached_prefill", "supports_paged_cache",
]
