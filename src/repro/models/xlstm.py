"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, scan).

Design notes (DESIGN.md §Arch-applicability):
* mLSTM is computed in the chunkwise-parallel form: intra-chunk
  decay-weighted attention + inter-chunk matrix state carried through
  ``ctx.ssm_scan`` (which handles CP boundary exchange).  Gating uses
  sigmoid forget/input gates — a stabilized simplification of the paper's
  exponential gating (recorded deviation; the exp-gating stabilizer is a
  max-plus scan that does not change the systems behaviour studied here).
* sLSTM is an elementwise recurrence, mapped directly onto ``ctx.ssm_scan``.
* Document resets zero the forget gate at intra-doc position 0.
* d_ff == 0: these blocks carry their own up/down projections (expand 2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _he

__all__ = ["mlstm_init", "mlstm_apply", "slstm_init", "slstm_apply",
           "mlstm_cache_init", "mlstm_decode", "slstm_cache_init",
           "slstm_decode"]

_CHUNK = 64


# ===================================================================== #
# mLSTM
# ===================================================================== #
def mlstm_init(rng, d: int, num_heads: int, *, expand: int = 2):
    di = expand * d
    rs = jax.random.split(rng, 7)
    return {
        "up": _he(rs[0], (d, 2 * di), d),
        "wq": _he(rs[1], (di, di), di),
        "wk": _he(rs[2], (di, di), di),
        "wv": _he(rs[3], (di, di), di),
        "wf": _he(rs[4], (di, num_heads), di),
        "wi": _he(rs[5], (di, num_heads), di),
        "down": _he(rs[6], (di, d), di),
    }


def mlstm_apply(p, x, ctx, *, num_heads: int):
    """x (B, T, d) -> (B, T, d).  Chunkwise-parallel linear attention with
    per-head scalar forget/input gates."""
    B, T, d = x.shape
    di = p["up"].shape[1] // 2
    H = num_heads
    dh = di // H

    xu, z = jnp.split(x @ p["up"].astype(x.dtype), 2, axis=-1)

    def heads(w, v):
        return (v @ w.astype(v.dtype)).reshape(B, T, H, dh).swapaxes(1, 2)

    q = heads(p["wq"], xu) * (dh ** -0.5)          # (B, H, T, dh)
    k = heads(p["wk"], xu)
    v = heads(p["wv"], xu)
    f = jax.nn.sigmoid((xu.astype(jnp.float32) @ p["wf"])).swapaxes(1, 2)  # (B,H,T)
    i = jax.nn.sigmoid((xu.astype(jnp.float32) @ p["wi"])).swapaxes(1, 2)
    # document reset
    f = f * (ctx.pos > 0)[:, None, :]

    c = min(_CHUNK, T)
    while T % c:
        c //= 2
    nc = T // c

    qc = q.reshape(B, H, nc, c, dh)
    kc = k.reshape(B, H, nc, c, dh).astype(jnp.float32)
    vc = v.reshape(B, H, nc, c, dh).astype(jnp.float32)
    fc = f.reshape(B, H, nc, c)
    ic = i.reshape(B, H, nc, c)

    lf = jnp.log(jnp.maximum(fc, 1e-30))
    clf = jnp.cumsum(lf, axis=-1)                   # inclusive, intra-chunk

    # ---- intra-chunk: decay-weighted causal attention ------------------ #
    # W[t, s] = exp(clf_t - clf_s) * i_s   for s <= t
    dmat = clf[..., :, None] - clf[..., None, :]
    causal = jnp.tril(jnp.ones((c, c), bool))
    # clamp *before* exp: anti-causal lanes have dmat > 0 and would produce
    # inf, whose zero-cotangent product is NaN in the backward pass.
    dmat = jnp.where(causal, dmat, -1e30)
    w = jnp.exp(dmat) * ic[..., None, :]
    qf = qc.astype(jnp.float32)
    scores = jnp.einsum("bhntd,bhnsd->bhnts", qf, kc) * w
    intra = jnp.einsum("bhnts,bhnsd->bhntd", scores, vc)
    intra_n = jnp.einsum("bhnts,bhnsd->bhntd", w, kc)  # normalizer numerator

    # ---- inter-chunk: matrix state scan over chunks --------------------- #
    decay_chunk = jnp.exp(clf[..., -1])                        # (B,H,nc)
    # contribution of chunk to state: sum_s exp(clf_end - clf_s) i_s k_s v_s^T
    tail = jnp.exp(clf[..., -1:] - clf) * ic                   # (B,H,nc,c)
    dC = jnp.einsum("bhns,bhnsk,bhnsv->bhnkv", tail, kc, vc)   # (B,H,nc,dh,dh)
    dN = jnp.einsum("bhns,bhnsk->bhnk", tail, kc)              # (B,H,nc,dh)

    # scan over the chunk axis (B*H batched); decay stays in broadcast
    # (singleton) form so the scan never materializes a (dh, dh) decay
    a_c = decay_chunk.swapaxes(1, 2)                           # (B,nc,H)
    C_states = ctx.ssm_scan(a_c[..., None, None],
                            dC.transpose(0, 2, 1, 3, 4))       # (B,nc,H,dh,dh)
    N_states = ctx.ssm_scan(a_c[..., None],
                            dN.transpose(0, 2, 1, 3))          # (B,nc,H,dh)
    # previous-chunk states (exclusive)
    C_prev = jnp.pad(C_states, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    N_prev = jnp.pad(N_states, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :-1]

    carry_w = jnp.exp(clf)                                     # decay from chunk start
    inter = jnp.einsum("bhntd,bnhdv->bhntv", qf * carry_w[..., None], C_prev)
    inter_n = jnp.einsum("bhntd,bnhd->bhnt", qf * carry_w[..., None], N_prev)

    num = intra + inter                                        # (B,H,nc,c,dh)
    den = jnp.einsum("bhntd,bhntd->bhnt", qf, intra_n)[..., None] \
        + inter_n[..., None]
    out = num / jnp.maximum(jnp.abs(den), 1.0)
    out = out.reshape(B, H, T, dh).swapaxes(1, 2).reshape(B, T, di)
    out = out.astype(x.dtype) * jax.nn.silu(z)
    return out @ p["down"].astype(x.dtype)


def mlstm_cache_init(batch: int, d: int, num_heads: int, *, expand: int, dtype):
    di = expand * d
    dh = di // num_heads
    return {
        "C": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
        "N": jnp.zeros((batch, num_heads, dh), jnp.float32),
    }


def mlstm_decode(p, x_t, cache, *, num_heads: int):
    B, d = x_t.shape
    di = p["up"].shape[1] // 2
    H, dh = num_heads, di // num_heads

    xu, z = jnp.split(x_t @ p["up"].astype(x_t.dtype), 2, axis=-1)
    q = (xu @ p["wq"].astype(xu.dtype)).reshape(B, H, dh).astype(jnp.float32) \
        * (dh ** -0.5)
    k = (xu @ p["wk"].astype(xu.dtype)).reshape(B, H, dh).astype(jnp.float32)
    v = (xu @ p["wv"].astype(xu.dtype)).reshape(B, H, dh).astype(jnp.float32)
    f = jax.nn.sigmoid(xu.astype(jnp.float32) @ p["wf"])       # (B,H)
    i = jax.nn.sigmoid(xu.astype(jnp.float32) @ p["wi"])

    C = f[..., None, None] * cache["C"] + i[..., None, None] * \
        jnp.einsum("bhk,bhv->bhkv", k, v)
    N = f[..., None] * cache["N"] + i[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.einsum("bhk,bhk->bh", q, N)[..., None]
    out = (num / jnp.maximum(jnp.abs(den), 1.0)).reshape(B, di)
    out = out.astype(x_t.dtype) * jax.nn.silu(z)
    return out @ p["down"].astype(x_t.dtype), {"C": C, "N": N}


# ===================================================================== #
# sLSTM
# ===================================================================== #
def slstm_init(rng, d: int):
    rs = jax.random.split(rng, 5)
    return {
        "wz": _he(rs[0], (d, d), d),
        "wi": _he(rs[1], (d, d), d),
        "wf": _he(rs[2], (d, d), d),
        "wo": _he(rs[3], (d, d), d),
        "down": _he(rs[4], (d, d), d),
    }


def slstm_apply(p, x, ctx):
    """x (B, T, d) -> (B, T, d).  c_t = f_t c_{t-1} + i_t z_t; h = o ⊙ c."""
    xf = x.astype(jnp.float32)
    z = jnp.tanh(xf @ p["wz"])
    i = jax.nn.sigmoid(xf @ p["wi"])
    f = jax.nn.sigmoid(xf @ p["wf"])
    o = jax.nn.sigmoid(xf @ p["wo"])
    f = f * (ctx.pos > 0).astype(f.dtype)[..., None]
    c = ctx.ssm_scan(f, i * z)
    h = (o * c).astype(x.dtype)
    return h @ p["down"].astype(x.dtype)


def slstm_cache_init(batch: int, d: int, dtype):
    return {"c": jnp.zeros((batch, d), jnp.float32)}


def slstm_decode(p, x_t, cache):
    xf = x_t.astype(jnp.float32)
    z = jnp.tanh(xf @ p["wz"])
    i = jax.nn.sigmoid(xf @ p["wi"])
    f = jax.nn.sigmoid(xf @ p["wf"])
    o = jax.nn.sigmoid(xf @ p["wo"])
    c = f * cache["c"] + i * z
    h = (o * c).astype(x_t.dtype)
    return h @ p["down"].astype(x_t.dtype), {"c": c}
