"""GQA attention block: projections + RoPE + (CP-aware) masked attention.

The inner attention is ``ctx.attn`` — locally a doc-masked kernel, under CP
the FlashCP shard_map island.  qk_norm (Qwen3) is per-head RMS norm applied
before RoPE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _he, rope

__all__ = ["attn_init", "attn_apply", "attn_cache_init", "attn_decode"]


def attn_init(rng, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    rs = jax.random.split(rng, 4)
    p = {
        "wq": _he(rs[0], (d, hq * hd), d),
        "wk": _he(rs[1], (d, hkv * hd), d),
        "wv": _he(rs[2], (d, hkv * hd), d),
        "wo": _he(rs[3], (hq * hd, d), hq * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _head_norm(x, g, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * g).astype(x.dtype)


def _project(p, cfg, x):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, T, cfg.num_heads, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, T, cfg.num_kv_heads, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, T, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = _head_norm(q, p["q_norm"], cfg.norm_eps)
        k = _head_norm(k, p["k_norm"], cfg.norm_eps)
    return (q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2))


def attn_apply(p, cfg, ctx, x):
    """x (B, T, d) -> (B, T, d)."""
    B, T, _ = x.shape
    q, k, v = _project(p, cfg, x)
    q = rope(q, ctx.pos, cfg.rope_theta)
    k = rope(k, ctx.pos, cfg.rope_theta)
    out = ctx.attn(q, k, v)                       # (B, Hq, T, hd)
    out = out.swapaxes(1, 2).reshape(B, T, cfg.num_heads * cfg.resolved_head_dim)
    return out @ p["wo"].astype(x.dtype)


# ------------------------------------------------------------------ #
# decode: one new token against a (sequence-sharded) KV cache
# ------------------------------------------------------------------ #
def attn_cache_init(cfg, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
    }


def attn_decode(p, cfg, x_t, pos_t, cache):
    """x_t (B, d); pos_t (B,) current positions.  Distributed flash-decode:
    under pjit the cache's sequence axis is sharded over the ``model`` mesh
    axis, and XLA partitions the fp32 softmax (max/sum all-reduce + psum of
    the weighted values) — the LSE-merge pattern — automatically."""
    B, d = x_t.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project(p, cfg, x_t[:, None, :])
    q = rope(q, pos_t[:, None], cfg.rope_theta)            # (B,Hq,1,hd)
    k = rope(k, pos_t[:, None], cfg.rope_theta)

    S = cache["k"].shape[2]
    # scatter the new KV at pos_t (per sample) — in-place update, not a
    # full-cache rewrite (the decode step is HBM-bound on the cache read).
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(cfg.num_kv_heads)[None, :]
    kc = cache["k"].at[bi, hi, pos_t[:, None]].set(k[:, :, 0, :])
    vc = cache["v"].at[bi, hi, pos_t[:, None]].set(v[:, :, 0, :])

    G = cfg.num_heads // cfg.num_kv_heads
    qf = (q.astype(jnp.float32) * hd ** -0.5) \
        .reshape(B, cfg.num_kv_heads, G, hd)
    s = jnp.einsum("bhgd,bhsd->bhgs", qf, kc.astype(jnp.float32))
    mask = (jnp.arange(S)[None, :] <= pos_t[:, None])[:, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p_att = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p_att, vc.astype(jnp.float32))
    out = out.reshape(B, cfg.num_heads * hd).astype(x_t.dtype)
    return out @ p["wo"].astype(x_t.dtype), {"k": kc, "v": vc}
