"""GQA attention block: projections + RoPE + (CP-aware) masked attention.

The inner attention is ``ctx.attn`` — locally a doc-masked kernel, under CP
the FlashCP shard_map island.  qk_norm (Qwen3) is per-head RMS norm applied
before RoPE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _he, rope

__all__ = ["attn_init", "attn_apply", "attn_cache_init",
           "attn_paged_cache_init", "attn_decode", "attn_decode_paged",
           "attn_prefill", "attn_prefill_paged"]


def attn_init(rng, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    rs = jax.random.split(rng, 4)
    p = {
        "wq": _he(rs[0], (d, hq * hd), d),
        "wk": _he(rs[1], (d, hkv * hd), d),
        "wv": _he(rs[2], (d, hkv * hd), d),
        "wo": _he(rs[3], (hq * hd, d), hq * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _head_norm(x, g, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * g).astype(x.dtype)


def _project(p, cfg, x):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, T, cfg.num_heads, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, T, cfg.num_kv_heads, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, T, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = _head_norm(q, p["q_norm"], cfg.norm_eps)
        k = _head_norm(k, p["k_norm"], cfg.norm_eps)
    return (q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2))


def attn_apply(p, cfg, ctx, x):
    """x (B, T, d) -> (B, T, d)."""
    B, T, _ = x.shape
    q, k, v = _project(p, cfg, x)
    q = rope(q, ctx.pos, cfg.rope_theta)
    k = rope(k, ctx.pos, cfg.rope_theta)
    out = ctx.attn(q, k, v)                       # (B, Hq, T, hd)
    out = out.swapaxes(1, 2).reshape(B, T, cfg.num_heads * cfg.resolved_head_dim)
    return out @ p["wo"].astype(x.dtype)


# ------------------------------------------------------------------ #
# decode: one new token against a (sequence-sharded) KV cache
# ------------------------------------------------------------------ #
def attn_cache_init(cfg, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
    }


def attn_paged_cache_init(cfg, num_blocks: int, block_size: int, dtype):
    """Global paged KV pool for one attention sub-layer: no batch axis —
    every request indexes the same pool through its block table (the
    token axis is flat; physical position = block_id * block_size +
    offset).  HBM scales with *allocated* blocks, not slots x max_len."""
    hd = cfg.resolved_head_dim
    shape = (cfg.num_kv_heads, num_blocks * block_size, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(p, cfg, x_t, pos_t, cache, *, impl: str = "flash",
                shards: int = 1, block_k: int = 256,
                interpret: bool | None = None):
    """x_t (B, d); pos_t (B,) current positions.

    ``impl="flash"`` (default) runs the fused Pallas flash-decode kernel
    per cache shard (``shards`` contiguous S-segments, 1 = whole cache)
    and merges the (o, m, l) partials on the online-LSE substrate —
    blocks past each request's length are never fetched, so ragged
    batches pay only for the cache they use.  ``impl="dense"`` keeps the
    XLA dense softmax as the parity oracle.  ``interpret=None`` picks
    Pallas interpret mode automatically off-TPU."""
    B, d = x_t.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project(p, cfg, x_t[:, None, :])
    q = rope(q, pos_t[:, None], cfg.rope_theta)            # (B,Hq,1,hd)
    k = rope(k, pos_t[:, None], cfg.rope_theta)

    # scatter the new KV at pos_t (per sample) — in-place update, not a
    # full-cache rewrite (the decode step is HBM-bound on the cache read).
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(cfg.num_kv_heads)[None, :]
    kc = cache["k"].at[bi, hi, pos_t[:, None]].set(k[:, :, 0, :])
    vc = cache["v"].at[bi, hi, pos_t[:, None]].set(v[:, :, 0, :])

    if impl == "flash":
        from repro.kernels.flash_decode import flash_decode_sharded

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = flash_decode_sharded(
            q[:, :, 0, :], kc, vc, pos_t, shards=shards,
            scale=hd ** -0.5, block_k=block_k, interpret=interpret)
        out = out.reshape(B, cfg.num_heads * hd).astype(x_t.dtype)
    elif impl == "dense":
        from repro.kernels.flash_decode import decode_reference

        out = decode_reference(q[:, :, 0, :], kc, vc, pos_t) \
            .reshape(B, cfg.num_heads * hd).astype(x_t.dtype)
    else:
        raise ValueError(f"unknown decode attention impl {impl!r}")
    return out @ p["wo"].astype(x_t.dtype), {"k": kc, "v": vc}


def attn_prefill(p, cfg, x, pos, cache, active):
    """Chunked-prefill attention: write this chunk's KV straight into the
    cache, then attend the chunk's queries against the cache prefix.

    x (B, T, d) chunk activations; pos (B, T) *global* cache positions of
    the chunk tokens (monotone per row); active (B, T) bool — False rows/
    tokens (padding past a short prompt, idle slots) neither write the
    cache nor produce output.  Causality falls out of the position mask:
    every cache entry at position <= pos[b, t] was written by this or an
    earlier chunk, and entries past the chunk are masked (unwritten or
    future).  Returns (out (B, T, d), new cache).
    """
    from repro.kernels.ref import mha_reference

    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project(p, cfg, x)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    S = cache["k"].shape[2]
    # scatter the chunk's KV at its global positions; inactive tokens are
    # routed out of bounds and dropped, leaving the cache untouched there
    bi = jnp.arange(B)[:, None, None]
    hi = jnp.arange(cfg.num_kv_heads)[None, :, None]
    ti = jnp.where(active, pos, S)[:, None, :]
    kc = cache["k"].at[bi, hi, ti].set(k, mode="drop")
    vc = cache["v"].at[bi, hi, ti].set(v, mode="drop")

    q_doc = jnp.where(active, 0, -1).astype(jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    kv_doc = jnp.zeros((B, S), jnp.int32)
    out = mha_reference(q, kc, vc, q_doc, pos, kv_doc, kv_pos,
                        scale=hd ** -0.5)
    out = out.swapaxes(1, 2).reshape(B, T, cfg.num_heads * hd)
    return out @ p["wo"].astype(x.dtype), {"k": kc, "v": vc}


# ------------------------------------------------------------------ #
# paged: block-table indirection into a global KV pool
# ------------------------------------------------------------------ #
def _phys_positions(tables, pos, active, block_size, nbtok):
    """Logical position -> flat pool position via the block table; tokens
    outside ``active`` route out of bounds (scatter mode="drop")."""
    blk = jnp.take_along_axis(tables, jnp.maximum(pos, 0) // block_size,
                              axis=1)
    phys = blk * block_size + jnp.maximum(pos, 0) % block_size
    return jnp.where(active & (pos >= 0), phys, nbtok)


def attn_decode_paged(p, cfg, x_t, pos_t, cache, tables, active, *,
                      impl: str = "flash", block_size: int,
                      interpret: bool | None = None):
    """One decode token against the paged pool.

    x_t (B, d); pos_t (B,) logical positions; tables (B, nk) block
    tables; active (B,) — inactive rows (idle / still-prefilling slots)
    never write the pool.  ``impl="flash"`` runs the block-table Pallas
    kernel; ``"dense"`` gathers the logical view and runs the XLA
    softmax oracle.  The caller must have made the written block private
    (refcount 1) — copy-on-write happens host-side in the engine.
    """
    B, _ = x_t.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project(p, cfg, x_t[:, None, :])
    q = rope(q, pos_t[:, None], cfg.rope_theta)            # (B,Hq,1,hd)
    k = rope(k, pos_t[:, None], cfg.rope_theta)

    nbtok = cache["k"].shape[1]
    phys = _phys_positions(tables, pos_t[:, None], active[:, None],
                           block_size, nbtok)[:, 0]         # (B,)
    hi = jnp.arange(cfg.num_kv_heads)[:, None]
    kc = cache["k"].at[hi, phys[None, :]].set(
        k[:, :, 0].swapaxes(0, 1).astype(cache["k"].dtype), mode="drop")
    vc = cache["v"].at[hi, phys[None, :]].set(
        v[:, :, 0].swapaxes(0, 1).astype(cache["v"].dtype), mode="drop")

    lengths = jnp.where(active, pos_t, -1)
    if impl == "flash":
        from repro.kernels.flash_decode import flash_decode_paged

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = flash_decode_paged(q[:, :, 0, :], kc, vc, lengths, tables,
                                 block_size=block_size, scale=hd ** -0.5,
                                 interpret=interpret)
    elif impl == "dense":
        from repro.kernels.flash_decode import paged_decode_reference

        out = paged_decode_reference(q[:, :, 0, :], kc, vc, lengths,
                                     tables, block_size=block_size,
                                     scale=hd ** -0.5)
    else:
        raise ValueError(f"unknown decode attention impl {impl!r}")
    out = out.reshape(B, cfg.num_heads * hd).astype(x_t.dtype)
    return out @ p["wo"].astype(x_t.dtype), {"k": kc, "v": vc}


def attn_prefill_paged(p, cfg, x, pos, cache, active, tables, *,
                       block_size: int, view_blocks: int):
    """Chunked-prefill attention through the block table (B = 1).

    Writes the chunk's roped KV at its physical pool positions, then
    attends the chunk's queries against the request's *gathered* logical
    prefix (``view_blocks`` blocks — the pow2 bucket covering the chunk
    end, so attention is O(C * view) not O(C * pool)).  active tokens
    beyond the prompt neither write nor contribute (same contract as
    :func:`attn_prefill`).
    """
    from repro.kernels.ref import mha_reference

    B, T, _ = x.shape
    assert B == 1, "paged prefill runs one request at a time"
    hd = cfg.resolved_head_dim
    q, k, v = _project(p, cfg, x)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    nbtok = cache["k"].shape[1]
    phys = _phys_positions(tables, pos, active, block_size, nbtok)  # (1,T)
    hi = jnp.arange(cfg.num_kv_heads)[:, None]
    kc = cache["k"].at[hi, phys[0][None, :]].set(
        k[0].astype(cache["k"].dtype), mode="drop")
    vc = cache["v"].at[hi, phys[0][None, :]].set(
        v[0].astype(cache["v"].dtype), mode="drop")

    # gather the logical prefix view [0, view_blocks * bs)
    S = view_blocks * block_size
    s_log = jnp.arange(S, dtype=jnp.int32)
    vblk = jnp.take_along_axis(
        tables, (s_log // block_size)[None, :], axis=1)[0]
    vphys = vblk * block_size + s_log % block_size
    kv_view = kc[:, vphys][None], vc[:, vphys][None]       # (1,Hkv,S,hd)

    q_doc = jnp.where(active, 0, -1).astype(jnp.int32)
    kv_pos = jnp.broadcast_to(s_log[None], (B, S))
    kv_doc = jnp.zeros((B, S), jnp.int32)
    out = mha_reference(q, kv_view[0], kv_view[1], q_doc, pos, kv_doc,
                        kv_pos, scale=hd ** -0.5)
    out = out.swapaxes(1, 2).reshape(B, T, cfg.num_heads * hd)
    return out @ p["wo"].astype(x.dtype), {"k": kc, "v": vc}
