"""Jit-ready attention ops.

* ``doc_flash_attention`` — the Pallas kernel pair (fwd + custom-VJP bwd)
  from :mod:`repro.kernels.doc_attention`.  TPU is the target; pass
  ``interpret=True`` to validate on CPU.  ``grid`` selects the kernel
  schedule: ``"rect"`` launches the padded rectangular visit grid,
  ``"flat"`` the flattened 1D work queue (one grid step per actual
  visit; see the kernel module docstring).
* ``doc_attention_xla``  — chunked pure-XLA implementation with identical
  semantics.  Used for CPU training runs and for the multi-pod dry-run
  (Pallas TPU kernels cannot lower on the CPU backend); differentiable by
  ordinary JAX AD.

Both implement the doc-mask visibility rule defined in ``ref.py``, and
both expose a **partial mode** for the CP overlap engine
(:mod:`repro.core.cp_attention`): instead of a finished attention output
they emit a merge-ready partial whose combination across KV subsets via
online-LSE rescaling reproduces full attention exactly:

* ``doc_flash_attention(..., partial=True)`` returns ``(o, lse)`` — the
  subset-normalized output plus its log-sum-exp.  The custom VJP folds
  the incoming ``d lse`` into the flash backward's ``delta`` term
  (``ds = p * (dp - (delta - dlse))``), so the same Pallas backward
  kernels serve the merged objective with exact gradients.
* ``doc_attention_xla(..., partial=True)`` returns the raw online-softmax
  triple ``(o_unnormalized, m, l)``; plain JAX AD differentiates it.

The two partial forms are interchangeable under the same merge: a
normalized ``(o, lse)`` pair is the triple ``(o, m=lse, l=1)``.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.table_layout import GRID_TABLE_HALF

from . import doc_attention as da
from .ref import doc_mask

__all__ = ["doc_flash_attention", "doc_attention_xla"]


def _float0_zero(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _split_tables(tables: tuple, grid: str):
    """(fwd/dQ tables, dKV reverse tables) halves of the combined tuple.

    rect: (kv_idx, kv_nvis | q_idx, q_nvis)
    flat: (fq_row, fq_col, fq_flags | rq_row, rq_col, rq_flags)
    """
    half = GRID_TABLE_HALF[grid]
    if len(tables) != 2 * half:
        raise ValueError(
            f"grid={grid!r} needs {2 * half} table arrays, got "
            f"{len(tables)}")
    return tables[:half], tables[half:]


# ===================================================================== #
# Pallas path
# ===================================================================== #
@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11, 12))
def _attn(q, k, v, q_doc, q_pos, kv_doc, kv_pos, tables,
          scale, block_q, block_k, grid, interpret):
    fwd_t, _ = _split_tables(tables, grid)
    out, _ = da.flash_fwd(
        q, k, v, q_doc, q_pos, kv_doc, kv_pos, fwd_t,
        scale=scale, block_q=block_q, block_k=block_k, grid=grid,
        interpret=interpret)
    return out


def _attn_fwd(q, k, v, q_doc, q_pos, kv_doc, kv_pos, tables,
              scale, block_q, block_k, grid, interpret):
    fwd_t, _ = _split_tables(tables, grid)
    out, lse = da.flash_fwd(
        q, k, v, q_doc, q_pos, kv_doc, kv_pos, fwd_t,
        scale=scale, block_q=block_q, block_k=block_k, grid=grid,
        interpret=interpret)
    res = (q, k, v, out, lse, q_doc, q_pos, kv_doc, kv_pos, tables)
    return out, res


def _flash_bwd(res, do, dlse, *, scale, block_q, block_k, grid, interpret):
    """Shared dq/dkv backward; ``dlse`` folds an (o, lse)-mode lse
    cotangent into delta (None for plain attention)."""
    (q, k, v, out, lse, q_doc, q_pos, kv_doc, kv_pos, tables) = res
    fwd_t, rev_t = _split_tables(tables, grid)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    if dlse is not None:
        delta = delta - dlse.astype(jnp.float32)
    dq = da.flash_bwd_dq(
        q, k, v, do, lse, delta, q_doc, q_pos, kv_doc, kv_pos,
        fwd_t, scale=scale, block_q=block_q, block_k=block_k, grid=grid,
        interpret=interpret)
    dk, dv = da.flash_bwd_dkv(
        q, k, v, do, lse, delta, q_doc, q_pos, kv_doc, kv_pos,
        rev_t, scale=scale, block_q=block_q, block_k=block_k, grid=grid,
        interpret=interpret)
    zeros = tuple(_float0_zero(x) for x in
                  (q_doc, q_pos, kv_doc, kv_pos))
    return (dq, dk, dv) + zeros + (tuple(_float0_zero(t) for t in tables),)


def _attn_bwd(scale, block_q, block_k, grid, interpret, res, do):
    return _flash_bwd(res, do, None, scale=scale, block_q=block_q,
                      block_k=block_k, grid=grid, interpret=interpret)


_attn.defvjp(_attn_fwd, _attn_bwd)


# ===================================================================== #
# Pallas partial mode: (o, lse) with exact gradients through both
# ===================================================================== #
@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11, 12))
def _attn_partial(q, k, v, q_doc, q_pos, kv_doc, kv_pos, tables,
                  scale, block_q, block_k, grid, interpret):
    fwd_t, _ = _split_tables(tables, grid)
    return da.flash_fwd(
        q, k, v, q_doc, q_pos, kv_doc, kv_pos, fwd_t,
        scale=scale, block_q=block_q, block_k=block_k, grid=grid,
        interpret=interpret)


def _attn_partial_fwd(q, k, v, q_doc, q_pos, kv_doc, kv_pos, tables,
                      scale, block_q, block_k, grid, interpret):
    out, res = _attn_fwd(q, k, v, q_doc, q_pos, kv_doc, kv_pos, tables,
                         scale, block_q, block_k, grid, interpret)
    return (out, res[4]), res


def _attn_partial_bwd(scale, block_q, block_k, grid, interpret, res, cts):
    """Backward of the (o, lse) pair with the standard flash kernels.

    With p = exp(s - lse): d s = p * (do . v - delta) + p * dlse, so the
    lse cotangent folds into the delta argument as ``delta - dlse`` and
    the unmodified dq / dkv kernels compute exact gradients of both
    outputs.  (d lse / d v = 0, which the dkv kernel respects for free.)
    """
    do, dlse = cts
    return _flash_bwd(res, do, dlse, scale=scale, block_q=block_q,
                      block_k=block_k, grid=grid, interpret=interpret)


_attn_partial.defvjp(_attn_partial_fwd, _attn_partial_bwd)


def doc_flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    q_doc: jax.Array, q_pos: jax.Array,
    kv_doc: jax.Array, kv_pos: jax.Array,
    tables: Any,
    *,
    scale: float | None = None,
    block_q: int = da.DEFAULT_BLOCK_Q,
    block_k: int = da.DEFAULT_BLOCK_K,
    grid: str = "rect",
    interpret: bool = False,
    partial: bool = False,
) -> jax.Array:
    """Document-masked causal flash attention (Pallas TPU kernel).

    ``tables`` is a :class:`~repro.kernels.doc_attention.BlockTables` or
    the matching array tuple for ``grid``: the rectangular 4-tuple
    ``(kv_idx, kv_nvis, q_idx, q_nvis)`` for ``grid="rect"``, the
    flattened work-queue 6-tuple ``(fq_row, fq_col, fq_flags, rq_row,
    rq_col, rq_flags)`` for ``grid="flat"``.

    ``partial=True`` returns ``(o, lse)`` — the KV-subset-normalized
    output and its log-sum-exp (``-inf`` on rows with nothing visible) —
    for online-LSE merging across subsets; gradients flow through both.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if isinstance(tables, da.BlockTables):
        block_q, block_k = tables.block_q, tables.block_k
        tables = tables.flat_as_jax() if grid == "flat" else tables.as_jax()
    else:
        tables = tuple(tables)
    fn = _attn_partial if partial else _attn
    return fn(q, k, v, q_doc, q_pos, kv_doc, kv_pos, tables,
              float(scale), block_q, block_k, grid, interpret)


# ===================================================================== #
# XLA fallback path (CPU training + dry-run lowering)
# ===================================================================== #
def doc_attention_xla(
    q: jax.Array, k: jax.Array, v: jax.Array,
    q_doc: jax.Array, q_pos: jax.Array,
    kv_doc: jax.Array, kv_pos: jax.Array,
    *,
    scale: float | None = None,
    q_chunk: int = 512,
    partial: bool = False,
):
    """Chunked dense attention with the doc-mask semantics of ``ref.py``.

    Chunking over the query axis bounds the live logits tensor to
    ``(B, Hq, q_chunk, Tk)`` — the XLA analogue of flash attention's
    working-set control (full flash semantics are only needed on TPU where
    the Pallas kernel takes over).

    ``partial=True`` returns the unnormalized online-softmax triple
    ``(o, m, l)`` in f32 (``m = -1e30`` on rows with nothing visible) for
    online-LSE merging across KV subsets; differentiable by plain JAX AD.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if Tq % q_chunk != 0:
        q_chunk = Tq
    nq = Tq // q_chunk

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_chunk(args):
        qc, qdc, qpc = args
        qc = qc.astype(jnp.float32).reshape(B, Hkv, G, q_chunk, D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kf) * scale
        mask = doc_mask(qdc, qpc, kv_doc, kv_pos)
        if partial:
            s = jnp.where(mask[:, None, None], s, da.NEG)
            m = jnp.max(s, axis=-1)
            p = jnp.where(mask[:, None, None], jnp.exp(s - m[..., None]), 0.0)
            l = jnp.sum(p, axis=-1)
            o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
            return (o.reshape(B, Hq, q_chunk, D),
                    m.reshape(B, Hq, q_chunk), l.reshape(B, Hq, q_chunk))
        s = jnp.where(mask[:, None, None], s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
        o = jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)
        return o.reshape(B, Hq, q_chunk, D)

    if nq == 1:
        out = one_chunk((q, q_doc, q_pos))
        if partial:
            return out
    else:
        qs = q.reshape(B, Hq, nq, q_chunk, D).transpose(2, 0, 1, 3, 4)
        qds = q_doc.reshape(B, nq, q_chunk).transpose(1, 0, 2)
        qps = q_pos.reshape(B, nq, q_chunk).transpose(1, 0, 2)
        outs = jax.lax.map(one_chunk, (qs, qds, qps))   # (nq, B, Hq, qc, *)
        if partial:
            o, m, l = outs
            return (o.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Tq, D),
                    m.transpose(1, 2, 0, 3).reshape(B, Hq, Tq),
                    l.transpose(1, 2, 0, 3).reshape(B, Hq, Tq))
        out = outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Tq, D)
    return out.astype(q.dtype)
