from .doc_attention import (BlockTables, build_block_tables,
                            build_work_queue, flash_bwd_dkv,
                            flash_bwd_dq, flash_fwd)
from .flash_decode import (decode_reference, flash_decode,
                           flash_decode_sharded)
from .ops import doc_attention_xla, doc_flash_attention
from .ref import doc_mask, mha_reference

__all__ = ["BlockTables", "build_block_tables", "build_work_queue",
           "decode_reference",
           "flash_decode", "flash_decode_sharded", "flash_bwd_dkv",
           "flash_bwd_dq", "flash_fwd", "doc_attention_xla",
           "doc_flash_attention", "doc_mask", "mha_reference"]
