"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

The decode shapes (decode_32k, long_500k) are HBM-bound on the cache read
(§Roofline) — the fused kernel streams K/V blocks through VMEM once,
keeping the online-softmax state in registers/VMEM, and *clamps* the
block index map at the request length so blocks past the end of a shorter
request are never fetched (ragged batches pay only for what they use).

Layout: q (B, Hq, D); k, v (B, Hkv, S, D); lengths (B,) — position t
attends to cache[0..t] inclusive (the current token's KV must already be
written at position lengths[b]).  A negative length marks a request with
no visible KV (e.g. an empty CP shard): nothing is fetched and the
partial is the merge identity.  GQA: the kernel processes one KV head's
whole query group per grid cell, so each cache block is read exactly once
per KV head.

Two output modes:

* ``partial=False`` (default) — the normalized attention output
  (B, Hq, D), zeros for empty rows.
* ``partial=True`` — the merge-ready triple ``(o, m, l)``: the
  *unnormalized* fp32 accumulator (B, Hq, D), the running row max
  (B, Hq) and the running row sum (B, Hq).  Partials from disjoint KV
  subsets combine with :func:`repro.core.cp_attention.merge_partials`
  and normalize with ``finalize_partial`` — the same online-LSE
  substrate the CP training islands run on.  Under CP serving the cache
  is sequence-sharded: each rank runs the kernel on its shard (local
  length = global length minus the shard offset, clamped) and ranks
  merge with the standard LSE combine — :func:`flash_decode_sharded` is
  the single-process form, ``merge_partials_axis`` the shard_map form.

Paged variant (:func:`flash_decode_paged`): the serving engine's KV
lives in a *global block pool* — per-layer arrays of shape
(Hkv, num_blocks, block_size, D) shared by every request — and each
request owns a *block table* mapping its logical block index to a
physical pool block.  The kernel prefetches the table alongside the
lengths and resolves the physical block inside the BlockSpec index map,
so the HBM fetch pattern is identical to the dense kernel's (one block
per grid step, clamped at the request length); only the *address* is
indirected.  Shared prefix blocks (serve/prefix.py) are therefore read
straight from the pool with no gather or copy.
``paged_decode_reference`` is the XLA gather + dense-softmax oracle.

Forward-only (inference); validated against ``decode_reference`` in
interpret mode (tests/test_kernels.py, tests/test_serve.py,
tests/test_paged.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode", "flash_decode_sharded", "flash_decode_paged",
           "decode_reference", "paged_decode_reference", "gather_paged_kv"]

NEG = -1e30
DEFAULT_BLOCK_K = 256


def decode_reference(q, k, v, lengths, *, scale=None):
    """Dense jnp oracle.  q (B,Hq,D); k,v (B,Hkv,S,D); lengths (B,)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D) * scale
    s = jnp.einsum("bhgd,bhsd->bhgs", qf, k.astype(jnp.float32))
    mask = (jnp.arange(S)[None, :] <= lengths[:, None])[:, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    out = jnp.where(lengths[:, None, None, None] >= 0, out, 0.0)
    return out.reshape(B, Hq, D).astype(q.dtype)


def _decode_body(len_ref, q_ref, k_ref, v_ref, refs,
                 scale: float, block_k: int, num_blocks: int,
                 partial: bool):
    """Shared online-softmax body for the dense-cache and paged kernels
    (they differ only in how the BlockSpec index map finds the KV block;
    the visit math is identical — positions are *logical*)."""
    if partial:
        o_ref, om_ref, ol_ref, acc_ref, m_ref, l_ref = refs
    else:
        o_ref, acc_ref, m_ref, l_ref = refs
    b, h, kb = (pl.program_id(i) for i in range(3))

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(kb * block_k <= length)
    def _visit():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, 0]                               # (bk, D)
        s = jax.lax.dot_general(
            q, k.T.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bk)
        pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        valid = pos <= length                         # (1, bk)
        s = jnp.where(valid, s, NEG)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        vv = v_ref[0, 0]
        pv = jax.lax.dot_general(
            p.astype(vv.dtype), vv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == num_blocks - 1)
    def _finalize():
        if partial:
            o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)
            om_ref[0, 0] = m_ref[...]
            ol_ref[0, 0] = l_ref[...]
        else:
            l = l_ref[:, :1]
            out = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30),
                            0.0)
            o_ref[0, 0] = out.astype(o_ref.dtype)


def _decode_kernel(len_ref,                      # scalar prefetch
                   q_ref, k_ref, v_ref, *refs, scale, block_k,
                   num_blocks, partial):
    _decode_body(len_ref, q_ref, k_ref, v_ref, refs, scale, block_k,
                 num_blocks, partial)


def _decode_kernel_paged(len_ref, tab_ref,       # scalar prefetch
                         q_ref, k_ref, v_ref, *refs, scale, block_k,
                         num_blocks, partial):
    # the table is consumed by the BlockSpec index map only
    _decode_body(len_ref, q_ref, k_ref, v_ref, refs, scale, block_k,
                 num_blocks, partial)


def flash_decode(q, k, v, lengths, *, scale=None,
                 block_k: int = DEFAULT_BLOCK_K, interpret: bool = False,
                 partial: bool = False):
    """q (B, Hq, D); k, v (B, Hkv, S, D); lengths (B,).

    ``partial=False`` -> normalized output (B, Hq, D).
    ``partial=True`` -> merge-ready ``(o, m, l)``: fp32 accumulator
    (B, Hq, D), row max (B, Hq), row sum (B, Hq).
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    while S % block_k:
        block_k //= 2
    nk = S // block_k

    def kv_block(b, h, kb, len_ref):
        # clamp past-the-end blocks to the last needed block: Pallas's
        # revisiting pipeline turns the repeat into a no-op fetch.  The
        # lower clamp covers negative lengths (nothing visible on this
        # shard): the fetch lands on block 0 but _visit never fires.
        last_needed = jnp.clip(len_ref[b] // block_k, 0, nk - 1)
        return (b, h, jnp.minimum(kb, last_needed), 0)

    out_specs = [pl.BlockSpec((1, 1, G, D), lambda b, h, kb, s_: (b, h, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((B, Hkv, G, D),
                                      jnp.float32 if partial else q.dtype)]
    if partial:
        stat_spec = pl.BlockSpec((1, 1, G, 128),
                                 lambda b, h, kb, s_: (b, h, 0, 0))
        out_specs += [stat_spec, stat_spec]
        out_shape += [jax.ShapeDtypeStruct((B, Hkv, G, 128), jnp.float32)] * 2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, kb, s_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), kv_block),
            pl.BlockSpec((1, 1, block_k, D), kv_block),
        ],
        out_specs=out_specs if partial else out_specs[0],
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, scale=float(scale),
                               block_k=block_k, num_blocks=nk,
                               partial=partial)
    q4 = q.reshape(B, Hkv, G, D)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=out_shape if partial else out_shape[0],
        interpret=interpret,
    )(lengths, q4, k, v)
    if not partial:
        return out.reshape(B, Hq, D)
    o, m, l = out
    return (o.reshape(B, Hq, D), m[..., 0].reshape(B, Hq),
            l[..., 0].reshape(B, Hq))


def flash_decode_sharded(q, k, v, lengths, *, shards: int, scale=None,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False):
    """Decode attention over a sequence-sharded cache, merged on the
    online-LSE substrate.

    The cache's S axis is split into ``shards`` contiguous segments
    (shard s owns positions [s*S/N, (s+1)*S/N)); each segment runs
    :func:`flash_decode` in partial mode against its *local* length
    (global length minus the segment offset, clamped), and the partials
    fold through ``merge_partials`` + ``finalize_partial`` — bit-for-bit
    the combine a CP decode island performs across ranks, executed
    in-process.  ``shards=1`` degenerates to one partial + finalize.
    """
    from repro.core.cp_attention import finalize_partial, merge_partials

    _, _, S, _ = k.shape
    assert S % shards == 0, (S, shards)
    Sl = S // shards
    parts = []
    for s in range(shards):
        local_len = jnp.clip(lengths - s * Sl, -1, Sl - 1)
        parts.append(flash_decode(
            q, k[:, :, s * Sl:(s + 1) * Sl], v[:, :, s * Sl:(s + 1) * Sl],
            local_len, scale=scale, block_k=block_k, interpret=interpret,
            partial=True))
    return finalize_partial(merge_partials(parts), q.dtype)


# --------------------------------------------------------------------- #
# paged decode: block-table indirection into a global KV block pool
# --------------------------------------------------------------------- #
def gather_paged_kv(pool, tables, block_size: int):
    """Materialize each request's logical KV view from the pool.

    pool (Hkv, NBtok, D) with NBtok = num_blocks * block_size; tables
    (B, nk) physical block ids per logical block.  Returns
    (B, Hkv, nk * block_size, D) — the dense layout the XLA oracle and
    the prefill attention expect.  Unwritten table slots point at block
    0; their values are garbage and must be masked by position.
    """
    B, nk = tables.shape
    off = jnp.arange(block_size, dtype=jnp.int32)
    phys = (tables[:, :, None] * block_size + off[None, None, :]) \
        .reshape(B, nk * block_size)                  # (B, S_logical)
    return pool[:, phys].transpose(1, 0, 2, 3)        # (B, Hkv, S, D)


def paged_decode_reference(q, k_pool, v_pool, lengths, tables,
                           *, block_size: int, scale=None):
    """XLA gather + dense-softmax oracle for the paged kernel.

    q (B, Hq, D); k_pool/v_pool (Hkv, num_blocks * block_size, D);
    tables (B, nk) int32; lengths (B,) — logical positions, as in
    :func:`decode_reference`."""
    k = gather_paged_kv(k_pool, tables, block_size)
    v = gather_paged_kv(v_pool, tables, block_size)
    return decode_reference(q, k, v, lengths, scale=scale)


def flash_decode_paged(q, k_pool, v_pool, lengths, tables,
                       *, block_size: int, scale=None,
                       interpret: bool = False):
    """Flash decode over a paged KV pool.

    q (B, Hq, D); k_pool/v_pool (Hkv, num_blocks * block_size, D) —
    the global pool, flat on the token axis; tables (B, nk) int32 maps
    each request's logical block to its physical pool block (unwritten
    slots must hold a valid index, conventionally 0); lengths (B,)
    logical positions (negative = nothing visible, output zeros).

    Grid and visit math are identical to :func:`flash_decode` with
    ``block_k = block_size`` — the only difference is the KV BlockSpec
    index map, which resolves ``tables[b, kb]`` (clamped at the last
    needed block, as the dense kernel clamps ``kb``) so blocks past a
    request's length are never fetched and shared prefix blocks are
    fetched from their single pool-resident copy.
    """
    B, Hq, D = q.shape
    Hkv, NBtok, _ = k_pool.shape
    assert NBtok % block_size == 0, (NBtok, block_size)
    G = Hq // Hkv
    nk = tables.shape[1]
    if scale is None:
        scale = D ** -0.5
    k4 = k_pool.reshape(Hkv, NBtok // block_size, block_size, D)
    v4 = v_pool.reshape(Hkv, NBtok // block_size, block_size, D)

    def kv_block(b, h, kb, len_ref, tab_ref):
        # same past-the-end clamp as the dense kernel, then the table
        # lookup turns the logical block into a physical pool block
        last_needed = jnp.clip(len_ref[b] // block_size, 0, nk - 1)
        return (h, tab_ref[b, jnp.minimum(kb, last_needed)], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, kb, l_, t_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_size, D), kv_block),
            pl.BlockSpec((1, 1, block_size, D), kv_block),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, kb, l_, t_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel_paged, scale=float(scale),
                               block_k=block_size, num_blocks=nk,
                               partial=False)
    q4 = q.reshape(B, Hkv, G, D)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lengths, tables, q4, k4, v4)
    return out.reshape(B, Hq, D)
