"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

The decode shapes (decode_32k, long_500k) are HBM-bound on the cache read
(§Roofline) — the fused kernel streams K/V blocks through VMEM once,
keeping the online-softmax state in registers/VMEM, and *clamps* the
block index map at the request length so blocks past the end of a shorter
request are never fetched (ragged batches pay only for what they use).

Layout: q (B, Hq, D); k, v (B, Hkv, S, D); lengths (B,) — position t
attends to cache[0..t] inclusive (the current token's KV must already be
written at position lengths[b]).  GQA: the kernel processes one KV head's
whole query group per grid cell, so each cache block is read exactly once
per KV head.

Forward-only (inference); validated against ``ref.decode_reference`` in
interpret mode (tests/test_kernels.py).  Under CP serving the cache is
sequence-sharded: each rank runs this kernel on its shard and ranks merge
with the standard LSE combine (the kernel returns (out, m, l) partials).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode", "decode_reference"]

NEG = -1e30
DEFAULT_BLOCK_K = 256


def decode_reference(q, k, v, lengths, *, scale=None):
    """Dense jnp oracle.  q (B,Hq,D); k,v (B,Hkv,S,D); lengths (B,)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D) * scale
    s = jnp.einsum("bhgd,bhsd->bhgs", qf, k.astype(jnp.float32))
    mask = (jnp.arange(S)[None, :] <= lengths[:, None])[:, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


def _decode_kernel(len_ref,                      # scalar prefetch
                   q_ref, k_ref, v_ref,
                   o_ref,
                   acc_ref, m_ref, l_ref,
                   *, scale: float, block_k: int, num_blocks: int):
    b, h, kb = (pl.program_id(i) for i in range(3))

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(kb * block_k <= length)
    def _visit():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, 0]                               # (bk, D)
        s = jax.lax.dot_general(
            q, k.T.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bk)
        pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        valid = pos <= length                         # (1, bk)
        s = jnp.where(valid, s, NEG)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        vv = v_ref[0, 0]
        pv = jax.lax.dot_general(
            p.astype(vv.dtype), vv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == num_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        out = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_decode(q, k, v, lengths, *, scale=None,
                 block_k: int = DEFAULT_BLOCK_K, interpret: bool = False):
    """q (B, Hq, D); k, v (B, Hkv, S, D); lengths (B,) -> (B, Hq, D)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    while S % block_k:
        block_k //= 2
    nk = S // block_k

    def kv_block(b, h, kb, len_ref):
        # clamp past-the-end blocks to the last needed block: Pallas's
        # revisiting pipeline turns the repeat into a no-op fetch
        last_needed = len_ref[b] // block_k
        return (b, h, jnp.minimum(kb, last_needed), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, kb, s_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), kv_block),
            pl.BlockSpec((1, 1, block_k, D), kv_block),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, kb, s_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, scale=float(scale),
                               block_k=block_k, num_blocks=nk)
    q4 = q.reshape(B, Hkv, G, D)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lengths, q4, k, v)
    return out.reshape(B, Hq, D)
