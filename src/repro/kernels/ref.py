"""Pure-jnp oracle for document-masked causal flash attention.

This is the correctness reference for the Pallas kernels in
``doc_attention.py`` (validated with ``assert_allclose`` across shape/dtype
sweeps in tests/test_kernels.py) and the semantic definition of attention
throughout the framework:

    token q may attend to token k   iff   doc(q) == doc(k)
                                      and pos(q) >= pos(k)
                                      and doc(q) >= 0 and doc(k) >= 0

``doc``/``pos`` are *document ids* and *intra-document positions* — under
context parallelism the Q rows live on one CP worker while the KV columns
are the concatenation of local KV and the gathered remote prefix buffer, so
Q and KV carry independent metadata arrays.  Negative doc ids mark padding.

Shapes (GQA): q (B, Hq, Tq, D); k, v (B, Hkv, Tk, D) with Hq % Hkv == 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["doc_mask", "mha_reference"]


def doc_mask(q_doc, q_pos, kv_doc, kv_pos) -> jax.Array:
    """Boolean visibility mask of shape (..., Tq, Tk)."""
    same_doc = q_doc[..., :, None] == kv_doc[..., None, :]
    causal = q_pos[..., :, None] >= kv_pos[..., None, :]
    valid = (q_doc[..., :, None] >= 0) & (kv_doc[..., None, :] >= 0)
    return same_doc & causal & valid


def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_doc: jax.Array,
    q_pos: jax.Array,
    kv_doc: jax.Array,
    kv_pos: jax.Array,
    *,
    scale: float | None = None,
    return_lse: bool = False,
):
    """Dense reference attention.  fp32 softmax; output in q.dtype.

    Rows with no visible key (e.g. padding queries) output zeros and
    ``lse = -inf`` — the same convention the kernels implement.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Tq, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    mask = doc_mask(q_doc, q_pos, kv_doc, kv_pos)  # (B, Tq, Tk)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)

    m = jnp.max(s, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe)
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    out = jnp.where(l > 0, out / jnp.maximum(l, 1e-30), 0.0)
    out = out.reshape(B, Hq, Tq, D).astype(q.dtype)

    if not return_lse:
        return out
    lse = (m_safe + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]
    lse = jnp.where(l[..., 0] > 0, lse, -jnp.inf).reshape(B, Hq, Tq)
    return out, lse
