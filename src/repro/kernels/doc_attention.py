"""Pallas TPU kernels: document-masked causal flash attention with
block-level sparsity (the compute hot-spot of FlashCP training).

TPU adaptation of the paper's kernel-efficiency insight (§2.3, Fig. 3):
instead of CUDA varlen batching, we exploit the *structure* FlashCP's
planner creates — whole documents laid out contiguously — with
splash-attention-style **visit tables**: the host enumerates, per query
block, exactly the KV blocks that contain any visible (same-document,
causal) key, and the kernel fetches KV via scalar-prefetched ``index_map``
lookups, so *skipped blocks are never fetched from HBM, let alone
computed*.

Two grid schedules walk those tables (``grid=`` on every kernel entry):

* ``grid="rect"`` — the original rectangular launch ``(B, H, nq, V)``
  where ``V`` is the *maximum* per-row visit count.  Padded visit slots
  repeat the previous block index (a no-op refetch under Pallas's
  revisiting pipeline) and are compute-gated by the per-row counts, but
  every padded slot still costs a grid step: on imbalanced document
  mixes the longest row's ``V`` taxes all ``B * nq`` rows.
* ``grid="flat"`` — a **flattened 1D work queue**: the host emits the
  CSR-style visit list itself, one grid step per *actual* visit, with
  per-step ``(row, col)`` owner metadata and FIRST/LAST/VALID flags
  marking block-row boundaries (``build_work_queue``).  Rows are sorted
  by descending visit count (greedy LPT — long rows schedule first, so
  a core-split grid stays balanced on skewed doc mixes), each row's
  steps stay contiguous (the accumulator scratch carries one row at a
  time), and zero-visit rows get a single sentinel step that writes
  their zero output.  Padding waste is erased: total steps equal the
  visit count (plus one sentinel per empty row and a pow2 tail bucket).

Whole-doc placement ⇒ long contiguous visible ranges ⇒ few partial blocks
and maximal MXU occupancy — exactly the paper's "kernel efficiency" axis,
re-expressed for the TPU memory hierarchy (HBM→VMEM streaming + 128×128
MXU tiles).

Layout (GQA): q (B, Hq, Tq, D); k, v (B, Hkv, Tk, D); per-token metadata
``q_doc/q_pos`` (B, Tq) and ``kv_doc/kv_pos`` (B, Tk) int32; doc id < 0 is
padding.  Visibility: same doc AND q_pos >= kv_pos.

The pure-jnp oracle lives in ``ref.py``; jit'd wrappers + custom VJP in
``ops.py``.  All kernels are validated against the oracle with
``interpret=True`` sweeps in tests/test_kernels.py; flat-vs-rect parity
and queue/permutation properties live in tests/test_workqueue.py.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.table_layout import GRID_TABLE_HALF

__all__ = [
    "BlockTables",
    "build_block_tables",
    "build_work_queue",
    "flash_fwd",
    "flash_bwd_dq",
    "flash_bwd_dkv",
    "DEFAULT_BLOCK_Q",
    "DEFAULT_BLOCK_K",
    "FLAG_FIRST",
    "FLAG_LAST",
    "FLAG_VALID",
]

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG = -1e30  # finite -inf stand-in inside kernels (no nan from inf-inf)

KIND_SKIP, KIND_PARTIAL, KIND_FULL = 0, 1, 2

# work-queue step flags (build_work_queue / the grid="flat" kernels)
FLAG_FIRST = 1   # first step of its block-row: reset the accumulators
FLAG_LAST = 2    # last step of its block-row: finalize + write outputs
FLAG_VALID = 4   # a real visit (unset on empty-row sentinels / pad tail)


# ===================================================================== #
# host-side visit tables
# ===================================================================== #
@dataclasses.dataclass
class BlockTables:
    """Scalar-prefetch tables driving the sparse grid.

    Rectangular layout (``grid="rect"``):
      fwd:  for each (b, q-block): the KV blocks to visit.
      bwd:  for each (b, kv-block): the Q blocks that visit it (reverse
      map).  Padded slots repeat the last valid index (cheap revisits)
      and are gated by the ``*_nvis`` counts.

    Flattened work-queue layout (``grid="flat"``): per sample, the same
    visit sets as a CSR step list — ``fq_*`` walks q-block rows (fwd +
    dQ), ``rq_*`` kv-block rows (dKV).  ``*_row``/``*_col`` are the
    per-step owner block and visited block; ``*_flags`` carries the
    FIRST/LAST/VALID row-boundary bits.  Rows are LPT-ordered
    (descending visit count) and zero-visit rows hold one !VALID
    sentinel step so their output still gets written.  The queues are
    derived lazily from the rectangular tables on first access
    (rect-only consumers never pay the flatten cost).
    """

    kv_idx: np.ndarray    # (B, nq, Vk) int32
    kv_nvis: np.ndarray   # (B, nq)     int32
    q_idx: np.ndarray     # (B, nk, Vq) int32
    q_nvis: np.ndarray    # (B, nk)     int32
    block_q: int
    block_k: int
    # occupancy stats — the kernel-efficiency metric used by benchmarks
    visited_frac: float   # visited blocks / all blocks
    full_frac: float      # fully-visible blocks / visited blocks
    # lazily-built flattened work queues (same visit sets, 1D schedule)
    _queues: tuple = dataclasses.field(default=None, repr=False)

    def _flat(self):
        if self._queues is None:
            self._queues = (*build_work_queue(self.kv_idx, self.kv_nvis),
                            *build_work_queue(self.q_idx, self.q_nvis))
        return self._queues

    @property
    def fq_row(self):     # (B, Sf) int32 owner q block per step
        return self._flat()[0]

    @property
    def fq_col(self):     # (B, Sf) int32 visited KV block
        return self._flat()[1]

    @property
    def fq_flags(self):   # (B, Sf) int32 FIRST|LAST|VALID bits
        return self._flat()[2]

    @property
    def rq_row(self):     # (B, Sr) int32 owner KV block per step
        return self._flat()[3]

    @property
    def rq_col(self):     # (B, Sr) int32 visiting Q block
        return self._flat()[4]

    @property
    def rq_flags(self):   # (B, Sr) int32
        return self._flat()[5]

    def as_jax(self):
        return (jnp.asarray(self.kv_idx), jnp.asarray(self.kv_nvis),
                jnp.asarray(self.q_idx), jnp.asarray(self.q_nvis))

    def flat_as_jax(self):
        """The 6-tuple the ``grid="flat"`` kernels consume."""
        return tuple(jnp.asarray(a) for a in self._flat())

    def grid_steps(self) -> dict[str, int]:
        """Executed grid-step counts per (head, ) of both schedules — the
        padding-waste accounting the kernel-efficiency bench reports."""
        B, nq, Vk = self.kv_idx.shape
        _, nk, Vq = self.q_idx.shape
        return {
            "rect_fwd": B * nq * Vk,
            "rect_rev": B * nk * Vq,
            "flat_fwd": int(self.fq_row.shape[0] * self.fq_row.shape[1]),
            "flat_rev": int(self.rq_row.shape[0] * self.rq_row.shape[1]),
            "visits": int(self.kv_nvis.sum()),
        }


def _pad_lists(lists: list[list[int]], width: int) -> np.ndarray:
    out = np.zeros((len(lists), width), dtype=np.int32)
    for i, l in enumerate(lists):
        if l:
            out[i, : len(l)] = l
            out[i, len(l):] = l[-1]  # repeat-last padding => no-op refetch
    return out


def build_work_queue(idx: np.ndarray, nvis: np.ndarray, *,
                     pad_to_steps: int | None = None):
    """Flatten rectangular visit tables into the 1D work-queue schedule.

    ``idx`` (B, R, V) / ``nvis`` (B, R) are one direction of a
    :class:`BlockTables` (kv_idx/kv_nvis for the fwd+dQ queue,
    q_idx/q_nvis for the dKV reverse queue).  Returns ``(row, col,
    flags)``, each (B, S) int32, where per sample the steps are the
    row-major visit list re-ordered so rows run in descending visit
    count (greedy LPT — the longest block-rows schedule first) with each
    row's visits contiguous and in ascending block order.  Rows with
    zero visits contribute one sentinel step (``FLAG_VALID`` unset,
    FIRST|LAST set) so the kernel still zero-initializes and writes
    their output block.  Samples are padded to a common ``S`` (and to
    ``pad_to_steps`` if given) by repeating the final step with flags 0
    — a no-op refetch that never re-triggers init/finalize.
    """
    idx = np.asarray(idx, np.int32)
    nvis = np.asarray(nvis)
    B, R, V = idx.shape
    nv = nvis.astype(np.int64)
    counts = np.maximum(nv, 1)              # sentinel step for empty rows
    s_real = counts.sum(axis=1)
    S = int(s_real.max()) if B else 1
    if pad_to_steps is not None:
        assert pad_to_steps >= S, (pad_to_steps, S)
        S = pad_to_steps
    row = np.zeros((B, S), np.int32)
    col = np.zeros((B, S), np.int32)
    flags = np.zeros((B, S), np.int32)
    for b in range(B):
        order = np.argsort(-nv[b], kind="stable")
        oc = counts[b][order]
        total = int(s_real[b])
        excl = np.cumsum(oc) - oc
        owner = np.repeat(order, oc).astype(np.int64)
        offs = np.arange(total, dtype=np.int64) - np.repeat(excl, oc)
        valid = offs < nv[b][owner]
        row[b, :total] = owner
        col[b, :total] = idx[b][owner, np.minimum(offs, V - 1)]
        flags[b, :total] = (
            FLAG_FIRST * (offs == 0)
            + FLAG_LAST * (offs == counts[b][owner] - 1)
            + FLAG_VALID * valid)
        if total < S:                        # repeat-last no-op pad tail
            row[b, total:] = row[b, total - 1]
            col[b, total:] = col[b, total - 1]
    return row, col, flags


_BIG = np.int32(1 << 30)     # invalid-token sentinel in int32 summaries
_KEY = np.int64(1) << 31     # (doc, pos) composite-key stride


def _scatter_lists(rows: np.ndarray, vals: np.ndarray, nvis: np.ndarray,
                   width: int) -> np.ndarray:
    """(R, width) visit table from sorted pair lists.

    ``rows`` must be ascending; ``vals`` ascending within each row (the
    order ``np.nonzero`` / interval expansion produce).  Padded slots
    repeat the last valid index (a Pallas revisit no-op fetch); empty rows
    are zeros — identical layout to the legacy list-of-lists builder.
    """
    R = nvis.shape[0]
    starts = np.zeros(R + 1, np.int64)
    np.cumsum(nvis, out=starts[1:])
    slot = np.arange(vals.shape[0], dtype=np.int64) - starts[rows]
    idx = np.zeros((R, width), np.int32)
    idx[rows, slot] = vals
    pad = np.arange(width, dtype=np.int32)[None, :] >= nvis[:, None]
    last = idx[np.arange(R), np.maximum(nvis - 1, 0)]
    np.copyto(idx, np.broadcast_to(last[:, None], idx.shape), where=pad)
    return idx


def _summ32(doc: np.ndarray, pos: np.ndarray, blk: int):
    """Per-block int32 summaries: (dmin, dmax, pmin, pmax, all_valid,
    single_doc).  Empty blocks encode as dmin=BIG / dmax=-1, which makes
    the any-valid guards of the pair classification implicit."""
    d = doc.reshape(doc.shape[0], -1, blk)
    p = pos.reshape(pos.shape[0], -1, blk)
    valid = d >= 0
    dmin = np.where(valid, d, _BIG).min(-1).astype(np.int32)
    dmax = np.where(valid, d, -1).max(-1).astype(np.int32)
    pmin = np.where(valid, p, _BIG).min(-1).astype(np.int32)
    pmax = np.where(valid, p, -1).max(-1).astype(np.int32)
    return dmin, dmax, pmin, pmax, valid.all(-1), dmin == dmax


def _detect_segments(kdmin, kdmax, kpmin, kpmax, ksingle) -> np.ndarray:
    """Cut points splitting one row's KV blocks into runs whose summaries
    are (doc, pos)-monotone — the property the interval path needs.  A
    fully plan-ordered row is one segment; a FlashCP concat layout
    ``[local | gathered buffers]`` autosplits at each buffer boundary."""
    nonempty = kdmax >= 0
    edmin = np.where(nonempty, kdmin, _BIG)
    edmax = np.where(nonempty, kdmax, _BIG)
    brk = edmin[1:] < edmax[:-1]
    same = ksingle[1:] & ksingle[:-1] & (kdmin[1:] == kdmin[:-1])
    brk |= same & ((kpmin[1:] < kpmin[:-1]) | (kpmax[1:] < kpmax[:-1]))
    return np.flatnonzero(brk) + 1


def _pairs_dense(qs, ks):
    """O(nq*nk) classification of one row -> (visited pairs, full count).

    The seed's boolean logic with the validity guards folded into int32
    sentinel summaries (empty blocks can never satisfy the overlap test)."""
    qdmin, qdmax, qpmin, qpmax, q_all, qsing = qs
    kdmin, kdmax, kpmin, kpmax, k_all, ksing = ks
    vis = qdmax[:, None] >= kdmin[None, :]
    vis &= kdmax[None, :] >= qdmin[:, None]
    qd_s = np.where(qsing, qdmin, np.int32(-3))
    kd_s = np.where(ksing, kdmin, np.int32(-4))
    sd = qd_s[:, None] == kd_s[None, :]
    anti = sd & (qpmax[:, None] < kpmin[None, :])
    np.logical_not(anti, out=anti)
    vis &= anti
    qpf = np.where(q_all, qpmin, np.int32(-1))
    kpf = np.where(k_all, kpmax, _BIG)
    full = qpf[:, None] >= kpf[None, :]
    full &= sd
    full &= vis
    qrows, cols = np.nonzero(vis)
    nvis = np.count_nonzero(vis, axis=-1).astype(np.int32)
    return qrows, cols.astype(np.int32), nvis, int(full.sum())


def _pairs_intervals(qs, ks, cuts, nk):
    """Sorted-segment classification of one row in O((nq + pairs) log nk).

    Within a monotone KV segment the visited set of a q block is an index
    interval [lo, hi) (binary search on the doc summaries) minus an
    anti-causal *suffix* of its own doc's single-block run — at most two
    intervals per (q block, segment), expanded to pair lists with the
    same repeat/cumsum construction the plan encoder uses.  Exactly
    reproduces the dense classification (same summaries, same rules).
    """
    qdmin, qdmax, qpmin, qpmax, q_all, qsing = qs
    kdmin, kdmax, kpmin, kpmax, k_all, ksing = ks
    nq = qdmin.shape[0]
    nonempty = kdmax >= 0
    edmin = np.where(nonempty, kdmin, _BIG)
    edmax = np.where(nonempty, kdmax, _BIG)
    qd64 = qdmin.astype(np.int64) * _KEY
    bounds = [0, *cuts.tolist(), nk]
    S = len(bounds) - 1
    starts = np.zeros((nq, S, 2), np.int64)
    lens = np.zeros((nq, S, 2), np.int64)
    n_full = 0
    for si in range(S):
        s, e = bounds[si], bounds[si + 1]
        lo = s + np.searchsorted(edmax[s:e], qdmin)
        hi = s + np.searchsorted(edmin[s:e], qdmax, side="right")
        hi = np.maximum(hi, lo)
        # anti-causal suffix of the q-doc's single-block run
        sidx = s + np.flatnonzero(ksing[s:e])
        anti_lo = anti_hi = hi
        if sidx.size:
            skey = kdmin[sidx].astype(np.int64) * _KEY + kpmin[sidx]
            r1 = np.searchsorted(skey, qd64 + (_KEY - 1), side="right")
            cnt = r1 - np.searchsorted(skey, qd64 + qpmax, side="right")
            cnt = np.where(qsing & (r1 > 0), cnt, 0)
            last = sidx[np.maximum(r1 - 1, 0)]
            anti_hi = np.where(cnt > 0, last + 1, hi)
            anti_lo = anti_hi - np.where(cnt > 0, cnt, 0)
            fidx = sidx[k_all[sidx]]
            if fidx.size:
                fkey = kdmin[fidx].astype(np.int64) * _KEY + kpmax[fidx]
                nf = (np.searchsorted(fkey, qd64 + qpmin, side="right")
                      - np.searchsorted(fkey, qd64))
                n_full += int(nf[qsing & q_all].sum())
        starts[:, si, 0] = lo
        lens[:, si, 0] = np.maximum(anti_lo - lo, 0)
        starts[:, si, 1] = anti_hi
        lens[:, si, 1] = np.maximum(hi - anti_hi, 0)
    flat_lens = lens.reshape(-1)
    flat_starts = starts.reshape(-1)
    total = int(flat_lens.sum())
    ar = np.arange(total, dtype=np.int64)
    excl = np.cumsum(flat_lens) - flat_lens
    cols = (ar + np.repeat(flat_starts - excl, flat_lens)).astype(np.int32)
    nvis = lens.sum((1, 2)).astype(np.int32)
    qrows = np.repeat(np.arange(nq, dtype=np.int64), nvis)
    return qrows, cols, nvis, n_full


def build_block_tables(
    q_doc: np.ndarray,
    q_pos: np.ndarray,
    kv_doc: np.ndarray,
    kv_pos: np.ndarray,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    legacy: bool = False,
) -> BlockTables:
    """Classify every (q-block, kv-block) pair as skip / partial / full.

    Sound conservatism: a pair is *skipped* only when provably no element
    is visible; *full* only when provably all elements are visible (the
    kernel then pays no masking).  Anything uncertain is partial.
    Within a block, FlashCP's executor lays tokens out sorted by
    (doc, pos), which makes the min/max summaries tight.

    The visit lists are built by pure-numpy sort/cumsum construction:
    plan-ordered (doc, pos)-monotone KV segments resolve each q block's
    visits to at most two index intervals per segment via binary search
    on the block summaries (cost scales with the number of *visited*
    pairs), with a dense sentinel-folded classification as the fallback
    for arbitrary layouts.  ``legacy=True`` selects the original
    O(nq x nk) Python list-of-lists construction, kept only as the
    parity/benchmark baseline.
    """
    q_doc = np.asarray(q_doc); q_pos = np.asarray(q_pos)
    kv_doc = np.asarray(kv_doc); kv_pos = np.asarray(kv_pos)
    B, Tq = q_doc.shape
    _, Tk = kv_doc.shape
    assert Tq % block_q == 0 and Tk % block_k == 0, (Tq, block_q, Tk, block_k)
    nq, nk = Tq // block_q, Tk // block_k

    if legacy:
        return _build_block_tables_legacy(q_doc, q_pos, kv_doc, kv_pos,
                                          block_q=block_q, block_k=block_k)

    qsum = _summ32(q_doc, q_pos, block_q)
    ksum = _summ32(kv_doc, kv_pos, block_k)

    per_row = []
    n_visited = n_full = 0
    for b in range(B):
        qs = tuple(a[b] for a in qsum)
        ks = tuple(a[b] for a in ksum)
        cuts = _detect_segments(ks[0], ks[1], ks[2], ks[3], ks[5])
        if cuts.size + 1 > max(8, nk // 8):
            qrows, cols, nvis, nf = _pairs_dense(qs, ks)
        else:
            qrows, cols, nvis, nf = _pairs_intervals(qs, ks, cuts, nk)
        per_row.append((qrows, cols, nvis))
        n_visited += int(nvis.sum())
        n_full += nf

    Vk = max(1, max(int(r[2].max()) if r[2].size else 0 for r in per_row))
    rev_nvis = [np.bincount(cols, minlength=nk).astype(np.int32)
                for _, cols, _ in per_row]
    Vq = max(1, max(int(n.max()) if n.size else 0 for n in rev_nvis))

    kv_idx = np.zeros((B, nq, Vk), np.int32)
    kv_nvis = np.zeros((B, nq), np.int32)
    q_idx = np.zeros((B, nk, Vq), np.int32)
    q_nvis = np.zeros((B, nk), np.int32)
    for b, (qrows, cols, nvis) in enumerate(per_row):
        kv_idx[b] = _scatter_lists(qrows, cols, nvis, Vk)
        kv_nvis[b] = nvis
        order = np.lexsort((qrows, cols))      # by kv block, q ascending
        q_idx[b] = _scatter_lists(cols[order], qrows[order].astype(np.int32),
                                  rev_nvis[b], Vq)
        q_nvis[b] = rev_nvis[b]

    return BlockTables(
        kv_idx=kv_idx, kv_nvis=kv_nvis, q_idx=q_idx, q_nvis=q_nvis,
        block_q=block_q, block_k=block_k,
        visited_frac=n_visited / max(B * nq * nk, 1),
        full_frac=n_full / max(n_visited, 1),
    )


def _build_block_tables_legacy(q_doc, q_pos, kv_doc, kv_pos, *, block_q,
                               block_k) -> BlockTables:
    """The seed implementation, frozen as the parity/benchmark baseline."""
    B, Tq = q_doc.shape
    _, Tk = kv_doc.shape
    nq, nk = Tq // block_q, Tk // block_k

    def summarize(doc, pos, blk):
        d = doc.reshape(B, -1, blk)
        p = pos.reshape(B, -1, blk)
        valid = d >= 0
        big = np.int64(1 << 40)
        dmin = np.where(valid, d, big).min(-1)
        dmax = np.where(valid, d, -1).max(-1)
        pmin = np.where(valid, p, big).min(-1)
        pmax = np.where(valid, p, -1).max(-1)
        any_valid = valid.any(-1)
        all_valid = valid.all(-1)
        return dmin, dmax, pmin, pmax, any_valid, all_valid

    qdmin, qdmax, qpmin, qpmax, q_any, q_all = summarize(q_doc, q_pos, block_q)
    kdmin, kdmax, kpmin, kpmax, k_any, k_all = summarize(kv_doc, kv_pos, block_k)

    # broadcast to (B, nq, nk)
    def bq_(x):
        return x[:, :, None]

    def bk_(x):
        return x[:, None, :]

    overlap = (bq_(qdmax) >= bk_(kdmin)) & (bk_(kdmax) >= bq_(qdmin))
    single_doc = (bq_(qdmin) == bq_(qdmax)) & (bk_(kdmin) == bk_(kdmax)) \
        & (bq_(qdmin) == bk_(kdmin))
    # single shared doc and strictly anti-causal -> nothing visible
    anti = single_doc & (bq_(qpmax) < bk_(kpmin))
    visited = overlap & ~anti & bq_(q_any) & bk_(k_any)
    full = single_doc & (bq_(qpmin) >= bk_(kpmax)) & bq_(q_all) & bk_(k_all)
    full &= visited

    kinds = np.where(visited, np.where(full, KIND_FULL, KIND_PARTIAL),
                     KIND_SKIP).astype(np.int32)

    kv_lists = [[int(k) for k in np.nonzero(kinds[b, qi])[0]]
                for b in range(B) for qi in range(nq)]
    q_lists = [[int(q) for q in np.nonzero(kinds[b, :, ki])[0]]
               for b in range(B) for ki in range(nk)]
    Vk = max(1, max((len(l) for l in kv_lists), default=0))
    Vq = max(1, max((len(l) for l in q_lists), default=0))

    kv_idx = _pad_lists(kv_lists, Vk).reshape(B, nq, Vk)
    kv_nvis = np.array([len(l) for l in kv_lists], np.int32).reshape(B, nq)
    q_idx = _pad_lists(q_lists, Vq).reshape(B, nk, Vq)
    q_nvis = np.array([len(l) for l in q_lists], np.int32).reshape(B, nk)

    n_visited = int((kinds != KIND_SKIP).sum())
    n_full = int((kinds == KIND_FULL).sum())
    return BlockTables(
        kv_idx=kv_idx, kv_nvis=kv_nvis, q_idx=q_idx, q_nvis=q_nvis,
        block_q=block_q, block_k=block_k,
        visited_frac=n_visited / max(kinds.size, 1),
        full_frac=n_full / max(n_visited, 1),
    )


# ===================================================================== #
# shared kernel helpers
# ===================================================================== #
def _visible(qd_ref, qp_ref, kd_ref, kp_ref):
    qd = qd_ref[0, :][:, None]
    qp = qp_ref[0, :][:, None]
    kd = kd_ref[0, :][None, :]
    kp = kp_ref[0, :][None, :]
    return (qd == kd) & (qp >= kp) & (qd >= 0) & (kd >= 0)


def _dot_f32(a, b):
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


# ===================================================================== #
# forward kernel: shared row bodies + the two grid schedules
# ===================================================================== #
def _fwd_init(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG)
    l_ref[...] = jnp.zeros_like(l_ref)


def _fwd_visit(q_ref, k_ref, v_ref, qd_ref, qp_ref, kd_ref, kp_ref,
               acc_ref, m_ref, l_ref, scale):
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0]
    s = _dot_f32(q, k.T.astype(jnp.float32)) * scale          # (bq, bk) f32
    vis = _visible(qd_ref, qp_ref, kd_ref, kp_ref)
    s = jnp.where(vis, s, NEG)

    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)                            # NEG-NEG -> 1
    p = jnp.where(vis, jnp.exp(s - m_new), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    vv = v_ref[0, 0]
    pv = _dot_f32(p.astype(vv.dtype), vv)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)


def _fwd_finalize(out_ref, lse_ref, acc_ref, m_ref, l_ref):
    l = l_ref[:, :1]
    m = m_ref[:, :1]
    out = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0)
    out_ref[0, 0] = out.astype(out_ref.dtype)
    lse = jnp.where(l[:, 0] > 0,
                    m[:, 0] + jnp.log(jnp.maximum(l[:, 0], 1e-30)),
                    -jnp.inf)
    lse_ref[0, 0] = lse


def _fwd_kernel(kv_idx_ref, kv_nvis_ref,           # scalar prefetch
                q_ref, k_ref, v_ref,
                qd_ref, qp_ref, kd_ref, kp_ref,    # metadata tiles
                out_ref, lse_ref,                  # outputs
                acc_ref, m_ref, l_ref,             # VMEM scratch
                *, scale: float, num_visits: int):
    b, _, qi, vi = (pl.program_id(i) for i in range(4))

    @pl.when(vi == 0)
    def _init():
        _fwd_init(acc_ref, m_ref, l_ref)

    @pl.when(vi < kv_nvis_ref[b, qi])
    def _visit():
        _fwd_visit(q_ref, k_ref, v_ref, qd_ref, qp_ref, kd_ref, kp_ref,
                   acc_ref, m_ref, l_ref, scale)

    @pl.when(vi == num_visits - 1)
    def _finalize():
        _fwd_finalize(out_ref, lse_ref, acc_ref, m_ref, l_ref)


def _fwd_kernel_flat(row_ref, col_ref, flags_ref,  # scalar prefetch (B, S)
                     q_ref, k_ref, v_ref,
                     qd_ref, qp_ref, kd_ref, kp_ref,
                     out_ref, lse_ref,
                     acc_ref, m_ref, l_ref,
                     *, scale: float):
    """Work-queue schedule: one grid step per actual visit.  Row
    boundaries arrive as prefetched FIRST/LAST flags instead of the
    rectangular grid's ``vi == 0`` / ``vi == V-1`` positions; sentinel
    and pad steps clear VALID so they fetch (a repeat) but never
    compute."""
    b, _, s = (pl.program_id(i) for i in range(3))
    flags = flags_ref[b, s]

    @pl.when((flags & FLAG_FIRST) != 0)
    def _init():
        _fwd_init(acc_ref, m_ref, l_ref)

    @pl.when((flags & FLAG_VALID) != 0)
    def _visit():
        _fwd_visit(q_ref, k_ref, v_ref, qd_ref, qp_ref, kd_ref, kp_ref,
                   acc_ref, m_ref, l_ref, scale)

    @pl.when((flags & FLAG_LAST) != 0)
    def _finalize():
        _fwd_finalize(out_ref, lse_ref, acc_ref, m_ref, l_ref)


def _check_grid(grid: str, tables) -> tuple:
    tables = tuple(tables)
    want = GRID_TABLE_HALF.get(grid)
    if want is None:
        raise ValueError(f"unknown kernel grid {grid!r}")
    if len(tables) != want:
        raise ValueError(
            f"grid={grid!r} kernels take {want} table arrays, got "
            f"{len(tables)}")
    return tables


def flash_fwd(q, k, v, q_doc, q_pos, kv_doc, kv_pos,
              tables, *,
              scale: float, block_q: int = DEFAULT_BLOCK_Q,
              block_k: int = DEFAULT_BLOCK_K, grid: str = "rect",
              interpret: bool = False):
    """Forward pass.  ``tables`` is ``(kv_idx, kv_nvis)`` for
    ``grid="rect"``, ``(fq_row, fq_col, fq_flags)`` for ``grid="flat"``.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    nq = Tq // block_q
    tables = _check_grid(grid, tables)

    if grid == "flat":
        row_t, col_t, flags_t = tables
        S = row_t.shape[-1]

        def q_map(b, h, s, row, col, flags):
            return (b, h, row[b, s], 0)

        def kv_map(b, h, s, row, col, flags):
            return (b, h // group, col[b, s], 0)

        def q_meta(b, h, s, row, col, flags):
            return (b, row[b, s])

        def kv_meta(b, h, s, row, col, flags):
            return (b, col[b, s])

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, Hq, S),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D), q_map),
                pl.BlockSpec((1, 1, block_k, D), kv_map),
                pl.BlockSpec((1, 1, block_k, D), kv_map),
                pl.BlockSpec((1, block_q), q_meta),
                pl.BlockSpec((1, block_q), q_meta),
                pl.BlockSpec((1, block_k), kv_meta),
                pl.BlockSpec((1, block_k), kv_meta),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, block_q, D), q_map),
                pl.BlockSpec((1, 1, block_q),
                             lambda b, h, s, row, col, flags:
                             (b, h, row[b, s])),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_q, D), jnp.float32),
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.VMEM((block_q, 128), jnp.float32),
            ],
        )
        kernel = functools.partial(_fwd_kernel_flat, scale=scale)
        inputs = (row_t, col_t, flags_t)
    else:
        kv_idx, kv_nvis = tables
        V = kv_idx.shape[-1]

        def kv_block(b, h, qi, vi, kv_idx, kv_nvis):
            return (b, h // group, kv_idx[b, qi, vi], 0)

        def kv_meta(b, h, qi, vi, kv_idx, kv_nvis):
            return (b, kv_idx[b, qi, vi])

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hq, nq, V),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, vi, *s: (b, h, qi, 0)),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, block_q), lambda b, h, qi, vi, *s: (b, qi)),
                pl.BlockSpec((1, block_q), lambda b, h, qi, vi, *s: (b, qi)),
                pl.BlockSpec((1, block_k), kv_meta),
                pl.BlockSpec((1, block_k), kv_meta),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, vi, *s: (b, h, qi, 0)),
                pl.BlockSpec((1, 1, block_q), lambda b, h, qi, vi, *s: (b, h, qi)),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_q, D), jnp.float32),
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.VMEM((block_q, 128), jnp.float32),
            ],
        )
        kernel = functools.partial(_fwd_kernel, scale=scale, num_visits=V)
        inputs = (kv_idx, kv_nvis)
    out, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Tq, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Tq), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs, q, k, v, q_doc, q_pos, kv_doc, kv_pos)
    return out, lse


# ===================================================================== #
# backward: dQ  (q-block rows; rect grid over visits or flat work queue)
# ===================================================================== #
def _dq_visit(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
              qd_ref, qp_ref, kd_ref, kp_ref, dq_acc, scale):
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, None]                      # (bq, 1)
    lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)
    delta = dl_ref[0, 0][:, None]

    s = _dot_f32(q, k.T.astype(jnp.float32)) * scale
    vis = _visible(qd_ref, qp_ref, kd_ref, kp_ref)
    p = jnp.where(vis, jnp.exp(s - lse_safe), 0.0)
    dp = _dot_f32(do, v.T.astype(jnp.float32))
    ds = p * (dp - delta) * scale
    dq_acc[...] += _dot_f32(ds.astype(k.dtype), k)


def _dq_kernel(kv_idx_ref, kv_nvis_ref,
               q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
               qd_ref, qp_ref, kd_ref, kp_ref,
               dq_ref,
               dq_acc,
               *, scale: float, num_visits: int):
    b, _, qi, vi = (pl.program_id(i) for i in range(4))

    @pl.when(vi == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    @pl.when(vi < kv_nvis_ref[b, qi])
    def _visit():
        _dq_visit(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                  qd_ref, qp_ref, kd_ref, kp_ref, dq_acc, scale)

    @pl.when(vi == num_visits - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _dq_kernel_flat(row_ref, col_ref, flags_ref,
                    q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                    qd_ref, qp_ref, kd_ref, kp_ref,
                    dq_ref,
                    dq_acc,
                    *, scale: float):
    b, _, s = (pl.program_id(i) for i in range(3))
    flags = flags_ref[b, s]

    @pl.when((flags & FLAG_FIRST) != 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    @pl.when((flags & FLAG_VALID) != 0)
    def _visit():
        _dq_visit(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                  qd_ref, qp_ref, kd_ref, kp_ref, dq_acc, scale)

    @pl.when((flags & FLAG_LAST) != 0)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def flash_bwd_dq(q, k, v, do, lse, delta, q_doc, q_pos, kv_doc, kv_pos,
                 tables, *, scale: float,
                 block_q: int = DEFAULT_BLOCK_Q,
                 block_k: int = DEFAULT_BLOCK_K, grid: str = "rect",
                 interpret: bool = False):
    """dQ pass; ``tables`` as in :func:`flash_fwd` (the same q-block
    work queue drives both)."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    nq = Tq // block_q
    tables = _check_grid(grid, tables)

    if grid == "flat":
        row_t, col_t, flags_t = tables
        S = row_t.shape[-1]

        def q_map(b, h, s, row, col, flags):
            return (b, h, row[b, s], 0)

        def q_vec(b, h, s, row, col, flags):
            return (b, h, row[b, s])

        def kv_map(b, h, s, row, col, flags):
            return (b, h // group, col[b, s], 0)

        def q_meta(b, h, s, row, col, flags):
            return (b, row[b, s])

        def kv_meta(b, h, s, row, col, flags):
            return (b, col[b, s])

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, Hq, S),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D), q_map),
                pl.BlockSpec((1, 1, block_k, D), kv_map),
                pl.BlockSpec((1, 1, block_k, D), kv_map),
                pl.BlockSpec((1, 1, block_q, D), q_map),
                pl.BlockSpec((1, 1, block_q), q_vec),
                pl.BlockSpec((1, 1, block_q), q_vec),
                pl.BlockSpec((1, block_q), q_meta),
                pl.BlockSpec((1, block_q), q_meta),
                pl.BlockSpec((1, block_k), kv_meta),
                pl.BlockSpec((1, block_k), kv_meta),
            ],
            out_specs=[pl.BlockSpec((1, 1, block_q, D), q_map)],
            scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        )
        kernel = functools.partial(_dq_kernel_flat, scale=scale)
        inputs = (row_t, col_t, flags_t)
    else:
        kv_idx, kv_nvis = tables
        V = kv_idx.shape[-1]

        def kv_block(b, h, qi, vi, kv_idx, kv_nvis):
            return (b, h // group, kv_idx[b, qi, vi], 0)

        def kv_meta(b, h, qi, vi, kv_idx, kv_nvis):
            return (b, kv_idx[b, qi, vi])

        def q_block(b, h, qi, vi, *s):
            return (b, h, qi, 0)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hq, nq, V),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D), q_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_q, D), q_block),
                pl.BlockSpec((1, 1, block_q), lambda b, h, qi, vi, *s: (b, h, qi)),
                pl.BlockSpec((1, 1, block_q), lambda b, h, qi, vi, *s: (b, h, qi)),
                pl.BlockSpec((1, block_q), lambda b, h, qi, vi, *s: (b, qi)),
                pl.BlockSpec((1, block_q), lambda b, h, qi, vi, *s: (b, qi)),
                pl.BlockSpec((1, block_k), kv_meta),
                pl.BlockSpec((1, block_k), kv_meta),
            ],
            out_specs=[pl.BlockSpec((1, 1, block_q, D), q_block)],
            scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        )
        kernel = functools.partial(_dq_kernel, scale=scale, num_visits=V)
        inputs = (kv_idx, kv_nvis)
    (dq,) = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Tq, D), q.dtype)],
        interpret=interpret,
    )(*inputs, q, k, v, do, lse, delta, q_doc, q_pos, kv_doc, kv_pos)
    return dq


# ===================================================================== #
# backward: dK, dV  (kv-block rows x GQA group; rect grid or flat queue)
# ===================================================================== #
def _dkv_visit(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
               qd_ref, qp_ref, kd_ref, kp_ref, dk_acc, dv_acc, scale):
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, None]
    lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)
    delta = dl_ref[0, 0][:, None]

    s = _dot_f32(q, k.T.astype(jnp.float32)) * scale    # (bq, bk)
    vis = _visible(qd_ref, qp_ref, kd_ref, kp_ref)
    p = jnp.where(vis, jnp.exp(s - lse_safe), 0.0)
    dv_acc[...] += _dot_f32(p.T.astype(do.dtype), do)
    dp = _dot_f32(do, v.T.astype(jnp.float32))
    ds = p * (dp - delta) * scale
    dk_acc[...] += _dot_f32(ds.T.astype(q.dtype), q)


def _dkv_kernel(q_idx_ref, q_nvis_ref,
                q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                qd_ref, qp_ref, kd_ref, kp_ref,
                dk_ref, dv_ref,
                dk_acc, dv_acc,
                *, scale: float, num_visits: int, group: int):
    b, _, ki, vqi, gi = (pl.program_id(i) for i in range(5))

    @pl.when((vqi == 0) & (gi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(vqi < q_nvis_ref[b, ki])
    def _visit():
        _dkv_visit(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                   qd_ref, qp_ref, kd_ref, kp_ref, dk_acc, dv_acc, scale)

    @pl.when((vqi == num_visits - 1) & (gi == group - 1))
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _dkv_kernel_flat(row_ref, col_ref, flags_ref,
                     q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                     qd_ref, qp_ref, kd_ref, kp_ref,
                     dk_ref, dv_ref,
                     dk_acc, dv_acc,
                     *, scale: float, group: int):
    b, _, s, gi = (pl.program_id(i) for i in range(4))
    flags = flags_ref[b, s]

    @pl.when(((flags & FLAG_FIRST) != 0) & (gi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when((flags & FLAG_VALID) != 0)
    def _visit():
        _dkv_visit(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                   qd_ref, qp_ref, kd_ref, kp_ref, dk_acc, dv_acc, scale)

    @pl.when(((flags & FLAG_LAST) != 0) & (gi == group - 1))
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_bwd_dkv(q, k, v, do, lse, delta, q_doc, q_pos, kv_doc, kv_pos,
                  tables, *, scale: float,
                  block_q: int = DEFAULT_BLOCK_Q,
                  block_k: int = DEFAULT_BLOCK_K, grid: str = "rect",
                  interpret: bool = False):
    """dK/dV pass.  ``tables`` is the *reverse* map: ``(q_idx, q_nvis)``
    for ``grid="rect"``, ``(rq_row, rq_col, rq_flags)`` for
    ``grid="flat"`` (rows are KV blocks, cols the visiting Q blocks)."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    nk = Tk // block_k
    tables = _check_grid(grid, tables)

    if grid == "flat":
        row_t, col_t, flags_t = tables
        S = row_t.shape[-1]

        def q_block(b, hkv, s, gi, row, col, flags):
            return (b, hkv * group + gi, col[b, s], 0)

        def q_vec(b, hkv, s, gi, row, col, flags):
            return (b, hkv * group + gi, col[b, s])

        def q_meta(b, hkv, s, gi, row, col, flags):
            return (b, col[b, s])

        def kv_block(b, hkv, s, gi, row, col, flags):
            return (b, hkv, row[b, s], 0)

        def kv_meta(b, hkv, s, gi, row, col, flags):
            return (b, row[b, s])

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, Hkv, S, group),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D), q_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_q, D), q_block),
                pl.BlockSpec((1, 1, block_q), q_vec),
                pl.BlockSpec((1, 1, block_q), q_vec),
                pl.BlockSpec((1, block_q), q_meta),
                pl.BlockSpec((1, block_q), q_meta),
                pl.BlockSpec((1, block_k), kv_meta),
                pl.BlockSpec((1, block_k), kv_meta),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_k, D), jnp.float32),
                pltpu.VMEM((block_k, D), jnp.float32),
            ],
        )
        kernel = functools.partial(_dkv_kernel_flat, scale=scale,
                                   group=group)
        inputs = (row_t, col_t, flags_t)
    else:
        q_idx, q_nvis = tables
        Vq = q_idx.shape[-1]

        def q_block(b, hkv, ki, vqi, gi, q_idx, q_nvis):
            return (b, hkv * group + gi, q_idx[b, ki, vqi], 0)

        def q_vec(b, hkv, ki, vqi, gi, q_idx, q_nvis):
            return (b, hkv * group + gi, q_idx[b, ki, vqi])

        def q_meta(b, hkv, ki, vqi, gi, q_idx, q_nvis):
            return (b, q_idx[b, ki, vqi])

        def kv_block(b, hkv, ki, vqi, gi, *s):
            return (b, hkv, ki, 0)

        def kv_meta(b, hkv, ki, vqi, gi, *s):
            return (b, ki)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hkv, nk, Vq, group),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D), q_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_q, D), q_block),
                pl.BlockSpec((1, 1, block_q), q_vec),
                pl.BlockSpec((1, 1, block_q), q_vec),
                pl.BlockSpec((1, block_q), q_meta),
                pl.BlockSpec((1, block_q), q_meta),
                pl.BlockSpec((1, block_k), kv_meta),
                pl.BlockSpec((1, block_k), kv_meta),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, block_k, D), kv_block),
                pl.BlockSpec((1, 1, block_k, D), kv_block),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_k, D), jnp.float32),
                pltpu.VMEM((block_k, D), jnp.float32),
            ],
        )
        kernel = functools.partial(_dkv_kernel, scale=scale, num_visits=Vq,
                                   group=group)
        inputs = (q_idx, q_nvis)
    dk, dv = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, Tk, D), k.dtype),
            jax.ShapeDtypeStruct((B, Hkv, Tk, D), v.dtype),
        ],
        interpret=interpret,
    )(*inputs, q, k, v, do, lse, delta, q_doc, q_pos, kv_doc, kv_pos)
    return dk, dv
