"""Parallel multi-sequence planning.

``make_batch`` plans ``batch_per_host`` packed sequences per step; with the
vectorized planner each plan is numpy-dominated and releases the GIL for
most of its runtime, so a small thread pool overlaps them nearly linearly.
The pool is deliberately thread- (not process-) based: plans are built
from shared ``PlanCache`` state and the arrays never need pickling.
"""

from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

__all__ = ["PlannerPool", "get_pool", "plan_many"]


class PlannerPool:
    """Thin ThreadPoolExecutor wrapper that preserves input order."""

    def __init__(self, max_workers: int):
        self.max_workers = max(int(max_workers), 1)
        self._ex = ThreadPoolExecutor(
            max_workers=self.max_workers,
            thread_name_prefix="repro-planner") if self.max_workers > 1 \
            else None

    def map(self, fn: Callable, items: Sequence) -> list:
        if self._ex is None or len(items) <= 1:
            return [fn(x) for x in items]
        return list(self._ex.map(fn, items))

    def close(self) -> None:
        if self._ex is not None:
            self._ex.shutdown(wait=False)
            self._ex = None

    def __del__(self):  # pragma: no cover - best-effort cleanup
        try:
            self.close()
        except Exception:
            pass


_POOLS: dict[int, PlannerPool] = {}


def get_pool(max_workers: int) -> PlannerPool:
    """Shared per-process pool (one per worker count)."""
    max_workers = max(int(max_workers), 1)
    pool = _POOLS.get(max_workers)
    if pool is None or pool._ex is None and pool.max_workers > 1:
        pool = _POOLS[max_workers] = PlannerPool(max_workers)
    return pool


def default_workers(batch: int) -> int:
    """Pool width for one host batch: no wider than the batch, capped by
    the host's CPU count (minus one for the training loop)."""
    cpus = os.cpu_count() or 1
    return max(1, min(int(batch), cpus - 1))


def plan_many(plan_fn: Callable, lens_list: Sequence, *,
              workers: int = 0) -> list:
    """Plan every length mix in ``lens_list``; ``workers=0`` auto-sizes."""
    if workers <= 0:
        workers = default_workers(len(lens_list))
    return get_pool(workers).map(plan_fn, lens_list)
