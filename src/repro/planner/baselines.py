"""Baseline CP sharding plans (paper §4.1): Llama3 CP, Per-Doc CP, Ring-Attn.

All baselines are expressed as :class:`~repro.planner.plan.ShardingPlan`s
over the *same* substrate as FlashCP so that the paper's comparisons
(Fig. 5/6/7) run on identical machinery; only the plan and the
communication style differ.

* ``llama3_plan``   — Per-Seq sharding: the packed sequence is split into
  2N equal chunks regardless of document boundaries (zigzag pairing i and
  2N-1-i, Fig. 1(b)); full-KV all-gather (Eq. 4).  Workload-imbalanced under
  document masking.
* ``per_doc_plan``  — every document is zigzag-split into 2N chunks
  (WLB-LLM); balanced but kernel-inefficient; full-KV all-gather (Eq. 4).
* ``ring_zigzag_plan`` — same shard layout as Per-Doc, but KV travels by
  P2P ring (``comm_style='ring'``).

All constructors are vectorized: a plan over thousands of shards is built
from a handful of numpy ops (segment intersection for the chunked schemes,
a (n_docs, 2N) size matrix for Per-Doc zigzag) — no per-shard Python loop.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .plan import ShardArrays, ShardingPlan, validate_plan
from .registry import register_planner

__all__ = ["llama3_plan", "per_doc_plan", "ring_zigzag_plan",
           "contiguous_plan", "BASELINE_PLANNERS"]


def _chunked_plan_arrays(doc_lens: np.ndarray, chunk_bounds: np.ndarray,
                         chunk_worker: np.ndarray) -> ShardArrays:
    """Shards produced by cutting the packed sequence at ``chunk_bounds``
    (monotone, covering [0, C]) and at every document boundary; segment k
    belongs to the chunk it falls in and to the document it falls in."""
    doc_bounds = np.concatenate([[0], np.cumsum(doc_lens)])
    cuts = np.unique(np.concatenate([doc_bounds, chunk_bounds]))
    seg_lo, seg_hi = cuts[:-1], cuts[1:]
    keep = seg_hi > seg_lo
    seg_lo, seg_hi = seg_lo[keep], seg_hi[keep]
    doc_id = np.searchsorted(doc_bounds, seg_lo, side="right") - 1
    chunk_id = np.searchsorted(chunk_bounds, seg_lo, side="right") - 1
    return ShardArrays(doc_id, seg_lo - doc_bounds[doc_id],
                       seg_hi - seg_lo, chunk_worker[chunk_id]).merged()


@register_planner(
    "llama3",
    description="Per-Seq 2N-chunk zigzag sharding (Llama3 CP); full-KV "
                "all-gather",
    comm_style="allgather", exec_style="allgather",
    order_invariant=False, cost_hint="vectorized", context_multiple=2)
def llama3_plan(doc_lens: Sequence[int], num_workers: int,
                *, validate: bool = True) -> ShardingPlan:
    """Per-Seq sharding: 2N uniform chunks of the packed sequence, worker i
    receives chunks i and 2N-1-i.  Document boundaries are ignored, so a
    chunk may contain pieces of several documents (each piece becomes a
    Shard of its own document)."""
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    ctx = int(doc_lens.sum())
    n2 = 2 * num_workers
    assert ctx % n2 == 0, f"context {ctx} must divide 2N={n2} for Llama3 CP"
    chunk = ctx // n2
    c = np.arange(n2)
    worker_of = np.where(c < num_workers, c, n2 - 1 - c)
    arrays = _chunked_plan_arrays(doc_lens, np.arange(n2 + 1) * chunk,
                                  worker_of)
    plan = ShardingPlan(doc_lens=doc_lens, arrays=arrays,
                        num_workers=num_workers, comm_style="allgather")
    if validate:
        validate_plan(plan)
    return plan


@register_planner(
    "per_doc",
    description="Per-Doc zigzag sharding (WLB-LLM); full-KV all-gather",
    comm_style="allgather", exec_style="allgather",
    needs_equal_tokens=False, order_invariant=True, cost_hint="vectorized")
def per_doc_plan(doc_lens: Sequence[int], num_workers: int,
                 *, validate: bool = True) -> ShardingPlan:
    """Per-Doc CP (WLB-LLM): zigzag-shard every document independently."""
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    n, n2 = len(doc_lens), 2 * num_workers
    base, rem = np.divmod(doc_lens, n2)                      # (n,)
    c = np.arange(n2)
    sizes = base[:, None] + (c[None, :] < rem[:, None])      # (n, 2N)
    starts = np.cumsum(sizes, axis=1) - sizes
    worker_of = np.where(c < num_workers, c, n2 - 1 - c)
    arrays = ShardArrays(
        np.repeat(np.arange(n), n2), starts.ravel(), sizes.ravel(),
        np.broadcast_to(worker_of, (n, n2)).ravel())
    keep = arrays.length > 0
    arrays = arrays._take(keep).merged()
    plan = ShardingPlan(doc_lens=doc_lens, arrays=arrays,
                        num_workers=num_workers, comm_style="allgather")
    if validate:
        # zigzag remainders can leave ±1-token differences between workers;
        # Per-Doc CP in practice pads documents — we only require coverage.
        validate_plan(plan, require_equal_tokens=False)
    return plan


@register_planner(
    "ring_zigzag", aliases=("ring",),
    description="Per-Doc zigzag layout with ring P2P KV exchange "
                "(Ring-Attn Zigzag)",
    comm_style="ring", exec_style="ring",
    needs_equal_tokens=False, order_invariant=True, cost_hint="vectorized")
def ring_zigzag_plan(doc_lens: Sequence[int], num_workers: int,
                     *, validate: bool = True) -> ShardingPlan:
    """Ring-Attn (Zigzag): Per-Doc layout with ring P2P communication."""
    plan = per_doc_plan(doc_lens, num_workers, validate=validate)
    plan.comm_style = "ring"
    return plan


@register_planner(
    "contiguous",
    description="Contiguous N-chunk sharding (order-preserving, for "
                "recurrent/hybrid archs) with sharding-aware comm",
    comm_style="flashcp", exec_style="contiguous",
    order_invariant=False, preserves_token_order=True,
    cost_hint="vectorized")
def contiguous_plan(doc_lens: Sequence[int], num_workers: int,
                    *, validate: bool = True) -> ShardingPlan:
    """Contiguous N-chunk sharding with FlashCP's sharding-aware comm.

    Used for recurrent architectures (Jamba's Mamba layers, xLSTM): SSM
    state must flow rank i -> i+1, so token order must be preserved across
    ranks.  FlashCP's communication mechanism still applies (documents
    wholly inside one chunk are never exchanged; only non-last doc pieces
    are), but Whole-Doc *placement* is constrained by the ordering —
    recorded in DESIGN.md §Arch-applicability.
    """
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    ctx = int(doc_lens.sum())
    assert ctx % num_workers == 0
    chunk = ctx // num_workers
    arrays = _chunked_plan_arrays(doc_lens,
                                  np.arange(num_workers + 1) * chunk,
                                  np.arange(num_workers))
    plan = ShardingPlan(doc_lens=doc_lens, arrays=arrays,
                        num_workers=num_workers, comm_style="flashcp")
    if validate:
        validate_plan(plan)
    return plan


@register_planner(
    "flashcp",
    description="FlashCP Algorithm 1: whole-doc LPT + equal-token repair "
                "+ per-doc zigzag fallback; sharding-aware comm (Eq. 5)",
    comm_style="flashcp", exec_style="flashcp",
    order_invariant=True, supports_target_ratio=True,
    cost_hint="vectorized")
def _flashcp_adapter(doc_lens, num_workers, *, validate=True,
                     target_ratio: float = 1.05):
    from .heuristic import flashcp_plan

    plan, _ = flashcp_plan(doc_lens, num_workers, validate=validate,
                           target_ratio=target_ratio)
    return plan


class _RegistryView(dict):
    """Legacy ``BASELINE_PLANNERS`` mapping, now a live view of the planner
    registry so newly registered strategies show up automatically."""

    def __missing__(self, name):
        from .registry import get_planner
        return get_planner(name)

    def __contains__(self, name):
        from .registry import available_planners
        return dict.__contains__(self, name) or \
            name in available_planners(include_aliases=True)


#: name -> planner fn, used by benchmarks and the training launcher.
#: Prefer :func:`repro.planner.get_planner`, which also exposes the
#: capability metadata; this mapping is kept for seed-era imports.
#: The seed's six entries are present eagerly (so iteration matches the
#: seed dict); any later-registered planner resolves lazily by name.
from .registry import get_planner as _get  # noqa: E402

BASELINE_PLANNERS = _RegistryView({
    name: _get(name)
    for name in ("llama3", "per_doc", "ring_zigzag", "ring", "contiguous",
                 "flashcp")
})
