"""Sharding-plan data structures for FlashCP context parallelism.

Terminology follows the paper (§3.1):

* A packed input sequence of context length ``C`` contains ``n`` documents
  ``D = [d_1 .. d_n]`` (lengths).
* Documents are partitioned into ``m`` shards ``S = [s_1 .. s_m]``; shard
  ``i`` has a *prefix length* ``p_i`` — the number of tokens of the same
  document preceding its start.
* Each shard is assigned to exactly one CP worker (Eq. 1); every worker holds
  exactly ``C / N`` tokens (Eq. 2, the equal-token constraint).
* A shard is a **last shard** iff it contains the final token of its
  document.  Only *non-last* shards ever need their KV communicated (§3.2).

The canonical shard storage is :class:`ShardArrays` — a structure-of-arrays
(doc_id / start / length / worker as int64 numpy arrays).  Every derived
quantity (token counts, attention workload, the Eq. 5 communication term,
plan validation) is a handful of vectorized numpy ops instead of a Python
loop over thousands of ``Shard`` objects, which is what makes host-side
planning+encoding at C = 131072 cheap enough to sit on the training input
path.  ``Shard`` objects remain available as a view for tests, debugging,
and small-scale manipulation.

Everything in this module is host-side ``numpy`` / pure Python; the
device-facing encoding lives in :mod:`repro.planner.encode`.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Shard",
    "ShardArrays",
    "ShardingPlan",
    "make_whole_doc_plan",
    "validate_plan",
    "merge_adjacent_shards",
    "shard_workload_array",
]


def shard_workload_array(prefix, length):
    """Vectorized W_i = (2 p_i + s_i + 1) * s_i / 2 (paper §3.1).

    Exact in float64 for any context length that fits a training window:
    every workload is a multiple of 0.5 well below 2**53.
    """
    prefix = np.asarray(prefix, dtype=np.int64)
    length = np.asarray(length, dtype=np.int64)
    return (2 * prefix + length + 1) * length / 2.0


@dataclasses.dataclass(frozen=True)
class Shard:
    """A contiguous slice of one document, assigned to one CP worker."""

    doc_id: int
    start: int      # offset inside the document == prefix length p_i
    length: int     # s_i, in tokens
    worker: int

    @property
    def end(self) -> int:
        return self.start + self.length

    def is_last(self, doc_len: int) -> bool:
        return self.end == doc_len

    def workload(self) -> float:
        """Attention workload W_i = (2 p_i + s_i + 1) * s_i / 2 (paper §3.1)."""
        return (2 * self.start + self.length + 1) * self.length / 2.0


class ShardArrays:
    """Structure-of-arrays shard storage: four parallel int64 arrays."""

    __slots__ = ("doc_id", "start", "length", "worker")

    def __init__(self, doc_id, start, length, worker):
        self.doc_id = np.asarray(doc_id, dtype=np.int64)
        self.start = np.asarray(start, dtype=np.int64)
        self.length = np.asarray(length, dtype=np.int64)
        self.worker = np.asarray(worker, dtype=np.int64)

    # ------------------------------------------------------------------ #
    @classmethod
    def empty(cls) -> "ShardArrays":
        z = np.zeros(0, np.int64)
        return cls(z, z.copy(), z.copy(), z.copy())

    @classmethod
    def from_shards(cls, shards: Iterable[Shard]) -> "ShardArrays":
        shards = list(shards)
        if not shards:
            return cls.empty()
        return cls([s.doc_id for s in shards], [s.start for s in shards],
                   [s.length for s in shards], [s.worker for s in shards])

    @classmethod
    def concatenate(cls, parts: Sequence["ShardArrays"]) -> "ShardArrays":
        parts = [p for p in parts if len(p)]
        if not parts:
            return cls.empty()
        return cls(np.concatenate([p.doc_id for p in parts]),
                   np.concatenate([p.start for p in parts]),
                   np.concatenate([p.length for p in parts]),
                   np.concatenate([p.worker for p in parts]))

    def __len__(self) -> int:
        return len(self.doc_id)

    def copy(self) -> "ShardArrays":
        return ShardArrays(self.doc_id.copy(), self.start.copy(),
                           self.length.copy(), self.worker.copy())

    def to_shards(self) -> list[Shard]:
        return [Shard(int(d), int(s), int(l), int(w))
                for d, s, l, w in zip(self.doc_id, self.start,
                                      self.length, self.worker)]

    # ------------------------------------------------------------------ #
    @property
    def end(self) -> np.ndarray:
        return self.start + self.length

    def workload(self) -> np.ndarray:
        return shard_workload_array(self.start, self.length)

    def is_last(self, doc_lens: np.ndarray) -> np.ndarray:
        return self.end == np.asarray(doc_lens, np.int64)[self.doc_id]

    def tokens_per_worker(self, num_workers: int) -> np.ndarray:
        return np.bincount(self.worker, weights=self.length,
                           minlength=num_workers).astype(np.int64)

    def workload_per_worker(self, num_workers: int) -> np.ndarray:
        return np.bincount(self.worker, weights=self.workload(),
                           minlength=num_workers)

    def nonlast_tokens_per_worker(self, doc_lens, num_workers: int
                                  ) -> np.ndarray:
        nonlast = ~self.is_last(doc_lens)
        return np.bincount(self.worker[nonlast],
                           weights=self.length[nonlast],
                           minlength=num_workers).astype(np.int64)

    # ------------------------------------------------------------------ #
    def sorted_by_doc(self) -> "ShardArrays":
        """Canonical (doc_id, start) order."""
        order = np.lexsort((self.start, self.doc_id))
        return self._take(order)

    def _take(self, idx) -> "ShardArrays":
        return ShardArrays(self.doc_id[idx], self.start[idx],
                           self.length[idx], self.worker[idx])

    def merged(self) -> "ShardArrays":
        """Merge shards of the same doc that are adjacent *and* co-located.

        Returns a new ShardArrays in canonical (doc_id, start) order — the
        vectorized equivalent of the seed's ``merge_adjacent_shards``.
        """
        if len(self) == 0:
            return ShardArrays.empty()
        a = self.sorted_by_doc()
        new_run = np.ones(len(a), dtype=bool)
        new_run[1:] = ((a.doc_id[1:] != a.doc_id[:-1])
                       | (a.start[1:] != a.end[:-1])
                       | (a.worker[1:] != a.worker[:-1]))
        starts_idx = np.nonzero(new_run)[0]
        length = np.add.reduceat(a.length, starts_idx)
        return ShardArrays(a.doc_id[starts_idx], a.start[starts_idx],
                           length, a.worker[starts_idx])


class ShardingPlan:
    """A complete sharding + distribution plan for one packed sequence.

    Backed by a :class:`ShardArrays`; the ``shards`` attribute materializes
    ``Shard`` objects lazily for compatibility with object-oriented callers.
    """

    def __init__(self, doc_lens, shards: list[Shard] | None = None,
                 num_workers: int | None = None,
                 comm_style: str = "flashcp",
                 arrays: ShardArrays | None = None):
        self.doc_lens = np.asarray(doc_lens, dtype=np.int64)
        assert num_workers is not None, "num_workers is required"
        self.num_workers = int(num_workers)
        # how KV is exchanged at execution time; informs cost models and the
        # device-side executor.  "flashcp" = sharding-aware compact
        # all-gather (Eq. 5); "allgather" = full-KV all-gather (Eq. 4,
        # Llama3/Per-Doc CP); "ring" = P2P ring exchange of full KV.
        self.comm_style = comm_style
        if arrays is None:
            arrays = ShardArrays.from_shards(shards or [])
        self.arrays = arrays
        self._shards: list[Shard] | None = \
            list(shards) if shards is not None else None

    # ------------------------------------------------------------------ #
    @property
    def shards(self) -> list[Shard]:
        if self._shards is None:
            self._shards = self.arrays.to_shards()
        return self._shards

    @property
    def context_len(self) -> int:
        return int(np.sum(self.doc_lens))

    @property
    def num_docs(self) -> int:
        return len(self.doc_lens)

    def shards_of_worker(self, j: int) -> list[Shard]:
        return self.arrays._take(self.arrays.worker == j).to_shards()

    def tokens_per_worker(self) -> np.ndarray:
        return self.arrays.tokens_per_worker(self.num_workers)

    def workload_per_worker(self) -> np.ndarray:
        return self.arrays.workload_per_worker(self.num_workers)

    def imbalance_ratio(self) -> float:
        """max_workload / avg_workload across CP workers (paper §4.3)."""
        w = self.workload_per_worker()
        avg = float(np.mean(w)) if len(w) else 0.0
        if avg == 0.0:
            return 1.0
        return float(np.max(w)) / avg

    # ------------------------------------------------------------------ #
    # communication (token counts; multiply by 4*H*D*(N-1) for bytes —
    # see repro.core.workload)
    # ------------------------------------------------------------------ #
    def nonlast_tokens_per_worker(self) -> np.ndarray:
        """Σ_{i∈Ŝ} x_ij s_i for each worker j — the Eq. 5 inner term."""
        return self.arrays.nonlast_tokens_per_worker(self.doc_lens,
                                                     self.num_workers)

    def comm_tokens(self) -> int:
        """Tokens each rank contributes to the KV exchange on the critical
        path.  For the sharding-aware scheme this is Eq. 5's max-term; for
        static schemes it is the full local KV, C / N (Eq. 4)."""
        if self.comm_style == "flashcp":
            return int(np.max(self.nonlast_tokens_per_worker()))
        return self.context_len // self.num_workers

    # ------------------------------------------------------------------ #
    def sorted_shards(self) -> list[Shard]:
        a = self.arrays
        order = np.lexsort((a.start, a.doc_id, a.worker))
        return a._take(order).to_shards()

    def describe(self) -> str:
        t = self.tokens_per_worker()
        w = self.workload_per_worker()
        lines = [
            f"ShardingPlan(N={self.num_workers}, C={self.context_len}, "
            f"docs={self.num_docs}, shards={len(self.arrays)}, "
            f"comm={self.comm_style})",
            f"  tokens/worker   : {t.tolist()}",
            f"  workload/worker : {[int(x) for x in w]}",
            f"  imbalance ratio : {self.imbalance_ratio():.4f}",
            f"  comm tokens     : {self.comm_tokens()} "
            f"(static would be {self.context_len // self.num_workers})",
        ]
        return "\n".join(lines)


# ---------------------------------------------------------------------- #
# constructors & checks
# ---------------------------------------------------------------------- #
def make_whole_doc_plan(
    doc_lens: Sequence[int], assignment: Sequence[int], num_workers: int
) -> ShardingPlan:
    """Plan in which every document is kept whole on ``assignment[i]``."""
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    arrays = ShardArrays(np.arange(len(doc_lens)),
                         np.zeros(len(doc_lens), np.int64),
                         doc_lens.copy(),
                         np.asarray(assignment, np.int64))
    return ShardingPlan(doc_lens=doc_lens, arrays=arrays,
                        num_workers=num_workers)


def validate_plan(plan: ShardingPlan, *, require_equal_tokens: bool = True,
                  token_tolerance: int = 0) -> None:
    """Raise ``AssertionError`` unless the plan is well formed.

    Invariants (tested property-style in tests/test_planner.py):
      * shards of each document tile [0, d_i) exactly, without overlap;
      * every shard has positive length and a valid worker id;
      * (optionally) Eq. 2 — every worker holds C/N tokens, within
        ``token_tolerance`` (zigzag chunk remainders can leave a few
        tokens of slack, absorbed by execution-side padding).
    """
    a = plan.arrays.sorted_by_doc()
    assert np.all(a.length > 0), \
        f"empty shard at doc {a.doc_id[a.length <= 0][:1]}"
    assert np.all((a.worker >= 0) & (a.worker < plan.num_workers)), \
        "bad worker id"
    assert np.all((a.doc_id >= 0) & (a.doc_id < plan.num_docs)), \
        "bad doc_id"

    present = np.unique(a.doc_id)
    assert len(present) == plan.num_docs and \
        (len(present) == 0 or (present == np.arange(plan.num_docs)).all()), \
        "missing documents"

    # tiling: within each doc, start == previous end; doc-first shard
    # starts at 0; doc-last shard ends at the document length.
    if len(a):
        doc_change = np.ones(len(a), dtype=bool)
        doc_change[1:] = a.doc_id[1:] != a.doc_id[:-1]
        first_idx = np.nonzero(doc_change)[0]
        assert np.all(a.start[first_idx] == 0), "doc does not start at 0"
        cont = ~doc_change
        assert np.all(a.start[1:][cont[1:]] == a.end[:-1][cont[1:]]), \
            "gap/overlap inside a document"
        last_idx = np.concatenate([first_idx[1:] - 1, [len(a) - 1]])
        assert np.all(a.end[last_idx] == plan.doc_lens[a.doc_id[last_idx]]), \
            "document not fully covered"

    if require_equal_tokens:
        t = plan.tokens_per_worker()
        c = plan.context_len
        n = plan.num_workers
        assert c % n == 0, f"context {c} not divisible by N={n}"
        assert int(t.max() - c // n) <= token_tolerance \
            and int(c // n - t.min()) <= token_tolerance, \
            f"equal-token constraint violated: {t.tolist()}"


def merge_adjacent_shards(shards: Iterable[Shard]) -> list[Shard]:
    """Merge shards of the same doc that are adjacent *and* co-located.

    The repair loop can produce e.g. [0,a)@w and [a,b)@w; merging keeps the
    kernel's shard count (and the comm accounting) minimal.
    """
    return ShardArrays.from_shards(shards).merged().to_shards()
