"""FlashCP planning stack: vectorized plan core, planner registry, cache.

Layering (everything host-side numpy):

* :mod:`repro.planner.plan`      — ``ShardArrays`` structure-of-arrays
  shard storage + ``ShardingPlan`` and vectorized validation/accounting;
* :mod:`repro.planner.registry`  — the ``Planner`` protocol,
  ``@register_planner`` and :func:`get_planner`, with per-planner
  capability metadata (:class:`PlannerInfo`);
* :mod:`repro.planner.heuristic` — Algorithm 1 (FlashCP), vectorized;
* :mod:`repro.planner.baselines` — Llama3 / Per-Doc / Ring / contiguous;
* :mod:`repro.planner.ilp`       — exact branch-and-bound reference;
* :mod:`repro.planner.encode`    — plan -> static-shaped device arrays;
* :mod:`repro.planner.cache`     — ``PlanCache`` keyed by (quantized)
  doc-length signature;
* :mod:`repro.planner.parallel`  — multi-sequence planning worker pool;
* :mod:`repro.planner.reference` — frozen seed implementations (golden
  parity + benchmark baseline; never used on the hot path).

The legacy ``repro.core.plan`` / ``heuristic`` / ``baselines`` / ``ilp`` /
``plan_exec`` modules re-export from here.
"""

from .plan import (Shard, ShardArrays, ShardingPlan, make_whole_doc_plan,
                   merge_adjacent_shards, shard_workload_array,
                   validate_plan)
from .registry import (RECURRENT_FAMILIES, Planner, PlannerInfo,
                       RegisteredPlanner, available_planners, get_planner,
                       planner_info, planners_for_family, register_planner)
from .heuristic import HeuristicStats, flashcp_plan, zigzag_doc_shards
from .baselines import (BASELINE_PLANNERS, contiguous_plan, llama3_plan,
                        per_doc_plan, ring_zigzag_plan)
from .ilp import BnBResult, bnb_plan
from .encode import (PlanEncoding, emit_visit_tables, encode_plan,
                     encode_plan_batch, pick_buffer_bucket,
                     plan_shape_hints, trivial_plan, visit_table_shapes)
from .cache import CacheStats, PlanCache
from .parallel import PlannerPool, get_pool, plan_many

__all__ = [
    "Shard", "ShardArrays", "ShardingPlan", "make_whole_doc_plan",
    "merge_adjacent_shards", "shard_workload_array", "validate_plan",
    "Planner", "PlannerInfo", "RegisteredPlanner", "available_planners",
    "get_planner", "planner_info", "planners_for_family",
    "RECURRENT_FAMILIES", "register_planner",
    "HeuristicStats", "flashcp_plan", "zigzag_doc_shards",
    "BASELINE_PLANNERS", "contiguous_plan", "llama3_plan", "per_doc_plan",
    "ring_zigzag_plan",
    "BnBResult", "bnb_plan",
    "PlanEncoding", "encode_plan", "encode_plan_batch",
    "emit_visit_tables", "visit_table_shapes",
    "pick_buffer_bucket", "plan_shape_hints", "trivial_plan",
    "CacheStats", "PlanCache",
    "PlannerPool", "get_pool", "plan_many",
]
