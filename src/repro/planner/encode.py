"""Plan execution encoding: ShardingPlan -> static-shaped device arrays.

XLA programs need static shapes, but FlashCP's plan is data-dependent.  The
split of labor (DESIGN.md §4):

* the planner output is encoded **per packed sequence** as a token
  permutation plus fixed-size metadata arrays;
* dynamic quantities (the Eq. 5 send-buffer size, the Pallas visit-table
  width) are **bucketed** to powers of two, so at most ``log2`` distinct
  executables exist and the compile cache absorbs them.

Plan-order layout: worker j's tokens occupy the contiguous slice
``[j*T_loc, (j+1)*T_loc)`` of every (B, C_pad) array.  Under pjit with the
sequence axis sharded over the ``model`` mesh axis, that slice *is* worker
j's local shard — host permutation implements FlashCP's token distribution
with zero device-side data movement.

Send-buffer semantics (sharding-aware communication, §3.2): worker j
contributes the KV of its *non-last* document shards, compacted (no
per-document padding — the paper's "single continuous communication
buffer"), padded to the bucket ``buf_len``; the device all-gathers these
buffers so every worker can serve queries whose prefix lives remotely.

The encoder is fully vectorized over the plan's :class:`ShardArrays`: all
per-token arrays are built with one repeat/cumsum expansion instead of a
Python loop over shards, and the batch encoder derives the shared
``t_loc`` / ``buf_len`` directly from plan accounting instead of running a
throwaway pre-encoding pass per sample.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
from collections import OrderedDict

import numpy as np

from repro.table_layout import FLAT_TABLE_NAMES

from .plan import Shard, ShardArrays, ShardingPlan

__all__ = ["PlanEncoding", "encode_plan", "encode_plan_batch",
           "emit_visit_tables", "visit_table_shapes",
           "pick_buffer_bucket", "plan_shape_hints", "trivial_plan"]


def _next_pow2(x: int, floor: int = 128) -> int:
    v = floor
    while v < x:
        v *= 2
    return v


def pick_buffer_bucket(comm_tokens: int, t_loc: int, floor: int = 128) -> int:
    """Static Eq.5 buffer size: pow2 bucket, at most the full local KV."""
    return min(_next_pow2(max(comm_tokens, 1), floor),
               _next_pow2(t_loc, floor))


def _aligned(x: int, align: int) -> int:
    return ((x + align - 1) // align) * align if align > 1 else x


@dataclasses.dataclass
class PlanEncoding:
    """Device-facing encoding of one packed sequence's sharding plan."""

    perm: np.ndarray        # (C_pad,) plan-order -> packed position (-1 pad)
    doc: np.ndarray         # (C_pad,) int32 doc id per plan-order token
    pos: np.ndarray         # (C_pad,) int32 intra-doc position
    send_idx: np.ndarray    # (N, buf_len) int32 local indices, -1 pad
    gath_doc: np.ndarray    # (N * buf_len,) int32, -1 pad
    gath_pos: np.ndarray    # (N * buf_len,) int32
    t_loc: int              # tokens per worker (C_pad // N)
    buf_len: int            # Eq. 5 bucket
    comm_tokens: int        # actual max_j non-last tokens (pre-bucket)
    imbalance: float


def trivial_plan(context_len: int) -> ShardingPlan:
    """Single-worker plan (smoke tests / local mode)."""
    return ShardingPlan(
        doc_lens=np.asarray([context_len], dtype=np.int64),
        shards=[Shard(0, 0, context_len, 0)],
        num_workers=1, comm_style="flashcp")


def _exec_order(plan: ShardingPlan) -> ShardArrays:
    """Shards in execution order: by worker, then (doc_id, start)."""
    a = plan.arrays
    return a._take(np.lexsort((a.start, a.doc_id, a.worker)))


def encode_plan(
    plan: ShardingPlan,
    *,
    buf_len: int | None = None,
    t_loc: int | None = None,
    align: int = 1,
    _out: dict[str, np.ndarray] | None = None,
) -> PlanEncoding:
    """Encode one plan.  ``_out`` optionally supplies preallocated,
    correctly-shaped destination arrays (one row of a batch stack) — the
    batch encoder uses this to write every sample straight into the
    stacked pipeline output with no per-sample allocation or copy."""
    N = plan.num_workers
    doc_starts = np.concatenate([[0], np.cumsum(plan.doc_lens)])[:-1]

    a = _exec_order(plan)
    m = len(a)
    tokens_per_worker = np.bincount(a.worker, weights=a.length,
                                    minlength=N).astype(np.int64)
    need_t = int(tokens_per_worker.max()) if m else 0
    if t_loc is None:
        t_loc = _aligned(need_t, align)
    assert t_loc >= need_t, (t_loc, need_t)

    C_pad = N * t_loc
    if _out is None:
        perm = np.empty(C_pad, np.int64)
        doc = np.empty(C_pad, np.int32)
        pos = np.empty(C_pad, np.int32)
    else:
        perm, doc, pos = _out["perm"], _out["doc"], _out["pos"]

    # ---- one repeat/cumsum expansion builds every per-token array ------ #
    # In exec order, tokens are already laid out contiguously per worker;
    # each worker's run is then *copied* (not scattered) into its
    # [j*t_loc, ...) slice of the padded arrays.  int32 intermediates:
    # context lengths are far below 2**31 and int32 halves the allocator
    # and bandwidth cost of the per-token expansion.
    total = int(a.length.sum())
    len32 = a.length.astype(np.int32)
    excl = np.cumsum(a.length) - a.length          # global exclusive cumsum
    ar = np.arange(total, dtype=np.int32)
    tok_doc = np.repeat(a.doc_id.astype(np.int32), len32)
    tok_pos = ar + np.repeat((a.start - excl).astype(np.int32), len32)
    packed = ar + np.repeat(
        (doc_starts[a.doc_id] + a.start - excl).astype(np.int64), len32)

    # worker runs are copied into their slices; only the (small) per-worker
    # padding tails are filled — never the full C_pad arrays.
    wseg = np.concatenate([[0], np.cumsum(tokens_per_worker)]).astype(np.int64)
    for j in range(N):
        lo, hi = int(wseg[j]), int(wseg[j + 1])
        o = j * t_loc
        run = hi - lo
        if run:
            perm[o: o + run] = packed[lo:hi]
            doc[o: o + run] = tok_doc[lo:hi]
            pos[o: o + run] = tok_pos[lo:hi]
        if run < t_loc:
            perm[o + run: o + t_loc] = -1
            doc[o + run: o + t_loc] = -1
            pos[o + run: o + t_loc] = 0

    # ---- compact per-worker send buffers (non-last shards only) -------- #
    # expanded over non-last shards alone, so the send-side cost scales
    # with the Eq. 5 communication volume, not the context length.
    nl = a.end < plan.doc_lens[a.doc_id]
    nl_len = len32[nl]
    nl_total = int(nl_len.sum())
    nl_excl = np.cumsum(nl_len) - nl_len
    # local (within-worker) start of each shard in plan-order layout
    nl_local = (excl - wseg[a.worker])[nl].astype(np.int32)
    ar_nl = ar[:nl_total]
    send_worker = np.repeat(a.worker[nl].astype(np.int32), nl_len)
    send_count = np.bincount(send_worker, minlength=N).astype(np.int64)
    max_send = int(send_count.max()) if N else 0
    if buf_len is None:
        buf_len = pick_buffer_bucket(max_send, t_loc)
    assert buf_len >= max_send, (
        f"Eq.5 bucket {buf_len} < required send volume {max_send}")

    if _out is None:
        send_idx = np.empty((N, buf_len), np.int32)
        gath_doc = np.empty(N * buf_len, np.int32)
        gath_pos = np.empty(N * buf_len, np.int32)
    else:
        send_idx = _out["send_idx"]
        gath_doc, gath_pos = _out["gath_doc"], _out["gath_pos"]
    if nl_total:
        # rank of each sent token within its worker's buffer: send tokens
        # appear in exec order, so worker groups are contiguous.  Sent
        # prefixes are copied per worker; only padding tails get filled.
        send_local = ar_nl + np.repeat(nl_local - nl_excl, nl_len)
        gd = np.repeat(a.doc_id[nl].astype(np.int32), nl_len)
        gp = ar_nl + np.repeat((a.start[nl].astype(np.int32) - nl_excl),
                               nl_len)
        send_excl = np.cumsum(send_count) - send_count
        sflat = send_idx.reshape(-1)
        for j in range(N):
            lo, cnt = int(send_excl[j]), int(send_count[j])
            o = j * buf_len
            if cnt:
                sflat[o: o + cnt] = send_local[lo: lo + cnt]
                gath_doc[o: o + cnt] = gd[lo: lo + cnt]
                gath_pos[o: o + cnt] = gp[lo: lo + cnt]
            if cnt < buf_len:
                sflat[o + cnt: o + buf_len] = -1
                gath_doc[o + cnt: o + buf_len] = -1
                gath_pos[o + cnt: o + buf_len] = 0
    else:
        send_idx.fill(-1)
        gath_doc.fill(-1)
        gath_pos.fill(0)

    return PlanEncoding(
        perm=perm, doc=doc, pos=pos, send_idx=send_idx,
        gath_doc=gath_doc, gath_pos=gath_pos, t_loc=t_loc, buf_len=buf_len,
        comm_tokens=max_send, imbalance=plan.imbalance_ratio())


def plan_shape_hints(plan: ShardingPlan, *, align: int = 1
                     ) -> tuple[int, int]:
    """(t_loc, buf_len) this plan would pick standalone — computed from the
    plan's accounting arrays without materializing an encoding."""
    t = plan.tokens_per_worker()
    t_loc = _aligned(int(t.max()) if len(t) else 0, align)
    max_send = int(plan.nonlast_tokens_per_worker().max())
    return t_loc, pick_buffer_bucket(max_send, t_loc)


def encode_plan_batch(
    plans: list[ShardingPlan],
    *,
    buf_len: int | None = None,
    t_loc: int | None = None,
    align: int = 1,
    workers: int = 0,
) -> tuple[dict[str, np.ndarray], list[PlanEncoding]]:
    """Encode a batch of per-sample plans with a common bucket.

    Returns (stacked arrays dict, per-sample encodings).  All samples share
    ``t_loc`` (max over batch, aligned — or the explicit ``t_loc``, which
    the dispatcher pins to ``C / cp`` so ragged per-group batches keep one
    static shape per degree) and ``buf_len`` (bucketed max).  The shared
    shapes are derived from plan accounting directly — the seed ran a full
    throwaway encoding pass per sample just to learn them.

    ``workers``: encoding is numpy-memcpy-dominated and releases the GIL,
    so multi-sample batches are encoded from a thread pool (0 = auto: one
    thread per sample up to the CPU count; 1 = serial).
    """
    N = plans[0].num_workers
    assert all(p.num_workers == N for p in plans)

    hints = [plan_shape_hints(p, align=align) for p in plans]
    need_t = max(h[0] for h in hints)
    if t_loc is None:
        t_loc = need_t
    assert t_loc >= need_t, (t_loc, need_t)
    if buf_len is None:
        buf_len = max(h[1] for h in hints)

    B = len(plans)
    C_pad = N * t_loc
    stack = {
        "perm": np.empty((B, C_pad), np.int64),
        "doc": np.empty((B, C_pad), np.int32),
        "pos": np.empty((B, C_pad), np.int32),
        "send_idx": np.empty((B, N, buf_len), np.int32),
        "gath_doc": np.empty((B, N * buf_len), np.int32),
        "gath_pos": np.empty((B, N * buf_len), np.int32),
    }

    # every sample encodes straight into its row of the stacked output —
    # no per-sample allocation, no np.stack copy.
    def one(b: int) -> PlanEncoding:
        return encode_plan(plans[b], buf_len=buf_len, t_loc=t_loc,
                           _out={k: v[b] for k, v in stack.items()})

    if workers == 0:
        # threading pays only with spare cores: encoding is memory-bound,
        # and on 1-2 core hosts pool overhead exceeds the overlap win.
        workers = min(B, max((os.cpu_count() or 1) - 1, 1))
        if (os.cpu_count() or 1) <= 2:
            workers = 1
    if workers > 1 and B > 1:
        from .parallel import get_pool
        encs = get_pool(workers).map(one, range(B))
    else:
        encs = [one(b) for b in range(B)]
    return stack, encs


# --------------------------------------------------------------------- #
# Pallas visit-table emission (planner side)
# --------------------------------------------------------------------- #
def _table_style(strategy: str) -> str:
    if strategy in ("flashcp", "contiguous"):
        return "flashcp"
    if strategy in ("allgather", "llama3", "per_doc", "ring", "ring_zigzag"):
        return "allgather"
    raise ValueError(f"no visit-table style for strategy {strategy!r}")


def _widen_tables(idx: np.ndarray, nvis: np.ndarray, width: int):
    """Pad visit lists to a wider static width (repeat-last no-op slots)."""
    V = idx.shape[-1]
    if width <= V:
        return idx
    last = np.take_along_axis(
        idx, np.maximum(nvis - 1, 0)[..., None], -1)
    pad = np.broadcast_to(last, (*idx.shape[:-1], width - V))
    return np.concatenate([idx, pad], axis=-1)


def _bucketed(idx, nvis, nblocks, pad_to):
    if pad_to == "full":
        return _widen_tables(idx, nvis, nblocks)
    if pad_to == "bucket":
        return _widen_tables(idx, nvis, min(_next_pow2(idx.shape[-1], 8),
                                            nblocks))
    return idx


def _widen_queue(row, col, flags, width):
    """Pad work queues to a wider static step count: repeat-last no-op
    steps with flags 0 (never FIRST/LAST/VALID, so they neither compute
    nor rewrite outputs — same semantics as build_work_queue's own pad
    tail)."""
    S = row.shape[-1]
    if width <= S:
        return row, col, flags
    pad = width - S
    tail = (*row.shape[:-1], pad)
    return (np.concatenate([row, np.broadcast_to(row[..., -1:], tail)], -1),
            np.concatenate([col, np.broadcast_to(col[..., -1:], tail)], -1),
            np.concatenate([flags, np.zeros(tail, flags.dtype)], -1))


def _queue_bucketed(row, col, flags, worst_steps, pad_to):
    if pad_to == "full":
        return _widen_queue(row, col, flags, worst_steps)
    if pad_to == "bucket":
        width = min(_next_pow2(row.shape[-1], 8), max(worst_steps, 1))
        width = max(width, row.shape[-1])
        return _widen_queue(row, col, flags, width)
    return row, col, flags


def _build_group(q_doc, q_pos, kv_doc, kv_pos, out_shape, *, block_q,
                 block_k, pad_to, grid="rect"):
    """One batched build_block_tables call over flattened (rows, T) pairs,
    reshaped to ``out_shape`` leading dims.  Returns a dict of base-named
    arrays: the rectangular 4 (``grid="rect"``/``"both"``) and/or the
    flattened work-queue 6 (``grid="flat"``/``"both"``)."""
    from repro.kernels.doc_attention import build_block_tables

    rows = int(np.prod(out_shape))
    t = build_block_tables(
        q_doc.reshape(rows, -1), q_pos.reshape(rows, -1),
        kv_doc.reshape(rows, -1), kv_pos.reshape(rows, -1),
        block_q=block_q, block_k=block_k)
    nq, nk = t.kv_nvis.shape[-1], t.q_nvis.shape[-1]
    out = {}
    if grid in ("rect", "both"):
        kv_idx = _bucketed(t.kv_idx, t.kv_nvis, nk, pad_to)
        q_idx = _bucketed(t.q_idx, t.q_nvis, nq, pad_to)
        out.update({
            "kv_idx": kv_idx.reshape(*out_shape, nq, -1),
            "kv_nvis": t.kv_nvis.reshape(*out_shape, nq),
            "q_idx": q_idx.reshape(*out_shape, nk, -1),
            "q_nvis": t.q_nvis.reshape(*out_shape, nk),
        })
    if grid in ("flat", "both"):
        worst = nq * nk
        fq = _queue_bucketed(t.fq_row, t.fq_col, t.fq_flags, worst, pad_to)
        rq = _queue_bucketed(t.rq_row, t.rq_col, t.rq_flags, worst, pad_to)
        for name, arr in zip(FLAT_TABLE_NAMES, (*fq, *rq)):
            out[name] = arr.reshape(*out_shape, -1)
    return out


_TABLE_CACHE: OrderedDict[bytes, dict] = OrderedDict()
_TABLE_CACHE_MAX = 8


def emit_visit_tables(
    doc: np.ndarray,
    pos: np.ndarray,
    gath_doc: np.ndarray | None = None,
    gath_pos: np.ndarray | None = None,
    *,
    num_workers: int,
    strategy: str = "flashcp",
    overlap: str = "chunked",
    block_q: int = 128,
    block_k: int = 128,
    pad_to: str = "bucket",
    grid: str = "rect",
    cache: bool = True,
) -> dict[str, np.ndarray]:
    """Per-rank Pallas visit tables for a batch-encoded plan.

    ``doc``/``pos`` are the stacked plan-order (B, C_pad) arrays of
    :func:`encode_plan_batch`; ``gath_doc``/``gath_pos`` the (B, N*buf)
    Eq.-5 buffer metadata (flashcp styles only).  One table set is built
    per (sample, rank) — and per hop for ``overlap="chunked"`` — with a
    single batched :func:`build_block_tables` call per group, so the cost
    is one vectorized pass regardless of CP size.

    Returns ``tab_*`` plan arrays matching what
    :func:`repro.core.cp_attention.make_cp_context` consumes:

    * ``overlap="none"``   — ``tab_{kv_idx,kv_nvis,q_idx,q_nvis}``
      (B, N, ...) for the monolithic concat layout (flashcp: ``[local |
      gathered-with-self-masked]``; allgather: full sequence).
    * ``overlap="chunked"`` — ``tab_loc_*`` (B, N, ...) for the local-KV
      partial plus ``tab_hop_*`` (B, N, N-1, ...) where hop h of rank r
      attends the payload of rank (r - 1 - h) mod N, matching the
      chunked engine's ppermute rotation.

    ``grid`` selects the kernel schedule the tables drive: ``"rect"``
    emits the rectangular ``*_{kv,q}_{idx,nvis}`` layout, ``"flat"`` the
    flattened work-queue ``*_{fq,rq}_{row,col,flags}`` layout
    (:func:`repro.kernels.doc_attention.build_work_queue` — one step per
    actual visit, LPT row order), ``"both"`` emits the two side by side
    (the ``grid=`` RunConfig switch then picks at step-build time).

    Visit widths / queue step counts are padded to a pow2 bucket
    (``pad_to="bucket"``) so at most log2 distinct executables exist;
    ``"full"`` pads to the worst-case width of :func:`visit_table_shapes`
    for AOT-spec-exact shapes.  Results are memoized on the metadata
    content (PlanCache-hit batches re-emit for free).
    """
    doc = np.ascontiguousarray(doc, np.int32)
    pos = np.ascontiguousarray(pos, np.int32)
    style = _table_style(strategy)
    if grid not in ("rect", "flat", "both"):
        raise ValueError(f"unknown table grid {grid!r}")
    if style == "flashcp":
        assert gath_doc is not None and gath_pos is not None, \
            "flashcp tables need the Eq.5 buffer metadata"
        gath_doc = np.ascontiguousarray(gath_doc, np.int32)
        gath_pos = np.ascontiguousarray(gath_pos, np.int32)

    key = None
    if cache:
        h = hashlib.blake2b(digest_size=16)
        for a in (doc, pos, gath_doc, gath_pos):
            h.update(b"|" if a is None else a.tobytes())
        h.update(f"{num_workers}/{style}/{overlap}/{block_q}/{block_k}/"
                 f"{pad_to}/{grid}".encode())
        key = h.digest()
        hit = _TABLE_CACHE.get(key)
        if hit is not None:
            _TABLE_CACHE.move_to_end(key)
            return dict(hit)

    B, C = doc.shape
    N = num_workers
    t_loc = C // N
    ld = doc.reshape(B, N, t_loc)
    lp = pos.reshape(B, N, t_loc)
    kw = dict(block_q=block_q, block_k=block_k, pad_to=pad_to, grid=grid)

    if overlap == "none":
        if style == "flashcp":
            L = gath_doc.shape[-1]
            buf = L // N
            gd = np.broadcast_to(gath_doc[:, None], (B, N, L)).copy()
            seg = np.arange(L) // buf
            gd[:, seg == np.arange(N)[:, None]] = -2     # self-masked
            gp = np.broadcast_to(gath_pos[:, None], (B, N, L))
            kd = np.concatenate([ld, gd], axis=-1)
            kp = np.concatenate([lp, gp], axis=-1)
        else:
            kd = np.broadcast_to(doc[:, None], (B, N, C))
            kp = np.broadcast_to(pos[:, None], (B, N, C))
        out = {f"tab_{name}": a for name, a in
               _build_group(ld, lp, kd, kp, (B, N), **kw).items()}
    elif overlap == "chunked":
        out = {f"tab_loc_{name}": a for name, a in
               _build_group(ld, lp, ld, lp, (B, N), **kw).items()}
        H = N - 1
        if style == "flashcp":
            L = gath_doc.shape[-1]
            segs_d = gath_doc.reshape(B, N, L // N)
            segs_p = gath_pos.reshape(B, N, L // N)
        else:
            segs_d, segs_p = ld, lp
        src = (np.arange(N)[:, None] - 1
               - np.arange(max(H, 1))[None, :]) % N     # (N, H)
        hop_kd = segs_d[:, src][:, :, :H]               # (B, N, H, seg)
        hop_kp = segs_p[:, src][:, :, :H]
        hop_qd = np.broadcast_to(ld[:, :, None], (B, N, max(H, 1), t_loc)
                                 )[:, :, :H]
        hop_qp = np.broadcast_to(lp[:, :, None], (B, N, max(H, 1), t_loc)
                                 )[:, :, :H]
        if H > 0:
            out.update({f"tab_hop_{name}": a for name, a in
                        _build_group(hop_qd, hop_qp, hop_kd, hop_kp,
                                     (B, N, H), **kw).items()})
        else:
            # zero-hop (N == 1) placeholders, width-matched to
            # visit_table_shapes so AOT specs agree
            nq = t_loc // block_q
            nk = segs_d.shape[-1] // block_k
            if grid in ("rect", "both"):
                out.update({
                    "tab_hop_kv_idx": np.zeros((B, N, 0, nq, nk), np.int32),
                    "tab_hop_kv_nvis": np.zeros((B, N, 0, nq), np.int32),
                    "tab_hop_q_idx": np.zeros((B, N, 0, nk, nq), np.int32),
                    "tab_hop_q_nvis": np.zeros((B, N, 0, nk), np.int32),
                })
            if grid in ("flat", "both"):
                out.update({f"tab_hop_{name}":
                            np.zeros((B, N, 0, nq * nk), np.int32)
                            for name in FLAT_TABLE_NAMES})
    else:
        raise ValueError(f"unknown overlap mode {overlap!r}")

    if cache and key is not None:
        _TABLE_CACHE[key] = dict(out)
        while len(_TABLE_CACHE) > _TABLE_CACHE_MAX:
            _TABLE_CACHE.popitem(last=False)
    return out


def _group_shapes(prefix: str, lead: tuple, nq: int, nk: int,
                  grid: str) -> dict[str, tuple]:
    out = {}
    if grid in ("rect", "both"):
        out.update({
            f"{prefix}kv_idx": (*lead, nq, nk),
            f"{prefix}kv_nvis": (*lead, nq),
            f"{prefix}q_idx": (*lead, nk, nq),
            f"{prefix}q_nvis": (*lead, nk),
        })
    if grid in ("flat", "both"):
        # worst-case queue: every row visits every column (then no
        # empty-row sentinels exist), so S_max = nq * nk both ways
        out.update({f"{prefix}{name}": (*lead, nq * nk)
                    for name in FLAT_TABLE_NAMES})
    return out


def visit_table_shapes(
    B: int,
    num_workers: int,
    t_loc: int,
    buf_len: int,
    *,
    strategy: str = "flashcp",
    overlap: str = "chunked",
    block_q: int = 128,
    block_k: int = 128,
    grid: str = "rect",
) -> dict[str, tuple]:
    """Worst-case-width static shapes of :func:`emit_visit_tables` output
    (dry-run / AOT input specs; ``pad_to="full"`` emission matches them).
    """
    N = num_workers
    nq = t_loc // block_q
    style = _table_style(strategy)
    if overlap == "none":
        kv_len = t_loc + N * buf_len if style == "flashcp" else N * t_loc
        nk = kv_len // block_k
        return _group_shapes("tab_", (B, N), nq, nk, grid)
    H = N - 1
    seg = buf_len if style == "flashcp" else t_loc
    nk_loc = t_loc // block_k
    nk_hop = seg // block_k
    return {
        **_group_shapes("tab_loc_", (B, N), nq, nk_loc, grid),
        **_group_shapes("tab_hop_", (B, N, H), nq, nk_hop, grid),
    }
