"""Plan execution encoding: ShardingPlan -> static-shaped device arrays.

XLA programs need static shapes, but FlashCP's plan is data-dependent.  The
split of labor (DESIGN.md §4):

* the planner output is encoded **per packed sequence** as a token
  permutation plus fixed-size metadata arrays;
* dynamic quantities (the Eq. 5 send-buffer size, the Pallas visit-table
  width) are **bucketed** to powers of two, so at most ``log2`` distinct
  executables exist and the compile cache absorbs them.

Plan-order layout: worker j's tokens occupy the contiguous slice
``[j*T_loc, (j+1)*T_loc)`` of every (B, C_pad) array.  Under pjit with the
sequence axis sharded over the ``model`` mesh axis, that slice *is* worker
j's local shard — host permutation implements FlashCP's token distribution
with zero device-side data movement.

Send-buffer semantics (sharding-aware communication, §3.2): worker j
contributes the KV of its *non-last* document shards, compacted (no
per-document padding — the paper's "single continuous communication
buffer"), padded to the bucket ``buf_len``; the device all-gathers these
buffers so every worker can serve queries whose prefix lives remotely.

The encoder is fully vectorized over the plan's :class:`ShardArrays`: all
per-token arrays are built with one repeat/cumsum expansion instead of a
Python loop over shards, and the batch encoder derives the shared
``t_loc`` / ``buf_len`` directly from plan accounting instead of running a
throwaway pre-encoding pass per sample.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from .plan import Shard, ShardArrays, ShardingPlan

__all__ = ["PlanEncoding", "encode_plan", "encode_plan_batch",
           "pick_buffer_bucket", "plan_shape_hints", "trivial_plan"]


def _next_pow2(x: int, floor: int = 128) -> int:
    v = floor
    while v < x:
        v *= 2
    return v


def pick_buffer_bucket(comm_tokens: int, t_loc: int, floor: int = 128) -> int:
    """Static Eq.5 buffer size: pow2 bucket, at most the full local KV."""
    return min(_next_pow2(max(comm_tokens, 1), floor),
               _next_pow2(t_loc, floor))


def _aligned(x: int, align: int) -> int:
    return ((x + align - 1) // align) * align if align > 1 else x


@dataclasses.dataclass
class PlanEncoding:
    """Device-facing encoding of one packed sequence's sharding plan."""

    perm: np.ndarray        # (C_pad,) plan-order -> packed position (-1 pad)
    doc: np.ndarray         # (C_pad,) int32 doc id per plan-order token
    pos: np.ndarray         # (C_pad,) int32 intra-doc position
    send_idx: np.ndarray    # (N, buf_len) int32 local indices, -1 pad
    gath_doc: np.ndarray    # (N * buf_len,) int32, -1 pad
    gath_pos: np.ndarray    # (N * buf_len,) int32
    t_loc: int              # tokens per worker (C_pad // N)
    buf_len: int            # Eq. 5 bucket
    comm_tokens: int        # actual max_j non-last tokens (pre-bucket)
    imbalance: float


def trivial_plan(context_len: int) -> ShardingPlan:
    """Single-worker plan (smoke tests / local mode)."""
    return ShardingPlan(
        doc_lens=np.asarray([context_len], dtype=np.int64),
        shards=[Shard(0, 0, context_len, 0)],
        num_workers=1, comm_style="flashcp")


def _exec_order(plan: ShardingPlan) -> ShardArrays:
    """Shards in execution order: by worker, then (doc_id, start)."""
    a = plan.arrays
    return a._take(np.lexsort((a.start, a.doc_id, a.worker)))


def encode_plan(
    plan: ShardingPlan,
    *,
    buf_len: int | None = None,
    t_loc: int | None = None,
    align: int = 1,
    _out: dict[str, np.ndarray] | None = None,
) -> PlanEncoding:
    """Encode one plan.  ``_out`` optionally supplies preallocated,
    correctly-shaped destination arrays (one row of a batch stack) — the
    batch encoder uses this to write every sample straight into the
    stacked pipeline output with no per-sample allocation or copy."""
    N = plan.num_workers
    doc_starts = np.concatenate([[0], np.cumsum(plan.doc_lens)])[:-1]

    a = _exec_order(plan)
    m = len(a)
    tokens_per_worker = np.bincount(a.worker, weights=a.length,
                                    minlength=N).astype(np.int64)
    need_t = int(tokens_per_worker.max()) if m else 0
    if t_loc is None:
        t_loc = _aligned(need_t, align)
    assert t_loc >= need_t, (t_loc, need_t)

    C_pad = N * t_loc
    if _out is None:
        perm = np.empty(C_pad, np.int64)
        doc = np.empty(C_pad, np.int32)
        pos = np.empty(C_pad, np.int32)
    else:
        perm, doc, pos = _out["perm"], _out["doc"], _out["pos"]

    # ---- one repeat/cumsum expansion builds every per-token array ------ #
    # In exec order, tokens are already laid out contiguously per worker;
    # each worker's run is then *copied* (not scattered) into its
    # [j*t_loc, ...) slice of the padded arrays.  int32 intermediates:
    # context lengths are far below 2**31 and int32 halves the allocator
    # and bandwidth cost of the per-token expansion.
    total = int(a.length.sum())
    len32 = a.length.astype(np.int32)
    excl = np.cumsum(a.length) - a.length          # global exclusive cumsum
    ar = np.arange(total, dtype=np.int32)
    tok_doc = np.repeat(a.doc_id.astype(np.int32), len32)
    tok_pos = ar + np.repeat((a.start - excl).astype(np.int32), len32)
    packed = ar + np.repeat(
        (doc_starts[a.doc_id] + a.start - excl).astype(np.int64), len32)

    # worker runs are copied into their slices; only the (small) per-worker
    # padding tails are filled — never the full C_pad arrays.
    wseg = np.concatenate([[0], np.cumsum(tokens_per_worker)]).astype(np.int64)
    for j in range(N):
        lo, hi = int(wseg[j]), int(wseg[j + 1])
        o = j * t_loc
        run = hi - lo
        if run:
            perm[o: o + run] = packed[lo:hi]
            doc[o: o + run] = tok_doc[lo:hi]
            pos[o: o + run] = tok_pos[lo:hi]
        if run < t_loc:
            perm[o + run: o + t_loc] = -1
            doc[o + run: o + t_loc] = -1
            pos[o + run: o + t_loc] = 0

    # ---- compact per-worker send buffers (non-last shards only) -------- #
    # expanded over non-last shards alone, so the send-side cost scales
    # with the Eq. 5 communication volume, not the context length.
    nl = a.end < plan.doc_lens[a.doc_id]
    nl_len = len32[nl]
    nl_total = int(nl_len.sum())
    nl_excl = np.cumsum(nl_len) - nl_len
    # local (within-worker) start of each shard in plan-order layout
    nl_local = (excl - wseg[a.worker])[nl].astype(np.int32)
    ar_nl = ar[:nl_total]
    send_worker = np.repeat(a.worker[nl].astype(np.int32), nl_len)
    send_count = np.bincount(send_worker, minlength=N).astype(np.int64)
    max_send = int(send_count.max()) if N else 0
    if buf_len is None:
        buf_len = pick_buffer_bucket(max_send, t_loc)
    assert buf_len >= max_send, (
        f"Eq.5 bucket {buf_len} < required send volume {max_send}")

    if _out is None:
        send_idx = np.empty((N, buf_len), np.int32)
        gath_doc = np.empty(N * buf_len, np.int32)
        gath_pos = np.empty(N * buf_len, np.int32)
    else:
        send_idx = _out["send_idx"]
        gath_doc, gath_pos = _out["gath_doc"], _out["gath_pos"]
    if nl_total:
        # rank of each sent token within its worker's buffer: send tokens
        # appear in exec order, so worker groups are contiguous.  Sent
        # prefixes are copied per worker; only padding tails get filled.
        send_local = ar_nl + np.repeat(nl_local - nl_excl, nl_len)
        gd = np.repeat(a.doc_id[nl].astype(np.int32), nl_len)
        gp = ar_nl + np.repeat((a.start[nl].astype(np.int32) - nl_excl),
                               nl_len)
        send_excl = np.cumsum(send_count) - send_count
        sflat = send_idx.reshape(-1)
        for j in range(N):
            lo, cnt = int(send_excl[j]), int(send_count[j])
            o = j * buf_len
            if cnt:
                sflat[o: o + cnt] = send_local[lo: lo + cnt]
                gath_doc[o: o + cnt] = gd[lo: lo + cnt]
                gath_pos[o: o + cnt] = gp[lo: lo + cnt]
            if cnt < buf_len:
                sflat[o + cnt: o + buf_len] = -1
                gath_doc[o + cnt: o + buf_len] = -1
                gath_pos[o + cnt: o + buf_len] = 0
    else:
        send_idx.fill(-1)
        gath_doc.fill(-1)
        gath_pos.fill(0)

    return PlanEncoding(
        perm=perm, doc=doc, pos=pos, send_idx=send_idx,
        gath_doc=gath_doc, gath_pos=gath_pos, t_loc=t_loc, buf_len=buf_len,
        comm_tokens=max_send, imbalance=plan.imbalance_ratio())


def plan_shape_hints(plan: ShardingPlan, *, align: int = 1
                     ) -> tuple[int, int]:
    """(t_loc, buf_len) this plan would pick standalone — computed from the
    plan's accounting arrays without materializing an encoding."""
    t = plan.tokens_per_worker()
    t_loc = _aligned(int(t.max()) if len(t) else 0, align)
    max_send = int(plan.nonlast_tokens_per_worker().max())
    return t_loc, pick_buffer_bucket(max_send, t_loc)


def encode_plan_batch(
    plans: list[ShardingPlan],
    *,
    buf_len: int | None = None,
    align: int = 1,
    workers: int = 0,
) -> tuple[dict[str, np.ndarray], list[PlanEncoding]]:
    """Encode a batch of per-sample plans with a common bucket.

    Returns (stacked arrays dict, per-sample encodings).  All samples share
    ``t_loc`` (max over batch, aligned) and ``buf_len`` (bucketed max).
    The shared shapes are derived from plan accounting directly — the seed
    ran a full throwaway encoding pass per sample just to learn them.

    ``workers``: encoding is numpy-memcpy-dominated and releases the GIL,
    so multi-sample batches are encoded from a thread pool (0 = auto: one
    thread per sample up to the CPU count; 1 = serial).
    """
    N = plans[0].num_workers
    assert all(p.num_workers == N for p in plans)

    hints = [plan_shape_hints(p, align=align) for p in plans]
    t_loc = max(h[0] for h in hints)
    if buf_len is None:
        buf_len = max(h[1] for h in hints)

    B = len(plans)
    C_pad = N * t_loc
    stack = {
        "perm": np.empty((B, C_pad), np.int64),
        "doc": np.empty((B, C_pad), np.int32),
        "pos": np.empty((B, C_pad), np.int32),
        "send_idx": np.empty((B, N, buf_len), np.int32),
        "gath_doc": np.empty((B, N * buf_len), np.int32),
        "gath_pos": np.empty((B, N * buf_len), np.int32),
    }

    # every sample encodes straight into its row of the stacked output —
    # no per-sample allocation, no np.stack copy.
    def one(b: int) -> PlanEncoding:
        return encode_plan(plans[b], buf_len=buf_len, t_loc=t_loc,
                           _out={k: v[b] for k, v in stack.items()})

    if workers == 0:
        # threading pays only with spare cores: encoding is memory-bound,
        # and on 1-2 core hosts pool overhead exceeds the overlap win.
        workers = min(B, max((os.cpu_count() or 1) - 1, 1))
        if (os.cpu_count() or 1) <= 2:
            workers = 1
    if workers > 1 and B > 1:
        from .parallel import get_pool
        encs = get_pool(workers).map(one, range(B))
    else:
        encs = [one(b) for b in range(B)]
    return stack, encs
