"""Planner registry: one API surface for every CP sharding strategy.

The seed selected planners by string ``if/else`` duplicated across the data
pipeline, the step builders, and the benchmarks — adding a strategy meant
editing four layers.  Here a planner is registered **once**:

    @register_planner("my_strategy", comm_style="flashcp",
                      exec_style="flashcp", order_invariant=True)
    def my_plan(doc_lens, num_workers, *, validate=True) -> ShardingPlan:
        ...

and every consumer resolves it by name with :func:`get_planner`, including
its capability metadata (:class:`PlannerInfo`):

* ``comm_style``    — the KV-exchange style stamped on emitted plans
  (``flashcp`` | ``allgather`` | ``ring``), used by cost models;
* ``exec_style``    — the execution-strategy name handed to the device-side
  step builders (:func:`repro.launch.steps.exec_strategy_of`);
* ``needs_equal_tokens`` — whether emitted plans satisfy Eq. 2 exactly
  (Per-Doc zigzag leaves ±1-token remainders handled by padding);
* ``order_invariant``    — the plan depends only on the *multiset* of
  document lengths, so :class:`repro.planner.cache.PlanCache` may
  canonicalize by sorted length;
* ``preserves_token_order`` — packed token order survives across ranks
  (required by recurrent/hybrid architectures — SSM state flows rank
  i → i+1);
* ``supports_target_ratio`` — accepts a ``target_ratio`` imbalance knob;
* ``cost_hint``          — rough planner cost class, used by tooling to
  warn before running exponential reference solvers on big inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from .plan import ShardingPlan

__all__ = ["Planner", "PlannerInfo", "RegisteredPlanner", "register_planner",
           "get_planner", "available_planners", "planner_info",
           "planners_for_family", "RECURRENT_FAMILIES"]

#: model families whose step builders require ``preserves_token_order``
#: planners (SSM state flows rank i -> i+1 across the CP axis)
RECURRENT_FAMILIES = ("hybrid", "ssm")


@dataclasses.dataclass(frozen=True)
class PlannerInfo:
    """Capability metadata attached to every registered planner."""

    name: str
    description: str = ""
    comm_style: str = "flashcp"       # comm style stamped on plans
    exec_style: str = "flashcp"       # strategy name for step builders
    needs_equal_tokens: bool = True   # plans satisfy Eq. 2 exactly
    order_invariant: bool = False     # plan depends only on length multiset
    preserves_token_order: bool = False
    supports_target_ratio: bool = False
    cost_hint: str = "vectorized"     # "vectorized" | "search" | "exponential"
    #: packed context must be a multiple of ``context_multiple * N``
    #: (llama3's 2N zigzag chunking; 1 for everyone else)
    context_multiple: int = 1
    aliases: tuple[str, ...] = ()


@runtime_checkable
class Planner(Protocol):
    """A CP sharding strategy: lengths + worker count -> ShardingPlan."""

    info: PlannerInfo

    def __call__(self, doc_lens, num_workers: int, *,
                 validate: bool = True, **kwargs) -> ShardingPlan:
        ...


class RegisteredPlanner:
    """Callable wrapper binding a planner function to its metadata."""

    __slots__ = ("info", "_fn")

    def __init__(self, info: PlannerInfo, fn: Callable[..., ShardingPlan]):
        self.info = info
        self._fn = fn

    def __call__(self, doc_lens, num_workers: int, *, validate: bool = True,
                 **kwargs) -> ShardingPlan:
        return self._fn(np.asarray(doc_lens, dtype=np.int64),
                        int(num_workers), validate=validate, **kwargs)

    def __repr__(self) -> str:
        return f"<planner {self.info.name!r} ({self.info.comm_style})>"


_REGISTRY: dict[str, RegisteredPlanner] = {}
_ALIASES: dict[str, str] = {}


def register_planner(name: str, *, aliases: tuple[str, ...] = (),
                     **info_kwargs) -> Callable:
    """Decorator registering ``fn`` as planner ``name``.

    Returns the original function unchanged, so direct imports keep
    working; registry consumers get the :class:`RegisteredPlanner` wrapper
    (with ``.info``) via :func:`get_planner`.
    """
    def deco(fn: Callable[..., ShardingPlan]) -> Callable[..., ShardingPlan]:
        if name in _REGISTRY:
            raise ValueError(f"planner {name!r} already registered")
        info = PlannerInfo(name=name, aliases=tuple(aliases), **info_kwargs)
        _REGISTRY[name] = RegisteredPlanner(info, fn)
        for alias in aliases:
            if alias in _REGISTRY or alias in _ALIASES:
                raise ValueError(f"planner alias {alias!r} already taken")
            _ALIASES[alias] = name
        return fn

    return deco


def get_planner(name: str) -> RegisteredPlanner:
    """Resolve a planner by name or alias.

    Raises ``KeyError`` listing the available planners on unknown names —
    the error the launchers surface for a mistyped ``--strategy``.
    """
    key = _ALIASES.get(name, name)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown planner {name!r}; available: "
            f"{available_planners(include_aliases=True)}") from None


def available_planners(*, include_aliases: bool = False) -> list[str]:
    names = list(_REGISTRY)
    if include_aliases:
        names += list(_ALIASES)
    return sorted(names)


def planner_info(name: str) -> PlannerInfo:
    return get_planner(name).info


def planners_for_family(family: str) -> list[str]:
    """Registered planner names whose capability metadata admits a model
    family: recurrent families (:data:`RECURRENT_FAMILIES`) require
    ``preserves_token_order``; every other family admits any planner.

    :func:`repro.launch.steps.effective_strategy` *swaps* an inadmissible
    request for ``contiguous`` at step-build time; the autotuner uses this
    list to never emit the inadmissible candidate in the first place
    (DESIGN.md §Autotune).
    """
    return [name for name in available_planners()
            if family not in RECURRENT_FAMILIES
            or get_planner(name).info.preserves_token_order]
