"""Plan cache keyed by a quantized document-length signature.

Long-context training streams draw packed sequences from a stationary
length distribution, so the *same mixes keep recurring* (DCP, arXiv
2510.10620, builds its whole planner tier around this observation).
``PlanCache`` exploits it on the host:

* **signature** — the sorted document lengths, optionally bucketed to a
  configurable ``granularity`` (ceil to multiples of g), plus the exact
  context length and CP size.  For planners whose
  :class:`~repro.planner.registry.PlannerInfo` declares
  ``order_invariant=True`` (FlashCP, Per-Doc, B&B) the signature sorts the
  lengths — two packings of the same length multiset share one entry, and
  the cached plan is re-labelled through the sort permutation on the way
  out.  Position-dependent planners (Llama3, contiguous) keep the packed
  order in the key.
* **exact hit** — the stored plan's document lengths match exactly: the
  plan is returned with doc ids remapped to the query's packing order.
  The first miss stores the *actual planner output* untouched, so a
  cache-enabled pipeline is plan-identical to a cache-disabled one on
  cold paths.
* **quantized hit** (``granularity > 1``) — the signature matches but the
  exact lengths differ by less than one bucket per document: the cached
  shard layout is *adapted* — per-document boundaries clamped to the new
  lengths, then the heuristic's equal-token repair restores Eq. 2 — and
  validated.  If adaptation fails validation the query falls back to a
  full re-plan (counted as a miss).

Entries are LRU-evicted; hit/miss/adapt statistics are exported for the
pipeline's per-batch stats.  All public methods are thread-safe — the
prefetcher plans sequences from a worker pool.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import numpy as np

from .heuristic import _ArrayState, _repair_equal_tokens
from .plan import ShardArrays, ShardingPlan, validate_plan
from .registry import RegisteredPlanner, get_planner

__all__ = ["PlanCache", "CacheStats"]


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    quantized_hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.quantized_hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return (self.hits + self.quantized_hits) / n if n else 0.0


@dataclasses.dataclass
class _Entry:
    lens: np.ndarray          # canonical doc lengths the plan was built for
    arrays: ShardArrays       # shards in canonical doc-id space
    comm_style: str


class PlanCache:
    """Memoizes ``planner(doc_lens, num_workers)`` across packed sequences."""

    def __init__(self, planner: str | RegisteredPlanner, num_workers: int,
                 *, granularity: int = 1, max_entries: int = 1024,
                 planner_kwargs: dict | None = None):
        self.planner = get_planner(planner) if isinstance(planner, str) \
            else planner
        self.num_workers = int(num_workers)
        self.granularity = max(int(granularity), 1)
        self.max_entries = int(max_entries)
        self.planner_kwargs = dict(planner_kwargs or {})
        self.stats = CacheStats()
        self._entries: OrderedDict[tuple, _Entry] = OrderedDict()
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ #
    def signature(self, doc_lens) -> tuple[tuple, np.ndarray]:
        """(cache key, canonical permutation) for one packed sequence.

        ``perm`` maps canonical doc index -> query doc index; identity for
        position-dependent planners.
        """
        lens = np.asarray(doc_lens, dtype=np.int64)
        if self.planner.info.order_invariant:
            perm = np.lexsort((np.arange(len(lens)), -lens))
        else:
            perm = np.arange(len(lens))
        canonical = lens[perm]
        g = self.granularity
        q = canonical if g == 1 else -(-canonical // g) * g
        key = (self.planner.info.name, self.num_workers, int(lens.sum()),
               q.tobytes())
        return key, perm

    # ------------------------------------------------------------------ #
    def plan(self, doc_lens) -> ShardingPlan:
        lens = np.asarray(doc_lens, dtype=np.int64)
        key, perm = self.signature(lens)
        canonical = lens[perm]

        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
        if entry is not None:
            if np.array_equal(entry.lens, canonical):
                with self._lock:
                    self.stats.hits += 1
                return self._materialize(entry.arrays, lens, perm,
                                         entry.comm_style)
            adapted = self._adapt(entry, canonical)
            if adapted is not None:
                with self._lock:
                    self.stats.quantized_hits += 1
                return self._materialize(adapted, lens, perm,
                                         entry.comm_style)

        # miss: run the planner on the query as-is, store canonically.
        plan = self.planner(lens, self.num_workers, **self.planner_kwargs)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(len(perm))
        stored = ShardArrays(inv[plan.arrays.doc_id], plan.arrays.start,
                             plan.arrays.length, plan.arrays.worker)
        with self._lock:
            self.stats.misses += 1
            self._entries[key] = _Entry(lens=canonical, arrays=stored,
                                        comm_style=plan.comm_style)
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
        return plan

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------------ #
    def _materialize(self, arrays: ShardArrays, lens: np.ndarray,
                     perm: np.ndarray, comm_style: str) -> ShardingPlan:
        """Relabel a canonical-space plan into the query's packing order."""
        remapped = ShardArrays(perm[arrays.doc_id], arrays.start.copy(),
                               arrays.length.copy(), arrays.worker.copy())
        return ShardingPlan(doc_lens=lens, arrays=remapped,
                            num_workers=self.num_workers,
                            comm_style=comm_style)

    def _adapt(self, entry: _Entry, canonical: np.ndarray
               ) -> ShardArrays | None:
        """Re-fit a cached shard layout to slightly different doc lengths.

        Per document: shard boundaries are clamped to the new length (the
        last surviving shard absorbs the difference), then the equal-token
        repair restores Eq. 2 if the planner requires it.  Returns None if
        the adapted plan fails validation — the caller re-plans.
        """
        new_total = int(canonical.sum())
        if len(entry.lens) != len(canonical) \
                or new_total % self.num_workers != 0:
            return None
        try:
            a = entry.arrays.sorted_by_doc()
            new_len_of = canonical[a.doc_id]
            start = np.minimum(a.start, new_len_of)
            end = np.minimum(a.end, new_len_of)
            # last shard of each doc (sorted order) stretches to the new end
            is_doc_last = np.ones(len(a), dtype=bool)
            if len(a) > 1:
                is_doc_last[:-1] = a.doc_id[:-1] != a.doc_id[1:]
            end = np.where(is_doc_last, new_len_of, end)
            length = end - start
            keep = length > 0
            adapted = ShardArrays(a.doc_id[keep], start[keep], length[keep],
                                  a.worker[keep])

            state = _ArrayState(self.num_workers,
                                np.zeros(self.num_workers, np.int64),
                                np.zeros(self.num_workers, np.float64),
                                canonical)
            for d, s, l, w in zip(adapted.doc_id, adapted.start,
                                  adapted.length, adapted.worker):
                state.add(int(d), int(s), int(l), int(w))
            if self.planner.info.needs_equal_tokens:
                _repair_equal_tokens(state, new_total // self.num_workers)
            out = state.to_arrays().merged()
            probe = ShardingPlan(doc_lens=canonical, arrays=out,
                                 num_workers=self.num_workers,
                                 comm_style=entry.comm_style)
            validate_plan(
                probe,
                require_equal_tokens=self.planner.info.needs_equal_tokens,
                token_tolerance=self.num_workers)
            return out
        except (AssertionError, RuntimeError):
            return None
