"""Exact reference optimizer for the sharding problem (paper §3.4, Table 2).

The paper formulates sharding as an ILP over shard-to-worker assignments
(Eq. 1-3 plus the Eq. 5 communication term) and reports that a commercial
solver needs tens of minutes per sequence.  No MILP package is available in
this offline environment, so this module provides an **exact branch-and-bound
search** with the same role: an optimality reference against which the
heuristic's communication saving and imbalance ratio are judged
(benchmarks/bench_ilp_vs_heuristic.py).

Search space: every document is assigned whole to one of the N workers
(branching, with worker-symmetry breaking and feasibility pruning); each
complete assignment is made Eq.2-feasible with the deterministic minimal
head-cut repair operator shared with the heuristic
(:func:`repro.planner.heuristic._repair_equal_tokens`).  The objective

    J(plan) = imbalance_ratio(plan) + lambda_comm * comm_tokens / (C / N)

is evaluated exactly on the repaired plan.  The search is exact over this
(assignment x repair-policy) space; for the small instances used in the
Table-2 comparison it explores the full tree within the node budget.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .heuristic import _ArrayState, _repair_equal_tokens
from .plan import ShardingPlan, validate_plan
from .registry import register_planner

__all__ = ["bnb_plan", "BnBResult"]


@dataclasses.dataclass
class BnBResult:
    plan: ShardingPlan
    objective: float
    nodes_explored: int
    proven_optimal: bool


def _evaluate(doc_lens: np.ndarray, assignment: list[int], num_workers: int,
              lambda_comm: float) -> tuple[float, ShardingPlan]:
    """Build + repair a plan for a complete whole-doc assignment; score it."""
    state = _ArrayState(num_workers,
                        np.zeros(num_workers, np.int64),
                        np.zeros(num_workers, np.float64), doc_lens)
    for did, w in enumerate(assignment):
        state.add(did, 0, int(doc_lens[did]), w)
    target = int(doc_lens.sum()) // num_workers
    _repair_equal_tokens(state, target)
    plan = ShardingPlan(doc_lens=doc_lens, arrays=state.to_arrays().merged(),
                        num_workers=num_workers, comm_style="flashcp")
    obj = plan.imbalance_ratio() + lambda_comm * plan.comm_tokens() / target
    return obj, plan


def bnb_plan(
    doc_lens: Sequence[int],
    num_workers: int,
    *,
    lambda_comm: float = 0.5,
    max_nodes: int = 2_000_000,
    validate: bool = True,
) -> BnBResult:
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    n = len(doc_lens)
    N = num_workers
    ctx = int(doc_lens.sum())
    assert ctx % N == 0
    target = ctx // N
    total_work = float(sum((d + 1) * d / 2.0 for d in doc_lens))

    # docs in decreasing length: big decisions first => strong pruning.
    order = sorted(range(n), key=lambda i: (-int(doc_lens[i]), i))

    best_obj = np.inf
    best_assignment: list[int] | None = None
    nodes = 0
    exhausted = True

    # incumbent from the heuristic (greedy LPT by workload) to prune early.
    from .heuristic import flashcp_plan

    heur_plan, _ = flashcp_plan(doc_lens, N, validate=False)
    heur_obj = heur_plan.imbalance_ratio() + \
        lambda_comm * heur_plan.comm_tokens() / target

    # Global lower bound: perfect balance, zero comm -> J >= 1.0.
    global_lb = 1.0

    tokens = np.zeros(N, dtype=np.int64)
    work = np.zeros(N, dtype=np.float64)
    assignment_by_doc = [0] * n

    def dfs(idx: int, used_workers: int) -> None:
        nonlocal best_obj, best_assignment, nodes, exhausted
        nodes += 1
        if nodes > max_nodes:
            exhausted = False
            return
        if best_obj <= global_lb + 1e-12:
            return
        if idx == n:
            obj, _ = _evaluate(doc_lens, assignment_by_doc, N, lambda_comm)
            if obj < best_obj:
                best_obj = obj
                best_assignment = list(assignment_by_doc)
            return

        did = order[idx]
        d = int(doc_lens[did])
        remaining = int(doc_lens[[order[k] for k in range(idx + 1, n)]].sum()) \
            if idx + 1 < n else 0

        # bound: the *workload* part of J can never beat
        # max(current max work, total/N) / (total/N); comm part >= 0.
        lb_work = max(float(np.max(work)) - _max_sheddable(work, tokens, target),
                      total_work / N)
        if lb_work / (total_work / N) >= best_obj - 1e-12:
            return

        # candidate workers: all used ones + one fresh (symmetry breaking),
        # least-loaded first for good incumbents.
        cand = list(range(min(used_workers + 1, N)))
        cand.sort(key=lambda j: work[j])
        for j in cand:
            # feasibility: worker token excess beyond target can always be
            # repaired by cuts, but if *deficits elsewhere* cannot absorb
            # remaining + excess, prune.
            tokens[j] += d
            work[j] += (d + 1) * d / 2.0
            total_excess = int(np.maximum(tokens - target, 0).sum())
            total_deficit = int(np.maximum(target - tokens, 0).sum())
            if total_excess <= total_deficit + remaining:
                assignment_by_doc[did] = j
                dfs(idx + 1, max(used_workers, j + 1))
            tokens[j] -= d
            work[j] -= (d + 1) * d / 2.0
            if nodes > max_nodes:
                exhausted = False
                break

    def _max_sheddable(work: np.ndarray, tokens: np.ndarray, target: int) -> float:
        """Upper bound on workload the max-loaded worker could shed via
        head cuts during repair (tokens above target, each moving at most a
        full-document triangle's per-token share).  Conservative: assume a
        token cut can shed up to `max doc len` pair-evaluations."""
        j = int(np.argmax(work))
        excess = max(int(tokens[j]) - target, 0)
        return float(excess) * float(doc_lens.max() if len(doc_lens) else 0)

    dfs(0, 0)

    if best_assignment is None or heur_obj < best_obj:
        # heuristic beat (or search never completed a leaf) — fall back.
        plan = heur_plan
        best_obj = min(best_obj, heur_obj)
        if validate:
            validate_plan(plan)
        return BnBResult(plan=plan, objective=float(heur_obj),
                         nodes_explored=nodes, proven_optimal=False)

    _, plan = _evaluate(doc_lens, best_assignment, N, lambda_comm)
    if validate:
        validate_plan(plan)
    return BnBResult(plan=plan, objective=float(best_obj),
                     nodes_explored=nodes, proven_optimal=exhausted)


@register_planner(
    "bnb", aliases=("ilp",),
    description="Exact branch-and-bound optimality reference (paper §3.4 "
                "ILP analogue); small instances only",
    comm_style="flashcp", exec_style="flashcp",
    order_invariant=True, cost_hint="exponential")
def _bnb_adapter(doc_lens, num_workers, *, validate=True,
                 lambda_comm: float = 0.5, max_nodes: int = 2_000_000):
    return bnb_plan(doc_lens, num_workers, lambda_comm=lambda_comm,
                    max_nodes=max_nodes, validate=validate).plan
