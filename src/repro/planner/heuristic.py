"""FlashCP heuristic sharding algorithm (paper Algorithm 1), vectorized.

Faithful structure:

  1. Sort documents by decreasing length.
  2. Greedy LPT: assign each *whole* document to the CP worker with the
     minimum attention workload (``Min_Worker_Add``).
  3. Equal-token repair (``Whole_Doc_Shard_and_Add``): while token counts
     are unequal, move tokens from over-full to under-full workers.  Two
     move kinds, cheapest first:
       (a) relocate a whole document (zero communication cost);
       (b) cut a *head piece* off a document and move it — the donated head
           becomes a non-last shard (communication ∝ its length, the
           paper's Δl), while the bulk tail stays in place as a last shard
           (never communicated).
  4. If the resulting workload imbalance ratio exceeds the target ``R``,
     pop the longest document into the *Per-Doc* set (zigzag 2N-chunk
     sharding, perfectly balanced) and repeat from 2 with the remainder.

Vectorization (this is the training-critical host path — it runs per
packed sequence inside the input pipeline):

* the mutable piece table is a structure-of-arrays (:class:`_ArrayState`)
  — every repair/exchange decision is an ``argmin``/``lexsort`` over
  numpy arrays instead of list comprehensions over piece objects;
* the Per-Doc zigzag base load is maintained **incrementally** across
  outer iterations (the seed rebuilt it from scratch each time a document
  was popped, which is quadratic in the number of popped documents);
* decision parity with the seed implementation is exact: insertion order,
  tie-breaking (first minimum in iteration order), and float arithmetic
  (all workloads are multiples of 0.5 below 2**53, hence exact in
  float64) are preserved, and ``tests/test_planner_registry.py`` asserts
  shard-for-shard identical plans against :mod:`repro.planner.reference`.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Sequence

import numpy as np

from .plan import (Shard, ShardArrays, ShardingPlan, validate_plan)

__all__ = ["flashcp_plan", "zigzag_doc_shards", "HeuristicStats",
           "_ArrayState", "_repair_equal_tokens"]


@dataclasses.dataclass
class HeuristicStats:
    outer_iterations: int
    per_doc_docs: int
    whole_docs: int
    cut_docs: int
    imbalance_ratio: float
    comm_tokens: int


# --------------------------------------------------------------------- #
# Per-Doc zigzag sharding (used for extreme documents and by baselines)
# --------------------------------------------------------------------- #
def _zigzag_chunks(doc_len: int, num_workers: int):
    """(sizes, worker_of) for the 2N zigzag chunks of one document."""
    n2 = 2 * num_workers
    base, rem = divmod(doc_len, n2)
    sizes = np.full(n2, base, np.int64)
    sizes[:rem] += 1
    c = np.arange(n2)
    worker_of = np.where(c < num_workers, c, n2 - 1 - c)
    return sizes, worker_of


def _merge_chunk_run(doc_id: int, sizes: np.ndarray, worker_of: np.ndarray
                     ) -> ShardArrays:
    """Merge contiguous same-worker zigzag chunks of one doc (vectorized)."""
    starts = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    keep = sizes > 0
    starts, lens, workers = starts[keep], sizes[keep], worker_of[keep]
    if len(lens) == 0:
        return ShardArrays.empty()
    # chunks are contiguous by construction: a run boundary is a worker
    # change (zero-size chunks were dropped but leave no gaps)
    new_run = np.ones(len(lens), dtype=bool)
    new_run[1:] = workers[1:] != workers[:-1]
    idx = np.nonzero(new_run)[0]
    return ShardArrays(np.full(len(idx), doc_id, np.int64), starts[idx],
                       np.add.reduceat(lens, idx), workers[idx])


def zigzag_doc_shards(doc_id: int, doc_len: int, num_workers: int
                      ) -> list[Shard]:
    """Split one document into 2N chunks; worker i gets chunks i and 2N-1-i.

    Pairing an early (cheap) with a late (expensive) chunk balances the
    causal attention workload across workers — the standard zigzag scheme
    of Per-Doc CP / Ring-Attn (Zigzag).
    """
    sizes, worker_of = _zigzag_chunks(doc_len, num_workers)
    return _merge_chunk_run(doc_id, sizes, worker_of).to_shards()


# --------------------------------------------------------------------- #
# internal mutable state for the whole-doc phase
# --------------------------------------------------------------------- #
class _ArrayState:
    """Piece table bucketed by worker; converts to ShardArrays on exit.

    The seed kept one flat piece list and scanned *all* pieces on every
    repair decision; here pieces additionally live in per-worker index
    buckets, so each decision scans only the donor's O(P/N) pieces — and
    the decision loops are plain Python (at a handful of pieces per worker,
    interpreter arithmetic beats numpy dispatch by an order of magnitude).

    Decision parity with the seed is exact: ``by_worker[j]`` holds global
    piece indices in ascending order, which IS the seed's insertion order
    restricted to worker j (moves re-insert in index order via bisect), so
    every first-minimum tie-break matches; all workloads are multiples of
    0.5 below 2**53, hence float64-exact regardless of summation order.
    """

    __slots__ = ("N", "doc_lens", "tokens", "work", "n",
                 "doc", "start", "length", "worker", "by_worker")

    def __init__(self, num_workers: int, base_tokens, base_workload,
                 doc_lens=None):
        self.N = num_workers
        self.doc_lens = None if doc_lens is None \
            else [int(d) for d in doc_lens]
        self.tokens = [int(t) for t in base_tokens]
        self.work = [float(w) for w in base_workload]
        self.n = 0
        self.doc: list[int] = []
        self.start: list[int] = []
        self.length: list[int] = []
        self.worker: list[int] = []
        self.by_worker: list[list[int]] = [[] for _ in range(num_workers)]

    # mutations (same token/work bookkeeping as the seed) ---------------- #
    def add(self, doc_id: int, start: int, length: int, worker: int) -> None:
        doc_id, start, length, worker = \
            int(doc_id), int(start), int(length), int(worker)
        self.doc.append(doc_id)
        self.start.append(start)
        self.length.append(length)
        self.worker.append(worker)
        self.by_worker[worker].append(self.n)
        self.n += 1
        self.tokens[worker] += length
        self.work[worker] += (2 * start + length + 1) * length / 2.0

    def move(self, i: int, worker: int) -> None:
        ln = self.length[i]
        w = (2 * self.start[i] + ln + 1) * ln / 2.0
        old = self.worker[i]
        self.tokens[old] -= ln
        self.work[old] -= w
        self.by_worker[old].remove(i)
        self.worker[i] = worker
        self.tokens[worker] += ln
        self.work[worker] += w
        bisect.insort(self.by_worker[worker], i)

    def cut_head(self, i: int, size: int, receiver: int) -> None:
        """Split ``size`` tokens off the front of piece ``i``; move the head
        to ``receiver``.  The tail stays put (its prefix grows)."""
        assert 0 < size < self.length[i]
        donor = self.worker[i]
        head = (self.doc[i], self.start[i], size)
        s, ln = self.start[i], self.length[i]
        old_w = (2 * s + ln + 1) * ln / 2.0
        s += size
        ln -= size
        self.start[i], self.length[i] = s, ln
        self.tokens[donor] -= size
        self.work[donor] += (2 * s + ln + 1) * ln / 2.0 - old_w
        self.add(head[0], head[1], head[2], receiver)

    def cut_tail(self, i: int, size: int, receiver: int) -> None:
        """Split ``size`` tokens off the end of piece ``i``; move the tail to
        ``receiver``.  Cheaper than a head cut when size > length/2: the
        moved tail keeps the piece's last-shard status (never sent)."""
        assert 0 < size < self.length[i]
        donor = self.worker[i]
        s, ln = self.start[i], self.length[i]
        tail = (self.doc[i], s + ln - size, size)
        old_w = (2 * s + ln + 1) * ln / 2.0
        ln -= size
        self.length[i] = ln
        self.tokens[donor] -= size
        self.work[donor] += (2 * s + ln + 1) * ln / 2.0 - old_w
        self.add(tail[0], tail[1], tail[2], receiver)

    # derived ------------------------------------------------------------ #
    def is_last(self, i: int) -> bool:
        if self.doc_lens is None:
            return True
        return self.start[i] + self.length[i] == self.doc_lens[self.doc[i]]

    def to_arrays(self) -> ShardArrays:
        return ShardArrays(np.asarray(self.doc, np.int64),
                           np.asarray(self.start, np.int64),
                           np.asarray(self.length, np.int64),
                           np.asarray(self.worker, np.int64))


# --------------------------------------------------------------------- #
# the algorithm
# --------------------------------------------------------------------- #
def flashcp_plan(
    doc_lens: Sequence[int],
    num_workers: int,
    *,
    target_ratio: float = 1.05,
    max_outer_iters: int | None = None,
    validate: bool = True,
) -> tuple[ShardingPlan, HeuristicStats]:
    """Run Algorithm 1 and return (plan, stats).

    ``doc_lens`` must sum to a context length divisible by ``num_workers``.
    """
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    n = len(doc_lens)
    ctx = int(doc_lens.sum())
    N = num_workers
    assert ctx % N == 0, f"context {ctx} not divisible by CP size {N}"
    per_worker = ctx // N
    if max_outer_iters is None:
        max_outer_iters = n + 1

    # documents sorted by decreasing length (line 1); ties broken by id for
    # determinism.
    order = np.lexsort((np.arange(n), -doc_lens))

    # ---- per-doc zigzag base load (docs popped at line 22), maintained
    # incrementally: each outer iteration pops exactly one document, so the
    # base state only ever *grows* — the 2N-chunk remainders are allocated
    # jointly, each doc's extra tokens going to the chunks of the currently
    # least-loaded workers, keeping the per-doc base within ±1 token. ----- #
    base_tokens = np.zeros(N, dtype=np.int64)
    base_work = np.zeros(N, dtype=np.float64)
    per_doc_parts: list[ShardArrays] = []
    per_doc_count = 0

    remaining = list(order)
    state: _ArrayState | None = None
    outer = 0
    pending_pop: int | None = None
    while True:
        outer += 1
        if pending_pop is not None:
            d = int(doc_lens[pending_pop])
            sizes, worker_of = _zigzag_chunks_joint(d, N, base_tokens)
            part = _merge_chunk_run(pending_pop, sizes, worker_of)
            per_doc_parts.append(part)
            np.add.at(base_tokens, part.worker, part.length)
            np.add.at(base_work, part.worker, part.workload())
            per_doc_count += 1
            pending_pop = None

        # ---- lines 5-9: greedy whole-doc LPT by attention workload ------ #
        state = _ArrayState(N, base_tokens, base_work, doc_lens)
        work = state.work
        rng_N = range(N)
        for did in remaining:
            j = min(rng_N, key=work.__getitem__)
            state.add(int(did), 0, int(doc_lens[did]), j)

        # ---- lines 10-16: equal-token repair ---------------------------- #
        _repair_equal_tokens(state, per_worker)

        # ---- beyond-paper refinement: comm-free workload exchange ------- #
        # Moving pieces between workers changes no shard's last-ness, so it
        # is (near-)free in Eq. 5 terms; exchanging a high-prefix piece on
        # the hottest worker against low-workload pieces on the coldest
        # often reaches the target ratio without popping documents into
        # Per-Doc sharding (which is what costs communication).
        _workload_exchange(state, per_worker, target_ratio)

        # ---- line 18: imbalance ratio of the full temporary plan -------- #
        work = state.work
        cur_ratio = max(work) / max(sum(work) / N, 1e-9)

        if cur_ratio <= target_ratio or not remaining \
                or outer >= max_outer_iters:
            break
        # ---- lines 19-23: pop the longest doc, shard it Per-Doc --------- #
        pending_pop = int(remaining.pop(0))

    # ---- build the final ShardingPlan ----------------------------------- #
    arrays = ShardArrays.concatenate(per_doc_parts + [state.to_arrays()])
    arrays = arrays.merged()
    plan = ShardingPlan(doc_lens=doc_lens, arrays=arrays, num_workers=N,
                        comm_style="flashcp")
    if validate:
        validate_plan(plan, token_tolerance=0 if not per_doc_count else N)

    whole = (arrays.start == 0) & (arrays.length == doc_lens[arrays.doc_id])
    whole_docs = len(np.unique(arrays.doc_id[whole]))
    stats = HeuristicStats(
        outer_iterations=outer,
        per_doc_docs=per_doc_count,
        whole_docs=whole_docs,
        cut_docs=n - whole_docs,
        imbalance_ratio=plan.imbalance_ratio(),
        comm_tokens=plan.comm_tokens(),
    )
    return plan, stats


def _zigzag_chunks_joint(doc_len: int, num_workers: int,
                         base_tokens: np.ndarray):
    """Zigzag chunk sizes with the remainder tokens routed to the chunks of
    the currently least-loaded workers (ties by chunk index)."""
    n2 = 2 * num_workers
    base, rem = divmod(doc_len, n2)
    sizes = np.full(n2, base, np.int64)
    c = np.arange(n2)
    worker_of = np.where(c < num_workers, c, n2 - 1 - c)
    if rem:
        chunk_order = np.lexsort((c, base_tokens[worker_of]))
        sizes[chunk_order[:rem]] += 1
    return sizes, worker_of


# --------------------------------------------------------------------- #
def _workload_exchange(state: _ArrayState, target_tokens: int,
                       target_ratio: float, max_iters: int = 40) -> None:
    """Reduce the attention-workload imbalance by exchanging pieces between
    the hottest and coldest workers (token counts re-repaired after each
    exchange).  Exchanges never change a piece's last-shard status, so the
    Eq. 5 communication set is essentially unchanged."""
    rng_n = range(state.N)
    for _ in range(max_iters):
        work = state.work
        mean = sum(work) / state.N
        if mean <= 0 or max(work) / mean <= target_ratio:
            return
        hot = max(rng_n, key=work.__getitem__)
        cold = min(rng_n, key=work.__getitem__)
        hidx = state.by_worker[hot]
        cidx = state.by_worker[cold]
        if not hidx:
            return
        gap = work[hot] - work[cold]

        # best single-piece exchange (B may be 'nothing' — the trailing 0
        # column); row-major argmin == first minimum in the seed's nested
        # iteration order, so tie-breaking matches exactly.
        st, ln = state.start, state.length
        wa = np.array([(2 * st[i] + ln[i] + 1) * ln[i] / 2.0 for i in hidx])
        wb = np.array([(2 * st[i] + ln[i] + 1) * ln[i] / 2.0 for i in cidx]
                      + [0.0])
        delta = wa[:, None] - wb[None, :]
        score = np.abs(gap - 2.0 * delta)
        score[(delta <= 0) | (delta >= gap)] = np.inf  # must shrink the gap
        k = int(np.argmin(score))
        if not np.isfinite(score.flat[k]):
            return
        a, b = divmod(k, len(wb))
        # capture piece ids before the first move: hidx/cidx alias the
        # live per-worker buckets, which the move mutates.
        ia = hidx[a]
        ib = cidx[b] if b < len(cidx) else None
        state.move(ia, cold)
        if ib is not None:
            state.move(ib, hot)
        _repair_equal_tokens(state, target_tokens)


def _repair_equal_tokens(state: _ArrayState, target: int) -> None:
    """``Whole_Doc_Shard_and_Add``: equalize token counts to ``target``.

    Strategy (cheapest communication first):
      1. relocate whole pieces donor→receiver when one fits the excess and
         the deficit (zero communication);
      2. cut head pieces of size min(excess, deficit) and move them (the
         donated head is a non-last shard; communication ∝ head length).

    Heads are preferentially cut from the piece whose transferred workload
    best levels the two workers' attention workloads, so token repair also
    nudges workload balance (Fig. 4(2) right: several small Δl cuts).
    """
    tokens = state.tokens
    work = state.work
    start = state.start
    length = state.length
    N = state.N
    rng_n = range(N)
    guard = 0
    while True:
        guard += 1
        if guard > 100_000:  # pragma: no cover - safety net
            raise RuntimeError("token repair failed to converge")
        # donor/receiver of excess - target: argmax/argmin commute with the
        # constant shift, so work on raw token counts.
        donor = max(rng_n, key=tokens.__getitem__)
        excess_d = tokens[donor] - target
        if excess_d <= 0:
            assert excess_d == 0 and min(tokens) == target, \
                f"tokens drifted: {tokens}"
            return
        receiver = min(rng_n, key=tokens.__getitem__)
        need = min(excess_d, target - tokens[receiver])
        assert need > 0

        donor_pieces = state.by_worker[donor]
        if not donor_pieces:
            # the excess sits entirely in per-doc zigzag base load (off by
            # at most a few tokens after joint remainder allocation);
            # execution-side padding absorbs it (plan_exec).
            return
        # (1) whole-piece relocation: largest piece that fits both sides.
        best_fit = -1
        fit_len = 0
        for i in donor_pieces:
            ln = length[i]
            if ln <= need and ln > fit_len:
                best_fit, fit_len = i, ln
        if best_fit >= 0:
            state.move(best_fit, receiver)
            continue

        # (2) cut exactly `need` tokens off a piece.  Direction matters for
        # communication (Eq. 5):
        #   - cutting a piece that is already non-last adds NOTHING (its
        #     tokens were all in the send set already);
        #   - a last piece pays min(need, len - need): move the head (head
        #     joins the send set) or move the tail (the remaining head
        #     joins the send set) — pick the cheaper side.
        # Ties are broken toward leveling the donor/receiver workloads.
        # (Every donor piece has length > need here.)
        gap = work[donor] - work[receiver]
        doc_lens = state.doc_lens
        doc = state.doc
        best = None
        best_i = -1
        best_tail = False
        for i in donor_pieces:
            s, ln = start[i], length[i]
            rest = ln - need
            last = doc_lens is None or s + ln == doc_lens[doc[i]]
            if last:
                added = need if need < rest else rest
                tail = rest < need
            else:
                added = 0
                tail = False
            pfx = s + rest if tail else s
            level = abs(gap - (2 * pfx + need + 1) * need)  # 2*moved
            key = (added, level)
            if best is None or key < best:
                best, best_i, best_tail = key, i, tail
        if best_tail:
            state.cut_tail(best_i, need, receiver)
        else:
            state.cut_head(best_i, need, receiver)
