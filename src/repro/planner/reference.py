"""Frozen seed implementations of the FlashCP planning stack.

This module is a self-contained, loop-based copy of the original (pre-SoA)
planner code: the ``Shard``-object data structures, Algorithm 1, the three
baselines, and the plan encoder.  It exists for two reasons:

* **golden parity** — ``tests/test_planner_registry.py`` asserts that the
  vectorized planners in :mod:`repro.planner` emit shard-for-shard identical
  plans to these references across seeds, datasets, and CP sizes;
* **speedup accounting** — ``benchmarks/bench_planner_runtime.py`` times
  this code as the baseline for the planning+encoding speedup it reports.

Do not "optimize" this file; it is the specification the fast path is
checked against.  Production code must import from :mod:`repro.planner`.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "RefShard",
    "RefShardingPlan",
    "ref_flashcp_plan",
    "ref_llama3_plan",
    "ref_per_doc_plan",
    "ref_ring_zigzag_plan",
    "ref_contiguous_plan",
    "ref_encode_plan",
    "ref_encode_plan_batch",
    "REFERENCE_PLANNERS",
]


def _shard_workload(prefix: int, length: int) -> float:
    return (2 * prefix + length + 1) * length / 2.0


@dataclasses.dataclass(frozen=True)
class RefShard:
    """A contiguous slice of one document, assigned to one CP worker."""

    doc_id: int
    start: int
    length: int
    worker: int

    @property
    def end(self) -> int:
        return self.start + self.length

    def is_last(self, doc_len: int) -> bool:
        return self.end == doc_len

    def workload(self) -> float:
        return _shard_workload(self.start, self.length)


@dataclasses.dataclass
class RefShardingPlan:
    doc_lens: np.ndarray
    shards: list[RefShard]
    num_workers: int
    comm_style: str = "flashcp"

    @property
    def context_len(self) -> int:
        return int(np.sum(self.doc_lens))

    @property
    def num_docs(self) -> int:
        return len(self.doc_lens)

    def tokens_per_worker(self) -> np.ndarray:
        t = np.zeros(self.num_workers, dtype=np.int64)
        for s in self.shards:
            t[s.worker] += s.length
        return t

    def workload_per_worker(self) -> np.ndarray:
        w = np.zeros(self.num_workers, dtype=np.float64)
        for s in self.shards:
            w[s.worker] += s.workload()
        return w

    def imbalance_ratio(self) -> float:
        w = self.workload_per_worker()
        avg = float(np.mean(w))
        if avg == 0.0:
            return 1.0
        return float(np.max(w)) / avg

    def nonlast_tokens_per_worker(self) -> np.ndarray:
        t = np.zeros(self.num_workers, dtype=np.int64)
        for s in self.shards:
            if not s.is_last(int(self.doc_lens[s.doc_id])):
                t[s.worker] += s.length
        return t

    def comm_tokens(self) -> int:
        if self.comm_style == "flashcp":
            return int(np.max(self.nonlast_tokens_per_worker()))
        return self.context_len // self.num_workers


def ref_validate_plan(plan: RefShardingPlan, *, require_equal_tokens=True,
                      token_tolerance: int = 0) -> None:
    by_doc: dict[int, list[RefShard]] = {}
    for s in plan.shards:
        assert s.length > 0, f"empty shard {s}"
        assert 0 <= s.worker < plan.num_workers, f"bad worker in {s}"
        assert 0 <= s.doc_id < plan.num_docs, f"bad doc_id in {s}"
        by_doc.setdefault(s.doc_id, []).append(s)

    assert set(by_doc) == set(range(plan.num_docs)), "missing documents"
    for doc_id, shards in by_doc.items():
        shards = sorted(shards, key=lambda s: s.start)
        pos = 0
        for s in shards:
            assert s.start == pos
            pos = s.end
        assert pos == int(plan.doc_lens[doc_id])

    if require_equal_tokens:
        t = plan.tokens_per_worker()
        c = plan.context_len
        n = plan.num_workers
        assert c % n == 0
        assert int(t.max() - c // n) <= token_tolerance \
            and int(c // n - t.min()) <= token_tolerance


def ref_merge_adjacent_shards(shards: Iterable[RefShard]) -> list[RefShard]:
    out: list[RefShard] = []
    for s in sorted(shards, key=lambda s: (s.doc_id, s.start)):
        if out and out[-1].doc_id == s.doc_id and out[-1].end == s.start \
                and out[-1].worker == s.worker:
            prev = out.pop()
            s = RefShard(s.doc_id, prev.start, prev.length + s.length,
                         s.worker)
        out.append(s)
    return out


# --------------------------------------------------------------------- #
# Algorithm 1 (seed implementation)
# --------------------------------------------------------------------- #
def ref_zigzag_doc_shards(doc_id: int, doc_len: int,
                          num_workers: int) -> list[RefShard]:
    n2 = 2 * num_workers
    base, rem = divmod(doc_len, n2)
    sizes = [base + (1 if c < rem else 0) for c in range(n2)]
    starts = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    shards = []
    for c in range(n2):
        if sizes[c] == 0:
            continue
        worker = c if c < num_workers else n2 - 1 - c
        shards.append(RefShard(doc_id=doc_id, start=int(starts[c]),
                               length=int(sizes[c]), worker=worker))
    return ref_merge_adjacent_shards(shards)


@dataclasses.dataclass
class _Piece:
    doc_id: int
    start: int
    length: int
    worker: int

    @property
    def end(self) -> int:
        return self.start + self.length

    def workload(self) -> float:
        return _shard_workload(self.start, self.length)


class _State:
    def __init__(self, num_workers, base_tokens, base_workload, doc_lens=None):
        self.N = num_workers
        self.pieces: list[_Piece] = []
        self.tokens = np.asarray(base_tokens, dtype=np.int64).copy()
        self.work = np.asarray(base_workload, dtype=np.float64).copy()
        self.doc_lens = doc_lens

    def is_last(self, piece: _Piece) -> bool:
        if self.doc_lens is None:
            return True
        return piece.end == int(self.doc_lens[piece.doc_id])

    def add(self, piece: _Piece) -> None:
        self.pieces.append(piece)
        self.tokens[piece.worker] += piece.length
        self.work[piece.worker] += piece.workload()

    def move(self, piece: _Piece, worker: int) -> None:
        self.tokens[piece.worker] -= piece.length
        self.work[piece.worker] -= piece.workload()
        piece.worker = worker
        self.tokens[worker] += piece.length
        self.work[worker] += piece.workload()

    def cut_head(self, piece: _Piece, size: int, receiver: int) -> _Piece:
        assert 0 < size < piece.length
        donor = piece.worker
        head = _Piece(piece.doc_id, piece.start, size, receiver)
        old_w = piece.workload()
        piece.start += size
        piece.length -= size
        self.tokens[donor] -= size
        self.work[donor] += piece.workload() - old_w
        self.add(head)
        return head

    def cut_tail(self, piece: _Piece, size: int, receiver: int) -> _Piece:
        assert 0 < size < piece.length
        donor = piece.worker
        tail = _Piece(piece.doc_id, piece.end - size, size, receiver)
        old_w = piece.workload()
        piece.length -= size
        self.tokens[donor] -= size
        self.work[donor] += piece.workload() - old_w
        self.add(tail)
        return tail


def _repair_equal_tokens(state: _State, target: int) -> None:
    guard = 0
    while True:
        guard += 1
        if guard > 100_000:  # pragma: no cover
            raise RuntimeError("token repair failed to converge")
        excess = state.tokens - target
        donor = int(np.argmax(excess))
        receiver = int(np.argmin(excess))
        if excess[donor] <= 0:
            assert np.all(excess == 0), f"tokens drifted: {state.tokens}"
            return
        need = int(min(excess[donor], -excess[receiver]))
        assert need > 0

        donor_pieces = [p for p in state.pieces if p.worker == donor]
        if not donor_pieces:
            return
        fits = [p for p in donor_pieces if p.length <= need]
        if fits:
            best = max(fits, key=lambda p: p.length)
            state.move(best, receiver)
            continue

        candidates = [p for p in donor_pieces if p.length > need]
        assert candidates, "no piece can donate a cut"
        gap = state.work[donor] - state.work[receiver]

        def added_comm(p: _Piece) -> int:
            if not state.is_last(p):
                return 0
            return min(need, p.length - need)

        def level_score(p: _Piece) -> float:
            if state.is_last(p) and need > p.length - need:
                moved = _shard_workload(p.end - need, need)
            else:
                moved = _shard_workload(p.start, need)
            return abs(gap - 2.0 * moved)

        best = min(candidates, key=lambda p: (added_comm(p), level_score(p)))
        if state.is_last(best) and need > best.length - need:
            state.cut_tail(best, need, receiver)
        else:
            state.cut_head(best, need, receiver)


def _workload_exchange(state: _State, target_tokens: int,
                       target_ratio: float, max_iters: int = 40) -> None:
    for _ in range(max_iters):
        work = state.work
        mean = float(np.mean(work))
        if mean <= 0 or float(np.max(work)) / mean <= target_ratio:
            return
        hot = int(np.argmax(work))
        cold = int(np.argmin(work))
        hot_pieces = [p for p in state.pieces if p.worker == hot]
        cold_pieces = [p for p in state.pieces if p.worker == cold]
        if not hot_pieces:
            return
        gap = work[hot] - work[cold]

        best = None
        for A in hot_pieces:
            wa = A.workload()
            for B in cold_pieces + [None]:
                wb = B.workload() if B is not None else 0.0
                delta = wa - wb
                if delta <= 0 or delta >= gap:
                    continue
                score = abs(gap - 2 * delta)
                if best is None or score < best[0]:
                    best = (score, A, B)
        if best is None:
            return
        _, A, B = best
        state.move(A, cold)
        if B is not None:
            state.move(B, hot)
        _repair_equal_tokens(state, target_tokens)


def ref_flashcp_plan(doc_lens: Sequence[int], num_workers: int, *,
                     target_ratio: float = 1.05,
                     max_outer_iters: int | None = None,
                     validate: bool = True) -> RefShardingPlan:
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    n = len(doc_lens)
    ctx = int(doc_lens.sum())
    N = num_workers
    assert ctx % N == 0
    per_worker = ctx // N
    if max_outer_iters is None:
        max_outer_iters = n + 1

    order = sorted(range(n), key=lambda i: (-int(doc_lens[i]), i))

    per_doc_ids: list[int] = []
    remaining: list[int] = list(order)

    state: _State | None = None
    outer = 0
    while True:
        outer += 1
        base_tokens = np.zeros(N, dtype=np.int64)
        base_work = np.zeros(N, dtype=np.float64)
        per_doc_shards: list[RefShard] = []
        n2 = 2 * N
        for did in per_doc_ids:
            d = int(doc_lens[did])
            base, rem = divmod(d, n2)
            sizes = [base] * n2
            worker_of = [c if c < N else n2 - 1 - c for c in range(n2)]
            if rem:
                chunk_order = sorted(
                    range(n2),
                    key=lambda c: (base_tokens[worker_of[c]], c))
                for c in chunk_order[:rem]:
                    sizes[c] += 1
            starts = np.concatenate([[0], np.cumsum(sizes)])[:-1]
            chunk_shards = [
                RefShard(did, int(starts[c]), int(sizes[c]), worker_of[c])
                for c in range(n2) if sizes[c] > 0]
            for s in ref_merge_adjacent_shards(chunk_shards):
                per_doc_shards.append(s)
                base_tokens[s.worker] += s.length
                base_work[s.worker] += s.workload()

        state = _State(N, base_tokens, base_work, doc_lens)
        for did in remaining:
            j = int(np.argmin(state.work))
            state.add(_Piece(did, 0, int(doc_lens[did]), j))

        _repair_equal_tokens(state, per_worker)
        _workload_exchange(state, per_worker, target_ratio)

        work = state.work
        cur_ratio = float(np.max(work)) / max(float(np.mean(work)), 1e-9)

        if cur_ratio <= target_ratio or not remaining \
                or outer >= max_outer_iters:
            break
        per_doc_ids.append(remaining.pop(0))

    shards = list(per_doc_shards)
    shards.extend(
        RefShard(p.doc_id, p.start, p.length, p.worker) for p in state.pieces
    )
    shards = ref_merge_adjacent_shards(shards)
    plan = RefShardingPlan(doc_lens=doc_lens, shards=shards, num_workers=N,
                           comm_style="flashcp")
    if validate:
        ref_validate_plan(plan, token_tolerance=0 if not per_doc_ids else N)
    return plan


# --------------------------------------------------------------------- #
# baselines (seed implementations)
# --------------------------------------------------------------------- #
def _doc_bounds(doc_lens: np.ndarray) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(doc_lens)])


def ref_llama3_plan(doc_lens, num_workers, *, validate=True) -> RefShardingPlan:
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    ctx = int(doc_lens.sum())
    n2 = 2 * num_workers
    assert ctx % n2 == 0
    chunk = ctx // n2
    bounds = _doc_bounds(doc_lens)

    shards: list[RefShard] = []
    for c in range(n2):
        worker = c if c < num_workers else n2 - 1 - c
        lo, hi = c * chunk, (c + 1) * chunk
        first = int(np.searchsorted(bounds, lo, side="right")) - 1
        pos = lo
        d = first
        while pos < hi:
            doc_end = int(bounds[d + 1])
            take = min(hi, doc_end) - pos
            shards.append(RefShard(doc_id=d, start=int(pos - bounds[d]),
                                   length=int(take), worker=worker))
            pos += take
            d += 1
    shards = ref_merge_adjacent_shards(shards)
    plan = RefShardingPlan(doc_lens=doc_lens, shards=shards,
                           num_workers=num_workers, comm_style="allgather")
    if validate:
        ref_validate_plan(plan)
    return plan


def ref_per_doc_plan(doc_lens, num_workers, *, validate=True) -> RefShardingPlan:
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    shards: list[RefShard] = []
    for did, d in enumerate(doc_lens):
        shards.extend(ref_zigzag_doc_shards(did, int(d), num_workers))
    plan = RefShardingPlan(doc_lens=doc_lens, shards=shards,
                           num_workers=num_workers, comm_style="allgather")
    if validate:
        ref_validate_plan(plan, require_equal_tokens=False)
    return plan


def ref_ring_zigzag_plan(doc_lens, num_workers, *, validate=True):
    plan = ref_per_doc_plan(doc_lens, num_workers, validate=validate)
    plan.comm_style = "ring"
    return plan


def ref_contiguous_plan(doc_lens, num_workers, *, validate=True):
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    ctx = int(doc_lens.sum())
    assert ctx % num_workers == 0
    chunk = ctx // num_workers
    bounds = _doc_bounds(doc_lens)

    shards: list[RefShard] = []
    for j in range(num_workers):
        lo, hi = j * chunk, (j + 1) * chunk
        first = int(np.searchsorted(bounds, lo, side="right")) - 1
        pos, d = lo, first
        while pos < hi:
            doc_end = int(bounds[d + 1])
            take = min(hi, doc_end) - pos
            shards.append(RefShard(doc_id=d, start=int(pos - bounds[d]),
                                   length=int(take), worker=j))
            pos += take
            d += 1
    shards = ref_merge_adjacent_shards(shards)
    plan = RefShardingPlan(doc_lens=doc_lens, shards=shards,
                           num_workers=num_workers, comm_style="flashcp")
    if validate:
        ref_validate_plan(plan)
    return plan


# --------------------------------------------------------------------- #
# plan encoding (seed implementation)
# --------------------------------------------------------------------- #
def _next_pow2(x: int, floor: int = 128) -> int:
    v = floor
    while v < x:
        v *= 2
    return v


def _pick_buffer_bucket(comm_tokens: int, t_loc: int, floor: int = 128) -> int:
    return min(_next_pow2(max(comm_tokens, 1), floor),
               _next_pow2(t_loc, floor))


@dataclasses.dataclass
class RefPlanEncoding:
    perm: np.ndarray
    doc: np.ndarray
    pos: np.ndarray
    send_idx: np.ndarray
    gath_doc: np.ndarray
    gath_pos: np.ndarray
    t_loc: int
    buf_len: int
    comm_tokens: int
    imbalance: float


def ref_encode_plan(plan: RefShardingPlan, *, buf_len=None, t_loc=None,
                    align: int = 1) -> RefPlanEncoding:
    N = plan.num_workers
    doc_starts = np.concatenate([[0], np.cumsum(plan.doc_lens)])[:-1]

    per_worker: list[list[RefShard]] = [[] for _ in range(N)]
    for s in plan.shards:
        per_worker[s.worker].append(s)
    for j in range(N):
        per_worker[j].sort(key=lambda s: (s.doc_id, s.start))

    tokens_per_worker = [sum(s.length for s in ws) for ws in per_worker]
    need_t = max(tokens_per_worker)
    if t_loc is None:
        t_loc = need_t
        if align > 1:
            t_loc = ((t_loc + align - 1) // align) * align
    assert t_loc >= need_t, (t_loc, need_t)

    C_pad = N * t_loc
    perm = np.full(C_pad, -1, np.int64)
    doc = np.full(C_pad, -1, np.int32)
    pos = np.zeros(C_pad, np.int32)

    send_lists: list[np.ndarray] = []
    for j, ws in enumerate(per_worker):
        cursor = j * t_loc
        send_local: list[np.ndarray] = []
        for s in ws:
            rng = np.arange(s.start, s.end)
            perm[cursor: cursor + s.length] = doc_starts[s.doc_id] + rng
            doc[cursor: cursor + s.length] = s.doc_id
            pos[cursor: cursor + s.length] = rng
            if not s.is_last(int(plan.doc_lens[s.doc_id])):
                base = cursor - j * t_loc
                send_local.append(np.arange(base, base + s.length))
            cursor += s.length
        send_lists.append(
            np.concatenate(send_local) if send_local
            else np.zeros(0, np.int64))

    max_send = max((len(s) for s in send_lists), default=0)
    if buf_len is None:
        buf_len = _pick_buffer_bucket(max_send, t_loc)
    assert buf_len >= max_send

    send_idx = np.full((N, buf_len), -1, np.int32)
    gath_doc = np.full(N * buf_len, -1, np.int32)
    gath_pos = np.zeros(N * buf_len, np.int32)
    for j, sl in enumerate(send_lists):
        send_idx[j, : len(sl)] = sl
        gath_doc[j * buf_len: j * buf_len + len(sl)] = doc[j * t_loc + sl]
        gath_pos[j * buf_len: j * buf_len + len(sl)] = pos[j * t_loc + sl]

    return RefPlanEncoding(
        perm=perm, doc=doc, pos=pos, send_idx=send_idx,
        gath_doc=gath_doc, gath_pos=gath_pos, t_loc=t_loc, buf_len=buf_len,
        comm_tokens=max_send, imbalance=plan.imbalance_ratio())


def ref_encode_plan_batch(plans, *, buf_len=None, align: int = 1):
    N = plans[0].num_workers
    assert all(p.num_workers == N for p in plans)

    pre = [ref_encode_plan(p, buf_len=None, align=align) for p in plans]
    t_loc = max(e.t_loc for e in pre)
    if buf_len is None:
        buf_len = max(e.buf_len for e in pre)
    encs = [ref_encode_plan(p, buf_len=buf_len, t_loc=t_loc) for p in plans]

    stack = {
        "perm": np.stack([e.perm for e in encs]),
        "doc": np.stack([e.doc for e in encs]).astype(np.int32),
        "pos": np.stack([e.pos for e in encs]).astype(np.int32),
        "send_idx": np.stack([e.send_idx for e in encs]).astype(np.int32),
        "gath_doc": np.stack([e.gath_doc for e in encs]).astype(np.int32),
        "gath_pos": np.stack([e.gath_pos for e in encs]).astype(np.int32),
    }
    return stack, encs


def _ref_flashcp_adapter(doc_lens, num_workers, *, validate=True):
    return ref_flashcp_plan(doc_lens, num_workers, validate=validate)


REFERENCE_PLANNERS = {
    "llama3": ref_llama3_plan,
    "per_doc": ref_per_doc_plan,
    "ring_zigzag": ref_ring_zigzag_plan,
    "ring": ref_ring_zigzag_plan,
    "contiguous": ref_contiguous_plan,
    "flashcp": _ref_flashcp_adapter,
}
