"""Two-stage config search: predict-prune-measure (DESIGN.md §Autotune).

:func:`tune` enumerates the admissible candidate space
(:func:`repro.autotune.space.enumerate_candidates`), scores every
candidate with the analytic :func:`repro.autotune.cost.predict`, prunes
to the top-K predicted frontier (:func:`prune_topk` — deterministic
``(score, candidate key)`` order, so ties never depend on enumeration
order), runs the deterministic measured trial
(:func:`repro.autotune.measure.measure_candidate`) on each survivor, and
selects the measured argmin.  The tuned knobs applied to the caller's
base :class:`~repro.configs.RunConfig` are the emitted artifact; the
whole result serializes to a canonical-JSON payload stored in the
content-addressed :class:`~repro.autotune.cache.ResultCache`.

Search correctness contract (property-tested in
``tests/test_autotune.py``):

* pruning never drops the optimum when the predictor ranks like the
  measurement (and with ``top_k >= |space|`` the search *is* brute
  force regardless of the predictor);
* the search is a pure function of its inputs — same pool, problem,
  dims, space, K -> byte-identical payload in any process;
* a cache round trip returns the identical payload without re-measuring.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.configs import RunConfig, run_config_to_dict

from .cache import ResultCache, signature_key, tune_signature
from .cost import CostEstimate, predict, spearman
from .cost_model import HW, ModelDims
from .measure import measure_candidate
from .space import (DEFAULT_SPACE, Candidate, SearchSpace, TuneProblem,
                    enumerate_candidates)

__all__ = ["TuneResult", "tune", "prune_topk", "brute_force",
           "autotune_run"]


@dataclasses.dataclass
class TuneResult:
    """Outcome of one :func:`tune` call."""

    best: Candidate
    best_measured: dict              # CostEstimate dict of the winner
    run_config: dict                 # tuned RunConfig (base + best knobs)
    frontier: list                   # [{candidate, predicted, measured}]
    candidates: list                 # [{candidate, predicted}] whole space
    n_candidates: int
    top_k: int
    spearman_frontier: float         # predicted-vs-measured on survivors
    key: str                         # content-address of the signature
    cached: bool = False             # served from the result cache

    def payload(self) -> dict:
        """The cacheable, deterministic part (no base-run fields, no
        cached flag — those are call-site facts, not search results)."""
        from .cache import TUNER_VERSION
        return {
            "version": TUNER_VERSION,
            "key": self.key,
            "best": self.best.as_dict(),
            "best_measured": self.best_measured,
            "frontier": self.frontier,
            "candidates": self.candidates,
            "n_candidates": self.n_candidates,
            "top_k": self.top_k,
            "spearman_frontier": self.spearman_frontier,
        }

    def to_json(self) -> str:
        """Canonical JSON of the search outcome — the bytes the
        determinism property compares across processes."""
        return json.dumps(self.payload(), sort_keys=True,
                          separators=(",", ":"))


def prune_topk(cands: list[Candidate], ests: list[CostEstimate],
               k: int) -> list[tuple[Candidate, CostEstimate]]:
    """The K best-predicted candidates in deterministic order.

    Sorted by ``(predicted step time, candidate key)`` — the key
    tiebreak makes the frontier (and therefore everything downstream)
    independent of input order.  ``k >= len(cands)`` is the identity
    (modulo that canonical re-ordering): pruning can then never drop
    anything, which is the brute-force escape hatch the property tests
    exploit.
    """
    order = sorted(range(len(cands)),
                   key=lambda i: (ests[i].step_s, cands[i].key()))
    return [(cands[i], ests[i]) for i in order[:max(k, 1)]]


def brute_force(cands: list[Candidate], costs: list[CostEstimate]
                ) -> tuple[Candidate, CostEstimate]:
    """Exhaustive argmin under the same ``(score, key)`` order the
    search uses — the reference the prune property compares against."""
    i = min(range(len(cands)),
            key=lambda i: (costs[i].step_s, cands[i].key()))
    return cands[i], costs[i]


def tune(pool, problem: TuneProblem, dims: ModelDims, *,
         base_run: RunConfig | None = None,
         space: SearchSpace = DEFAULT_SPACE,
         top_k: int = 8,
         cache: ResultCache | None = None,
         hw: dict = HW,
         train: bool = True,
         predict_fn=None,
         measure_fn=None) -> TuneResult:
    """Run the two-stage search; see module docstring.

    ``predict_fn`` / ``measure_fn`` override the scoring stages
    (signature ``fn(candidate, pool, problem, dims)``) — the property
    tests inject synthetic cost models; production callers leave the
    defaults.
    """
    pool = np.asarray(pool, dtype=np.int64)
    base_run = base_run if base_run is not None else RunConfig()
    pred = predict_fn or (lambda c, p, pr, dm:
                          predict(c, p, pr, dm, hw=hw, train=train))
    meas = measure_fn or (lambda c, p, pr, dm:
                          measure_candidate(c, p, pr, dm, hw=hw,
                                            train=train))
    key = signature_key(tune_signature(problem, dims, pool, space))

    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            best = Candidate(**hit["best"])
            return TuneResult(
                best=best, best_measured=hit["best_measured"],
                run_config=run_config_to_dict(best.apply(base_run)),
                frontier=hit["frontier"], candidates=hit["candidates"],
                n_candidates=hit["n_candidates"], top_k=hit["top_k"],
                spearman_frontier=hit["spearman_frontier"], key=key,
                cached=True)

    cands = enumerate_candidates(problem, space)
    if not cands:
        raise ValueError(
            f"no admissible candidate for problem {problem}: the mesh / "
            f"divisibility constraints reject the whole space")
    predicted = [pred(c, pool, problem, dims) for c in cands]
    frontier = prune_topk(cands, predicted, top_k)
    measured = [meas(c, pool, problem, dims) for c, _ in frontier]
    best, best_m = brute_force([c for c, _ in frontier], measured)

    rho = spearman([p.step_s for _, p in frontier],
                   [m.step_s for m in measured]) if len(frontier) > 1 \
        else 1.0
    result = TuneResult(
        best=best,
        best_measured=best_m.as_dict(),
        run_config=run_config_to_dict(best.apply(base_run)),
        frontier=[{"candidate": c.as_dict(), "predicted": p.as_dict(),
                   "measured": m.as_dict()}
                  for (c, p), m in zip(frontier, measured)],
        candidates=[{"candidate": c.as_dict(), "predicted": p.as_dict()}
                    for c, p in zip(cands, predicted)],
        n_candidates=len(cands),
        top_k=top_k,
        spearman_frontier=rho,
        key=key)
    if cache is not None:
        cache.put(key, result.payload())
    return result


def autotune_run(run: RunConfig, cfg, *, data: int, model: int,
                 context_len: int, seqs: int, dataset: str = "wlb_llm",
                 cache_dir: str = "", top_k: int = 8,
                 space: SearchSpace = DEFAULT_SPACE
                 ) -> tuple[RunConfig, TuneResult]:
    """Tune a training run's config knobs before launch
    (``train.py --autotune``).

    Samples one representative document pool from the run's own dataset
    stream (seeded by ``run.seed`` — deterministic, and the same
    distribution every training step draws from), derives the
    :class:`TuneProblem` from the mesh and the pipeline's alignment
    rules, and returns ``(tuned RunConfig, TuneResult)``.
    """
    from repro.configs import run_config_from_dict
    from repro.data.packing import sample_doc_pool

    align = 128 if run.attention_impl == "pallas" \
        else (1 if data * model == 1 else 16)
    problem = TuneProblem(
        data=data, model=model, context_len=context_len, seqs=seqs,
        quantum=align, attention_impl=run.attention_impl,
        family=cfg.family)
    dims = ModelDims(
        num_heads=cfg.num_heads, kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, d_model=cfg.d_model,
        d_ff=cfg.d_ff)
    rng = np.random.default_rng(run.seed)
    pool = sample_doc_pool(dataset, seqs * context_len, rng,
                           max_doc_len=context_len, min_docs=seqs)
    result = tune(pool, problem, dims, base_run=run, space=space,
                  top_k=top_k, cache=ResultCache(cache_dir or None))
    return run_config_from_dict(result.run_config), result
