"""Content-addressed result cache for tuned configs (DESIGN.md §Autotune).

A tuning run is a pure function of ``(model dims, mesh/problem geometry,
length-profile signature, search space, tuner version)``; its result is
stored under the blake2b digest of that tuple's canonical JSON, exactly
the :class:`repro.planner.cache.PlanCache` recipe one level up.  The
length profile is signed by the *quantized sorted* pool lengths
(:data:`LENGTH_QUANTUM`-token buckets), so a re-sampled pool with the
same shape distribution hits the same entry while a genuinely different
mix does not.

Entries are one JSON file per key with atomic tmp+rename writes, so a
crashed tuner never leaves a torn entry and concurrent writers of the
same key converge on identical bytes (payloads are deterministic).
Corrupt or unreadable entries read as misses.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path

import numpy as np

__all__ = ["ResultCache", "tune_signature", "signature_key",
           "LENGTH_QUANTUM", "TUNER_VERSION"]

#: doc lengths are bucketed to this many tokens in the cache signature
LENGTH_QUANTUM = 64

#: bump on any change to the search semantics or payload schema — old
#: entries then simply miss instead of deserializing wrongly
TUNER_VERSION = 1


def tune_signature(problem, dims, pool, space) -> dict:
    """The canonical identity of one tuning run (JSON-safe dict)."""
    lens = np.asarray(pool, dtype=np.int64)
    qlens = np.sort((np.maximum(lens, 1) + LENGTH_QUANTUM - 1)
                    // LENGTH_QUANTUM * LENGTH_QUANTUM)
    return {
        "version": TUNER_VERSION,
        "problem": problem.as_dict(),
        "dims": dataclasses.asdict(dims),
        "space": space.as_dict(),
        "pool": {"n_docs": int(lens.size),
                 "total_tokens": int(lens.sum()),
                 "qlens": qlens.tolist()},
    }


def signature_key(signature: dict) -> str:
    blob = json.dumps(signature, sort_keys=True,
                      separators=(",", ":")).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


class ResultCache:
    """Filesystem-backed content-addressed store of tune payloads.

    ``root=None`` (or empty) disables persistence — every lookup misses
    and puts are dropped — so callers never branch on "cache configured".
    """

    def __init__(self, root: str | os.PathLike | None):
        self.root = Path(root) if root else None
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> Path:
        assert self.root is not None
        return self.root / f"tune_{key}.json"

    def get(self, key: str) -> dict | None:
        if self.root is None:
            self.misses += 1
            return None
        try:
            payload = json.loads(self._path(key).read_text())
        except (OSError, ValueError):
            self.misses += 1
            return None
        if payload.get("version") != TUNER_VERSION:
            self.misses += 1
            return None
        self.hits += 1
        return payload

    def put(self, key: str, payload: dict) -> Path | None:
        if self.root is None:
            return None
        self.root.mkdir(parents=True, exist_ok=True)
        final = self._path(key)
        blob = json.dumps(payload, sort_keys=True, indent=1)
        tmp = final.with_suffix(f".tmp.{os.getpid()}")
        tmp.write_text(blob)
        os.replace(tmp, final)      # atomic within one filesystem
        return final
