"""Unified candidate scoring: ``predict(candidate, pool, problem, dims)
-> CostEstimate`` (DESIGN.md §Autotune).

Merges the repo's three cost sources into one ranking function:

* :func:`repro.autotune.cost_model.step_breakdown` — per-plan analytic
  attention / comm / copy / GEMM roofline terms;
* the exposed-comm idea of ``launch.hlo_analysis.schedule_model`` — a
  two-stream (compute vs collective) hop pipeline
  (:func:`pipeline_exposed`) credits chunked-overlap candidates with the
  compute their per-hop payloads hide, exactly the quantity the HLO
  schedule model reads off the real lowered program;
* :mod:`repro.dispatch.balance` imbalance simulation — candidates are
  laid out with the *actual* dispatcher (adaptive) or the static packer
  (off), and the step estimate is the max over CP-group completion
  times, expressed through :func:`scale_by_imbalance`.

Monotonicity contract (property-tested): :func:`comm_seconds` is
monotone non-decreasing in wire bytes, :func:`pipeline_exposed` in every
hop's comm time, and :func:`scale_by_imbalance` in the imbalance ratio —
more modeled comm volume never predicts less comm time; higher imbalance
never predicts lower step time.

Everything here is deterministic host-side numpy: predictions depend
only on (candidate, pool, problem, dims, hw), never on RNG or wall
clock, which is what makes search results cache-stable across processes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.dispatch import dispatch_step, imbalance, pack_pool
from repro.planner import get_planner

from .cost_model import (BLOCK, HW, ModelDims, step_breakdown, tile_flops,
                         visited_tile_counts)
from .space import Candidate, TuneProblem, candidate_degrees, _dispatch_cfg

__all__ = ["CostEstimate", "Layout", "candidate_layout", "predict",
           "comm_seconds", "pipeline_exposed", "scale_by_imbalance",
           "spearman"]


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """One candidate's scored step cost (predicted or measured)."""

    step_s: float            # the ranking objective
    attn_s: float
    exposed_comm_s: float    # comm residue on the critical path
    comm_s: float            # raw wire time (pre-overlap credit)
    linear_s: float
    other_s: float
    comm_bytes: float
    cp_degree: int
    n_groups: int
    work_imbalance: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# ------------------------------------------------------------------ #
# monotone primitives
# ------------------------------------------------------------------ #
def comm_seconds(wire_bytes: float, hw: dict = HW) -> float:
    """Wire time of a KV exchange; monotone in ``wire_bytes``."""
    return max(float(wire_bytes), 0.0) / hw["ici_bw"]


def pipeline_exposed(hop_comm_s: Sequence[float],
                     hop_compute_s: Sequence[float]) -> float:
    """Exposed comm of a chunked hop pipeline (two-resource schedule).

    Hop payloads transfer back-to-back on the comm stream; hop ``h``'s
    partial-attention compute starts once its payload arrived *and* the
    compute stream is free.  Exposed = compute-stream makespan minus its
    busy time — the same quantity ``schedule_model`` extracts from real
    HLO.  Monotone non-decreasing in every ``hop_comm_s`` entry and
    non-increasing in every ``hop_compute_s`` entry.
    """
    t_comm = 0.0
    t_comp = 0.0
    busy = 0.0
    for c_s, k_s in zip(hop_comm_s, hop_compute_s):
        t_comm += max(float(c_s), 0.0)
        t_comp = max(t_comp, t_comm) + max(float(k_s), 0.0)
        busy += max(float(k_s), 0.0)
    return max(0.0, t_comp - busy)


def scale_by_imbalance(balanced_s: float, imb: float) -> float:
    """Step time from mean group time and a max/mean imbalance ratio;
    monotone in both arguments (ratios below 1 are clamped)."""
    return max(float(balanced_s), 0.0) * max(float(imb), 1.0)


def spearman(pred: Sequence[float], meas: Sequence[float]) -> float:
    """Spearman rank correlation (tie-averaged ranks, pure numpy — the CI
    image has no scipy).  Two constant vectors agree perfectly (1.0); a
    constant vector against a varying one carries no rank signal (0.0)."""
    a, b = _ranks(pred), _ranks(meas)
    sa, sb = a.std(), b.std()
    if len(a) < 2 or (sa == 0.0 and sb == 0.0):
        return 1.0
    if sa == 0.0 or sb == 0.0:
        return 0.0
    return float(((a - a.mean()) * (b - b.mean())).mean() / (sa * sb))


def _ranks(x: Sequence[float]) -> np.ndarray:
    v = np.asarray(x, dtype=np.float64)
    order = np.argsort(v, kind="stable")
    ranks = np.empty(len(v), dtype=np.float64)
    ranks[order] = np.arange(len(v), dtype=np.float64)
    # average ranks over ties so equal scores compare as equal
    sv = v[order]
    i = 0
    while i < len(sv):
        j = i
        while j + 1 < len(sv) and sv[j + 1] == sv[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0
        i = j + 1
    return ranks


# ------------------------------------------------------------------ #
# candidate layout: which rows run at which degree in which group
# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class Layout:
    """A candidate's simulated batch layout, shared verbatim between
    ``predict`` and the measured trial so the two scores differ only in
    how each row is costed — never in what runs where."""

    cp_degree: int
    n_groups: int
    rows: tuple                      # per-row doc-length arrays, group-major
    group_of_row: np.ndarray         # (seqs,) int64


def candidate_layout(cand: Candidate, pool: np.ndarray,
                     problem: TuneProblem) -> Layout:
    """Lay the pool out exactly as the pipeline would under ``cand``:
    the real dispatcher for ``adaptive`` (degree choice + LPT balancing),
    the static worst-fit packer with in-order group assignment for
    ``off`` (no cross-rank balancing — the baseline's weakness the
    dispatcher exists to fix)."""
    pool = np.asarray(pool, dtype=np.int64)
    mult = get_planner(cand.cp_strategy).info.context_multiple
    if cand.dispatch == "adaptive":
        cfg = _dispatch_cfg(problem, cand.dispatch_target_imbalance,
                            context_multiple=mult)
        dp = dispatch_step(pool, cfg, problem.context_len)
        return Layout(dp.cp_degree, dp.n_groups, tuple(dp.rows),
                      np.asarray(dp.group_of_row, dtype=np.int64))
    degree = candidate_degrees(cand, problem)[-1]      # the full model axis
    packed = pack_pool(pool, problem.seqs, problem.context_len,
                       quantum=int(np.lcm(degree * mult,
                                          max(problem.quantum, 1))))
    n_groups = problem.data * problem.model // degree
    per_group = problem.seqs // n_groups
    group_of_row = np.arange(problem.seqs, dtype=np.int64) // per_group
    return Layout(degree, n_groups, tuple(packed.bins), group_of_row)


# ------------------------------------------------------------------ #
# prediction
# ------------------------------------------------------------------ #
def _overlap_exposed(cand: Candidate, comm_bytes: float, attn_s: float,
                     degree: int, hw: dict) -> float:
    """Exposed comm of one row under the candidate's overlap mode.

    Ring plans already carry their hop-overlap credit inside
    ``step_breakdown``; for the collective styles, ``chunked`` pipelines
    the (N-1) payload hops against the partial attention each hop
    unlocks (the gathered-KV share of the row's attention), plus a
    per-hop online-LSE merge pass; ``none`` exposes the full wire time.
    """
    raw = comm_seconds(comm_bytes, hw)
    if degree <= 1 or comm_bytes <= 0:
        return 0.0
    if cand.cp_overlap != "chunked":
        return raw
    hops = degree - 1
    # attention attributable to gathered (non-local) KV — the compute a
    # hop's arrival unlocks; 1/degree of the work is local-only.
    hop_attn = attn_s * (1.0 - 1.0 / degree) / hops
    merge_s = hops * (comm_bytes / hops) \
        * 2.0 / hw["hbm_bw"]          # fp32 partial/LSE read+write per hop
    return pipeline_exposed([raw / hops] * hops, [hop_attn] * hops) + merge_s


def _pow2_bucket(x: np.ndarray, floor: int = 8) -> np.ndarray:
    """Vectorized ``encode._next_pow2``: next power of two, floored."""
    x = np.maximum(np.ceil(x), 1.0)
    return np.maximum(2.0 ** np.ceil(np.log2(x)), float(floor))


def _tables_attn_s(cand: Candidate, plan, degree: int, dims: ModelDims,
                   hw: dict, fb: float) -> float:
    """Attention time of one row when Pallas visit tables are lowered —
    the *same formula the measured trial reads off the emitted tables*
    (raw visited-tile MXU work + padded grid-step waste + per-launch
    overhead), evaluated on analytic per-worker counters instead of the
    tables themselves.  Sharing the formula is what keeps predicted and
    measured scores rank-consistent on the table path; the analytic
    ``_kernel_eff`` curve models monolithic flash kernels and does not
    apply — the table kernel's short-shard penalty *is* the padding and
    launch terms.
    """
    t = visited_tile_counts(plan)
    nq = np.ceil(plan.context_len / plan.num_workers / BLOCK)
    rect = nq * _pow2_bucket(t["kv_tiles_max"])
    if cand.kernel_grid == "rect":
        steps = rect
    else:
        # the flat queue's pow2 bucket never exceeds the full rectangle
        steps = np.minimum(_pow2_bucket(t["visited"]), rect)
    waste = np.maximum(steps - t["visited"], 0.0)
    per_rank = fb * tile_flops(1.0, dims) * t["visited"] \
        / hw["peak_flops"] + waste * hw["grid_step_overhead_s"]
    hops = degree - 1 if cand.cp_overlap == "chunked" and degree > 1 else 0
    launches = 1 + hops
    return float(per_rank.max()) + launches * hw["kernel_overhead_s"]


def predict(cand: Candidate, pool, problem: TuneProblem, dims: ModelDims,
            *, hw: dict = HW, train: bool = True) -> CostEstimate:
    """Analytic step-cost estimate of one candidate on one document pool.

    Per row of the candidate's layout: plan with the candidate's
    strategy at the layout degree, take the analytic
    :func:`step_breakdown`, then apply the candidate's execution
    adjustments (overlap pipelining, rect-grid waste, int8 wire +
    quantize passes).  Rows sum within a CP group (they run
    back-to-back on the same devices); the step estimate is the mean
    group time scaled by the max/mean group imbalance — identically the
    max, but routed through the monotone :func:`scale_by_imbalance`.
    """
    layout = candidate_layout(cand, pool, problem)
    degree = layout.cp_degree
    planner = get_planner(cand.cp_strategy)
    dt = 1 if cand.kv_comm_dtype == "int8" else 2
    fb = 3.0 if train else 1.0

    group = np.zeros(layout.n_groups)
    parts = {"attn_s": np.zeros(layout.n_groups),
             "exposed_comm_s": np.zeros(layout.n_groups),
             "comm_s": np.zeros(layout.n_groups),
             "linear_s": np.zeros(layout.n_groups),
             "other_s": np.zeros(layout.n_groups),
             "comm_bytes": np.zeros(layout.n_groups)}
    for r, lens in enumerate(layout.rows):
        if len(lens) == 0:
            continue
        g = int(layout.group_of_row[r])
        plan = planner(lens, degree, validate=False)
        bd = step_breakdown(plan, dims, train=train, hw=hw, dtype_bytes=dt)
        tables = problem.attention_impl == "pallas" \
            and plan.comm_style != "ring"
        attn = _tables_attn_s(cand, plan, degree, dims, hw, fb) if tables \
            else bd["attn_s"]
        raw = comm_seconds(bd["comm_bytes"], hw)
        if plan.comm_style == "ring":
            exposed = bd["comm_s"]       # hop credit already applied
        else:
            exposed = _overlap_exposed(cand, bd["comm_bytes"], attn,
                                       degree, hw)
        other = bd["other_s"]
        if dt == 1 and bd["comm_bytes"] > 0:
            # quantize + dequantize memory passes over the wire payload
            other += 2.0 * bd["comm_bytes"] / hw["hbm_bw"]
        parts["attn_s"][g] += attn
        parts["exposed_comm_s"][g] += exposed
        parts["comm_s"][g] += raw
        parts["linear_s"][g] += bd["linear_s"]
        parts["other_s"][g] += other
        parts["comm_bytes"][g] += bd["comm_bytes"]
        group[g] += attn + exposed + other + bd["linear_s"]

    imb = imbalance(group) if group.any() else 1.0
    gmax = int(np.argmax(group))
    return CostEstimate(
        step_s=scale_by_imbalance(float(group.mean()), imb),
        attn_s=float(parts["attn_s"][gmax]),
        exposed_comm_s=float(parts["exposed_comm_s"][gmax]),
        comm_s=float(parts["comm_s"][gmax]),
        linear_s=float(parts["linear_s"][gmax]),
        other_s=float(parts["other_s"][gmax]),
        comm_bytes=float(parts["comm_bytes"][gmax]),
        cp_degree=degree,
        n_groups=layout.n_groups,
        work_imbalance=float(imb),
    )
