"""Cost-model-driven config autotuner (DESIGN.md §Autotune).

Turns the engine's independent run-config knobs (``cp_strategy``,
``cp_overlap``, ``kernel_grid``, ``dispatch`` + target,
``kv_comm_dtype``) into one search: enumerate the admissible space from
planner capability metadata and the dispatcher's mesh/divisibility
checks, score every candidate with the unified analytic cost model,
prune to a top-K predicted frontier, run deterministic measured trials
on the survivors, and emit a tuned, serializable
:class:`~repro.configs.RunConfig` behind a content-addressed result
cache.  Entry points: ``train.py --autotune`` and
``scripts/autotune.py``.

Host-side numpy only — importable without JAX.
"""

from .cache import (LENGTH_QUANTUM, TUNER_VERSION, ResultCache,
                    signature_key, tune_signature)
from .cost import (CostEstimate, Layout, candidate_layout, comm_seconds,
                   pipeline_exposed, predict, scale_by_imbalance, spearman)
from .cost_model import (BLOCK, HW, L_HALF, ModelDims, step_breakdown,
                         visited_tile_counts)
from .measure import measure_candidate, measure_many
from .search import TuneResult, autotune_run, brute_force, prune_topk, tune
from .space import (DEFAULT_SPACE, Candidate, SearchSpace, TuneProblem,
                    candidate_admissible, candidate_degrees,
                    enumerate_candidates)

__all__ = [
    "LENGTH_QUANTUM", "TUNER_VERSION", "ResultCache", "signature_key",
    "tune_signature",
    "CostEstimate", "Layout", "candidate_layout", "comm_seconds",
    "pipeline_exposed", "predict", "scale_by_imbalance", "spearman",
    "BLOCK", "HW", "L_HALF", "ModelDims", "step_breakdown",
    "visited_tile_counts",
    "measure_candidate", "measure_many",
    "TuneResult", "autotune_run", "brute_force", "prune_topk", "tune",
    "DEFAULT_SPACE", "Candidate", "SearchSpace", "TuneProblem",
    "candidate_admissible", "candidate_degrees", "enumerate_candidates",
]
