"""Analytic step-cost model for CP strategies on TPU v5e (DESIGN.md §Autotune).

This container is CPU-only, so paper-figure comparisons (Fig. 5/6/7) and
the autotuner's predicted scores are produced from *measured plan
properties* (communication volume from Eq.4/5 accounting, attention block
occupancy from the kernel's visit tables, workload imbalance from the
planner) combined with v5e hardware constants.  The model has four terms
per training step, mirroring the paper's Fig. 6 breakdown:

  comm   — KV exchange on the CP critical path (AllGather+ReduceScatter or
           ring hops); ring overlaps with compute (credited up to the
           blockwise attention time), matching Ring-Attn's design.
  attn   — attention kernel time: visited-block MXU work at the roofline,
           *including masked waste inside partial blocks*, plus a per-shard
           kernel-invocation overhead (short shards hurt — Fig. 3).
  other  — data-copy overhead: per-shard fixed cost + bytes moved
           (Per-Doc's many small copies — §4.3 "Others").
  linear — QKV/O + FFN GEMMs; identical across methods (equal tokens) but
           kept so relative speedups are end-to-end, not attention-only.

Historically this lived in ``benchmarks/cost_model.py``; it moved here so
the autotuner (:mod:`repro.autotune`) can import it without reaching into
the benchmark tree.  The old module re-exports everything.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.workload import plan_comm_bytes
from repro.planner import ShardingPlan

HW = {
    "peak_flops": 197e12,        # bf16 MXU
    "hbm_bw": 819e9,
    "ici_bw": 50e9,              # per-link; CP ring/collective bottleneck
    "kernel_overhead_s": 5e-6,   # per attention-kernel invocation
    "copy_overhead_s": 2e-6,     # per shard copy setup
    # per wasted (padded / masked no-op) kernel grid step: the control
    # cost of stepping the schedule without useful MXU work — what the
    # rect grid pays over the flat work queue
    "grid_step_overhead_s": 2e-7,
}

BLOCK = 128                      # MXU-aligned attention tile


@dataclasses.dataclass
class ModelDims:
    num_heads: int = 32
    kv_heads: int = 8
    head_dim: int = 128
    d_model: int = 0             # 0 -> heads * head_dim
    d_ff: int = 0                # 0 -> 4x d_model

    def __post_init__(self):
        if self.d_model == 0:
            self.d_model = self.num_heads * self.head_dim
        if self.d_ff == 0:
            self.d_ff = 4 * self.d_model


#: MXU-utilization half-saturation length for flash-attention kernels —
#: the paper's Fig. 3 effect: short shards starve the kernel.  eff(L) =
#: L / (L + L_HALF): 50% at 2K, 89% at 16K, 94% at 32K, matching
#: published FlashAttention utilization-vs-seqlen curves.
L_HALF = 2048.0


def _kernel_eff(extent: int) -> float:
    return extent / (extent + L_HALF)


def _attention_block_work(plan: ShardingPlan, *, ring: bool = False
                          ) -> tuple[float, int]:
    """(effective block pairs = visited tiles incl. masked waste, divided
    by the per-kernel MXU efficiency, shard count) for the busiest worker.

    Collective strategies run one kernel per shard over its full KV run
    (extent = prefix + length); ring processes each shard blockwise per
    rotation hop, so the kernel extent collapses to the shard length —
    the paper's Ring-Attn kernel-efficiency penalty.

    Vectorized over the plan's ShardArrays: one pass of numpy ops instead
    of a Python loop over every shard of every worker."""
    a = plan.arrays
    if len(a) == 0:
        return 0.0, 0
    # kv tiles visited by each shard's q tiles: ceil sizes to BLOCK
    q_tiles = -(-a.length // BLOCK)
    kv_len = a.start + a.length
    kv_tiles = -(-kv_len // BLOCK)
    # causal-doc structure: roughly half the q x kv tile rectangle above
    # the diagonal is skipped for the local triangle
    tri = q_tiles * (q_tiles + 1) / 2.0
    rect = q_tiles * np.maximum(kv_tiles - q_tiles, 0)
    extent = a.length if ring else kv_len
    pairs = (tri + rect) * BLOCK * BLOCK / _kernel_eff(extent)
    per_worker = np.bincount(a.worker, weights=pairs,
                             minlength=plan.num_workers)
    shards_per_worker = np.bincount(a.worker, minlength=plan.num_workers)
    return float(per_worker.max()), int(shards_per_worker.max())


def tile_flops(visited_tiles: float, dims: "ModelDims") -> float:
    """MXU flops of ``visited_tiles`` BLOCK x BLOCK attention tiles (qk +
    pv matmuls, all heads) — the unit both the autotuner's predicted and
    measured table-path attention terms are denominated in."""
    return visited_tiles * BLOCK * BLOCK * 2 * dims.head_dim \
        * dims.num_heads * 2


def visited_tile_counts(plan: ShardingPlan) -> dict[str, np.ndarray]:
    """Per-worker raw tile occupancy of a plan's causal visit structure.

    Returns ``visited`` (tri+rect visited BLOCK×BLOCK tiles, no
    efficiency scaling), ``q_tiles`` (total q tiles) and ``kv_tiles_max``
    (widest per-shard KV extent in tiles) — the pieces the autotuner's
    rect-vs-flat grid term needs: a rectangular schedule steps
    ``q_tiles * kv_tiles_max`` per worker while the flat work queue steps
    only the visited count (DESIGN.md §Autotune).
    """
    N = plan.num_workers
    a = plan.arrays
    if len(a) == 0:
        z = np.zeros(N)
        return {"visited": z, "q_tiles": z.copy(), "kv_tiles_max": z.copy()}
    q_tiles = -(-a.length // BLOCK)
    kv_tiles = -(-(a.start + a.length) // BLOCK)
    tri = q_tiles * (q_tiles + 1) / 2.0
    rect = q_tiles * np.maximum(kv_tiles - q_tiles, 0)
    visited = np.bincount(a.worker, weights=tri + rect, minlength=N)
    qt = np.bincount(a.worker, weights=q_tiles, minlength=N)
    kv_max = np.zeros(N)
    np.maximum.at(kv_max, a.worker, kv_tiles.astype(np.float64))
    return {"visited": visited, "q_tiles": qt, "kv_tiles_max": kv_max}


def step_breakdown(plan: ShardingPlan, dims: ModelDims,
                   *, train: bool = True, hw: dict = HW,
                   dtype_bytes: int = 2) -> dict:
    """Four-term analytic step cost of one plan (see module docstring).

    ``dtype_bytes`` sets the KV wire dtype (2 = bf16 native, 1 = the
    int8-quantized exchange) — the autotuner sweeps it; every seed
    benchmark keeps the default.
    """
    N = plan.num_workers
    C = plan.context_len
    tokens_per_worker = C // N
    fb = 3.0 if train else 1.0        # fwd + bwd(2x) GEMM factor

    # ---- attention ------------------------------------------------- #
    ring = plan.comm_style == "ring"
    pairs, n_shards = _attention_block_work(plan, ring=ring)
    attn_flops = pairs * 2 * dims.head_dim * dims.num_heads * 2  # qk + pv
    kernel_launches = n_shards * (N if ring else 1)
    attn_s = fb * attn_flops / hw["peak_flops"] \
        + kernel_launches * hw["kernel_overhead_s"]

    # ---- communication ----------------------------------------------- #
    comm_bytes = plan_comm_bytes(plan, dims.kv_heads, dims.head_dim,
                                 dtype_bytes=dtype_bytes, fwd_and_bwd=train)
    comm_s = comm_bytes / hw["ici_bw"]
    if plan.comm_style == "ring":
        # ring overlaps each hop with blockwise compute; only the
        # non-overlapped remainder is exposed, plus LSE-merge passes
        merge_s = (N - 1) * tokens_per_worker * dims.num_heads \
            * dims.head_dim * 4 * 2 / hw["hbm_bw"]
        comm_s = max(0.0, comm_s - attn_s) + merge_s

    # ---- data copies (§4.3 "Others") ---------------------------------- #
    copy_bytes = int(plan.arrays.length.sum()) / N * dims.kv_heads \
        * dims.head_dim * 2 * 2
    other_s = len(plan.arrays) / N * hw["copy_overhead_s"] \
        + copy_bytes / hw["hbm_bw"]

    # ---- token-linear GEMMs (equal across methods) -------------------- #
    d = dims.d_model
    lin_flops = tokens_per_worker * (
        2 * d * (dims.num_heads + 2 * dims.kv_heads) * dims.head_dim
        + 2 * dims.num_heads * dims.head_dim * d
        + 2 * 3 * d * dims.d_ff)
    linear_s = fb * lin_flops / hw["peak_flops"]

    total = attn_s + comm_s + other_s + linear_s
    return {"attn_s": attn_s, "comm_s": comm_s, "other_s": other_s,
            "linear_s": linear_s, "total_s": total,
            "comm_bytes": comm_bytes, "shards": len(plan.arrays),
            "imbalance": plan.imbalance_ratio()}
