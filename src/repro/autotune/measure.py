"""Measured short trials: cost a candidate from the *real* artifacts the
pipeline would lower, not from analytic proxies (DESIGN.md §Autotune).

Where :func:`repro.autotune.cost.predict` estimates from plan accounting,
a measured trial actually builds, for every row of the candidate's
layout, the encoding (:func:`repro.planner.encode_plan` — the bucketed
Eq.5 buffer is the true padded wire size) and, for table-lowered
strategies, the emitted visit tables
(:func:`repro.planner.emit_visit_tables` at the candidate's overlap/grid
settings) — then reads the trial's cost off those artifacts' exact
counters: visited tiles, padded grid steps (rect rectangle vs flat
work-queue width, pow2 bucket padding included), kernel launches per
rank (1 + hops when chunked), and bucketed buffer bytes on the wire.

The trial is deterministic: it times nothing, so identical inputs yield
bit-identical :class:`~repro.autotune.cost.CostEstimate` values in any
process — the property the tuner's cache keys rely on.  (On this CPU
container a wall-clock trial would measure the host emulation, not the
modeled accelerator; counting real artifact work against the v5e
constants is the faithful stand-in, and is exactly how the committed
benchmark figures are produced.)
"""

from __future__ import annotations

import numpy as np

from repro.dispatch import imbalance
from repro.planner import encode_plan, get_planner
from repro.planner.encode import emit_visit_tables

from .cost import (CostEstimate, Layout, candidate_layout, comm_seconds,
                   pipeline_exposed, scale_by_imbalance)
from .cost_model import (BLOCK, HW, ModelDims, _attention_block_work,
                         tile_flops)
from .space import Candidate, TuneProblem

__all__ = ["measure_candidate", "measure_many"]

#: (fwd K+V) x (bwd resend + grad exchange) wire factor, as in
#: repro.core.workload.plan_comm_bytes
_TRAIN_WIRE_FACTOR = 4
_INFER_WIRE_FACTOR = 2


def _rank_counters(tabs: dict, overlap: str, degree: int):
    """Per-rank (visited tiles, rect grid steps, flat queue steps,
    kernel launches) summed over local + hop tables."""
    if overlap == "chunked":
        groups = [("tab_loc_", 1)] + ([("tab_hop_", degree - 1)]
                                      if degree > 1 else [])
    else:
        groups = [("tab_", 1)]
    visited = np.zeros(degree)
    rect_steps = np.zeros(degree)
    flat_steps = np.zeros(degree)
    launches = 0
    for prefix, hops in groups:
        nvis = tabs[f"{prefix}kv_nvis"]          # (B, N, [H,] nq)
        idx = tabs[f"{prefix}kv_idx"]            # (B, N, [H,] nq, W)
        fq = tabs[f"{prefix}fq_row"]             # (B, N, [H,] S)
        axes = tuple(a for a in range(nvis.ndim) if a != 1)
        visited += nvis.sum(axis=axes)
        nq, width = idx.shape[-2], idx.shape[-1]
        rect_steps += float(nq * width) * hops * idx.shape[0]
        flat_steps += float(fq.shape[-1]) * hops * fq.shape[0]
        launches += hops
    return visited, rect_steps, flat_steps, launches


def measure_candidate(cand: Candidate, pool, problem: TuneProblem,
                      dims: ModelDims, *, hw: dict = HW,
                      train: bool = True) -> CostEstimate:
    """Cost one candidate from fully-built per-row artifacts.

    Shares :func:`candidate_layout` with ``predict`` — same degree, same
    rows, same groups — so the measured/predicted gap isolates the
    execution model (bucketed wire padding, emitted table widths, launch
    counts) rather than layout differences.
    """
    layout: Layout = candidate_layout(cand, pool, problem)
    degree = layout.cp_degree
    planner = get_planner(cand.cp_strategy)
    style = planner.info.comm_style
    dt = 1 if cand.kv_comm_dtype == "int8" else 2
    fb = 3.0 if train else 1.0
    wire_factor = _TRAIN_WIRE_FACTOR if train else _INFER_WIRE_FACTOR
    align = max(problem.quantum, 1)
    # table emission needs block-divisible rank slices; measure with the
    # kernel's real block when the problem's quantum doesn't pin one
    tables = problem.attention_impl == "pallas" and style != "ring"
    if tables:
        align = int(np.lcm(align, BLOCK))

    group = np.zeros(layout.n_groups)
    parts = {k: np.zeros(layout.n_groups) for k in
             ("attn_s", "exposed_comm_s", "comm_s", "linear_s", "other_s",
              "comm_bytes")}
    for r, lens in enumerate(layout.rows):
        if len(lens) == 0:
            continue
        g = int(layout.group_of_row[r])
        plan = planner(lens, degree, validate=False)
        enc = encode_plan(plan, align=align)

        # ---- wire: the *bucketed* Eq.5 buffer is what actually moves --- #
        if degree > 1:
            comm_tokens = enc.buf_len if style == "flashcp" else enc.t_loc
        else:
            comm_tokens = 0
        wire = wire_factor * comm_tokens * dims.kv_heads * dims.head_dim \
            * (degree - 1) * dt
        raw = comm_seconds(wire, hw)

        # ---- attention from emitted tables (or ring blockwise) -------- #
        if tables:
            stack_doc = enc.doc[None]
            stack_pos = enc.pos[None]
            gd = enc.gath_doc[None] if style == "flashcp" else None
            gp = enc.gath_pos[None] if style == "flashcp" else None
            tabs = emit_visit_tables(
                stack_doc, stack_pos, gd, gp, num_workers=degree,
                strategy=style, overlap=cand.cp_overlap, grid="both",
                block_q=BLOCK, block_k=BLOCK, cache=False)
            visited, rect_steps, flat_steps, launches = _rank_counters(
                tabs, cand.cp_overlap, degree)
            steps = rect_steps if cand.kernel_grid == "rect" else flat_steps
            waste = np.maximum(steps - visited, 0.0)
            attn_rank = fb * tile_flops(1.0, dims) * visited \
                / hw["peak_flops"] + waste * hw["grid_step_overhead_s"]
            attn = float(attn_rank.max()) \
                + launches * hw["kernel_overhead_s"]
            busiest = int(np.argmax(attn_rank))
            hop_attn_busiest = _hop_attn(tabs, cand.cp_overlap, busiest,
                                         dims, fb, hw)
        else:
            pairs, n_shards = _attention_block_work(
                plan, ring=(style == "ring"))
            launches = n_shards * (degree if style == "ring" else 1)
            attn = fb * pairs * 2 * dims.head_dim * dims.num_heads * 2 \
                / hw["peak_flops"] + launches * hw["kernel_overhead_s"]
            hop_attn_busiest = None

        # ---- exposed comm under the candidate's overlap mode ---------- #
        t_loc = enc.t_loc
        if degree <= 1 or wire == 0:
            exposed = 0.0
        elif style == "ring":
            merge_s = (degree - 1) * t_loc * dims.num_heads \
                * dims.head_dim * 4 * 2 / hw["hbm_bw"]
            exposed = max(0.0, raw - attn) + merge_s
        elif cand.cp_overlap == "chunked":
            hops = degree - 1
            hop_comm = [raw / hops] * hops
            if hop_attn_busiest is None:
                hop_attn_busiest = [attn * (1 - 1 / degree) / hops] * hops
            merge_s = hops * (wire / hops) * 2.0 / hw["hbm_bw"]
            exposed = pipeline_exposed(hop_comm, hop_attn_busiest) + merge_s
        else:
            exposed = raw

        # ---- copies, quantize passes, linear GEMMs -------------------- #
        other = len(plan.arrays) / degree * hw["copy_overhead_s"] \
            + int(plan.arrays.length.sum()) / degree * dims.kv_heads \
            * dims.head_dim * 2 * 2 / hw["hbm_bw"]
        if dt == 1 and wire > 0:
            other += 2.0 * wire / hw["hbm_bw"]
        d = dims.d_model
        lin_flops = t_loc * (
            2 * d * (dims.num_heads + 2 * dims.kv_heads) * dims.head_dim
            + 2 * dims.num_heads * dims.head_dim * d
            + 2 * 3 * d * dims.d_ff)
        linear = fb * lin_flops / hw["peak_flops"]

        parts["attn_s"][g] += attn
        parts["exposed_comm_s"][g] += exposed
        parts["comm_s"][g] += raw
        parts["linear_s"][g] += linear
        parts["other_s"][g] += other
        parts["comm_bytes"][g] += wire
        group[g] += attn + exposed + other + linear

    imb = imbalance(group) if group.any() else 1.0
    gmax = int(np.argmax(group))
    return CostEstimate(
        step_s=scale_by_imbalance(float(group.mean()), imb),
        attn_s=float(parts["attn_s"][gmax]),
        exposed_comm_s=float(parts["exposed_comm_s"][gmax]),
        comm_s=float(parts["comm_s"][gmax]),
        linear_s=float(parts["linear_s"][gmax]),
        other_s=float(parts["other_s"][gmax]),
        comm_bytes=float(parts["comm_bytes"][gmax]),
        cp_degree=degree,
        n_groups=layout.n_groups,
        work_imbalance=float(imb),
    )


def _hop_attn(tabs: dict, overlap: str, rank: int, dims: ModelDims,
              fb: float, hw: dict) -> list[float] | None:
    """Per-hop partial-attention times of one rank's chunked tables —
    the compute each payload arrival unlocks in the hop pipeline."""
    if overlap != "chunked" or "tab_hop_kv_nvis" not in tabs:
        return None
    nvis = tabs["tab_hop_kv_nvis"]           # (B, N, H, nq)
    if nvis.shape[2] == 0:
        return None
    per_hop = nvis[:, rank].sum(axis=(0, 2))  # (H,)
    return [fb * tile_flops(float(v), dims) / hw["peak_flops"]
            for v in per_hop]


def measure_many(cands, pool, problem: TuneProblem, dims: ModelDims,
                 *, hw: dict = HW, train: bool = True) -> list[CostEstimate]:
    return [measure_candidate(c, pool, problem, dims, hw=hw, train=train)
            for c in cands]
