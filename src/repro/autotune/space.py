"""Autotuner search space: candidates, admissibility, enumeration
(DESIGN.md §Autotune).

A :class:`Candidate` is one point in the discrete run-config space the
tuner searches — exactly the :class:`repro.configs.RunConfig` knobs the
execution engine dispatches on (``cp_strategy``, ``cp_overlap``,
``kernel_grid``, ``dispatch`` + target, ``kv_comm_dtype``).  Enumeration
is *metadata-driven*: strategies come from the planner registry filtered
by family capability (:func:`repro.planner.planners_for_family`) and
mesh/divisibility admissibility is delegated to the dispatcher's own
:func:`repro.dispatch.cp_degree_options` checks (``g | model``, batch
shardability, Eq.2 context division, quantum alignment) so the tuner can
never emit a config the pipeline would reject.

Inert-knob canonicalization keeps the space free of duplicate points
(two candidates that compile to the same program): ``kernel_grid`` is
pinned to ``flat`` unless the run lowers Pallas tables, the dispatch
target is pinned when dispatch is off, and the comm knobs
(``cp_overlap``, ``kv_comm_dtype``) are pinned when no admissible degree
exceeds 1 (no KV ever crosses ranks).  Canonicalization is what makes
"same inputs -> bit-identical tuned config" testable: the emitted list
is sorted by :meth:`Candidate.key` and depends only on its inputs.
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.configs import RunConfig
from repro.dispatch import DispatchConfig, cp_degree_options
from repro.planner import (available_planners, get_planner,
                           planners_for_family)

__all__ = ["Candidate", "SearchSpace", "TuneProblem", "DEFAULT_SPACE",
           "enumerate_candidates", "candidate_degrees",
           "candidate_admissible"]

#: canonical value for the dispatch target when dispatch is off (the
#: knob is inert there; pinning it dedups the space)
_CANON_TARGET = 1.1


@dataclasses.dataclass(frozen=True)
class TuneProblem:
    """The fixed context a search runs against: mesh axes, batch window
    geometry, and the model/runtime facts admissibility depends on."""

    data: int = 1
    model: int = 1
    context_len: int = 4096
    seqs: int = 1
    #: per-worker slice alignment (the pipeline's Pallas block size when
    #: ``attention_impl == "pallas"``); 0/1 = unconstrained
    quantum: int = 1
    attention_impl: str = "xla"
    family: str = "dense"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the run-config search space (RunConfig overrides)."""

    cp_strategy: str = "flashcp"
    cp_overlap: str = "chunked"            # chunked | none
    kernel_grid: str = "flat"              # flat | rect
    dispatch: str = "off"                  # off | adaptive
    dispatch_target_imbalance: float = _CANON_TARGET
    kv_comm_dtype: str = "native"          # native | int8

    def key(self) -> tuple:
        """Total deterministic order over candidates (ties in every score
        break on this, so selections are process-stable)."""
        return (self.cp_strategy, self.cp_overlap, self.kernel_grid,
                self.dispatch, round(self.dispatch_target_imbalance, 6),
                self.kv_comm_dtype)

    def apply(self, run: RunConfig) -> RunConfig:
        """The tuned RunConfig: ``run`` with this candidate's knobs set."""
        return dataclasses.replace(
            run, cp_strategy=self.cp_strategy, cp_overlap=self.cp_overlap,
            kernel_grid=self.kernel_grid, dispatch=self.dispatch,
            dispatch_target_imbalance=self.dispatch_target_imbalance,
            kv_comm_dtype=self.kv_comm_dtype)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Knob options the enumerator sweeps.  ``strategies=()`` means every
    registered planner admissible for the problem's family."""

    strategies: tuple[str, ...] = ()
    overlaps: tuple[str, ...] = ("chunked", "none")
    grids: tuple[str, ...] = ("flat", "rect")
    dispatch_modes: tuple[str, ...] = ("off", "adaptive")
    dispatch_targets: tuple[float, ...] = (1.05, 1.1, 1.3)
    kv_dtypes: tuple[str, ...] = ("native", "int8")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


DEFAULT_SPACE = SearchSpace()


def _dispatch_cfg(problem: TuneProblem, target: float,
                  fixed_cp: int = 0,
                  context_multiple: int = 1) -> DispatchConfig:
    # a planner needing ctx % (k*N) == 0 (llama3's 2N zigzag) gets bins
    # packed to multiples of k*model — divisible by k*g for every g | model
    bq = context_multiple * problem.model if context_multiple > 1 else 1
    return DispatchConfig(
        data=problem.data, model=problem.model, seqs=problem.seqs,
        target_imbalance=target, min_cp=1, fixed_cp=fixed_cp,
        quantum=problem.quantum, bin_quantum=bq)


def _context_multiple(strategy: str) -> int:
    return get_planner(strategy).info.context_multiple


def candidate_degrees(cand: Candidate, problem: TuneProblem) -> list[int]:
    """Admissible CP degrees this candidate may run at, via the
    dispatcher's own divisibility checks.  ``dispatch=off`` pins the full
    model axis (the static pipeline's degree); empty = inadmissible."""
    if cand.cp_strategy not in available_planners():
        return []
    fixed = 0 if cand.dispatch == "adaptive" else problem.model
    cfg = _dispatch_cfg(problem, cand.dispatch_target_imbalance, fixed,
                        _context_multiple(cand.cp_strategy))
    return cp_degree_options(cfg, problem.context_len, strict=False)


def candidate_admissible(cand: Candidate, problem: TuneProblem) -> bool:
    """Re-derivable admissibility predicate (the property tests assert
    every enumerated candidate passes it): the strategy must be
    registered and family-admissible, and at least one CP degree must
    clear the dispatcher's mesh/divisibility gauntlet."""
    if cand.cp_strategy not in available_planners():
        return False
    if cand.cp_strategy not in planners_for_family(problem.family):
        return False
    return bool(candidate_degrees(cand, problem))


def _canonicalize(cand: Candidate, problem: TuneProblem) -> Candidate:
    """Pin inert knobs so distinct candidates are distinct programs."""
    updates: dict = {}
    if problem.attention_impl != "pallas":
        # visit tables are never emitted; the grid knob does nothing
        updates["kernel_grid"] = "flat"
    if cand.dispatch == "off":
        updates["dispatch_target_imbalance"] = _CANON_TARGET
    degrees = candidate_degrees(cand, problem)
    if degrees and max(degrees) <= 1:
        # no admissible degree moves KV across ranks: the comm knobs are
        # inert — pin them to the RunConfig defaults
        updates["cp_overlap"] = "chunked"
        updates["kv_comm_dtype"] = "native"
    return dataclasses.replace(cand, **updates) if updates else cand


def enumerate_candidates(problem: TuneProblem,
                         space: SearchSpace = DEFAULT_SPACE
                         ) -> list[Candidate]:
    """Every admissible, canonical candidate of ``space`` for ``problem``,
    deduplicated and sorted by :meth:`Candidate.key`.

    Deterministic by construction: option tuples are iterated in given
    order, the registry listing is sorted, and the output order depends
    only on the (problem, space) inputs — never on hashing or RNG.
    """
    # default strategy set: family-admissible planners, minus reference
    # solvers too expensive to plan every batch with (cost_hint
    # "exponential" — bnb exists for Table 2, not production steps)
    strategies = space.strategies or tuple(
        s for s in planners_for_family(problem.family)
        if get_planner(s).info.cost_hint != "exponential")
    out: dict[tuple, Candidate] = {}
    for strat, overlap, grid, mode in itertools.product(
            strategies, space.overlaps, space.grids, space.dispatch_modes):
        targets = space.dispatch_targets if mode == "adaptive" \
            else (_CANON_TARGET,)
        for target, dtype in itertools.product(targets, space.kv_dtypes):
            cand = Candidate(
                cp_strategy=strat, cp_overlap=overlap, kernel_grid=grid,
                dispatch=mode, dispatch_target_imbalance=float(target),
                kv_comm_dtype=dtype)
            if not candidate_admissible(cand, problem):
                continue
            cand = _canonicalize(cand, problem)
            out.setdefault(cand.key(), cand)
    return [out[k] for k in sorted(out)]
