"""Visit-table layout contract shared by the kernel, planner and CP
layers (import-light: no jax/numpy, safe from host-only planner code).

One kernel grid schedule <-> one table array family:

* ``grid="rect"`` — rectangular visit tables, 2 arrays per direction:
  ``(idx, nvis)`` for the fwd/dQ map and the dKV reverse map.
* ``grid="flat"`` — flattened work queues, 3 arrays per direction:
  ``(row, col, flags)`` (see
  :func:`repro.kernels.doc_attention.build_work_queue`).

The planner emitter (:func:`repro.planner.encode.emit_visit_tables`)
prefixes these base names per table group (``tab_``, ``tab_loc_``,
``tab_hop_``); :func:`repro.core.cp_attention.make_cp_context` resolves
the same keys back out of the plan arrays.
"""

from __future__ import annotations

RECT_TABLE_NAMES = ("kv_idx", "kv_nvis", "q_idx", "q_nvis")
FLAT_TABLE_NAMES = ("fq_row", "fq_col", "fq_flags",
                    "rq_row", "rq_col", "rq_flags")

#: arrays per direction (fwd/dQ map | dKV reverse map) for each grid
GRID_TABLE_HALF = {"rect": 2, "flat": 3}


def grid_table_names(grid: str) -> tuple[str, ...]:
    if grid not in GRID_TABLE_HALF:
        raise ValueError(f"unknown kernel grid {grid!r}")
    return FLAT_TABLE_NAMES if grid == "flat" else RECT_TABLE_NAMES


def table_keys(prefix: str, grid: str) -> tuple[str, ...]:
    """Plan-array key family for one table group (e.g. ``tab_loc_``)."""
    return tuple(f"{prefix}{n}" for n in grid_table_names(grid))
