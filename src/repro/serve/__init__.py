"""CP serving engine: continuous batching over a paged KV block pool.

``ServeEngine`` drives budgeted chunked prefill + ragged flash-decode
steps over a global block pool (``BlockPool``) with cross-request
prefix sharing (``PrefixCache``); the dense per-slot stripe layout
survives as the parity oracle and the recurrent-arch fallback.
``Scheduler``/``Request`` manage slot admission, the SplitFuse-style
token budget, and retirement; ``sampling`` holds the per-request keyed
greedy/temperature/top-k sampler.  ``resilience`` adds bounded
deadline-aware admission, the fault-quarantine watchdog, chaos
injection, and engine snapshot/restore (DESIGN.md
§Serving-resilience).  See launch/serve.py for the CLI and README
"Serving engine" for the architecture.
"""

from .block_pool import BlockPool
from .engine import ServeEngine
from .prefix import PrefixCache
from .resilience import (AdmissionConfig, ChaosInjector, EngineKilled,
                         Watchdog, parse_chaos)
from .sampling import (apply_top_k, sample_tokens, sample_tokens_keyed)
from .scheduler import Request, Scheduler, SlotState

__all__ = ["ServeEngine", "Request", "Scheduler", "SlotState",
           "BlockPool", "PrefixCache",
           "AdmissionConfig", "ChaosInjector", "EngineKilled",
           "Watchdog", "parse_chaos",
           "apply_top_k", "sample_tokens", "sample_tokens_keyed"]
