"""CP serving engine: continuous batching over a slot-based KV cache.

``ServeEngine`` drives chunked cache-writing prefill + ragged
flash-decode steps; ``Scheduler``/``Request`` manage slot admission and
retirement; ``sampling`` holds the per-slot greedy/temperature/top-k
sampler.  See launch/serve.py for the CLI and README "Serving engine"
for the architecture.
"""

from .engine import ServeEngine
from .sampling import apply_top_k, sample_tokens
from .scheduler import Request, Scheduler, SlotState

__all__ = ["ServeEngine", "Request", "Scheduler", "SlotState",
           "apply_top_k", "sample_tokens"]
