"""Token-budget scheduler: slot admission, unified prefill/decode planning.

The engine owns ``num_slots`` request slots over a shared KV store (paged
block pool or dense stripes).  Requests queue FIFO; free slots admit the
head of the queue (``admit`` consults a placement callback so the engine
can refuse — pool exhaustion — without losing FIFO order), and a slot
frees the moment its request finishes (EOS or ``max_new``).

Each engine step is planned as **one token budget** spent across pending
prefill chunks *and* decode tokens (SplitFuse-style): every decode-ready
slot gets its decode token first, and the remaining budget trickles
prompt chunks in for slots still prefilling — a long prompt never stalls
in-flight decodes.  ``unified=False`` restores the serial discipline
(drain all pending prefill before any decode) as the stall baseline the
serve bench measures against.

Oversized requests (``prompt_len + max_new > max_len``) are *rejected*,
not raised: they appear in ``finished`` with ``status="rejected"`` so
one bad request cannot kill the engine loop; completed requests carry
``status="ok"``.

Host-side bookkeeping only — all array work lives in the engine.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import numpy as np

__all__ = ["Request", "SlotState", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # prompt token ids (Tp,) int32
    max_new: int = 16
    temperature: float = 0.0            # 0 = greedy
    top_k: int = 0                      # 0 = unrestricted
    eos_id: int = -1                    # -1 = never stops early
    # audio-frontend prompts: per-token frame embeddings (Tp, d_model);
    # ``tokens`` still carries the codec ids for bookkeeping
    frames: np.ndarray | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class SlotState:
    request: Request
    prefilled: int = 0          # prompt tokens whose KV is in cache
    length: int = 0             # tokens in cache (prompt + generated)
    generated: list[int] = dataclasses.field(default_factory=list)
    # paged layout: this request's block table (physical pool block per
    # logical block), prefix-cache hit size, and the reserved
    # copy-on-write spare for the fully-cached-prompt case
    table: list[int] = dataclasses.field(default_factory=list)
    cached_tokens: int = 0
    spare: int | None = None

    @property
    def prompt_len(self) -> int:
        return self.request.prompt_len

    @property
    def needs_prefill(self) -> bool:
        return self.prefilled < self.prompt_len

    @property
    def decode_ready(self) -> bool:
        return not self.needs_prefill and bool(self.generated)

    @property
    def done(self) -> bool:
        r = self.request
        if len(self.generated) >= r.max_new:
            return True
        return bool(self.generated) and r.eos_id >= 0 \
            and self.generated[-1] == r.eos_id


class Scheduler:
    """FIFO queue + slot table + per-step token-budget planner.

    ``token_budget`` tokens are spent per engine step (0 picks
    ``num_slots + prefill_chunk`` — every decode plus one full prompt
    chunk).  ``admit()`` pairs free slots with queued requests through a
    placement callback; ``plan_step()`` splits the budget; ``record()``
    appends decode tokens and retires finished slots.  An engine hooks
    ``on_retire(slot, state)`` to release KV blocks.
    """

    def __init__(self, num_slots: int, max_len: int, *,
                 prefill_chunk: int = 64, token_budget: int = 0,
                 unified: bool = True):
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget or (num_slots + prefill_chunk)
        self.unified = unified
        self.queue: deque[Request] = deque()
        self.slots: list[SlotState | None] = [None] * num_slots
        self.finished: dict[int, dict[str, Any]] = {}
        self.on_retire: Callable[[int, SlotState], None] | None = None

    # ------------------------------------------------------------- #
    def submit(self, req: Request) -> bool:
        """Queue one request; malformed/oversized requests are recorded
        as rejected in ``finished`` (returns False) instead of raising —
        a bad request must not kill the engine loop."""
        if req.prompt_len == 0:
            self.reject(req, "empty prompt")
        elif req.max_new <= 0:
            self.reject(req, f"non-positive max_new {req.max_new}")
        elif req.prompt_len + req.max_new > self.max_len:
            self.reject(req, f"prompt {req.prompt_len} + max_new "
                        f"{req.max_new} exceeds max_len {self.max_len}")
        else:
            self.queue.append(req)
            return True
        return False

    def reject(self, req: Request, reason: str) -> None:
        """Record ``req`` as rejected in ``finished`` (empty tokens)."""
        self.finished[req.rid] = {
            "status": "rejected", "reason": reason,
            "tokens": np.zeros((0,), np.int32),
            "prompt_len": req.prompt_len}

    def admit(self, place: Callable[[Request], dict | None] | None = None,
              ) -> list[tuple[int, Request]]:
        """Fill free slots from the queue head.  ``place`` reserves
        engine-side resources for a request and returns placement info
        ({"table": [...], "cached": m, "start": s, "spare": b} for the
        paged layout, {} for dense) or None — meaning the request cannot
        be placed *now* (pool exhausted); admission stops there to keep
        FIFO order (backoff, retried next step)."""
        placed = []
        for s in range(self.num_slots):
            if self.slots[s] is not None or not self.queue:
                continue
            req = self.queue[0]
            info = place(req) if place is not None else {}
            if info is None:
                break
            self.queue.popleft()
            st = SlotState(req, table=list(info.get("table", [])),
                           cached_tokens=int(info.get("cached", 0)),
                           spare=info.get("spare"))
            st.prefilled = st.length = int(info.get("start", 0))
            self.slots[s] = st
            placed.append((s, req))
        return placed

    # ------------------------------------------------------------- #
    def plan_step(self) -> tuple[list[tuple[int, int, int]], list[int]]:
        """Split this step's token budget.  Returns
        ``(prefill_items, decode_slots)`` with prefill_items =
        [(slot, start, n_tokens)].  Unified: decode-ready slots are
        funded first (one token each), the remainder buys prompt chunks.
        Serial (unified=False): all pending prefill drains before any
        decode — the stall baseline."""
        decode = [s for s in self.active_slots
                  if self.slots[s].decode_ready]
        pending = [s for s in self.active_slots
                   if self.slots[s].needs_prefill]
        if not self.unified:
            if pending:
                s = pending[0]
                st = self.slots[s]
                n = min(self.prefill_chunk, st.prompt_len - st.prefilled)
                return [(s, st.prefilled, n)], []
            return [], decode
        prefill = []
        budget = max(self.token_budget - len(decode), 0)
        for s in pending:
            if budget <= 0:
                break
            st = self.slots[s]
            n = min(self.prefill_chunk, st.prompt_len - st.prefilled,
                    budget)
            prefill.append((s, st.prefilled, n))
            budget -= n
        return prefill, decode

    def note_prefill(self, slot: int, n_tokens: int) -> None:
        """``n_tokens`` more prompt tokens entered the cache."""
        st = self.slots[slot]
        st.prefilled += n_tokens
        st.length = st.prefilled
        assert st.prefilled <= st.prompt_len, (st.prefilled, st.prompt_len)

    # ------------------------------------------------------------- #
    @property
    def active_slots(self) -> list[int]:
        return [s for s, st in enumerate(self.slots) if st is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(st is not None for st in self.slots)

    def lengths(self) -> np.ndarray:
        """Per-slot cache occupancy (0 for idle slots) — the ragged
        ``pos_t``/``lengths`` feed for the decode step."""
        return np.asarray([0 if st is None else st.length
                           for st in self.slots], np.int32)

    def active_mask(self) -> np.ndarray:
        return np.asarray([st is not None for st in self.slots], bool)

    def temperatures(self) -> np.ndarray:
        return np.asarray([0.0 if st is None else st.request.temperature
                           for st in self.slots], np.float32)

    def top_ks(self) -> np.ndarray:
        return np.asarray([0 if st is None else st.request.top_k
                           for st in self.slots], np.int32)

    def rids(self) -> np.ndarray:
        return np.asarray([0 if st is None else st.request.rid
                           for st in self.slots], np.int32)

    def sample_counts(self) -> np.ndarray:
        """Per-slot index of the *next* sample in its request's key
        stream (= tokens generated so far)."""
        return np.asarray([0 if st is None else len(st.generated)
                           for st in self.slots], np.int32)

    # ------------------------------------------------------------- #
    def start(self, slot: int, first_token: int) -> None:
        """Mark a freshly-prefilled slot: cache holds the prompt, and the
        prefill's last logits produced the first generated token."""
        st = self.slots[slot]
        st.prefilled = st.prompt_len
        st.length = max(st.length, st.prompt_len)
        st.generated.append(int(first_token))
        self._maybe_retire(slot)

    def record(self, tokens: np.ndarray, slots: list[int] | None = None,
               ) -> list[int]:
        """One decode step happened for ``slots`` (default: every
        decode-ready slot): each consumed its last token (cache grew by
        one) and sampled the next.  Returns slots retired this step."""
        if slots is None:
            slots = [s for s in self.active_slots
                     if self.slots[s].decode_ready]
        retired = []
        for s in slots:
            st = self.slots[s]
            st.length += 1
            st.generated.append(int(tokens[s]))
            if self._maybe_retire(s):
                retired.append(s)
        return retired

    def _maybe_retire(self, slot: int) -> bool:
        st = self.slots[slot]
        if not st.done:
            return False
        gen = st.generated
        r = st.request
        if r.eos_id >= 0 and r.eos_id in gen:
            gen = gen[:gen.index(r.eos_id) + 1]
        self.finished[r.rid] = {"status": "ok",
                                "tokens": np.asarray(gen, np.int32),
                                "prompt_len": r.prompt_len}
        if self.on_retire is not None:
            self.on_retire(slot, st)
        self.slots[slot] = None
        return True
