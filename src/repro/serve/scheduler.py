"""Continuous-batching scheduler: slot admission, ragged decode, retirement.

The engine owns ``num_slots`` cache rows.  Requests queue FIFO; whenever a
slot is free the next request is admitted into it (prefill), and a slot
frees the moment its request finishes (EOS or ``max_new`` tokens) — other
slots keep decoding, so a finished short request never holds a long one
hostage (the decode batch is *ragged* by construction: per-slot ``lengths``
drive the attention mask / flash-decode block clamp).

Host-side bookkeeping only — all array work lives in the engine.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np

__all__ = ["Request", "SlotState", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # prompt token ids (Tp,) int32
    max_new: int = 16
    temperature: float = 0.0            # 0 = greedy
    top_k: int = 0                      # 0 = unrestricted
    eos_id: int = -1                    # -1 = never stops early
    # audio-frontend prompts: per-token frame embeddings (Tp, d_model);
    # ``tokens`` still carries the codec ids for bookkeeping
    frames: np.ndarray | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class SlotState:
    request: Request
    length: int = 0                     # tokens in cache (prompt + generated)
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        r = self.request
        if len(self.generated) >= r.max_new:
            return True
        return bool(self.generated) and r.eos_id >= 0 \
            and self.generated[-1] == r.eos_id


class Scheduler:
    """FIFO queue + slot table.  ``admit()`` pairs free slots with queued
    requests; ``record()`` appends sampled tokens and retires finished
    slots, returning the completed requests."""

    def __init__(self, num_slots: int, max_len: int):
        self.num_slots = num_slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.slots: list[SlotState | None] = [None] * num_slots
        self.finished: dict[int, dict[str, Any]] = {}

    # ------------------------------------------------------------- #
    def submit(self, req: Request) -> None:
        if req.prompt_len + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + max_new "
                f"{req.max_new} exceeds cache max_len {self.max_len}")
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots from the queue; returns (slot, request) pairs
        the engine must prefill."""
        placed = []
        for s in range(self.num_slots):
            if self.slots[s] is None and self.queue:
                req = self.queue.popleft()
                self.slots[s] = SlotState(req)
                placed.append((s, req))
        return placed

    # ------------------------------------------------------------- #
    @property
    def active_slots(self) -> list[int]:
        return [s for s, st in enumerate(self.slots) if st is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(st is not None for st in self.slots)

    def lengths(self) -> np.ndarray:
        """Per-slot cache occupancy (0 for idle slots) — the ragged
        ``pos_t``/``lengths`` feed for the decode step."""
        return np.asarray([0 if st is None else st.length
                           for st in self.slots], np.int32)

    def active_mask(self) -> np.ndarray:
        return np.asarray([st is not None for st in self.slots], bool)

    def temperatures(self) -> np.ndarray:
        return np.asarray([0.0 if st is None else st.request.temperature
                           for st in self.slots], np.float32)

    def top_ks(self) -> np.ndarray:
        return np.asarray([0 if st is None else st.request.top_k
                           for st in self.slots], np.int32)

    # ------------------------------------------------------------- #
    def start(self, slot: int, first_token: int) -> None:
        """Mark a freshly-prefilled slot: cache holds the prompt, and the
        prefill's last logits produced the first generated token."""
        st = self.slots[slot]
        st.length = st.request.prompt_len
        st.generated.append(int(first_token))
        self._maybe_retire(slot)

    def record(self, tokens: np.ndarray) -> list[int]:
        """One decode step happened: every active slot consumed its last
        token (cache grew by one) and sampled the next.  Returns slots
        retired this step."""
        retired = []
        for s in self.active_slots:
            st = self.slots[s]
            st.length += 1
            st.generated.append(int(tokens[s]))
            if self._maybe_retire(s):
                retired.append(s)
        return retired

    def _maybe_retire(self, slot: int) -> bool:
        st = self.slots[slot]
        if not st.done:
            return False
        gen = st.generated
        r = st.request
        if r.eos_id >= 0 and r.eos_id in gen:
            gen = gen[:gen.index(r.eos_id) + 1]
        self.finished[r.rid] = {"tokens": np.asarray(gen, np.int32),
                                "prompt_len": r.prompt_len}
        self.slots[slot] = None
        return True
