"""Token-budget scheduler: slot admission, unified prefill/decode planning.

The engine owns ``num_slots`` request slots over a shared KV store (paged
block pool or dense stripes).  Requests queue FIFO; free slots admit from
the queue head (``admit`` consults a placement callback so the engine
can refuse — pool exhaustion — without losing a request), and a slot
frees the moment its request finishes (EOS or ``max_new``).

Each engine step is planned as **one token budget** spent across pending
prefill chunks *and* decode tokens (SplitFuse-style): every decode-ready
slot gets its decode token first, and the remaining budget trickles
prompt chunks in for slots still prefilling — a long prompt never stalls
in-flight decodes.  ``unified=False`` restores the serial discipline
(drain all pending prefill before any decode) as the stall baseline the
serve bench measures against.

Resilience (DESIGN.md §Serving-resilience): the queue is bounded and
overload sheds by deadline slack under ``AdmissionConfig``'s
``"deadline"`` policy (strict ``"fifo"`` is the parity baseline), a
blocked head can be jumped by up to ``lookahead`` placeable requests
under a starvation guard, and faults abort individual requests without
touching the rest.  Every submitted request terminates in ``finished``
with one of four statuses — ``"ok"``, ``"rejected"`` (malformed or
unplaceable), ``"shed"`` (overload victim), ``"aborted"`` (fault
quarantine or engine step cap) — so one bad request can never kill the
engine loop *and* no request is ever silently dropped.  Per-status
reason-keyed counters live in ``outcomes``.

Host-side bookkeeping only — all array work lives in the engine.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import numpy as np

from .resilience import AdmissionConfig, deadline_slack, shed_key

__all__ = ["Request", "SlotState", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # prompt token ids (Tp,) int32
    max_new: int = 16
    temperature: float = 0.0            # 0 = greedy
    top_k: int = 0                      # 0 = unrestricted
    eos_id: int = -1                    # -1 = never stops early
    # audio-frontend prompts: per-token frame embeddings (Tp, d_model);
    # ``tokens`` still carries the codec ids for bookkeeping
    frames: np.ndarray | None = None
    # resilience: engine steps from submission within which the request
    # must finish (-1 = no deadline) and its shed priority — lower
    # priority sheds first under overload
    deadline_steps: int = -1
    priority: int = 0
    # stamped by Scheduler.submit (engine-step clock + wall clock)
    submit_step: int = 0
    submit_s: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class SlotState:
    request: Request
    prefilled: int = 0          # prompt tokens whose KV is in cache
    length: int = 0             # tokens in cache (prompt + generated)
    generated: list[int] = dataclasses.field(default_factory=list)
    # paged layout: this request's block table (physical pool block per
    # logical block), prefix-cache hit size, and the reserved
    # copy-on-write spare for the fully-cached-prompt case
    table: list[int] = dataclasses.field(default_factory=list)
    cached_tokens: int = 0
    spare: int | None = None

    @property
    def prompt_len(self) -> int:
        return self.request.prompt_len

    @property
    def needs_prefill(self) -> bool:
        return self.prefilled < self.prompt_len

    @property
    def decode_ready(self) -> bool:
        return not self.needs_prefill and bool(self.generated)

    @property
    def done(self) -> bool:
        r = self.request
        if len(self.generated) >= r.max_new:
            return True
        return bool(self.generated) and r.eos_id >= 0 \
            and self.generated[-1] == r.eos_id


class Scheduler:
    """FIFO queue + slot table + per-step token-budget planner.

    ``token_budget`` tokens are spent per engine step (0 picks
    ``num_slots + prefill_chunk`` — every decode plus one full prompt
    chunk).  ``admit()`` pairs free slots with queued requests through a
    placement callback; ``plan_step()`` splits the budget; ``record()``
    appends decode tokens and retires finished slots.  An engine hooks
    ``on_retire(slot, state)`` to release KV blocks.

    ``admission`` bounds the queue and selects the overload policy
    (see :class:`~.resilience.AdmissionConfig`); ``clock`` is the
    engine-step counter the deadline math runs on (the engine advances
    it every step).
    """

    def __init__(self, num_slots: int, max_len: int, *,
                 prefill_chunk: int = 64, token_budget: int = 0,
                 unified: bool = True,
                 admission: AdmissionConfig | None = None):
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget or (num_slots + prefill_chunk)
        self.unified = unified
        self.admission = admission or AdmissionConfig()
        self.queue: deque[Request] = deque()
        self.slots: list[SlotState | None] = [None] * num_slots
        self.finished: dict[int, dict[str, Any]] = {}
        self.on_retire: Callable[[int, SlotState], None] | None = None
        self.clock = 0
        # per-status, reason-keyed terminal counters (engine.stats
        # aliases these dicts — mutate in place, never rebind)
        self.outcomes: dict[str, dict[str, int]] = {
            "rejected": {}, "shed": {}, "aborted": {}}
        self.duplicates: list[dict[str, Any]] = []
        # look-ahead starvation guard: how often the current blocked
        # head has been jumped, and who that head is
        self._head_rid: int | None = None
        self._head_skips = 0

    # ------------------------------------------------------------- #
    def _count(self, status: str, kind: str) -> None:
        c = self.outcomes[status]
        c[kind] = c.get(kind, 0) + 1

    def _entry(self, req: Request, status: str, tokens,
               reason: str | None = None) -> dict[str, Any]:
        latency = self.clock - req.submit_step
        e: dict[str, Any] = {
            "status": status,
            "tokens": np.asarray(tokens, np.int32),
            "prompt_len": req.prompt_len,
            "submit_step": req.submit_step, "finish_step": self.clock,
            "latency_steps": latency,
            "latency_s": time.perf_counter() - req.submit_s,
            "deadline_steps": req.deadline_steps,
            # only a completed request can meet its deadline; goodput
            # counts (status ok) x (within deadline, if any)
            "deadline_met": status == "ok"
            and (req.deadline_steps < 0 or latency <= req.deadline_steps),
        }
        if reason is not None:
            e["reason"] = reason
        return e

    def _tracks(self, rid: int) -> bool:
        return rid in self.finished \
            or any(r.rid == rid for r in self.queue) \
            or any(st is not None and st.request.rid == rid
                   for st in self.slots)

    # ------------------------------------------------------------- #
    def submit(self, req: Request) -> bool:
        """Queue one request; malformed/oversized/overflow requests are
        recorded in ``finished`` (returns False) instead of raising — a
        bad request must not kill the engine loop.  A duplicate rid is
        refused *without* touching ``finished`` (it would clobber the
        earlier request's entry) and logged in ``duplicates``."""
        req.submit_step = self.clock
        req.submit_s = time.perf_counter()
        if self._tracks(req.rid):
            self._count("rejected", "duplicate_rid")
            self.duplicates.append({
                "rid": req.rid,
                "reason": f"duplicate rid {req.rid}: a request with this "
                          "id is already queued, active, or finished"})
            return False
        if req.prompt_len == 0:
            self.reject(req, "empty prompt", kind="empty_prompt")
        elif req.max_new <= 0:
            self.reject(req, f"non-positive max_new {req.max_new}",
                        kind="bad_max_new")
        elif req.prompt_len + req.max_new > self.max_len:
            self.reject(req, f"prompt {req.prompt_len} + max_new "
                        f"{req.max_new} exceeds max_len {self.max_len}",
                        kind="oversized")
        elif self.admission.max_queue \
                and len(self.queue) >= self.admission.max_queue:
            return self._overflow(req)
        else:
            self.queue.append(req)
            return True
        return False

    def _overflow(self, req: Request) -> bool:
        """Queue full.  FIFO policy sheds the incoming request (strict
        arrival order — the parity baseline); deadline policy sheds the
        queued-or-incoming request with the worst ``shed_key`` (lowest
        priority, then least deadline slack)."""
        if self.admission.policy != "deadline":
            self.shed(req, f"queue full ({len(self.queue)} waiting)",
                      kind="queue_full")
            return False
        victim = min([*self.queue, req],
                     key=lambda r: shed_key(r, self.clock,
                                            self.prefill_chunk))
        slack = deadline_slack(victim, self.clock, self.prefill_chunk)
        self.shed(victim, f"queue full ({len(self.queue)} waiting): "
                  f"least-slack victim (priority {victim.priority}, "
                  f"slack {slack})", kind="queue_full")
        if victim is req:
            return False
        self.queue.remove(victim)
        self.queue.append(req)
        return True

    def reject(self, req: Request, reason: str,
               kind: str = "unplaceable") -> None:
        """Record ``req`` as rejected in ``finished`` (empty tokens)."""
        self._count("rejected", kind)
        self.finished[req.rid] = self._entry(
            req, "rejected", np.zeros((0,), np.int32), reason)

    def shed(self, req: Request, reason: str, kind: str) -> None:
        """Record ``req`` as an overload-shedding victim."""
        self._count("shed", kind)
        self.finished[req.rid] = self._entry(
            req, "shed", np.zeros((0,), np.int32), reason)

    def _shed_expired(self) -> None:
        """Deadline policy: drop queued requests whose deadline is
        unmeetable even if admitted this instant (optimistic estimate —
        a shed request provably could not have finished in time)."""
        keep: deque[Request] = deque()
        while self.queue:
            r = self.queue.popleft()
            slack = deadline_slack(r, self.clock, self.prefill_chunk)
            if slack < 0:
                self.shed(r, "deadline unmeetable in queue "
                          f"(slack {slack} steps at admission)",
                          kind="deadline_expired")
            else:
                keep.append(r)
        self.queue = keep

    def admit(self, place: Callable[[Request], dict | None] | None = None,
              ) -> list[tuple[int, Request]]:
        """Fill free slots from the queue.  ``place`` reserves
        engine-side resources for a request and returns placement info
        ({"table": [...], "cached": m, "start": s, "spare": b} for the
        paged layout, {} for dense) or None — the request cannot be
        placed *now* (pool exhausted) and stays queued in order.

        With ``admission.lookahead == 0`` a blocked request stops
        admission entirely (strict FIFO: head-of-line blocking).  With
        lookahead N, up to N requests past the first blocked one are
        probed, so a small request behind a pool-hogging head still
        admits — bounded by the starvation guard: once the same head
        has been jumped ``starvation_limit`` times, look-ahead pauses
        until that head places (or sheds), so it cannot starve."""
        if self.admission.policy == "deadline":
            self._shed_expired()
        placed: list[tuple[int, Request]] = []
        free = [s for s in range(self.num_slots) if self.slots[s] is None]
        lookahead = self.admission.lookahead
        if self._head_skips >= self.admission.starvation_limit:
            lookahead = 0
        blocked: list[Request] = []
        while free and self.queue and len(blocked) <= lookahead:
            req = self.queue.popleft()
            info = place(req) if place is not None else {}
            if info is None:
                blocked.append(req)
                continue
            s = free.pop(0)
            st = SlotState(req, table=list(info.get("table", [])),
                           cached_tokens=int(info.get("cached", 0)),
                           spare=info.get("spare"))
            st.prefilled = st.length = int(info.get("start", 0))
            self.slots[s] = st
            placed.append((s, req))
        for r in reversed(blocked):
            self.queue.appendleft(r)
        # starvation accounting: the head pops first, so any placement
        # in a call where the head blocked is a jump over it
        if blocked:
            head = blocked[0]
            if head.rid != self._head_rid:
                self._head_rid, self._head_skips = head.rid, 0
            if placed:
                self._head_skips += 1
        elif self._head_rid is not None \
                and not any(r.rid == self._head_rid for r in self.queue):
            self._head_rid, self._head_skips = None, 0
        return placed

    # ------------------------------------------------------------- #
    def plan_step(self) -> tuple[list[tuple[int, int, int]], list[int]]:
        """Split this step's token budget.  Returns
        ``(prefill_items, decode_slots)`` with prefill_items =
        [(slot, start, n_tokens)].  Unified: decode-ready slots are
        funded first (one token each), the remainder buys prompt chunks.
        Serial (unified=False): all pending prefill drains before any
        decode — the stall baseline."""
        decode = [s for s in self.active_slots
                  if self.slots[s].decode_ready]
        pending = [s for s in self.active_slots
                   if self.slots[s].needs_prefill]
        if not self.unified:
            if pending:
                s = pending[0]
                st = self.slots[s]
                n = min(self.prefill_chunk, st.prompt_len - st.prefilled)
                return [(s, st.prefilled, n)], []
            return [], decode
        prefill = []
        budget = max(self.token_budget - len(decode), 0)
        for s in pending:
            if budget <= 0:
                break
            st = self.slots[s]
            n = min(self.prefill_chunk, st.prompt_len - st.prefilled,
                    budget)
            prefill.append((s, st.prefilled, n))
            budget -= n
        return prefill, decode

    def note_prefill(self, slot: int, n_tokens: int) -> None:
        """``n_tokens`` more prompt tokens entered the cache."""
        st = self.slots[slot]
        st.prefilled += n_tokens
        st.length = st.prefilled
        assert st.prefilled <= st.prompt_len, (st.prefilled, st.prompt_len)

    # ------------------------------------------------------------- #
    @property
    def active_slots(self) -> list[int]:
        return [s for s, st in enumerate(self.slots) if st is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(st is not None for st in self.slots)

    def lengths(self) -> np.ndarray:
        """Per-slot cache occupancy (0 for idle slots) — the ragged
        ``pos_t``/``lengths`` feed for the decode step."""
        return np.asarray([0 if st is None else st.length
                           for st in self.slots], np.int32)

    def active_mask(self) -> np.ndarray:
        return np.asarray([st is not None for st in self.slots], bool)

    def temperatures(self) -> np.ndarray:
        return np.asarray([0.0 if st is None else st.request.temperature
                           for st in self.slots], np.float32)

    def top_ks(self) -> np.ndarray:
        return np.asarray([0 if st is None else st.request.top_k
                           for st in self.slots], np.int32)

    def rids(self) -> np.ndarray:
        return np.asarray([0 if st is None else st.request.rid
                           for st in self.slots], np.int32)

    def sample_counts(self) -> np.ndarray:
        """Per-slot index of the *next* sample in its request's key
        stream (= tokens generated so far)."""
        return np.asarray([0 if st is None else len(st.generated)
                           for st in self.slots], np.int32)

    # ------------------------------------------------------------- #
    def start(self, slot: int, first_token: int) -> None:
        """Mark a freshly-prefilled slot: cache holds the prompt, and the
        prefill's last logits produced the first generated token."""
        st = self.slots[slot]
        st.prefilled = st.prompt_len
        st.length = max(st.length, st.prompt_len)
        st.generated.append(int(first_token))
        self._maybe_retire(slot)

    def record(self, tokens: np.ndarray, slots: list[int] | None = None,
               ) -> list[int]:
        """One decode step happened for ``slots`` (default: every
        decode-ready slot): each consumed its last token (cache grew by
        one) and sampled the next.  Returns slots retired this step."""
        if slots is None:
            slots = [s for s in self.active_slots
                     if self.slots[s].decode_ready]
        retired = []
        for s in slots:
            st = self.slots[s]
            st.length += 1
            st.generated.append(int(tokens[s]))
            if self._maybe_retire(s):
                retired.append(s)
        return retired

    def abort(self, slot: int, reason: str, kind: str = "fault") -> None:
        """Quarantine one active request: record it as ``"aborted"``
        with the tokens generated so far, release its KV (``on_retire``)
        and free the slot.  Healthy slots are untouched — per-request
        keyed sampling keeps their token streams bitwise identical."""
        st = self.slots[slot]
        assert st is not None, f"abort of idle slot {slot}"
        r = st.request
        self._count("aborted", kind)
        self.finished[r.rid] = self._entry(
            r, "aborted", np.asarray(st.generated, np.int32), reason)
        if self.on_retire is not None:
            self.on_retire(slot, st)
        self.slots[slot] = None

    def abort_all(self, reason: str, kind: str = "step_cap") -> None:
        """Abort every in-flight and queued request (engine step cap /
        shutdown): partial tokens are preserved, nothing is silently
        dropped from ``finished``."""
        for s in list(self.active_slots):
            self.abort(s, reason, kind=kind)
        while self.queue:
            r = self.queue.popleft()
            self._count("aborted", kind)
            self.finished[r.rid] = self._entry(
                r, "aborted", np.zeros((0,), np.int32),
                f"{reason} (queued, never admitted)")

    def _maybe_retire(self, slot: int) -> bool:
        st = self.slots[slot]
        if not st.done:
            return False
        gen = st.generated
        r = st.request
        if r.eos_id >= 0 and r.eos_id in gen:
            gen = gen[:gen.index(r.eos_id) + 1]
        self.finished[r.rid] = self._entry(r, "ok", gen)
        if self.on_retire is not None:
            self.on_retire(slot, st)
        self.slots[slot] = None
        return True
