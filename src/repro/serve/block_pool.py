"""Global KV block pool: fixed-size blocks, free-list alloc, refcounts.

The serving engine's KV memory is one pool of ``num_blocks`` blocks of
``block_size`` token positions each (per layer — the device arrays live
in the engine's paged cache, see ``models.init_paged_cache``; this class
is the *host-side allocator* over their block index space).  Each
request owns a block table (list of physical block ids); blocks are
refcounted so a prefix block can back many tables at once
(serve/prefix.py) and stays allocated while the prefix cache itself
holds a reference.

Invariants:

* a block is either on the free list (refcount 0) or allocated
  (refcount >= 1) — never both;
* ``alloc`` is all-or-nothing: a request that cannot get every block it
  asked for gets none (admission backoff, no partial reservations);
* ``release`` decrements and returns blocks to the free list at zero —
  LIFO, so recently-freed blocks are reused first (warm HBM).

Host-side bookkeeping only; see ``ServeEngine`` for the device arrays.
"""

from __future__ import annotations

__all__ = ["BlockPool"]


class BlockPool:
    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError(f"bad pool geometry {num_blocks}x{block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._ref = [0] * num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))   # pop() -> block 0
        self.peak_allocated = 0

    # ------------------------------------------------------------- #
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def allocated_count(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.allocated_count / self.num_blocks

    def refcount(self, bid: int) -> int:
        return self._ref[bid]

    def is_shared(self, bid: int) -> bool:
        return self._ref[bid] > 1

    # ------------------------------------------------------------- #
    def alloc(self, n: int) -> list[int] | None:
        """Take ``n`` blocks off the free list (refcount 1 each), or
        ``None`` if fewer than ``n`` are free — all-or-nothing."""
        if n < 0:
            raise ValueError(n)
        if len(self._free) < n:
            return None
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            assert self._ref[b] == 0, (b, self._ref[b])
            self._ref[b] = 1
        self.peak_allocated = max(self.peak_allocated, self.allocated_count)
        return ids

    def retain(self, ids) -> None:
        """Add one reference to each allocated block in ``ids``."""
        for b in ids:
            if self._ref[b] <= 0:
                raise ValueError(f"retain of free block {b}")
            self._ref[b] += 1

    def release(self, ids) -> list[int]:
        """Drop one reference from each block; returns the blocks that
        reached refcount 0 and went back to the free list."""
        freed = []
        for b in ids:
            if self._ref[b] <= 0:
                raise ValueError(f"release of free block {b}")
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free.append(b)
                freed.append(b)
        return freed

    def stats(self) -> dict:
        return {"num_blocks": self.num_blocks,
                "block_size": self.block_size,
                "allocated": self.allocated_count,
                "free": self.free_count,
                "peak_allocated": self.peak_allocated,
                "occupancy": self.occupancy}
