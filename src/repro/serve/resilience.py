"""Serving resilience: admission control, fault quarantine, snapshots.

The serving twin of ``runtime/recovery.py``'s training contract
(DESIGN.md §Serving-resilience).  Three independent mechanisms:

* **Bounded admission with deadline-aware shedding** —
  :class:`AdmissionConfig` caps the queue and picks the overload
  policy: ``"fifo"`` sheds the *incoming* request when the queue is
  full (strict arrival order, the parity baseline), ``"deadline"``
  sheds the queued-or-incoming request least likely to meet its
  deadline (lowest ``(priority, slack)``) and drops queued requests
  whose deadline became unmeetable.  ``lookahead`` lets up to that
  many requests jump a head that cannot be placed right now (pool
  backoff) — fixing head-of-line blocking — under a starvation guard:
  once the head has been jumped ``starvation_limit`` times, look-ahead
  is suspended until the head places.
* **Fault quarantine** (:class:`Watchdog`) — per-step detection of
  non-finite logits (checked inside the jitted decode program) and
  planned-but-no-progress slots; the poisoned request is aborted
  (status ``"aborted"``, reason recorded, KV blocks released) while
  every healthy request finishes with bitwise-identical tokens —
  per-request keyed sampling makes token streams independent of batch
  composition, so removing one request cannot perturb the others.
* **Snapshot / drain-restore** (:func:`snapshot_engine` /
  :func:`restore_engine`) — the full engine state (KV cache leaves,
  scheduler queue/slots/finished, block pool refcounts, prefix-cache
  trie, per-request RNG counters = tokens generated so far) through
  the PR-7 ``CheckpointManager`` atomic-commit path, so a killed
  engine restores mid-decode with zero request loss and bitwise token
  parity.

:class:`ChaosInjector` is the serving-side ``FailureInjector``:
deterministic NaN-logits / stuck-slot / latency-spike / kill faults
keyed on (rid, engine step), driving the chaos tests and the
``resilience`` bench suite.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "AdmissionConfig", "ChaosInjector", "EngineKilled", "Watchdog",
    "deadline_slack", "estimate_steps", "parse_chaos", "restore_engine",
    "shed_key", "snapshot_engine",
]


# ------------------------------------------------------------------- #
# admission policy
# ------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Queue bound + overload policy for :class:`~.scheduler.Scheduler`.

    ``max_queue=0`` keeps the queue unbounded (the pre-resilience
    behavior).  ``lookahead=0`` is strict FIFO admission: a head that
    cannot be placed blocks everything behind it."""
    max_queue: int = 0
    policy: str = "fifo"            # "fifo" | "deadline"
    lookahead: int = 0              # requests that may jump a blocked head
    starvation_limit: int = 8       # head jumps before look-ahead pauses

    def __post_init__(self):
        if self.policy not in ("fifo", "deadline"):
            raise ValueError(f"unknown admission policy {self.policy!r}")


def estimate_steps(prompt_len: int, max_new: int, prefill_chunk: int) -> int:
    """Optimistic engine-step lower bound to serve a queued request:
    one step per prompt chunk (the final chunk also yields the first
    token) plus one decode step per remaining token.  Optimistic on
    purpose — shedding on it never sheds a request that could still
    have met its deadline under ideal scheduling."""
    chunks = -(-prompt_len // max(prefill_chunk, 1))
    return chunks + max(max_new - 1, 0)


def deadline_slack(req, clock: int, prefill_chunk: int) -> float:
    """Engine steps to spare before ``req``'s deadline becomes
    unmeetable even if admitted *now* (+inf when no deadline)."""
    if req.deadline_steps < 0:
        return math.inf
    due = req.submit_step + req.deadline_steps
    return due - clock - estimate_steps(req.prompt_len, req.max_new,
                                        prefill_chunk)


def shed_key(req, clock: int, prefill_chunk: int):
    """Shed order under overload: lowest priority first, then least
    slack, then newest arrival (highest rid) as the tie-break."""
    return (req.priority, deadline_slack(req, clock, prefill_chunk),
            -req.rid)


# ------------------------------------------------------------------- #
# fault quarantine
# ------------------------------------------------------------------- #
class Watchdog:
    """Per-slot no-progress detector.  A slot counts as *stalled* only
    on steps where the scheduler planned work for it (prefill chunk or
    decode token) and none landed — budget starvation and serial-mode
    waits plan nothing and so can never trip it.  ``stall_patience``
    consecutive stalled steps abort the slot's request."""

    def __init__(self, stall_patience: int = 8):
        self.stall_patience = stall_patience
        self._stalled: dict[int, int] = {}

    def observe(self, planned: set[int], progressed: set[int],
                ) -> list[tuple[int, int]]:
        """Returns ``[(slot, consecutive_stalled_steps)]`` for slots
        that just hit the patience limit."""
        out = []
        for s in planned:
            if s in progressed:
                self._stalled.pop(s, None)
                continue
            c = self._stalled.get(s, 0) + 1
            self._stalled[s] = c
            if c >= self.stall_patience:
                out.append((s, c))
        return out

    def clear(self, slot: int) -> None:
        self._stalled.pop(slot, None)


class EngineKilled(RuntimeError):
    """Raised by an injected kill (``ChaosInjector.kill_at``) — the
    serving analogue of a host loss.  The CLI catches it, rebuilds the
    engine, and restores the latest snapshot."""


@dataclasses.dataclass
class ChaosInjector:
    """Deterministic serve-path fault injection (the serving
    ``FailureInjector``).  All faults key on the engine step counter,
    so a restored run does not re-fire a fault it already survived —
    ``kill_fired`` additionally makes the kill idempotent in-process.

    * ``nan_logits[rid] = step`` — from that step on, the request's
      decode/prefill logits rows are poisoned to NaN (a corrupted
      KV page / bad expert, as seen by the sampler).
    * ``stuck[rid] = step`` — from that step on, planned work for the
      request is dropped before execution (a wedged device callback).
    * ``delays[step] = seconds`` — a latency spike at one step.
    * ``kill_at`` — raise :class:`EngineKilled` entering that step.
    """
    nan_logits: dict[int, int] = dataclasses.field(default_factory=dict)
    stuck: dict[int, int] = dataclasses.field(default_factory=dict)
    delays: dict[int, float] = dataclasses.field(default_factory=dict)
    kill_at: int = -1
    kill_fired: bool = False

    def poisons(self, rid: int, step: int) -> bool:
        t = self.nan_logits.get(rid)
        return t is not None and step >= t

    def is_stuck(self, rid: int, step: int) -> bool:
        t = self.stuck.get(rid)
        return t is not None and step >= t

    def delay(self, step: int) -> float:
        return self.delays.get(step, 0.0)

    def maybe_kill(self, step: int) -> None:
        if self.kill_at >= 0 and step >= self.kill_at \
                and not self.kill_fired:
            self.kill_fired = True
            raise EngineKilled(f"injected engine kill at step {step}")


def parse_chaos(nan_specs=(), stuck_specs=(), delay_specs=(),
                kill_at: int = -1) -> ChaosInjector | None:
    """Build a :class:`ChaosInjector` from CLI specs: ``RID:STEP`` for
    NaN/stuck faults, ``STEP:SECONDS`` for latency spikes.  Returns
    None when nothing is injected."""
    def pairs(specs):
        for spec in specs or ():
            a, b = str(spec).split(":")
            yield int(a), b
    nan = {r: int(s) for r, s in pairs(nan_specs)}
    stuck = {r: int(s) for r, s in pairs(stuck_specs)}
    delays = {st: float(sec) for st, sec in pairs(delay_specs)}
    if not (nan or stuck or delays or kill_at >= 0):
        return None
    return ChaosInjector(nan_logits=nan, stuck=stuck, delays=delays,
                         kill_at=kill_at)


# ------------------------------------------------------------------- #
# snapshot / restore
# ------------------------------------------------------------------- #
def _engine_geometry(eng) -> dict:
    return {
        "arch": eng.cfg.name, "layout": eng.layout,
        "num_slots": eng.num_slots, "max_len": eng.max_len,
        "prefill_chunk": eng.prefill_chunk,
        "block_size": eng.block_size, "num_blocks": eng.num_blocks,
        "seed": eng._seed, "prefix_cache": eng.prefix is not None,
        "cache_leaves": len(jax.tree.leaves(eng.cache)),
    }


def _request_meta(req, now_s: float) -> dict:
    return {
        "rid": req.rid, "max_new": req.max_new,
        "temperature": req.temperature, "top_k": req.top_k,
        "eos_id": req.eos_id, "deadline_steps": req.deadline_steps,
        "priority": req.priority, "submit_step": req.submit_step,
        # perf_counter is not comparable across processes: persist the
        # elapsed wait and rebase it onto the restoring process's clock
        "waited_s": now_s - req.submit_s,
    }


def snapshot_engine(eng, directory: str, *, blocking: bool = True) -> int:
    """Persist the engine mid-flight through ``CheckpointManager``
    (atomic commit, LATEST pointer, retention).  Everything a restored
    engine needs resumes exactly: KV cache leaves, queue + slot states
    (block tables, generated tokens = the per-request RNG counters),
    finished results, pool refcounts, prefix trie, stats.  Returns the
    snapshot's step id (the engine step counter)."""
    sc = eng.sched
    step = int(eng.stats["steps"])
    now_s = time.perf_counter()

    reqs = {r.rid: r for r in sc.queue}
    slots_meta = {}
    for s in sc.active_slots:
        st = sc.slots[s]
        reqs[st.request.rid] = st.request
        slots_meta[str(s)] = {
            "rid": st.request.rid, "prefilled": st.prefilled,
            "length": st.length,
            "generated": [int(t) for t in st.generated],
            "table": [int(b) for b in st.table],
            "cached_tokens": st.cached_tokens, "spare": st.spare,
        }

    state: dict[str, Any] = {
        "cache": {f"{i:05d}": leaf
                  for i, leaf in enumerate(jax.tree.leaves(eng.cache))},
    }
    if reqs:
        state["prompts"] = {str(rid): np.asarray(r.tokens, np.int32)
                            for rid, r in reqs.items()}
    frames = {str(rid): np.asarray(r.frames, np.float32)
              for rid, r in reqs.items() if r.frames is not None}
    if frames:
        state["frames"] = frames
    if sc.finished:
        state["fin_tokens"] = {
            str(rid): np.asarray(e["tokens"], np.int32)
            for rid, e in sc.finished.items()}

    extra = {
        "geometry": _engine_geometry(eng),
        "engine": {
            "next_rid": eng._next_rid,
            "stats": {k: v for k, v in eng.stats.items()
                      if not isinstance(v, dict)},
        },
        "scheduler": {
            "clock": sc.clock,
            "queue": [r.rid for r in sc.queue],
            "slots": slots_meta,
            "requests": {str(rid): _request_meta(r, now_s)
                         for rid, r in reqs.items()},
            "finished": {str(rid): {k: v for k, v in e.items()
                                    if k != "tokens"}
                         for rid, e in sc.finished.items()},
            "outcomes": sc.outcomes,
            "duplicates": sc.duplicates,
            "head_rid": sc._head_rid,
            "head_skips": sc._head_skips,
        },
        "pool": None if eng.pool is None else {
            "ref": [int(v) for v in eng.pool._ref],
            "free": [int(v) for v in eng.pool._free],
            "peak": int(eng.pool.peak_allocated),
        },
        "prefix": None if eng.prefix is None else {
            # nodes in LRU order (oldest first): replaying inserts in
            # this order reproduces both the trie and the LRU list
            "nodes": [[int(eng.prefix._key_of[bid][0]),
                       [int(t) for t in eng.prefix._key_of[bid][1]],
                       int(bid)]
                      for bid in eng.prefix._lru],
            "hits": eng.prefix.hits, "misses": eng.prefix.misses,
        },
    }
    eng._snapshot_manager(directory).save(step, state, extra=extra,
                                          blocking=blocking)
    return step


def restore_engine(eng, directory: str, step: int | None = None) -> int:
    """Load a :func:`snapshot_engine` snapshot into a freshly
    constructed engine with *matching geometry* (same arch, layout,
    slots, lengths, seed — anything else would change compiled shapes
    or token streams) and resume from it.  Returns the restored step."""
    from repro.checkpoint import CheckpointManager
    from .scheduler import Request, SlotState

    mgr = CheckpointManager(directory)
    snap_step, tree, manifest = mgr.restore(step)
    x = manifest["extra"]
    mine, theirs = _engine_geometry(eng), x["geometry"]
    bad = {k: (theirs.get(k), mine[k]) for k in mine
           if mine[k] != theirs.get(k)}
    if bad:
        raise ValueError(
            f"snapshot geometry mismatch (snapshot vs engine): {bad}")

    saved = tree.get("cache", {})
    leaves, treedef = jax.tree.flatten(eng.cache)
    if len(saved) != len(leaves):
        raise ValueError(f"snapshot has {len(saved)} cache leaves, "
                         f"engine expects {len(leaves)}")
    eng.cache = jax.tree.unflatten(
        treedef, [jnp.asarray(saved[k]) for k in sorted(saved)])

    xs = x["scheduler"]
    prompts = tree.get("prompts", {})
    frame_arrays = tree.get("frames", {})
    now_s = time.perf_counter()

    def mk_request(meta: dict) -> Request:
        rid = int(meta["rid"])
        r = Request(
            rid=rid, tokens=np.asarray(prompts[str(rid)], np.int32),
            max_new=int(meta["max_new"]),
            temperature=float(meta["temperature"]),
            top_k=int(meta["top_k"]), eos_id=int(meta["eos_id"]),
            frames=None if str(rid) not in frame_arrays
            else np.asarray(frame_arrays[str(rid)], np.float32),
            deadline_steps=int(meta["deadline_steps"]),
            priority=int(meta["priority"]))
        r.submit_step = int(meta["submit_step"])
        r.submit_s = now_s - float(meta["waited_s"])
        return r

    sc = eng.sched
    req_meta = xs["requests"]
    sc.queue = deque(mk_request(req_meta[str(rid)])
                     for rid in xs["queue"])
    sc.slots = [None] * eng.num_slots
    for s_str, sm in xs["slots"].items():
        sc.slots[int(s_str)] = SlotState(
            request=mk_request(req_meta[str(sm["rid"])]),
            prefilled=int(sm["prefilled"]), length=int(sm["length"]),
            generated=[int(t) for t in sm["generated"]],
            table=[int(b) for b in sm["table"]],
            cached_tokens=int(sm["cached_tokens"]), spare=sm["spare"])
    fin_tokens = tree.get("fin_tokens", {})
    sc.finished = {}
    for rid_str, meta in xs["finished"].items():
        entry = dict(meta)
        entry["tokens"] = np.asarray(
            fin_tokens.get(rid_str, np.zeros((0,), np.int32)), np.int32)
        sc.finished[int(rid_str)] = entry
    sc.clock = int(xs["clock"])
    sc._head_rid = xs["head_rid"]
    sc._head_skips = int(xs["head_skips"])
    sc.duplicates = list(xs.get("duplicates", []))
    for kind, counts in xs["outcomes"].items():
        # in place: engine.stats aliases these dicts
        sc.outcomes[kind].clear()
        sc.outcomes[kind].update(counts)

    if eng.pool is not None:
        p = x["pool"]
        eng.pool._ref = [int(v) for v in p["ref"]]
        eng.pool._free = [int(v) for v in p["free"]]
        eng.pool.peak_allocated = int(p["peak"])
    if eng.prefix is not None:
        px = x["prefix"]
        pc = eng.prefix
        pc._by_key.clear()
        pc._key_of.clear()
        pc._children.clear()
        pc._lru.clear()
        for parent, toks, bid in px["nodes"]:
            key = (int(parent), tuple(int(t) for t in toks))
            pc._by_key[key] = int(bid)
            pc._key_of[int(bid)] = key
            pc._children.setdefault(int(bid), 0)
            pc._lru.append(int(bid))
        for (parent, _toks) in pc._by_key:
            if parent in pc._children:
                pc._children[parent] += 1
        pc.hits, pc.misses = int(px["hits"]), int(px["misses"])

    eng._next_rid = int(x["engine"]["next_rid"])
    for k, v in x["engine"]["stats"].items():
        eng.stats[k] = v
    if eng.watchdog is not None:        # stall counters do not carry over
        eng.watchdog = Watchdog(eng.watchdog.stall_patience)
    return snap_step
