"""Token sampling for the serving engine: greedy + temperature / top-k.

Per-slot sampling parameters ride as arrays so one jitted sampler serves
a heterogeneous batch: ``temperature`` (B,) — 0 selects greedy argmax for
that slot; ``top_k`` (B,) int — 0 disables the top-k filter for that
slot.  Greedy slots are bitwise argmax (the flash-vs-dense parity oracle
in the serving smoke runs on them).

Determinism: the engine samples through :func:`sample_tokens_keyed`,
which derives an independent key per row as
``fold_in(fold_in(engine_key, rid), n_generated)`` — each request owns
its own key *stream*, indexed by how many tokens it has produced.  Two
identical concurrent temperature>0 requests therefore sample
independently (different rids), and any single request reproduces
bit-for-bit given the engine seed, regardless of which slot it landed
in or what else shared the batch.  (:func:`sample_tokens` keeps the
one-key-per-call form for direct use.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["apply_top_k", "sample_tokens", "sample_tokens_jit",
           "sample_tokens_keyed", "sample_tokens_keyed_jit"]


def apply_top_k(logits, top_k):
    """Mask logits outside each row's top-k.  logits (B, V); top_k (B,)
    int32, 0 = no restriction.  Ties at the k-th value are kept."""
    B, V = logits.shape
    srt = jnp.sort(logits, axis=-1)                      # ascending
    idx = jnp.clip(V - jnp.maximum(top_k, 1), 0, V - 1)
    thr = jnp.take_along_axis(srt, idx[:, None], axis=-1)
    keep = (top_k <= 0)[:, None] | (logits >= thr)
    return jnp.where(keep, logits, -jnp.inf)


def sample_tokens(rng, logits, temperature, top_k):
    """One token per row.  logits (B, V) -> (B,) int32.

    temperature (B,): 0 -> greedy argmax; >0 -> categorical over
    top-k-filtered logits scaled by 1/temperature.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = apply_top_k(logits.astype(jnp.float32), top_k) \
        / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0, greedy, sampled)


#: process-wide jitted sampler (shared across engines — one compile per
#: batch shape)
sample_tokens_jit = jax.jit(sample_tokens)


def sample_tokens_keyed(base_key, rids, counts, logits, temperature,
                        top_k):
    """Per-request key streams: row b samples with
    ``fold_in(fold_in(base_key, rids[b]), counts[b])``.

    rids (B,) int32 request ids; counts (B,) int32 per-request sample
    index (= tokens generated so far).  Greedy rows (temperature 0) are
    bitwise argmax, key-independent.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = apply_top_k(logits.astype(jnp.float32), top_k) \
        / jnp.maximum(temperature, 1e-6)[:, None]

    def one(rid, cnt, row):
        key = jax.random.fold_in(jax.random.fold_in(base_key, rid), cnt)
        return jax.random.categorical(key, row)

    sampled = jax.vmap(one)(rids, counts, scaled).astype(jnp.int32)
    return jnp.where(temperature <= 0, greedy, sampled)


sample_tokens_keyed_jit = jax.jit(sample_tokens_keyed)
