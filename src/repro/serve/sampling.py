"""Token sampling for the serving engine: greedy + temperature / top-k.

Per-slot sampling parameters ride as arrays so one jitted sampler serves
a heterogeneous batch: ``temperature`` (B,) — 0 selects greedy argmax for
that slot; ``top_k`` (B,) int — 0 disables the top-k filter for that
slot.  Greedy slots are bitwise argmax (the flash-vs-dense parity oracle
in the serving smoke runs on them).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["apply_top_k", "sample_tokens", "sample_tokens_jit"]


def apply_top_k(logits, top_k):
    """Mask logits outside each row's top-k.  logits (B, V); top_k (B,)
    int32, 0 = no restriction.  Ties at the k-th value are kept."""
    B, V = logits.shape
    srt = jnp.sort(logits, axis=-1)                      # ascending
    idx = jnp.clip(V - jnp.maximum(top_k, 1), 0, V - 1)
    thr = jnp.take_along_axis(srt, idx[:, None], axis=-1)
    keep = (top_k <= 0)[:, None] | (logits >= thr)
    return jnp.where(keep, logits, -jnp.inf)


def sample_tokens(rng, logits, temperature, top_k):
    """One token per row.  logits (B, V) -> (B,) int32.

    temperature (B,): 0 -> greedy argmax; >0 -> categorical over
    top-k-filtered logits scaled by 1/temperature.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = apply_top_k(logits.astype(jnp.float32), top_k) \
        / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0, greedy, sampled)


#: process-wide jitted sampler (shared across engines — one compile per
#: batch shape)
sample_tokens_jit = jax.jit(sample_tokens)
