"""Continuous-batching CP serving engine over a paged KV block pool.

KV layouts (``kv_layout``):

* **paged** (default for attention-only archs) — one global pool of
  ``num_blocks`` x ``block_size`` token positions per attention
  sub-layer (``models.init_paged_cache``); each request holds a block
  table mapping logical to physical blocks (``block_pool.BlockPool``
  does the host-side accounting).  KV memory scales with *live tokens*,
  not ``num_slots x max_len``; identical prompt prefixes share blocks
  through ``prefix.PrefixCache`` (written once, refcounted,
  copy-on-write when a shared block must be appended).
* **dense** — the PR-4 per-slot stripe layout, kept as the parity
  oracle (paged and dense greedy decodes must agree bitwise) and for
  recurrent archs (Jamba/xLSTM scan states have no block structure).

Each :meth:`step` spends one **token budget** (SplitFuse-style,
``scheduler.plan_step``): decode-ready slots get their decode token
first, the remaining budget trickles prompt chunks in — a long prompt
prefills *alongside* in-flight decodes instead of stalling them.
``unified=False`` restores serial prefill-then-decode as the stall
baseline.

Three jitted program families: chunked cache-writing **prefill**
(per-slot dense view or block-table scatter/gather), ragged **decode**
(flash-decode kernel, block-table indirected for paged), and keyed
**sampling** — every request samples from its own
``fold_in(fold_in(engine_key, rid), n_generated)`` key stream, so
results are per-request reproducible regardless of batch composition.

Resilience (DESIGN.md §Serving-resilience): admission is bounded and
deadline-aware (``max_queue`` / ``admission`` / ``admit_lookahead``), a
watchdog quarantines requests with non-finite logits or stalled slots
(the decode programs return a per-row finite mask so NaN never reaches
a healthy request's results), and :meth:`snapshot` /
:meth:`restore_snapshot` persist the whole engine mid-decode through
the checkpoint manager's atomic-commit path — a killed engine restores
with zero request loss and bitwise token parity.
"""

from __future__ import annotations

import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import (decode_step, init_cache, init_paged_cache,
                          init_params, prefill_forward,
                          supports_cached_prefill, supports_paged_cache)
from .block_pool import BlockPool
from .prefix import PrefixCache
from .resilience import (AdmissionConfig, ChaosInjector, Watchdog,
                         restore_engine, snapshot_engine)
from .sampling import sample_tokens_keyed, sample_tokens_keyed_jit
from .scheduler import Request, Scheduler, SlotState

__all__ = ["ServeEngine"]


def _slot_view(cache, slot):
    return jax.tree.map(
        lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1), cache)


def _slot_write(cache, view, slot):
    return jax.tree.map(
        lambda l, nl: jax.lax.dynamic_update_slice_in_dim(
            l, nl.astype(l.dtype), slot, axis=1), cache, view)


def _mask_rows(new, old, active):
    """Keep ``new`` only on active slot rows (row axis 1 of every dense
    cache leaf: (P, B, ...))."""
    def sel(n, o):
        m = active.reshape((1, -1) + (1,) * (n.ndim - 2))
        return jnp.where(m, n.astype(o.dtype), o)
    return jax.tree.map(sel, new, old)


class ServeEngine:
    """Drive requests through budgeted prefill + continuous decode.

    Parameters: ``kv_layout`` "auto" (paged when the arch supports it) /
    "paged" / "dense"; ``block_size`` tokens per KV block;
    ``num_blocks`` pool size (0 = dense-equivalent capacity
    ``num_slots * ceil(max_len/block_size)``); ``token_budget`` tokens
    per step (0 = ``num_slots + prefill_chunk``); ``prefix_cache``
    shares identical prompt prefixes across requests (paged only);
    ``unified=False`` serializes prefill before decode (stall baseline).
    ``decode_impl`` "flash" (default) or "dense" (XLA softmax oracle);
    ``attn_shards`` splits the *dense* decode cache into LSE-merged
    segments; ``interpret=None`` auto-selects Pallas interpret off-TPU.

    Resilience knobs: ``max_queue`` bounds the queue (0 = unbounded),
    ``admission`` picks the overload policy ("fifo" sheds the incoming
    request, "deadline" sheds the least-slack one), ``admit_lookahead``
    lets placeable requests jump a pool-blocked head (0 = strict FIFO)
    under ``starvation_limit``; ``watchdog=False`` disables fault
    quarantine (the pre-resilience engine, kept for the chaos
    regression tests); ``stall_patience`` is the consecutive
    planned-but-no-progress steps before a slot aborts; ``chaos`` takes
    a :class:`~.resilience.ChaosInjector`.
    """

    def __init__(self, cfg: ModelConfig, params=None, *,
                 num_slots: int = 4, max_len: int = 256,
                 prefill_chunk: int = 64, decode_impl: str = "flash",
                 attn_shards: int = 1, block_k: int = 256,
                 interpret: bool | None = None, seed: int = 0,
                 kv_layout: str = "auto", block_size: int = 16,
                 num_blocks: int = 0, token_budget: int = 0,
                 prefix_cache: bool = True, unified: bool = True,
                 max_queue: int = 0, admission: str = "fifo",
                 admit_lookahead: int = 4, starvation_limit: int = 8,
                 watchdog: bool = True, stall_patience: int = 8,
                 chaos: ChaosInjector | None = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_chunk = min(prefill_chunk, max_len)
        self.decode_impl = decode_impl
        self.cached_prefill = supports_cached_prefill(cfg)

        if kv_layout == "auto":
            # paged when the arch can (attention-only mixers); sharded
            # decode (LSE-merged stripe segments) is a dense-layout
            # feature, so attn_shards>1 keeps the stripes
            kv_layout = "paged" if supports_paged_cache(cfg) \
                and attn_shards == 1 else "dense"
        elif kv_layout == "paged" and not supports_paged_cache(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV requires attention-only mixers "
                "(recurrent scan states have no block structure)")
        elif kv_layout not in ("paged", "dense"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if kv_layout == "paged" and attn_shards > 1:
            raise ValueError("attn_shards>1 is a dense-layout feature "
                             "(LSE-merged stripe segments)")
        self.layout = kv_layout

        if params is None:
            params = init_params(jax.random.PRNGKey(seed), cfg)
        self.params = params

        self.block_size = block_size
        self._nk = -(-max_len // block_size)     # table width in blocks
        if kv_layout == "paged":
            self.num_blocks = num_blocks or num_slots * self._nk
            self.pool = BlockPool(self.num_blocks, block_size)
            self.prefix = PrefixCache(block_size) if prefix_cache else None
            self.cache = init_paged_cache(cfg, self.num_blocks, block_size)
        else:
            self.num_blocks = 0
            self.pool = None
            self.prefix = None
            self.cache = init_cache(cfg, num_slots, max_len)

        self.sched = Scheduler(
            num_slots, max_len, prefill_chunk=self.prefill_chunk,
            token_budget=token_budget, unified=unified,
            admission=AdmissionConfig(
                max_queue=max_queue, policy=admission,
                lookahead=admit_lookahead,
                starvation_limit=starvation_limit))
        self.sched.on_retire = self._on_retire
        self.watchdog = Watchdog(stall_patience) if watchdog else None
        self.chaos = chaos
        self._seed = seed
        self._snap_mgrs: dict[str, Any] = {}
        self._base_key = jax.random.PRNGKey(seed)
        self._next_rid = 0
        self.stats: dict[str, Any] = {
            "prefill_tokens": 0, "prefill_chunk_tokens": 0,
            "prefill_cached_tokens": 0, "prefill_steps": 0,
            "prefill_decode_steps": 0, "prefill_s": 0.0,
            "decode_tokens": 0, "decode_steps": 0, "decode_s": 0.0,
            "admitted": 0, "retired": 0, "steps": 0,
            "stalled_decode_steps": 0, "cow_copies": 0,
            "admission_backoffs": 0,
            "pool_block_steps": 0, "live_token_steps": 0,
            "chaos_delay_s": 0.0, "snapshots": 0,
            # reason-keyed terminal counters, aliased from the
            # scheduler (single source; mutated in place)
            "rejected_by_reason": self.sched.outcomes["rejected"],
            "shed_by_reason": self.sched.outcomes["shed"],
            "aborted_by_reason": self.sched.outcomes["aborted"]}

        bs = block_size
        dec_kw = dict(attn_impl=decode_impl, attn_shards=attn_shards,
                      block_k=block_k, interpret=interpret)

        def _decode_batch(tok, frames):
            if cfg.frontend == "audio_frames":
                # modality gap of the stubbed EnCodec frontend: generated
                # steps have no codec->frame embedder, so continuation
                # frames are zeros; *prompt* frames flow through prefill.
                return {"frame_embeds": frames}
            return {"tokens": tok}

        def _sample_guarded(logits, poison, key, rids, counts, temps, topk):
            # chaos NaN lands here (post-attention, pre-sampler — the
            # observable effect of a corrupted KV page); the per-row
            # finite mask travels back so the host can quarantine the
            # poisoned row without a second device round trip
            logits = logits.astype(jnp.float32)
            logits = jnp.where(poison[:, None], jnp.nan, logits)
            finite = jnp.all(jnp.isfinite(logits), axis=-1)
            nxt = sample_tokens_keyed(key, rids, counts, logits, temps,
                                      topk)
            return nxt, logits, finite

        def decode_fn(params, cache, tok, pos_t, active, poison, key,
                      rids, counts, temps, topk):
            frames = jnp.zeros((num_slots, cfg.d_model), jnp.dtype(cfg.dtype))
            logits, new_cache = decode_step(
                params, cfg, cache, _decode_batch(tok, frames), pos_t,
                **dec_kw)
            new_cache = _mask_rows(new_cache, cache, active)
            nxt, logits, finite = _sample_guarded(
                logits, poison, key, rids, counts, temps, topk)
            return nxt, logits, finite, new_cache

        def decode_paged_fn(params, cache, tok, pos_t, tables, active,
                            poison, key, rids, counts, temps, topk):
            frames = jnp.zeros((num_slots, cfg.d_model), jnp.dtype(cfg.dtype))
            logits, new_cache = decode_step(
                params, cfg, cache, _decode_batch(tok, frames), pos_t,
                attn_impl=decode_impl, block_k=block_k,
                interpret=interpret, block_tables=tables, block_size=bs,
                write_mask=active)
            nxt, logits, finite = _sample_guarded(
                logits, poison, key, rids, counts, temps, topk)
            return nxt, logits, finite, new_cache

        def _chunk_batch(tokens, frames):
            batch = {"tokens": tokens}
            if cfg.frontend == "audio_frames":
                batch = {"frame_embeds": frames}
            elif cfg.frontend == "vit_patches":
                T = tokens.shape[1]
                batch["patch_embeds"] = jnp.zeros(
                    (1, T, cfg.d_model), jnp.dtype(cfg.dtype))
                batch["patch_mask"] = jnp.zeros((1, T), bool)
            return batch

        def prefill_chunk_fn(params, cache, slot, tokens, frames, pos,
                             active, *, with_logits, s_view):
            view = _slot_view(cache, slot)
            # crop the attended cache to the pow2 bucket covering this
            # chunk's end: prefill attention is O(C * s_view), not
            # O(C * max_len) (attn caches are (P, 1, Hkv, S, hd))
            crop = jax.tree.map(lambda l: l[:, :, :, :s_view], view)
            logits, ncrop = prefill_forward(
                params, cfg, crop, _chunk_batch(tokens, frames), pos,
                active, with_logits=with_logits)
            nview = jax.tree.map(
                lambda f, n: jax.lax.dynamic_update_slice_in_dim(
                    f, n.astype(f.dtype), 0, axis=3), view, ncrop)
            return logits, _slot_write(cache, nview, slot)

        def prefill_paged_fn(params, cache, table, tokens, frames, pos,
                             active, *, with_logits, view_blocks):
            logits, new_cache = prefill_forward(
                params, cfg, cache, _chunk_batch(tokens, frames), pos,
                active, with_logits=with_logits, block_tables=table,
                block_size=bs, view_blocks=view_blocks)
            return logits, new_cache

        def replay_fn(params, cache, tok, frames, pos_t, active):
            logits, new_cache = decode_step(
                params, cfg, cache, _decode_batch(tok, frames), pos_t,
                **dec_kw)
            return logits, _mask_rows(new_cache, cache, active)

        def copy_block_fn(cache, src, dst):
            # copy-on-write: clone pool block src -> dst (flat token
            # axis 2 of every paged leaf (P, Hkv, NB*bs, hd))
            def cp(l):
                blk = jax.lax.dynamic_slice_in_dim(l, src * bs, bs, axis=2)
                return jax.lax.dynamic_update_slice_in_dim(
                    l, blk, dst * bs, axis=2)
            return jax.tree.map(cp, cache)

        # the cache argument is donated everywhere: the engine always
        # replaces self.cache with the program's output, so XLA can
        # update the KV buffers in place instead of keeping two copies
        self._decode_fn = jax.jit(decode_fn, donate_argnums=(1,))
        self._decode_paged_fn = jax.jit(decode_paged_fn, donate_argnums=(1,))
        self._replay_fn = jax.jit(replay_fn, donate_argnums=(1,))
        self._copy_block_fn = jax.jit(copy_block_fn, donate_argnums=(0,))
        self._prefill_fns: dict[tuple, Any] = {}
        self._prefill_chunk_body = prefill_chunk_fn
        self._prefill_paged_body = prefill_paged_fn

    # ------------------------------------------------------------- #
    def _prefill_fn(self, with_logits: bool, view: int):
        """Jitted prefill-chunk program per (head?, view bucket); only
        the final chunk pays the (T, vocab) head projection.  ``view``
        is the dense s_view (tokens) or paged view_blocks (blocks)."""
        key = (self.layout, with_logits, view)
        if key not in self._prefill_fns:
            if self.layout == "paged":
                body = functools.partial(self._prefill_paged_body,
                                         with_logits=with_logits,
                                         view_blocks=view)
            else:
                body = functools.partial(self._prefill_chunk_body,
                                         with_logits=with_logits,
                                         s_view=view)
            self._prefill_fns[key] = jax.jit(body, donate_argnums=(1,))
        return self._prefill_fns[key]

    def _view_bucket(self, end: int) -> int:
        """pow2 cache-view bucket covering prefix ``end`` (tokens), in
        this layout's view unit — bounds jit specialization to
        O(log(max_len)) prefill variants."""
        if self.layout == "paged":
            v = 1
            while v * self.block_size < end:
                v *= 2
            return min(v, self._nk)
        s = self.prefill_chunk
        while s < end:
            s *= 2
        return min(s, self.max_len)

    def _prefill_buckets(self, prompt_len: int):
        """(is_last, view bucket) per chunk of an un-budget-split
        ``prompt_len`` prompt — the variants warmup precompiles."""
        C = self.prefill_chunk
        n_chunks = -(-prompt_len // C)
        return [(ci == n_chunks - 1, self._view_bucket((ci + 1) * C))
                for ci in range(n_chunks)]

    # ------------------------------------------------------------- #
    def submit(self, tokens, *, max_new: int = 16, temperature: float = 0.0,
               top_k: int = 0, eos_id: int = -1, frames=None,
               deadline_steps: int = -1, priority: int = 0) -> int:
        """Queue one request; returns its request id.  Oversized
        requests land in the results dict with status="rejected";
        overload victims (bounded queue) with status="shed".
        ``deadline_steps`` is the engine-step budget the request must
        finish within (-1 = none); lower ``priority`` sheds first."""
        rid = self._next_rid
        self._next_rid += 1
        self.sched.clock = self.stats["steps"]
        self.sched.submit(Request(
            rid=rid, tokens=np.asarray(tokens, np.int32), max_new=max_new,
            temperature=temperature, top_k=top_k, eos_id=eos_id,
            frames=None if frames is None
            else np.asarray(frames, np.float32),
            deadline_steps=deadline_steps, priority=priority))
        return rid

    # ------------------------------------------------------------- #
    # paged block accounting (host side)
    def _place(self, req: Request) -> dict | None:
        """Reserve KV for one request at admission.  Paged: match the
        prefix cache, then allocate the remaining blocks all-or-nothing
        (evicting unreferenced cached blocks if short); None = backoff,
        the request stays queued at the FIFO head."""
        if self.layout != "paged":
            return {}
        bs = self.block_size
        Tp, mn = req.prompt_len, req.max_new
        matched = [] if self.prefix is None \
            else self.prefix.match(req.tokens)
        nk_req = -(-(Tp + mn - 1) // bs)
        while True:
            m = len(matched) * bs
            # a fully-cached prompt still recomputes its final token (its
            # logits seed sampling): reserve the copy-on-write spare for
            # the shared block that write lands in
            start = min(m, Tp - 1)
            n_fresh = nk_req - len(matched)
            n_spare = 1 if m >= Tp else 0
            need = n_fresh + n_spare
            # retain the matched chain BEFORE evicting: at refcount >= 2
            # the LRU sweep's refcount==1 check cannot free blocks this
            # request is about to read (evicted-and-reallocated matched
            # blocks would alias fresh blocks in the table — silent
            # prefix corruption)
            self.pool.retain(matched)
            if self.prefix is not None and self.pool.free_count < need:
                self.prefix.evict(need - self.pool.free_count, self.pool)
            got = self.pool.alloc(need)
            if got is not None:
                break
            self.pool.release(matched)      # drop the reservation; the
            if not matched:                 # cache ref remains
                self.stats["admission_backoffs"] += 1
                return None
            # retry with a shorter match: the popped tail becomes an
            # evictable leaf again, and a no-longer-full match drops the
            # spare — trade cached tokens for fit before backing off
            matched.pop()
        self.stats["prefill_cached_tokens"] += m
        return {"table": matched + got[:n_fresh], "cached": m,
                "start": start, "spare": got[n_fresh] if n_spare else None}

    def _on_retire(self, slot: int, st: SlotState) -> None:
        self.stats["retired"] += 1
        if self.watchdog is not None:
            self.watchdog.clear(slot)
        if self.layout == "paged":
            self.pool.release(st.table)
            if st.spare is not None:
                self.pool.release([st.spare])

    def _ensure_private(self, st: SlotState, bi: int) -> None:
        """Copy-on-write logical block ``bi`` of this request's table if
        it is shared (prefix-cached with other readers)."""
        if bi >= len(st.table):
            return
        bid = st.table[bi]
        if not self.pool.is_shared(bid):
            return
        if st.spare is not None:
            nb, st.spare = st.spare, None
        else:
            got = self.pool.alloc(1)
            if got is None:
                raise RuntimeError("copy-on-write with exhausted pool")
            nb = got[0]
        self.cache = self._copy_block_fn(
            self.cache, jnp.asarray(bid, jnp.int32),
            jnp.asarray(nb, jnp.int32))
        self.pool.release([bid])
        st.table[bi] = nb
        self.stats["cow_copies"] += 1

    def _tables_matrix(self) -> np.ndarray:
        tab = np.zeros((self.num_slots, self._nk), np.int32)
        for s in self.sched.active_slots:
            t = self.sched.slots[s].table
            tab[s, :len(t)] = t
        return tab

    # ------------------------------------------------------------- #
    def _first_token(self, req: Request, logits_row) -> int:
        """Sample a request's first token (count 0 of its key stream)
        from the prefill's last logits."""
        tok = sample_tokens_keyed_jit(
            self._base_key, jnp.asarray([req.rid], jnp.int32),
            jnp.zeros((1,), jnp.int32),
            logits_row[None].astype(jnp.float32),
            jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32))
        return int(np.asarray(tok)[0])

    def _run_prefill_chunk(self, slot: int, start: int, n: int) -> bool:
        """Prefill prompt tokens [start, start+n) of one slot; on the
        final chunk, sample the first token and start decoding.
        Returns False when the slot was aborted (non-finite final
        logits — the poisoned request is quarantined before its blocks
        reach the prefix cache)."""
        sc = self.sched
        st = sc.slots[slot]
        req = st.request
        C = self.prefill_chunk
        Tp = req.prompt_len
        t0 = time.perf_counter()
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = req.tokens[start:start + n]
        frames = np.zeros((1, C, self.cfg.d_model), np.float32)
        if req.frames is not None:
            frames[0, :n] = req.frames[start:start + n]
        pos = jnp.asarray(np.arange(start, start + C, dtype=np.int32)[None])
        active = jnp.asarray((np.arange(C) < n)[None])
        with_logits = start + n >= Tp
        fn = self._prefill_fn(with_logits, self._view_bucket(start + C))
        if self.layout == "paged":
            # only this chunk's first block can be prefix-shared (later
            # blocks are freshly allocated): COW it before writing
            self._ensure_private(st, start // self.block_size)
            table = jnp.asarray(self._tables_matrix()[slot][None])
            logits, self.cache = fn(self.params, self.cache, table,
                                    jnp.asarray(toks), jnp.asarray(frames),
                                    pos, active)
        else:
            logits, self.cache = fn(self.params, self.cache,
                                    jnp.asarray(slot, jnp.int32),
                                    jnp.asarray(toks), jnp.asarray(frames),
                                    pos, active)
        sc.note_prefill(slot, n)
        self.stats["prefill_steps"] += 1
        self.stats["prefill_chunk_tokens"] += n
        if with_logits:
            row = np.asarray(logits[0, n - 1], np.float32)
            if self.chaos is not None \
                    and self.chaos.poisons(req.rid, self.stats["steps"]):
                row = np.full_like(row, np.nan)
            if self.watchdog is not None and not np.isfinite(row).all():
                self.stats["prefill_s"] += time.perf_counter() - t0
                sc.abort(slot, "non-finite prefill logits at step "
                         f"{self.stats['steps']}", kind="nan_logits")
                return False
            if self.layout == "paged" and self.prefix is not None:
                nfull = Tp // self.block_size
                if nfull:
                    self.prefix.insert(req.tokens[:nfull * self.block_size],
                                       st.table[:nfull], self.pool)
            first = self._first_token(req, row)
            self.stats["prefill_tokens"] += Tp
            sc.start(slot, first)
        self.stats["prefill_s"] += time.perf_counter() - t0
        return True

    def _prefill_replay(self, slot: int, req: Request) -> bool:
        """Recurrent-mixer fallback (dense layout): feed the whole
        prompt through the decode path one token at a time at admission,
        updates masked to this slot's row.  Audio prompts replay their
        *real* frame embeddings.  Returns False when the slot was
        aborted (non-finite final logits)."""
        t0 = time.perf_counter()
        B = self.num_slots
        onehot = jnp.zeros((B,), bool).at[slot].set(True)
        logits = None
        for t in range(req.prompt_len):
            tok = jnp.zeros((B,), jnp.int32).at[slot].set(
                int(req.tokens[t]))
            frames = jnp.zeros((B, self.cfg.d_model), jnp.float32)
            if req.frames is not None:
                frames = frames.at[slot].set(jnp.asarray(req.frames[t]))
            pos_t = jnp.zeros((B,), jnp.int32).at[slot].set(t)
            logits, self.cache = self._replay_fn(
                self.params, self.cache, tok, frames, pos_t, onehot)
            self.stats["prefill_decode_steps"] += 1
        row = np.asarray(logits[slot], np.float32)
        if self.chaos is not None \
                and self.chaos.poisons(req.rid, self.stats["steps"]):
            row = np.full_like(row, np.nan)
        self.stats["prefill_tokens"] += req.prompt_len
        self.stats["prefill_chunk_tokens"] += req.prompt_len
        self.stats["prefill_s"] += time.perf_counter() - t0
        if self.watchdog is not None and not np.isfinite(row).all():
            self.sched.abort(slot, "non-finite prefill logits at step "
                             f"{self.stats['steps']}", kind="nan_logits")
            return False
        self.sched.start(slot, self._first_token(req, row))
        return True

    # ------------------------------------------------------------- #
    def _decode_once(self, decode_slots: list[int]) -> list[int]:
        """One batched decode step; poisoned rows (non-finite logits)
        are quarantined — only healthy slots record their token.
        Returns the healthy slots."""
        sc = self.sched
        B = self.num_slots
        dmask = np.zeros((B,), bool)
        dmask[decode_slots] = True
        tok = np.zeros((B,), np.int32)
        poison = np.zeros((B,), bool)
        for s in decode_slots:
            tok[s] = sc.slots[s].generated[-1]
            if self.chaos is not None and self.chaos.poisons(
                    sc.slots[s].request.rid, self.stats["steps"]):
                poison[s] = True
        lengths = np.where(dmask, sc.lengths(), 0).astype(np.int32)
        t0 = time.perf_counter()
        common = (jnp.asarray(tok), jnp.asarray(lengths))
        tail = (jnp.asarray(poison), self._base_key,
                jnp.asarray(sc.rids()), jnp.asarray(sc.sample_counts()),
                jnp.asarray(sc.temperatures()), jnp.asarray(sc.top_ks()))
        if self.layout == "paged":
            # safety net: a decode write must never land in a shared
            # block (prefix sharing covers full *prompt* blocks only,
            # and full-match COW happens at prefill — this should not
            # fire, but a silent shared-block write would corrupt
            # another request's prefix)
            for s in decode_slots:
                self._ensure_private(
                    sc.slots[s], sc.slots[s].length // self.block_size)
            nxt, _, finite, self.cache = self._decode_paged_fn(
                self.params, self.cache, *common,
                jnp.asarray(self._tables_matrix()), jnp.asarray(dmask),
                *tail)
        else:
            nxt, _, finite, self.cache = self._decode_fn(
                self.params, self.cache, *common, jnp.asarray(dmask), *tail)
        nxt = np.asarray(jax.block_until_ready(nxt))
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        if self.watchdog is None:
            healthy = list(decode_slots)
        else:
            finite = np.asarray(finite)
            healthy = [s for s in decode_slots if finite[s]]
            for s in decode_slots:
                if not finite[s]:
                    sc.abort(s, "non-finite decode logits at step "
                             f"{self.stats['steps']}", kind="nan_logits")
        self.stats["decode_tokens"] += len(healthy)
        sc.record(nxt, healthy)
        return healthy

    # ------------------------------------------------------------- #
    def step(self) -> bool:
        """One engine step: admit what fits, spend the token budget on
        prefill chunks + decode tokens, then run the fault watchdog.
        Returns False when idle."""
        sc = self.sched
        step_no = self.stats["steps"]
        sc.clock = step_no
        if self.chaos is not None:
            self.chaos.maybe_kill(step_no)
            d = self.chaos.delay(step_no)
            if d > 0:
                time.sleep(d)
                self.stats["chaos_delay_s"] += d
        placed = sc.admit(self._place)
        self.stats["admitted"] += len(placed)
        if not self.cached_prefill:
            for slot, req in placed:
                self._prefill_replay(slot, req)
        if sc.queue and not placed and not sc.active_slots:
            # the head backed off even into an idle engine (its block
            # working set exceeds the pool after full prefix-cache
            # eviction): reject it instead of wedging the loop — the
            # requests queued behind it must still run
            req = sc.queue.popleft()
            nk = -(-(req.prompt_len + req.max_new - 1) // self.block_size)
            sc.reject(req, f"working set of {nk} KV blocks exceeds the "
                      f"{self.num_blocks}-block pool",
                      kind="pool_unplaceable")
        n_ready = sum(1 for s in sc.active_slots
                      if sc.slots[s].decode_ready)
        prefill_items, decode_slots = sc.plan_step()
        planned = {s for s, _, _ in prefill_items} | set(decode_slots)
        if self.chaos is not None:
            # a stuck slot's planned work is dropped before execution
            # (a wedged device callback) — the watchdog must catch it
            def _stuck(s):
                return self.chaos.is_stuck(sc.slots[s].request.rid,
                                           step_no)
            prefill_items = [it for it in prefill_items
                             if not _stuck(it[0])]
            decode_slots = [s for s in decode_slots if not _stuck(s)]
        progressed: set[int] = set()
        for slot, start, n in prefill_items:
            if self._run_prefill_chunk(slot, start, n):
                progressed.add(slot)
            else:
                planned.discard(slot)       # aborted, not stalled
        if decode_slots:
            healthy = self._decode_once(decode_slots)
            progressed |= set(healthy)
            planned -= set(decode_slots) - set(healthy)
        elif n_ready:
            # decode-ready slots got no token this step (serial mode
            # draining a long prefill) — the stall the unified budget
            # eliminates
            self.stats["stalled_decode_steps"] += 1
        if self.watchdog is not None:
            for slot, n_stalled in self.watchdog.observe(planned,
                                                         progressed):
                sc.abort(slot, f"no scheduler progress for {n_stalled} "
                         f"planned steps (stuck slot {slot})",
                         kind="stall")
        self.stats["steps"] += 1
        self.stats["live_token_steps"] += sum(
            sc.slots[s].length for s in sc.active_slots)
        if self.layout == "paged":
            self.stats["pool_block_steps"] += self.pool.allocated_count
        return sc.has_work

    def run(self, max_steps: int = 100_000, *, snapshot_every: int = 0,
            snapshot_dir: str | None = None, drain_at: int = -1,
            ) -> dict[int, dict[str, Any]]:
        """Drain the queue; returns {rid: {"status", "tokens",
        "prompt_len", ...}} — every submitted rid is present with
        status "ok", "rejected", "shed", or "aborted" (hitting
        ``max_steps`` aborts the in-flight requests with their partial
        tokens rather than dropping them).  ``snapshot_every`` persists
        the engine to ``snapshot_dir`` every N steps; ``drain_at``
        stops at that engine step with a final snapshot (orderly
        drain — a restored engine resumes the in-flight work)."""
        if (snapshot_every > 0 or drain_at >= 0) and not snapshot_dir:
            raise ValueError("snapshot_every/drain_at require "
                             "snapshot_dir")
        steps = 0
        while True:
            if drain_at >= 0 and self.stats["steps"] >= drain_at \
                    and self.sched.has_work:
                self.snapshot(snapshot_dir)
                break
            more = self.step()
            steps += 1
            if snapshot_every > 0 and steps % snapshot_every == 0:
                self.snapshot(snapshot_dir)
            if not more:
                break
            if steps >= max_steps:
                self.sched.abort_all(
                    f"engine step cap {max_steps} reached")
                break
        return self.sched.finished

    # ------------------------------------------------------------- #
    def warmup(self, prompt_len: int | None = None) -> None:
        """Compile the decode + prefill + sampling programs outside the
        timed window (all-inactive calls leave cache *values* untouched;
        outputs are reassigned because the cache argument is donated).
        ``prompt_len`` warms every prefill-chunk variant a prompt of
        that length uses (default: a single-chunk prompt)."""
        B = self.num_slots
        zi = jnp.zeros((B,), jnp.int32)
        zmask = jnp.zeros((B,), bool)
        zf = jnp.zeros((B,), jnp.float32)
        tail = (zmask, self._base_key, zi, zi, zf, zi)
        if self.layout == "paged":
            ztab = jnp.zeros((B, self._nk), jnp.int32)
            _, _, _, self.cache = self._decode_paged_fn(
                self.params, self.cache, zi, zi, ztab, zmask, *tail)
        else:
            _, _, _, self.cache = self._decode_fn(
                self.params, self.cache, zi, zi, zmask, *tail)
        sample_tokens_keyed_jit(
            self._base_key, jnp.zeros((1,), jnp.int32),
            jnp.zeros((1,), jnp.int32),
            jnp.zeros((1, self.cfg.vocab_size), jnp.float32),
            jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.int32))
        C = self.prefill_chunk
        if not self.cached_prefill:
            _, self.cache = self._replay_fn(
                self.params, self.cache, zi,
                jnp.zeros((B, self.cfg.d_model), jnp.float32), zi, zmask)
            return
        zchunk = (jnp.zeros((1, C), jnp.int32),
                  jnp.zeros((1, C, self.cfg.d_model), jnp.float32),
                  jnp.asarray(np.arange(C, dtype=np.int32)[None]),
                  jnp.zeros((1, C), bool))
        lead = jnp.zeros((1, self._nk), jnp.int32) \
            if self.layout == "paged" else jnp.asarray(0, jnp.int32)
        for is_last, view in set(self._prefill_buckets(prompt_len or C)):
            _, self.cache = self._prefill_fn(is_last, view)(
                self.params, self.cache, lead, *zchunk)

    # ------------------------------------------------------------- #
    def _snapshot_manager(self, directory: str):
        if directory not in self._snap_mgrs:
            from repro.checkpoint import CheckpointManager
            self._snap_mgrs[directory] = CheckpointManager(directory)
        return self._snap_mgrs[directory]

    def snapshot(self, directory: str) -> int:
        """Persist the full engine state (KV cache, scheduler, block
        pool, prefix trie, per-request RNG counters) atomically; see
        :func:`~.resilience.snapshot_engine`.  Returns the snapshot's
        step id."""
        step = snapshot_engine(self, directory)
        self.stats["snapshots"] += 1
        return step

    def restore_snapshot(self, directory: str,
                         step: int | None = None) -> int:
        """Resume from a snapshot taken by an engine with identical
        geometry (call after construction + :meth:`warmup`; warmup's
        all-inactive calls leave cache *values* untouched, so the order
        does not matter).  Returns the restored step id."""
        return restore_engine(self, directory, step)

    def latency_percentiles(self, statuses=("ok",)) -> dict[str, float]:
        """p50/p99 request latency (submit -> terminal entry) over
        ``finished`` entries with the given statuses, in engine steps
        and wall seconds."""
        fin = [e for e in self.sched.finished.values()
               if e["status"] in statuses and "latency_steps" in e]
        if not fin:
            return {"n": 0, "p50_steps": 0.0, "p99_steps": 0.0,
                    "p50_s": 0.0, "p99_s": 0.0}
        steps = np.asarray([e["latency_steps"] for e in fin], np.float64)
        secs = np.asarray([e["latency_s"] for e in fin], np.float64)
        return {"n": len(fin),
                "p50_steps": float(np.percentile(steps, 50)),
                "p99_steps": float(np.percentile(steps, 99)),
                "p50_s": float(np.percentile(secs, 50)),
                "p99_s": float(np.percentile(secs, 99))}

    # ------------------------------------------------------------- #
    def kv_cache_bytes(self) -> int:
        """Device bytes of the KV store (pool or stripes)."""
        return int(sum(l.nbytes for l in jax.tree.leaves(self.cache)))

    def kv_token_capacity(self) -> int:
        """Token positions the KV store can hold."""
        if self.layout == "paged":
            return self.num_blocks * self.block_size
        return self.num_slots * self.max_len

    def throughput(self) -> dict[str, float]:
        """``prefill_tok_s`` counts *computed* tokens only — prefix-cache
        hits skip compute and must not inflate the rate; the effective
        rate (prompt tokens served, cached included) is reported
        separately."""
        s = self.stats
        dt = max(s["prefill_s"], 1e-9)
        return {
            "prefill_tok_s": s["prefill_chunk_tokens"] / dt,
            "prefill_effective_tok_s": s["prefill_tokens"] / dt,
            "decode_tok_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
        }
