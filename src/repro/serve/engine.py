"""Continuous-batching CP serving engine.

One engine owns a ``num_slots`` x ``max_len`` KV cache and three jitted
programs:

* **prefill** — chunked, cache-writing: each prompt chunk runs
  :func:`repro.models.prefill_forward` on its slot's cache view, writing
  roped KV directly from the forward pass (prefill cost is
  ``ceil(Tp / prefill_chunk)`` forward calls — *independent of Tp in
  decode steps*; the old engine replayed all Tp prompt tokens through
  ``decode_step``).  Archs with recurrent mixers (Jamba, xLSTM) fall back
  to masked replay prefill — their decode caches hold scan states that a
  chunked forward does not produce.
* **decode** — one ragged step for every active slot:
  ``decode_step`` with per-slot ``lengths`` as positions, flash-decode
  attention by default (``decode_impl="dense"`` keeps the XLA softmax as
  the parity oracle), and per-row masking so idle/retired slots never
  touch live cache rows.  Sampling (greedy / temperature / top-k,
  per-slot) happens in the same program.
* **sample** — the prefill's last-token logits produce each request's
  first token, counted as *prefill* output (decode tok/s measures decode
  steps only).

The scheduler (``scheduler.py``) admits queued requests into free slots
and retires finished ones mid-flight — a finished short request frees its
slot for the next queued prompt while long requests keep decoding.
"""

from __future__ import annotations

import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import (decode_step, init_cache, init_params,
                          prefill_forward, supports_cached_prefill)
from .sampling import sample_tokens, sample_tokens_jit
from .scheduler import Request, Scheduler

__all__ = ["ServeEngine"]


def _slot_view(cache, slot):
    return jax.tree.map(
        lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1), cache)


def _slot_write(cache, view, slot):
    return jax.tree.map(
        lambda l, nl: jax.lax.dynamic_update_slice_in_dim(
            l, nl.astype(l.dtype), slot, axis=1), cache, view)


def _mask_rows(new, old, active):
    """Keep ``new`` only on active slot rows (row axis 1 of every cache
    leaf: (P, B, ...))."""
    def sel(n, o):
        m = active.reshape((1, -1) + (1,) * (n.ndim - 2))
        return jnp.where(m, n.astype(o.dtype), o)
    return jax.tree.map(sel, new, old)


class ServeEngine:
    """Drive requests through prefill + continuous-batching decode.

    Parameters: ``decode_impl`` "flash" (default) or "dense";
    ``attn_shards`` splits the decode cache into LSE-merged segments
    (emulating a CP-sharded cache in-process); ``interpret=None``
    auto-selects Pallas interpret mode off-TPU.
    """

    def __init__(self, cfg: ModelConfig, params=None, *,
                 num_slots: int = 4, max_len: int = 256,
                 prefill_chunk: int = 64, decode_impl: str = "flash",
                 attn_shards: int = 1, block_k: int = 256,
                 interpret: bool | None = None, seed: int = 0):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_chunk = min(prefill_chunk, max_len)
        self.decode_impl = decode_impl
        self.cached_prefill = supports_cached_prefill(cfg)
        if params is None:
            params = init_params(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self.cache = init_cache(cfg, num_slots, max_len)
        self.sched = Scheduler(num_slots, max_len)
        self.rng = jax.random.PRNGKey(seed)
        self._next_rid = 0
        self.stats: dict[str, Any] = {
            "prefill_tokens": 0, "prefill_steps": 0,
            "prefill_decode_steps": 0, "prefill_s": 0.0,
            "decode_tokens": 0, "decode_steps": 0, "decode_s": 0.0,
            "admitted": 0, "retired": 0}

        dec_kw = dict(attn_impl=decode_impl, attn_shards=attn_shards,
                      block_k=block_k, interpret=interpret)

        def _decode_batch(tok, frames):
            if cfg.frontend == "audio_frames":
                # modality gap of the stubbed EnCodec frontend: generated
                # steps have no codec->frame embedder, so continuation
                # frames are zeros; *prompt* frames flow through prefill.
                return {"frame_embeds": frames}
            return {"tokens": tok}

        def decode_fn(params, cache, tok, pos_t, active, rng, temps, topk):
            frames = jnp.zeros((num_slots, cfg.d_model), jnp.dtype(cfg.dtype))
            logits, new_cache = decode_step(
                params, cfg, cache, _decode_batch(tok, frames), pos_t,
                **dec_kw)
            new_cache = _mask_rows(new_cache, cache, active)
            nxt = sample_tokens(rng, logits.astype(jnp.float32), temps, topk)
            return nxt, logits, new_cache

        def prefill_chunk_fn(params, cache, slot, tokens, frames, pos,
                             active, *, with_logits, s_view):
            batch = {"tokens": tokens}
            if cfg.frontend == "audio_frames":
                batch = {"frame_embeds": frames}
            elif cfg.frontend == "vit_patches":
                T = tokens.shape[1]
                batch["patch_embeds"] = jnp.zeros(
                    (1, T, cfg.d_model), jnp.dtype(cfg.dtype))
                batch["patch_mask"] = jnp.zeros((1, T), bool)
            view = _slot_view(cache, slot)
            # crop the attended cache to the pow2 bucket covering this
            # chunk's end: prefill attention is O(C * s_view), not
            # O(C * max_len) (attn caches are (P, 1, Hkv, S, hd))
            crop = jax.tree.map(lambda l: l[:, :, :, :s_view], view)
            logits, ncrop = prefill_forward(params, cfg, crop, batch, pos,
                                            active, with_logits=with_logits)
            nview = jax.tree.map(
                lambda f, n: jax.lax.dynamic_update_slice_in_dim(
                    f, n.astype(f.dtype), 0, axis=3), view, ncrop)
            return logits, _slot_write(cache, nview, slot)

        def replay_fn(params, cache, tok, frames, pos_t, active):
            logits, new_cache = decode_step(
                params, cfg, cache, _decode_batch(tok, frames), pos_t,
                **dec_kw)
            return logits, _mask_rows(new_cache, cache, active)

        # the cache argument is donated everywhere: the engine always
        # replaces self.cache with the program's output, so XLA can
        # update the (num_slots x max_len) KV buffers in place instead
        # of keeping two full copies live
        self._decode_fn = jax.jit(decode_fn, donate_argnums=(1,))
        self._replay_fn = jax.jit(replay_fn, donate_argnums=(1,))
        self._prefill_fns: dict[tuple[bool, int], Any] = {}
        self._prefill_chunk_body = prefill_chunk_fn

    def _prefill_fn(self, with_logits: bool, s_view: int):
        """Jitted prefill-chunk program per (head?, cache-view bucket);
        only the final chunk pays the (T, vocab) head projection."""
        key = (with_logits, s_view)
        if key not in self._prefill_fns:
            self._prefill_fns[key] = jax.jit(
                functools.partial(self._prefill_chunk_body,
                                  with_logits=with_logits, s_view=s_view),
                donate_argnums=(1,))
        return self._prefill_fns[key]

    def _prefill_buckets(self, prompt_len: int):
        """(is_last, s_view) for each chunk of a ``prompt_len`` prompt."""
        C = self.prefill_chunk
        n_chunks = -(-prompt_len // C)
        out = []
        for ci in range(n_chunks):
            s_view = C
            while s_view < (ci + 1) * C:
                s_view *= 2
            out.append((ci == n_chunks - 1, min(s_view, self.max_len)))
        return out

    # ------------------------------------------------------------- #
    def submit(self, tokens, *, max_new: int = 16, temperature: float = 0.0,
               top_k: int = 0, eos_id: int = -1, frames=None) -> int:
        """Queue one request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        self.sched.submit(Request(
            rid=rid, tokens=np.asarray(tokens, np.int32), max_new=max_new,
            temperature=temperature, top_k=top_k, eos_id=eos_id,
            frames=None if frames is None
            else np.asarray(frames, np.float32)))
        return rid

    def _split(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    # ------------------------------------------------------------- #
    def _prefill(self, slot: int, req: Request) -> None:
        t0 = time.perf_counter()
        if self.cached_prefill:
            logits_last = self._prefill_cached(slot, req)
        else:
            logits_last = self._prefill_replay(slot, req)
        first = sample_tokens_jit(
            self._split(), logits_last[None].astype(jnp.float32),
            jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32))
        first = int(np.asarray(first)[0])
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += req.prompt_len
        self.stats["admitted"] += 1
        self.sched.start(slot, first)
        if self.sched.slots[slot] is None:
            self.stats["retired"] += 1

    def _prefill_cached(self, slot: int, req: Request):
        C = self.prefill_chunk
        Tp = req.prompt_len
        n_chunks = -(-Tp // C)
        toks = np.zeros((1, n_chunks * C), np.int32)
        toks[0, :Tp] = req.tokens
        frames = np.zeros((1, n_chunks * C, self.cfg.d_model), np.float32)
        if req.frames is not None:
            frames[0, :Tp] = req.frames
        slot_j = jnp.asarray(slot, jnp.int32)
        logits = None
        for ci, (is_last, s_view) in enumerate(self._prefill_buckets(Tp)):
            sl = slice(ci * C, (ci + 1) * C)
            pos = jnp.asarray(np.arange(ci * C, (ci + 1) * C,
                                        dtype=np.int32)[None])
            active = jnp.asarray((np.arange(ci * C, (ci + 1) * C) < Tp)[None])
            logits, self.cache = self._prefill_fn(is_last, s_view)(
                self.params, self.cache, slot_j, jnp.asarray(toks[:, sl]),
                jnp.asarray(frames[:, sl]), pos, active)
            self.stats["prefill_steps"] += 1
        return logits[0, (Tp - 1) - (n_chunks - 1) * C]

    def _prefill_replay(self, slot: int, req: Request):
        """Recurrent-mixer fallback: feed the prompt through the decode
        path one token at a time, updates masked to this slot's row.
        Audio prompts replay their *real* frame embeddings."""
        B = self.num_slots
        onehot = jnp.zeros((B,), bool).at[slot].set(True)
        logits = None
        for t in range(req.prompt_len):
            tok = jnp.zeros((B,), jnp.int32).at[slot].set(
                int(req.tokens[t]))
            frames = jnp.zeros((B, self.cfg.d_model), jnp.float32)
            if req.frames is not None:
                frames = frames.at[slot].set(jnp.asarray(req.frames[t]))
            pos_t = jnp.zeros((B,), jnp.int32).at[slot].set(t)
            logits, self.cache = self._replay_fn(
                self.params, self.cache, tok, frames, pos_t, onehot)
            self.stats["prefill_decode_steps"] += 1
        return logits[slot]

    # ------------------------------------------------------------- #
    def _decode_once(self) -> None:
        sc = self.sched
        active = jnp.asarray(sc.active_mask())
        lengths = jnp.asarray(sc.lengths())
        tok = np.zeros((self.num_slots,), np.int32)
        for s in sc.active_slots:
            tok[s] = sc.slots[s].generated[-1]
        t0 = time.perf_counter()
        nxt, _, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(tok), lengths, active,
            self._split(), jnp.asarray(sc.temperatures()),
            jnp.asarray(sc.top_ks()))
        nxt = np.asarray(jax.block_until_ready(nxt))
        self.stats["decode_s"] += time.perf_counter() - t0
        n_active = len(sc.active_slots)
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += n_active
        self.stats["retired"] += len(sc.record(nxt))

    def step(self) -> bool:
        """Admit + prefill newly placed requests, then one decode step.
        Returns False when no work remains."""
        for slot, req in self.sched.admit():
            self._prefill(slot, req)
        if self.sched.active_slots:
            self._decode_once()
        return self.sched.has_work

    def run(self, max_steps: int = 100_000) -> dict[int, dict[str, Any]]:
        """Drain the queue; returns {rid: {"tokens", "prompt_len"}}."""
        steps = 0
        while self.step():
            steps += 1
            if steps >= max_steps:
                break
        return self.sched.finished

    def warmup(self, prompt_len: int | None = None) -> None:
        """Compile the decode + prefill + sampling programs outside the
        timed window (all-inactive calls leave cache *values* untouched;
        outputs are reassigned because the cache argument is donated).
        ``prompt_len`` warms every prefill-chunk variant a prompt of
        that length uses (default: a single-chunk prompt)."""
        zi = jnp.zeros((self.num_slots,), jnp.int32)
        _, _, self.cache = self._decode_fn(
            self.params, self.cache, zi, zi,
            jnp.zeros((self.num_slots,), bool), self._split(),
            jnp.zeros((self.num_slots,), jnp.float32), zi)
        sample_tokens_jit(self._split(),
                          jnp.zeros((1, self.cfg.vocab_size), jnp.float32),
                          jnp.zeros((1,), jnp.float32),
                          jnp.zeros((1,), jnp.int32))
        C = self.prefill_chunk
        if self.cached_prefill:
            for is_last, s_view in set(
                    self._prefill_buckets(prompt_len or C)):
                _, self.cache = self._prefill_fn(is_last, s_view)(
                    self.params, self.cache, jnp.asarray(0, jnp.int32),
                    jnp.zeros((1, C), jnp.int32),
                    jnp.zeros((1, C, self.cfg.d_model), jnp.float32),
                    jnp.asarray(np.arange(C, dtype=np.int32)[None]),
                    jnp.zeros((1, C), bool))
        else:
            _, self.cache = self._replay_fn(
                self.params, self.cache, zi,
                jnp.zeros((self.num_slots, self.cfg.d_model), jnp.float32),
                zi, jnp.zeros((self.num_slots,), bool))

    def throughput(self) -> dict[str, float]:
        s = self.stats
        return {
            "prefill_tok_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
            "decode_tok_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
        }
