"""Prefix cache: a hash-chain trie over *full* prompt token blocks.

Identical prompt prefixes (system prompts, few-shot preambles) are
prefilled once: after a request's prefill completes, each full
``block_size`` block of its *prompt* is registered under the key
``(parent_block_id, block_tokens)`` — the parent id uniquely identifies
the whole prefix chain, so lookup is exact (no hash collisions to
reason about) and O(blocks).  A later request walks the chain from the
root and adopts every matched block into its own table (pool refcount
+1 per reader), skipping that prefix's prefill compute entirely.

Only full blocks are cached — a partially-filled tail is private to its
request (sharing it would force copy-on-write on every first decode
append).  Generated tokens are never cached.  When a request's prompt
is *entirely* made of matched full blocks, the engine still recomputes
the final prompt token (its logits seed sampling) — the write lands in
the last matched block, which is shared, so the engine copy-on-writes
it first (the ``cow_copies`` stat counts exactly these).

Cached blocks carry one reference from the cache itself, so they stay
pool-resident after their last reader retires.  ``evict`` walks blocks
in LRU order (touched on match) and frees *leaf* nodes with no readers
(refcount 1 — the cache's own) — parents are only evictable once their
children are gone, keeping every remaining chain matchable.
"""

from __future__ import annotations

__all__ = ["PrefixCache"]

_ROOT = -1


class PrefixCache:
    def __init__(self, block_size: int):
        self.block_size = block_size
        self._by_key: dict[tuple, int] = {}     # (parent_bid, tokens) -> bid
        self._key_of: dict[int, tuple] = {}     # bid -> its key
        self._children: dict[int, int] = {}     # bid -> live child count
        self._lru: list[int] = []               # bids, oldest first
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._by_key)

    # ------------------------------------------------------------- #
    def _touch(self, bid: int) -> None:
        try:
            self._lru.remove(bid)
        except ValueError:
            pass
        self._lru.append(bid)

    def match(self, tokens) -> list[int]:
        """Longest chain of cached full blocks prefixing ``tokens``.
        Returns their block ids (possibly empty); matched blocks are
        LRU-touched.  The caller must ``pool.retain`` them."""
        bs = self.block_size
        ids: list[int] = []
        parent = _ROOT
        for k in range(len(tokens) // bs):
            key = (parent, tuple(int(t) for t in tokens[k * bs:(k + 1) * bs]))
            bid = self._by_key.get(key)
            if bid is None:
                break
            ids.append(bid)
            self._touch(bid)
            parent = bid
        self.hits += len(ids) * bs
        self.misses += len(tokens) - len(ids) * bs
        return ids

    def insert(self, tokens, block_ids, pool) -> int:
        """Register the full blocks of ``tokens`` (backed by
        ``block_ids``, the owning request's table prefix).  Blocks whose
        chain key already exists are skipped (a concurrent identical
        prompt won the race; its copy stays canonical).  New blocks get
        a cache reference (``pool.retain``).  Returns #blocks added."""
        bs = self.block_size
        parent = _ROOT
        added = 0
        for k in range(len(tokens) // bs):
            key = (parent, tuple(int(t) for t in tokens[k * bs:(k + 1) * bs]))
            bid = self._by_key.get(key)
            if bid is None:
                bid = int(block_ids[k])
                self._by_key[key] = bid
                self._key_of[bid] = key
                self._children[bid] = 0
                if parent != _ROOT:
                    self._children[parent] += 1
                pool.retain([bid])
                self._lru.append(bid)
                added += 1
            parent = bid
        return added

    # ------------------------------------------------------------- #
    def evict(self, n_blocks: int, pool) -> int:
        """Free up to ``n_blocks`` pool blocks by dropping LRU leaf
        nodes nobody is reading (refcount 1 = only the cache's own
        reference).  Returns the number actually freed."""
        freed = 0
        progress = True
        while freed < n_blocks and progress:
            progress = False
            for bid in list(self._lru):
                if self._children.get(bid, 0) == 0 \
                        and pool.refcount(bid) == 1:
                    self._drop(bid, pool)
                    freed += 1
                    progress = True
                    if freed >= n_blocks:
                        break
        return freed

    def _drop(self, bid: int, pool) -> None:
        key = self._key_of.pop(bid)
        del self._by_key[key]
        del self._children[bid]
        self._lru.remove(bid)
        parent = key[0]
        if parent != _ROOT:
            self._children[parent] -= 1
        pool.release([bid])

    # ------------------------------------------------------------- #
    def hit_rate(self) -> float:
        seen = self.hits + self.misses
        return self.hits / seen if seen else 0.0

    def stats(self) -> dict:
        return {"nodes": len(self), "hit_tokens": self.hits,
                "miss_tokens": self.misses, "hit_rate": self.hit_rate()}
