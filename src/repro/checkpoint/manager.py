"""Fault-tolerant checkpointing: atomic, async, reshard-on-restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, mesh info
        arrays.npz             # host-local shards (process-addressable)
    <dir>/step_000123.tmp      # staging; atomically renamed on commit

Properties required at 1000+-node scale:
* **atomicity** — a crash mid-save never corrupts the latest checkpoint
  (tmp-dir staging + ``os.replace`` commit + LATEST pointer written last);
* **async** — saves run on a background thread off the training loop's
  critical path (`save(..., blocking=False)`);
* **elastic restore** — arrays are stored in global logical form; restoring
  onto a *different* mesh shape just re-applies the new sharding rules
  (reshard-on-load), which is what lets a job shrink/grow after failures;
* **retention** — keep the newest ``keep`` checkpoints.

In this single-process container each "host" holds the full array; the
layout and commit protocol are the multi-host ones.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict[str, Any]):
    tree: dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- #
    def save(self, step: int, state: dict[str, Any], *,
             extra: dict | None = None, blocking: bool = True) -> None:
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        self.wait()  # never two writers in flight
        if blocking:
            self._write(step, host_state, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra or {}),
                daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host_state, extra):
        name = f"step_{step:09d}"
        final = os.path.join(self.directory, name)
        tmp = f"{final}.{os.getpid()}.{threading.get_ident()}.tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        flat = _flatten(host_state)
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic commit
        with open(os.path.join(self.directory, "LATEST.tmp"), "w") as f:
            f.write(name)
        os.replace(os.path.join(self.directory, "LATEST.tmp"),
                   os.path.join(self.directory, "LATEST"))
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- #
    def all_steps(self) -> list[int]:
        out = []
        for n in os.listdir(self.directory):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        path = os.path.join(self.directory, "LATEST")
        if not os.path.exists(path):
            steps = self.all_steps()
            return steps[-1] if steps else None
        with open(path) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, step: int | None = None, *, shardings=None):
        """Load a checkpoint; optionally apply (possibly *different*) target
        shardings — elastic reshard-on-restore."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        tree = _unflatten(flat)
        if shardings is not None:
            flat_t, treedef = jax.tree.flatten(tree)
            flat_s = treedef.flatten_up_to(shardings)
            tree = treedef.unflatten([
                jax.device_put(a, s) if s is not None else a
                for a, s in zip(flat_t, flat_s)])
        return step, tree, manifest
