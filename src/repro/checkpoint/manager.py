"""Fault-tolerant checkpointing: atomic, async, reshard-on-restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, mesh info
        arrays.npz             # host-local shards (process-addressable)
    <dir>/step_000123.tmp      # staging; atomically renamed on commit

Properties required at 1000+-node scale:
* **atomicity** — a crash mid-save never corrupts the latest checkpoint
  (tmp-dir staging + ``os.replace`` commit + LATEST pointer written last);
* **async, never silent** — saves run on a background thread off the
  training loop's critical path (``save(..., blocking=False)``); an
  exception in the writer thread is captured and re-raised on the next
  ``wait()``/``save()`` (a failed save must never vanish — recovery
  depends on the latest checkpoint actually existing), and the failed
  attempt's staging dir is cleaned so the next save succeeds;
* **stale-staging GC** — ``*.tmp`` staging dirs left by crashed
  *processes* (their pid/tid-scoped names never match a new process's
  ``os.path.exists`` check) are swept at construction and after every
  commit;
* **elastic restore** — arrays are stored in global logical form; restoring
  onto a *different* mesh shape just re-applies the new sharding rules
  (reshard-on-load), which is what lets a job shrink/grow after failures;
* **retention** — keep the newest ``keep`` checkpoints.

In this single-process container each "host" holds the full array; the
layout and commit protocol are the multi-host ones.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict[str, Any]):
    tree: dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self._sweep_stale_tmp()

    # ------------------------------------------------------------- #
    def save(self, step: int, state: dict[str, Any], *,
             extra: dict | None = None, blocking: bool = True) -> None:
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        self.wait()  # never two writers in flight; raises a pending error
        if blocking:
            self._guarded_write(step, host_state, extra or {})
            self._raise_pending()
        else:
            self._thread = threading.Thread(
                target=self._guarded_write,
                args=(step, host_state, extra or {}), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join any in-flight writer.  Re-raises an exception the writer
        thread hit (async saves must never fail silently — recovery
        depends on the checkpoint actually existing)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"checkpoint save failed in {self.directory}") from err

    def _guarded_write(self, step, host_state, extra):
        """_write with the staging dir cleaned and the exception captured
        on failure (re-raised by the next ``wait()``/``save()``)."""
        tmp = self._tmp_path(step)
        try:
            self._write(step, host_state, extra, tmp)
        except BaseException as e:  # noqa: BLE001 — captured, not dropped
            shutil.rmtree(tmp, ignore_errors=True)
            self._error = e

    def _tmp_path(self, step: int) -> str:
        final = os.path.join(self.directory, f"step_{step:09d}")
        return f"{final}.{os.getpid()}.{threading.get_ident()}.tmp"

    def _write(self, step, host_state, extra, tmp: str):
        name = f"step_{step:09d}"
        final = os.path.join(self.directory, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        flat = _flatten(host_state)
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic commit
        with open(os.path.join(self.directory, "LATEST.tmp"), "w") as f:
            f.write(name)
        os.replace(os.path.join(self.directory, "LATEST.tmp"),
                   os.path.join(self.directory, "LATEST"))
        self._gc(protect=step)

    def _gc(self, protect: int | None = None):
        """Retention by step number, but never the step just committed:
        a directory reused across runs can hold stale *higher*-numbered
        steps, and GC-by-number would otherwise delete the new run's
        checkpoint out from under its own LATEST pointer."""
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            if s == protect:
                continue
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
        self._sweep_stale_tmp()

    def _sweep_stale_tmp(self):
        """Remove ``*.tmp`` staging dirs left behind by dead processes.

        Staging names are pid/tid-scoped (``step_X.<pid>.<tid>.tmp``), so
        a crashed process's leftovers are never matched by a new writer's
        ``os.path.exists(tmp)`` check and would leak forever.  A tmp dir
        is stale when its embedded pid is not a live process; this
        process's own dirs are left alone (a writer may be in flight —
        failed same-process writes clean up after themselves)."""
        for n in os.listdir(self.directory):
            if not (n.startswith("step_") and n.endswith(".tmp")):
                continue
            parts = n[:-len(".tmp")].split(".")
            pid = None
            if len(parts) >= 3:
                try:
                    pid = int(parts[-2])
                except ValueError:
                    pid = None
            if pid == os.getpid():
                continue
            alive = False
            if pid is not None:
                try:
                    os.kill(pid, 0)
                    alive = True            # pid is a live process: keep
                except ProcessLookupError:
                    alive = False           # dead: stale, sweep
                except PermissionError:
                    alive = True            # live but foreign: keep
                except OSError:
                    alive = False
            if not alive:
                shutil.rmtree(os.path.join(self.directory, n),
                              ignore_errors=True)

    # ------------------------------------------------------------- #
    def all_steps(self) -> list[int]:
        out = []
        for n in os.listdir(self.directory):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        """Step named by the LATEST pointer, validated: a pointer left
        dangling (its step dir gone or incomplete) falls back to the
        newest step that actually has a manifest on disk."""
        path = os.path.join(self.directory, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                step = int(f.read().strip().split("_")[1])
            if self._complete(step):
                return step
        for step in reversed(self.all_steps()):
            if self._complete(step):
                return step
        return None

    def _complete(self, step: int) -> bool:
        return os.path.exists(os.path.join(
            self.directory, f"step_{step:09d}", "manifest.json"))

    def restore(self, step: int | None = None, *, shardings=None):
        """Load a checkpoint; optionally apply (possibly *different*) target
        shardings — elastic reshard-on-restore."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        tree = _unflatten(flat)
        if shardings is not None:
            flat_t, treedef = jax.tree.flatten(tree)
            flat_s = treedef.flatten_up_to(shardings)
            tree = treedef.unflatten([
                jax.device_put(a, s) if s is not None else a
                for a, s in zip(flat_t, flat_s)])
        return step, tree, manifest
