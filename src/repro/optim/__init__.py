from .adamw import adamw_init, adamw_update
from .clipping import clip_by_global_norm, global_norm
from .grad_compress import compress_tree, ef_init, wire_bytes
from .schedule import constant, warmup_cosine

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "global_norm", "compress_tree", "ef_init", "wire_bytes",
           "constant", "warmup_cosine"]
