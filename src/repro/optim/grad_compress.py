"""Gradient compression for the data-parallel all-reduce.

Two schemes with error feedback (EF), both validated by convergence tests:

* ``topk``  — keep the k largest-magnitude entries per tensor (sparsify),
  accumulate the residual locally and add it back next step (EF-SGD).
* ``int8``  — per-tensor symmetric int8 quantization with EF.

At 1000+-node scale the DP all-reduce of a 100B-param model is tens of GB
per step; compression trades a controlled bias (bounded by EF) for 4-30x
less traffic on the slowest links (paper-orthogonal, framework-level
distributed-optimization feature).

Usage: ``compressed, new_ef = compress_tree(grads, ef, scheme)`` *before*
the (pjit-implicit) all-reduce; decompression is the identity for these
schemes because values stay in the original dtype lanes — the traffic
saving comes from the sparse/int8 wire format, which we model in the cost
accounting (`wire_bytes`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_tree", "ef_init", "wire_bytes"]


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_one(g, ef, frac):
    gf = g.astype(jnp.float32) + ef
    flat = gf.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(gf) >= thresh).astype(jnp.float32)
    sent = gf * mask
    return sent.astype(g.dtype), gf - sent


def _int8_one(g, ef):
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    sent = q * scale
    return sent.astype(g.dtype), gf - sent


def compress_tree(grads, ef, scheme: str, *, topk_frac: float = 0.05):
    """Returns (compressed grads, new error-feedback state)."""
    if scheme == "none":
        return grads, ef
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = treedef.flatten_up_to(ef)
    out, new_ef = [], []
    for g, e in zip(leaves, ef_leaves):
        if scheme == "topk":
            s, r = _topk_one(g, e, topk_frac)
        elif scheme == "int8":
            s, r = _int8_one(g, e)
        else:
            raise ValueError(scheme)
        out.append(s)
        new_ef.append(r)
    return treedef.unflatten(out), treedef.unflatten(new_ef)


def wire_bytes(params, scheme: str, *, topk_frac: float = 0.05) -> int:
    """Bytes on the wire per DP all-reduce under each scheme."""
    n = sum(p.size for p in jax.tree.leaves(params))
    if scheme == "topk":
        return int(n * topk_frac) * 8          # (index, value) pairs
    if scheme == "int8":
        return n * 1 + 4
    return n * 4
