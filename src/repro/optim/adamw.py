"""AdamW with fp32 optimizer state mirroring the param pytree.

Minimal, dependency-free (no optax offline).  The state shards exactly like
the parameters (runtime/sharding.py applies the same PartitionSpecs), so
ZeRO-style sharded optimizer state falls out of the FSDP param sharding.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update"]


@dataclasses.dataclass
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params, *, keep_master: bool | None = None):
    """Optimizer state.  When the params are stored in a reduced dtype
    (bf16 model weights — halves the FSDP all-gather volume, §Perf #1),
    the state carries the fp32 master copy; for fp32 params the params
    tree itself is the master."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    if keep_master is None:
        keep_master = any(p.dtype != jnp.float32
                          for p in jax.tree.leaves(params))
    state = {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if keep_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    """Returns (new_params, new_state).  ``lr`` may be a traced scalar
    (schedule value)."""
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c
    has_master = "master" in state

    def upd(p, g, mu, nu, master):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        m = master if master is not None else p.astype(jnp.float32)
        step = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * m
        new_m = m - lr * step
        return new_m.astype(p.dtype), mu, nu, new_m

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_ms = treedef.flatten_up_to(state["master"]) if has_master \
        else [None] * len(flat_p)
    out = [upd(p, g, m, n, ms) for p, g, m, n, ms in
           zip(flat_p, flat_g, flat_mu, flat_nu, flat_ms)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out]),
                 "count": count}
    if has_master:
        new_state["master"] = treedef.unflatten([o[3] for o in out])
    return new_p, new_state
